"""Serving demo: continuous batching with a multi-adapter bank.

Three tenants share one deployed base model: two fine-tuned GSOFT adapters
("alice", "bob") plus the raw base model. Requests stream in Poisson-style,
are admitted into decode slots as others finish, and every slot rotates its
activations with ITS OWN adapter (x Q_adapter, O(b*d)/token) — no offline
merge, no per-request weight copies. Compare with the merged static path:

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen2-72b] [--static]
"""
import argparse
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.launch.serve import make_demo_adapters
from repro.serve.engine import ServeEngine, StaticServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--static", action="store_true",
                    help="merged single-adapter static engine (paper §6.1)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))

    # pretend we fine-tuned twice: two random GSOFT adapters
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = make_demo_adapters(["alice", "bob"], rt.params, pcfg)

    rng = np.random.default_rng(0)
    if args.static:
        # one adapter merged offline — every request gets "alice"
        merged = ModelRuntime(cfg, rt.params, adapters=adapters["alice"],
                              peft_cfg=pcfg)
        eng = StaticServeEngine(merged, max_batch=4, max_len=64)
        for _ in range(args.requests):
            eng.add_request(
                rng.integers(1, 200, size=rng.integers(4, 12)).tolist(),
                max_new_tokens=int(rng.integers(2, 9)))
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0
    else:
        eng = ServeEngine(rt.with_bank(adapters, pcfg), max_batch=4,
                          max_len=64)
        tenants = ["alice", "bob", None]          # None = base model slot 0
        for i in range(args.requests):
            eng.add_request(
                rng.integers(1, 200, size=rng.integers(4, 12)).tolist(),
                max_new_tokens=int(rng.integers(2, 9)),
                adapter=tenants[i % len(tenants)])
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0

    toks = eng.stats["tokens_generated"]
    print(f"{len(results)} requests, {toks} tokens in {dt:.2f}s  "
          f"({toks / dt:.1f} tok/s, {eng.stats['decode_steps']} decode "
          f"steps, {eng.stats['prefills']} prefills)")
    for req in eng.finished[:6]:
        who = req.adapter if getattr(req, "adapter", None) else "base"
        print(f"  req {req.rid} [{who:6s}]: {req.output}")


if __name__ == "__main__":
    main()
