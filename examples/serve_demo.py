"""Serving demo: GSOFT-adapted model, adapters MERGED offline (paper §6.1 —
zero inference overhead), batched prefill + decode through the engine.

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen2-72b]
"""
import argparse
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.models import api
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=12)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = api.init_params(cfg, jax.random.PRNGKey(0))

    # pretend we fine-tuned: random GSOFT adapters, merged before serving
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = peft_lib.init_peft(pcfg, params, jax.random.PRNGKey(1))
    adapters = jax.tree.map(  # (a constant shift would cancel in K = A - A^T)
        lambda a: a + 0.1 * jax.random.normal(jax.random.PRNGKey(2), a.shape),
        adapters)

    eng = ServeEngine(cfg, params, max_batch=4, max_len=64,
                      adapters=adapters, peft_cfg=pcfg)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.add_request(rng.integers(1, 200, size=rng.integers(4, 12)).tolist(),
                        max_new_tokens=8)
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    print(f"{len(results)} requests, {eng.stats['tokens_generated']} tokens "
          f"in {dt:.2f}s  ({eng.stats['tokens_generated']/dt:.1f} tok/s)")
    for rid in sorted(results)[:3]:
        print(f"  req {rid}: {results[rid]}")


if __name__ == "__main__":
    main()
