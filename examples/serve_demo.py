"""Serving demo: continuous batching with a MIXED-METHOD multi-adapter bank.

Four tenants share one deployed base model: three fine-tuned adapters with
three DIFFERENT orthogonal parametrizations — "alice" (GSOFT, the paper's
GS rotation), "bob" (BOFT butterfly), "carol" (Householder product / HOFT)
— plus the raw base model. Every parametrization is a ``core.methods``
registry entry, so the engine neither knows nor cares which method a slot
uses: requests stream in, are admitted into decode slots as others finish,
and every slot rotates its activations with ITS OWN adapter (x Q_adapter,
activation-side) — no offline merge, no per-request weight copies.

    PYTHONPATH=src python examples/serve_demo.py [--arch qwen2-72b]
        [--static]           # merged single-adapter reference (paper §6.1)
        [--quantize int8]    # int8 base weights, bf16 rotations (QOFT)
"""
import argparse
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.launch.serve import make_demo_adapters
from repro.serve.engine import ServeEngine, StaticServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-72b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--static", action="store_true",
                    help="merged single-adapter static engine (paper §6.1)")
    ap.add_argument("--quantize", choices=("none", "int8"), default="none",
                    help="serve the bank over int8 base weights "
                         "(rotations stay bf16)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))

    # pretend we fine-tuned three times, each with a different method
    cfgs = {
        "alice": peft_lib.PEFTConfig(method="gsoft", block_size=8),
        "bob": peft_lib.PEFTConfig(method="boft", block_size=8),
        "carol": peft_lib.PEFTConfig(method="householder", reflections=4),
    }
    adapters = make_demo_adapters(list(cfgs), rt.params, cfgs)

    rng = np.random.default_rng(0)
    if args.static:
        # one adapter merged offline — every request gets "alice"
        merged = ModelRuntime(cfg, rt.params, adapters=adapters["alice"],
                              peft_cfg=cfgs["alice"])
        if args.quantize != "none":
            merged = merged.quantized(args.quantize)
        eng = StaticServeEngine(merged, max_batch=4, max_len=64)
        for _ in range(args.requests):
            eng.add_request(
                rng.integers(1, 200, size=rng.integers(4, 12)).tolist(),
                max_new_tokens=int(rng.integers(2, 9)))
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0
    else:
        banked = rt.attach(adapters, cfgs)
        if args.quantize != "none":
            banked = banked.quantized(args.quantize)
        print(f"bank methods: {list(banked.bank.bank_methods)}"
              + (f", base weights {args.quantize}"
                 if args.quantize != "none" else ""))
        eng = ServeEngine(banked, max_batch=4, max_len=64)
        tenants = ["alice", "bob", "carol", None]   # None = base, slot 0
        for i in range(args.requests):
            eng.add_request(
                rng.integers(1, 200, size=rng.integers(4, 12)).tolist(),
                max_new_tokens=int(rng.integers(2, 9)),
                adapter=tenants[i % len(tenants)])
        t0 = time.perf_counter()
        results = eng.run()
        dt = time.perf_counter() - t0

    toks = eng.stats["tokens_generated"]
    print(f"{len(results)} requests, {toks} tokens in {dt:.2f}s  "
          f"({toks / dt:.1f} tok/s, {eng.stats['decode_steps']} decode "
          f"steps, {eng.stats['prefills']} prefills)")
    for req in eng.finished[:8]:
        name = req.adapter if getattr(req, "adapter", None) else "base"
        method = ("merged gsoft" if args.static else
                  (cfgs[name].method if name in cfgs else "identity"))
        print(f"  req {req.rid} [{name:6s}/{method:12s}]: {req.output}")


if __name__ == "__main__":
    main()
