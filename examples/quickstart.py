"""Quickstart: the Group-and-Shuffle core API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (gsoft_layout, init_blocks, gs_apply, gs_materialize,
                        orthogonal_blocks, orthogonality_error,
                        min_factors_dense, project_to_gs,
                        AdapterSpec, init_adapter, materialize, merge)

# --- 1. an orthogonal GS matrix:  Q = P^T L P R  ---------------------------
d, b = 64, 8                       # r = d/b = 8 blocks; dense since r <= b
layout = gsoft_layout(d, b)
key = jax.random.PRNGKey(0)
L = orthogonal_blocks(jax.random.normal(key, layout.lspec.param_shape) * 0.3)
R = orthogonal_blocks(jax.random.normal(key, layout.rspec.param_shape) * 0.3)

Q = gs_materialize(layout, L, R)
print(f"Q is {Q.shape}, orthogonality error "
      f"{np.abs(Q.T @ Q - np.eye(d)).max():.2e}, "
      f"dense fraction {(np.abs(Q) > 1e-9).mean():.2f}")
print(f"factors needed for dense (Thm 2): GS={min_factors_dense(b, d//b)} "
      f"vs butterfly={1 + int(np.ceil(np.log2(d//b)))}")

# fast structured apply (never materializes Q):
x = jax.random.normal(key, (4, d))
y = gs_apply(layout, L, R, x)
assert np.allclose(np.asarray(y), np.asarray(x) @ Q.T, atol=1e-4)
print("structured apply == dense apply  (2*d*b flops vs d^2)")

# --- 2. GSOFT: orthogonal fine-tuning of a frozen weight -------------------
W = jax.random.normal(key, (d, 32))
spec = AdapterSpec(method="gsoft", d_in=d, d_out=32, block_size=b)
adapter = init_adapter(spec, key)                    # K = 0 -> Q = I
W_eff = materialize(spec, adapter, W)
assert np.allclose(np.asarray(W_eff), np.asarray(W), atol=1e-6)
print("identity init: W_eff == W (fine-tuning starts at the pretrained model)")

# train-ish update, then merge for inference (zero overhead).
# (NB: adding a CONSTANT would be a no-op — K = A - A^T cancels it.)
adapter = jax.tree.map(
    lambda p: p + 0.1 * jax.random.normal(key, p.shape), adapter)
W_eff = materialize(spec, adapter, W)
s0 = np.linalg.svd(np.asarray(W), compute_uv=False)
s1 = np.linalg.svd(np.asarray(W_eff), compute_uv=False)
print(f"after rotation: singular values preserved to {np.abs(s0-s1).max():.2e}"
      " (the hyperspherical-energy property)")
W_merged = merge(spec, adapter, W)
assert np.allclose(np.asarray(W_merged), np.asarray(W_eff))
print("merged weights == adapted weights: no inference overhead")

# --- 3. projection of an arbitrary matrix onto the GS class (Alg. 1) -------
A = np.random.default_rng(0).normal(size=(d, d))
Lp, Rp = project_to_gs(A, layout)
err = np.linalg.norm(A - gs_materialize(layout, Lp, Rp)) / np.linalg.norm(A)
print(f"projection residual of a random matrix: {err:.3f} "
      "(structure captures part of any operator)")
