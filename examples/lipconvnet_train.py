"""Certified-robust training with GS orthogonal convolutions (paper §7.3).

Trains LipConvnet-10 with GS-SOC layers on synthetic CIFAR-shaped data and
reports clean + certified accuracy (margin / sqrt(2) certificate).

    PYTHONPATH=src python examples/lipconvnet_train.py [--steps 30]
"""
import argparse

import jax
import jax.numpy as jnp

from repro import optim
from repro.models.lipconvnet import (LipConvnetConfig, init_lipconvnet,
                                     lipconvnet_loss)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--conv", default="gs", choices=["gs", "soc"])
    args = ap.parse_args()

    cfg = LipConvnetConfig(depth=10, base_width=8, num_classes=10,
                           image_size=32, groups=(4, 0), terms=4,
                           conv_layer=args.conv)
    key = jax.random.PRNGKey(0)
    params = init_lipconvnet(cfg, key)

    x = jax.random.normal(jax.random.PRNGKey(1), (128, 32, 32, 3)) * 0.5
    w = jax.random.normal(jax.random.PRNGKey(2), (3, 10))
    labels = jnp.argmax(x[:, :8, :8].mean(axis=(1, 2)) @ w, axis=-1)

    ocfg = optim.OptimizerConfig(learning_rate=3e-3, weight_decay=0.0)
    opt = optim.init(ocfg, params)

    @jax.jit
    def step(p, o):
        (l, m), g = jax.value_and_grad(
            lambda q: lipconvnet_loss(cfg, q, x, labels), has_aux=True)(p)
        p, o, _ = optim.update(ocfg, g, o, p)
        return p, o, l, m

    for s in range(args.steps):
        params, opt, loss, metrics = step(params, opt)
        if s % 5 == 0 or s == args.steps - 1:
            print(f"step {s:3d} loss {float(loss):.4f} "
                  f"acc {float(metrics['accuracy']):.3f} "
                  f"cert@36/255 {float(metrics['certified']):.3f}")


if __name__ == "__main__":
    main()
