"""End-to-end driver: GSOFT fine-tune of a language model with the full
framework path — config, data pipeline, PEFT engine, AdamW, checkpointing,
heartbeat, resume.

Default is CPU-sized (~10M params, 300 steps, a couple of minutes); pass
--hundred-m for the ~100M-parameter variant of the same architecture
(identical code path — only the config scales).

    PYTHONPATH=src python examples/finetune_lm.py [--hundred-m] [--steps N]
"""
import argparse
import tempfile

from repro import optim
from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.data import DataConfig
from repro.optim import schedules
from repro.train.loop import LoopConfig, train
from repro.train.steps import TrainStepConfig


def model_config(hundred_m: bool) -> ModelConfig:
    if hundred_m:
        return ModelConfig(
            name="gs-lm-100m", family="decoder", num_layers=12, d_model=768,
            num_heads=12, num_kv_heads=12, head_dim=64, d_ff=3072,
            vocab_size=32768, mlp_type="swiglu", dtype="f32",
            param_dtype="f32", remat="none", attn_chunk=256)
    return ModelConfig(
        name="gs-lm-10m", family="decoder", num_layers=4, d_model=256,
        num_heads=8, num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=512,
        mlp_type="swiglu", dtype="f32", param_dtype="f32", remat="none",
        attn_chunk=128)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hundred-m", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--peft", default="gsoft")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_config(args.hundred_m)
    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="gsoft_ckpt_")
    print(f"model {cfg.name}; checkpoints -> {ckpt}")

    tcfg = TrainStepConfig(
        peft=peft_lib.PEFTConfig(method=args.peft, block_size=16),
        opt=optim.OptimizerConfig(learning_rate=3e-3),
        num_microbatches=2,
        schedule=schedules.warmup_cosine(20, args.steps),
    )
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=min(cfg.vocab_size, 256))
    loop = LoopConfig(steps=args.steps, log_every=20, ckpt_every=100,
                      ckpt_dir=ckpt, heartbeat_path=f"{ckpt}/heartbeat")
    out = train(cfg, tcfg, dcfg, loop)
    h = out["history"]
    print(f"\nloss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} over "
          f"{args.steps} steps; adapters are "
          f"{peft_lib.count_params(out['trainable'])} params vs "
          f"{peft_lib.count_params(out['frozen'])} frozen")
    assert h[-1]["loss"] < h[0]["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
