"""Serving engine benchmark: static vs continuous batching on a
mixed-length workload (ragged prompts, ragged output budgets — the traffic
shape continuous batching exists for).

Reports throughput (tok/s), p50/p95 per-request latency, and scheduler
utilization = generated tokens / (decode_steps * max_batch), the
deterministic measure of how much decode work the scheduler wastes on
finished-or-empty rows (lockstep static batching burns steps on the
max(max_new) barrier; slot-based continuous batching refills them).

The ``cluster`` lane (ISSUE 8) is the 1->N replica scaling curve: Poisson
mixed-length multi-tenant traffic whose adapter working set thrashes ONE
replica's HBM budget but partitions cleanly across two under the
``EngineCluster`` affinity router. Tokens are asserted identical across
replica counts, and the 2-replica speedup / affinity hit rate ride the
summary so the scale-out trajectory is tracked PR-over-PR.

``REPRO_BENCH_TINY=1`` shrinks the workload for the CI smoke lane and
writes a ``BENCH_serve.json`` summary at the repo root (uploaded as a CI
artifact so the serving-perf trajectory is tracked PR-over-PR).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core.runtime import ModelRuntime
from repro.serve.engine import ServeEngine, StaticServeEngine

from .common import emit, mixed_workload, run_engine_timed, write_summary

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))


def run():
    cfg = get_smoke_config("qwen2-72b")
    n_req = 16 if TINY else 48
    max_batch = 4
    prompt_hi = 12 if TINY else 32
    # wide output-budget spread: the lockstep max(max_new) barrier is what
    # static batching pays for and slot refill is what continuous wins on
    max_new_hi = 32 if TINY else 48
    max_len = prompt_hi + max_new_hi + 8
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    workload = mixed_workload(n_req, prompt_hi, max_new_hi, seed=0)
    # warmup = the same workload, so every shape both schedulers will see
    # (static: per-batch pad shapes; continuous: prefill buckets) is
    # compiled before the timed pass — the comparison measures scheduling,
    # not retracing
    warmup = workload

    res = {}
    for name, make in (
        ("static", lambda: StaticServeEngine(
            rt, max_batch=max_batch, max_len=max_len, eos_id=-1)),
        ("continuous", lambda: ServeEngine(
            rt, max_batch=max_batch, max_len=max_len, eos_id=-1)),
    ):
        r = res[name] = run_engine_timed(make, warmup, workload)
        emit(f"serve/{name}_mixed",
             1e6 * r["dt"] / max(r["tokens"], 1),
             f"tok/s={r['tok_s']:.1f};util={r['util']:.2f};"
             f"p50_ms={r['p50_ms']:.0f};p95_ms={r['p95_ms']:.0f};"
             f"decode_steps={r['decode_steps']}")

    speedup = res["continuous"]["tok_s"] / max(res["static"]["tok_s"], 1e-9)
    emit("serve/continuous_speedup", 0.0,
         f"x{speedup:.2f};util {res['static']['util']:.2f}->"
         f"{res['continuous']['util']:.2f}")

    # ---- store-paged lane: same traffic shape, per-request adapters paged
    # under an HBM budget smaller than the tenant count (residency counters
    # ride the CSV so eviction/hit-rate/page-in trends are tracked here too)
    from repro.store import AdapterStore
    from repro.core import peft as peft_lib
    from repro.launch.serve import make_demo_adapters
    n_ad = 6 if TINY else 12
    meths = ("gsoft", "boft", "householder")
    bank_peft = {f"a{i}": peft_lib.PEFTConfig(method=meths[i % 3],
                                              block_size=8)
                 for i in range(n_ad)}
    adapters = make_demo_adapters(list(bank_peft), rt.params, bank_peft)
    store = AdapterStore.from_adapters(adapters, bank_peft)
    rt_store = rt.attach(store, hbm_budget=max(n_ad // 2, 3))
    wl_store = mixed_workload(n_req, prompt_hi, max_new_hi, seed=0,
                              adapters=list(bank_peft))
    r = res["store_paged"] = run_engine_timed(
        lambda: ServeEngine(rt_store, max_batch=max_batch, max_len=max_len,
                            eos_id=-1), wl_store, wl_store)
    st = rt_store.bank.stats()
    emit("serve/store_paged_mixed",
         1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f};hit_rate={st['hit_rate']:.2f};"
         f"evictions={st['evictions']};"
         f"page_in_p95_ms={st['page_in_ms_p95']:.1f};"
         f"max_resident={st['max_resident']}/{st['capacity']};"
         f"compaction={st['compaction_ratio']:.2f}x")

    # ---- store-resident lane: same adapter traffic with a budget that fits
    # every tenant. Once resident, the paged bank must serve within ~10% of
    # an eagerly-attached bank — i.e. the decode hot loop does no per-step
    # host work (adapter contexts are cached on the bank version).
    rt_eager = rt.attach(adapters, bank_peft)
    r = res["store_eager"] = run_engine_timed(
        lambda: ServeEngine(rt_eager, max_batch=max_batch, max_len=max_len,
                            eos_id=-1), wl_store, wl_store)
    emit("serve/store_eager_mixed", 1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f}")
    rt_res = rt.attach(store, hbm_budget=n_ad)
    r = res["store_resident"] = run_engine_timed(
        lambda: ServeEngine(rt_res, max_batch=max_batch, max_len=max_len,
                            eos_id=-1), wl_store, wl_store)
    resident_ratio = r["tok_s"] / max(res["store_eager"]["tok_s"], 1e-9)
    st = rt_res.bank.stats()
    emit("serve/store_resident_mixed", 1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f};vs_eager=x{resident_ratio:.2f};"
         f"evictions={st['evictions']};hit_rate={st['hit_rate']:.2f}")

    cluster = _lane_cluster(rt)
    tracing = _lane_tracing(rt, workload, max_batch, max_len)

    if TINY:
        summary = {"backend": jax.default_backend(), "arch": cfg.name,
                   "continuous_speedup": speedup,
                   "store_resident_vs_eager": resident_ratio}
        for name, r in res.items():
            for key, val in r.items():
                summary[f"{name}_{key}"] = val
        summary.update(cluster)
        summary.update(tracing)
        write_summary("serve", summary)


def _lane_tracing(rt, workload, max_batch, max_len):
    """Tracing-overhead bound (ISSUE 10): a ``TraceRecorder`` + ``SLOMonitor``
    attached to the continuous engine must cost at most 5% throughput —
    the hooks are host-side list appends off the jitted dispatch path.
    Off/on runs are INTERLEAVED in pairs and each side keeps its best,
    so machine drift across the sweep hits both sides alike and one
    scheduler hiccup doesn't fail the bound (a couple of extra pairs run
    before declaring a miss); every finished request must carry a
    complete span set (submit/prefill/first-token/finish) with TTFT/TPOT
    percentiles in the SLO report."""
    from repro.obs import SLOMonitor, TraceRecorder

    make_plain = lambda: ServeEngine(rt, max_batch=max_batch,  # noqa: E731
                                     max_len=max_len, eos_id=-1)
    tracers = []

    def make_traced():
        tr = TraceRecorder(slo=SLOMonitor(window=512))
        tracers.append(tr)
        return ServeEngine(rt, max_batch=max_batch, max_len=max_len,
                           eos_id=-1, tracer=tr)

    off = on = None
    for pair in range(5):
        r_off = run_engine_timed(make_plain, workload, workload)
        r_on = run_engine_timed(make_traced, workload, workload)
        if off is None or r_off["tok_s"] > off["tok_s"]:
            off = r_off
        if on is None or r_on["tok_s"] > on["tok_s"]:
            on = r_on
        if pair >= 2 and on["tok_s"] >= 0.97 * off["tok_s"]:
            break                        # bound met with margin; stop early
    for tr in tracers:                  # warmup + timed pass both traced
        done = tr.finished
        assert len(done) == 2 * len(workload), \
            f"expected {2 * len(workload)} finished traces, got {len(done)}"
        incomplete = [t.rid for t in done if not t.complete]
        assert not incomplete, f"incomplete spans for rids {incomplete}"
    rep = tracers[-1].slo.report()
    assert rep["ttft_ms"]["p95"] > 0 and rep["tpot_ms"]["p50"] > 0, \
        f"SLO report missing latency percentiles: {rep}"
    ratio = on["tok_s"] / max(off["tok_s"], 1e-9)
    emit("serve/tracing_overhead", 0.0,
         f"on_vs_off=x{ratio:.3f};ttft_p95_ms={rep['ttft_ms']['p95']:.1f};"
         f"tpot_p50_ms={rep['tpot_ms']['p50']:.2f}")
    assert ratio >= 0.95, \
        f"tracing overhead: x{ratio:.3f} of untraced throughput (< x0.95)"
    return {"tracing_overhead_ratio": ratio,
            "tracing_ttft_p95_ms": rep["ttft_ms"]["p95"],
            "tracing_tpot_p50_ms": rep["tpot_ms"]["p50"]}


def _poisson_arrivals(n: int, rate: float, seed: int) -> np.ndarray:
    """Cumulative Poisson arrival times in DECODE-TICK units (exponential
    gaps at ``rate`` requests/tick) — deterministic, no wall-clock sleeps,
    so the timed pass measures serving, not the arrival process."""
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _drive_poisson(eng, workload, arrivals):
    """Feed ``workload`` as it arrives on the tick clock, stepping the
    engine/cluster between waves; returns (wall_s, {rid: tokens})."""
    nxt, tick, busy = 0, 0, True
    out = {}
    t0 = time.perf_counter()
    while nxt < len(workload) or busy:
        while nxt < len(workload) and arrivals[nxt] <= tick:
            out[eng.add_request(**workload[nxt])] = None
            nxt += 1
        busy = eng.step()
        tick += 1
    dt = time.perf_counter() - t0
    for r in eng.drain_finished():
        out[r.rid] = r.output
    return dt, out


def _lane_cluster(rt):
    """1 -> N replica scaling (ISSUE 8): the tenant working set (``n_ad``
    adapters) is twice ONE replica's paged-bank budget, so a single engine
    pages factors on nearly every admission while the 2-replica cluster's
    affinity router partitions tenants into two working sets that each
    fit — page-ins happen once per tenant per home. Same per-replica
    resources on both sides; greedy tokens must agree exactly."""
    from repro.core import peft as peft_lib
    from repro.distrib import EngineCluster
    from repro.launch.serve import make_demo_adapters
    from repro.store import AdapterStore

    n_ad, max_batch = 8, 4
    n_req = 32 if TINY else 64
    budget = n_ad // 2                     # one replica holds half the tenants
    # method split uncorrelated with tenant index parity: the affinity
    # router alternates first sightings across replicas, so an i%2 method
    # assignment would hand each replica ONE method's tenants and starve
    # the per-method capacity split
    bank_peft = {f"t{i}": peft_lib.PEFTConfig(
        method="gsoft" if i < n_ad // 2 else "boft", block_size=8)
        for i in range(n_ad)}
    adapters = make_demo_adapters(list(bank_peft), rt.params, bank_peft)
    store = AdapterStore.from_adapters(adapters, bank_peft)
    wl = mixed_workload(n_req, 12, 16, seed=3, adapters=list(bank_peft))
    arrivals = _poisson_arrivals(n_req, rate=2.0, seed=3)

    rows, outputs = [], {}
    for n in (1, 2):
        cl = EngineCluster([ServeEngine(rt.attach(store, hbm_budget=budget),
                                        max_batch=max_batch, max_len=40,
                                        eos_id=-1) for _ in range(n)])
        _drive_poisson(cl, wl, arrivals)   # warmup: compile + page + homes
        toks0 = cl.stats["tokens_generated"]
        dt, out = _drive_poisson(cl, wl, arrivals)
        toks = cl.stats["tokens_generated"] - toks0
        tok_s = toks / max(dt, 1e-9)
        ahr = cl.affinity_hit_rate()
        outputs[n] = [out[k] for k in sorted(out)]
        rows.append({"replicas": n, "tok_s": tok_s, "tokens": toks,
                     "affinity_hit_rate": ahr})
        emit(f"serve/cluster_{n}replica", 1e6 * dt / max(toks, 1),
             f"tok/s={tok_s:.1f};affinity_hit_rate={ahr:.2f};"
             f"rebalanced={cl.routing['rebalanced']}")
    assert outputs[1] == outputs[2], \
        "cluster tokens diverged from single-replica tokens"
    speedup = rows[1]["tok_s"] / max(rows[0]["tok_s"], 1e-9)
    ahr = rows[1]["affinity_hit_rate"]
    assert speedup >= 1.5, f"2-replica speedup x{speedup:.2f} < x1.5"
    assert ahr >= 0.9, f"affinity hit rate {ahr:.2f} < 0.9"
    emit("serve/cluster_scaling_speedup", 0.0,
         f"x{speedup:.2f};affinity_hit_rate={ahr:.2f};tokens_equal=1")
    return {"cluster_scaling": rows, "cluster_speedup": speedup,
            "cluster_affinity_hit_rate": ahr}


if __name__ == "__main__":
    run()
