"""Serving engine benchmark: static vs continuous batching on a
mixed-length workload (ragged prompts, ragged output budgets — the traffic
shape continuous batching exists for).

Reports throughput (tok/s), p50/p95 per-request latency, and scheduler
utilization = generated tokens / (decode_steps * max_batch), the
deterministic measure of how much decode work the scheduler wastes on
finished-or-empty rows (lockstep static batching burns steps on the
max(max_new) barrier; slot-based continuous batching refills them).

``REPRO_BENCH_TINY=1`` shrinks the workload for the CI smoke lane and
writes a ``BENCH_serve.json`` summary at the repo root (uploaded as a CI
artifact so the serving-perf trajectory is tracked PR-over-PR).
"""
from __future__ import annotations

import json
import os
import pathlib

import jax

from repro.config import get_smoke_config
from repro.core.runtime import ModelRuntime
from repro.serve.engine import ServeEngine, StaticServeEngine

from .common import emit, mixed_workload, run_engine_timed

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))


def run():
    cfg = get_smoke_config("qwen2-72b")
    n_req = 16 if TINY else 48
    max_batch = 4
    prompt_hi = 12 if TINY else 32
    # wide output-budget spread: the lockstep max(max_new) barrier is what
    # static batching pays for and slot refill is what continuous wins on
    max_new_hi = 32 if TINY else 48
    max_len = prompt_hi + max_new_hi + 8
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    workload = mixed_workload(n_req, prompt_hi, max_new_hi, seed=0)
    # warmup = the same workload, so every shape both schedulers will see
    # (static: per-batch pad shapes; continuous: prefill buckets) is
    # compiled before the timed pass — the comparison measures scheduling,
    # not retracing
    warmup = workload

    res = {}
    for name, make in (
        ("static", lambda: StaticServeEngine(
            rt, max_batch=max_batch, max_len=max_len, eos_id=-1)),
        ("continuous", lambda: ServeEngine(
            rt, max_batch=max_batch, max_len=max_len, eos_id=-1)),
    ):
        r = res[name] = run_engine_timed(make, warmup, workload)
        emit(f"serve/{name}_mixed",
             1e6 * r["dt"] / max(r["tokens"], 1),
             f"tok/s={r['tok_s']:.1f};util={r['util']:.2f};"
             f"p50_ms={r['p50_ms']:.0f};p95_ms={r['p95_ms']:.0f};"
             f"decode_steps={r['decode_steps']}")

    speedup = res["continuous"]["tok_s"] / max(res["static"]["tok_s"], 1e-9)
    emit("serve/continuous_speedup", 0.0,
         f"x{speedup:.2f};util {res['static']['util']:.2f}->"
         f"{res['continuous']['util']:.2f}")

    # ---- store-paged lane: same traffic shape, per-request adapters paged
    # under an HBM budget smaller than the tenant count (residency counters
    # ride the CSV so eviction/hit-rate/page-in trends are tracked here too)
    from repro.store import AdapterStore
    from repro.core import peft as peft_lib
    from repro.launch.serve import make_demo_adapters
    n_ad = 6 if TINY else 12
    meths = ("gsoft", "boft", "householder")
    bank_peft = {f"a{i}": peft_lib.PEFTConfig(method=meths[i % 3],
                                              block_size=8)
                 for i in range(n_ad)}
    adapters = make_demo_adapters(list(bank_peft), rt.params, bank_peft)
    store = AdapterStore.from_adapters(adapters, bank_peft)
    rt_store = rt.attach(store, hbm_budget=max(n_ad // 2, 3))
    wl_store = mixed_workload(n_req, prompt_hi, max_new_hi, seed=0,
                              adapters=list(bank_peft))
    r = res["store_paged"] = run_engine_timed(
        lambda: ServeEngine(rt_store, max_batch=max_batch, max_len=max_len,
                            eos_id=-1), wl_store, wl_store)
    st = rt_store.bank.stats()
    emit("serve/store_paged_mixed",
         1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f};hit_rate={st['hit_rate']:.2f};"
         f"evictions={st['evictions']};"
         f"page_in_p95_ms={st['page_in_ms_p95']:.1f};"
         f"max_resident={st['max_resident']}/{st['capacity']};"
         f"compaction={st['compaction_ratio']:.2f}x")

    # ---- store-resident lane: same adapter traffic with a budget that fits
    # every tenant. Once resident, the paged bank must serve within ~10% of
    # an eagerly-attached bank — i.e. the decode hot loop does no per-step
    # host work (adapter contexts are cached on the bank version).
    rt_eager = rt.attach(adapters, bank_peft)
    r = res["store_eager"] = run_engine_timed(
        lambda: ServeEngine(rt_eager, max_batch=max_batch, max_len=max_len,
                            eos_id=-1), wl_store, wl_store)
    emit("serve/store_eager_mixed", 1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f}")
    rt_res = rt.attach(store, hbm_budget=n_ad)
    r = res["store_resident"] = run_engine_timed(
        lambda: ServeEngine(rt_res, max_batch=max_batch, max_len=max_len,
                            eos_id=-1), wl_store, wl_store)
    resident_ratio = r["tok_s"] / max(res["store_eager"]["tok_s"], 1e-9)
    st = rt_res.bank.stats()
    emit("serve/store_resident_mixed", 1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f};vs_eager=x{resident_ratio:.2f};"
         f"evictions={st['evictions']};hit_rate={st['hit_rate']:.2f}")

    if TINY:
        summary = {"backend": jax.default_backend(), "arch": cfg.name,
                   "continuous_speedup": speedup,
                   "store_resident_vs_eager": resident_ratio}
        for name, r in res.items():
            for key, val in r.items():
                summary[f"{name}_{key}"] = val
        out = pathlib.Path(__file__).resolve().parents[1] / "BENCH_serve.json"
        out.write_text(json.dumps(summary, indent=2, sort_keys=True))
        print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    run()
