"""Serving engine benchmark: static vs continuous batching on a
mixed-length workload (ragged prompts, ragged output budgets — the traffic
shape continuous batching exists for).

Reports throughput (tok/s), p50/p95 per-request latency, and scheduler
utilization = generated tokens / (decode_steps * max_batch), the
deterministic measure of how much decode work the scheduler wastes on
finished-or-empty rows (lockstep static batching burns steps on the
max(max_new) barrier; slot-based continuous batching refills them).

``REPRO_BENCH_TINY=1`` shrinks the workload for the CI smoke lane.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core.runtime import ModelRuntime
from repro.serve.engine import (ServeEngine, StaticServeEngine,
                                latency_percentiles)

from .common import emit

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))


def _workload(n_req, prompt_hi, max_new_hi, seed=0):
    rng = np.random.default_rng(seed)
    return [
        {"prompt": rng.integers(1, 200,
                                size=int(rng.integers(4, prompt_hi + 1))
                                ).tolist(),
         "max_new_tokens": int(rng.integers(2, max_new_hi + 1))}
        for _ in range(n_req)
    ]


def _run_engine(make_engine, warmup, workload):
    eng = make_engine()
    for req in warmup:                       # compile prefill buckets + decode
        eng.add_request(**req)
    eng.run()
    eng.drain_finished()
    steps0, toks0 = eng.stats["decode_steps"], eng.stats["tokens_generated"]
    for req in workload:
        eng.add_request(**req)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = eng.stats["tokens_generated"] - toks0
    steps = eng.stats["decode_steps"] - steps0
    lat = latency_percentiles(eng.drain_finished())
    return {"tok_s": toks / max(dt, 1e-9), "dt": dt, "tokens": toks,
            "decode_steps": steps,
            "util": toks / max(steps * eng.max_batch, 1),
            "p50_ms": lat[50] * 1e3, "p95_ms": lat[95] * 1e3}


def run():
    cfg = get_smoke_config("qwen2-72b")
    n_req = 16 if TINY else 48
    max_batch = 4
    prompt_hi = 12 if TINY else 32
    # wide output-budget spread: the lockstep max(max_new) barrier is what
    # static batching pays for and slot refill is what continuous wins on
    max_new_hi = 32 if TINY else 48
    max_len = prompt_hi + max_new_hi + 8
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    workload = _workload(n_req, prompt_hi, max_new_hi, seed=0)
    # warmup = the same workload, so every shape both schedulers will see
    # (static: per-batch pad shapes; continuous: prefill buckets) is
    # compiled before the timed pass — the comparison measures scheduling,
    # not retracing
    warmup = workload

    res = {}
    for name, make in (
        ("static", lambda: StaticServeEngine(
            rt, max_batch=max_batch, max_len=max_len, eos_id=-1)),
        ("continuous", lambda: ServeEngine(
            rt, max_batch=max_batch, max_len=max_len, eos_id=-1)),
    ):
        r = res[name] = _run_engine(make, warmup, workload)
        emit(f"serve/{name}_mixed",
             1e6 * r["dt"] / max(r["tokens"], 1),
             f"tok/s={r['tok_s']:.1f};util={r['util']:.2f};"
             f"p50_ms={r['p50_ms']:.0f};p95_ms={r['p95_ms']:.0f};"
             f"decode_steps={r['decode_steps']}")

    speedup = res["continuous"]["tok_s"] / max(res["static"]["tok_s"], 1e-9)
    emit("serve/continuous_speedup", 0.0,
         f"x{speedup:.2f};util {res['static']['util']:.2f}->"
         f"{res['continuous']['util']:.2f}")


if __name__ == "__main__":
    run()
