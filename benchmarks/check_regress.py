"""Bench-regression gate over the ``BENCH_*.json`` trajectories.

Every bench suite run appends a timestamped entry to its suite's
``history`` (``common.write_summary``), so the repo carries its own perf
trajectory. This gate compares each suite's ``latest`` entry against the
median of the last ``--window`` PRIOR history entries and fails (exit 1)
when either

* a throughput-like key (``*tok_s*``, ``*img_s*``, ``*speedup*``) drops
  by more than ``--threshold`` (default 25%) after machine-speed
  normalization, or
* an equality-assertion key (any boolean, e.g. ``tokens_equal``) that
  held in the baseline no longer holds — numerical drift is a
  correctness bug, not a slowdown.

Machine-speed normalization: histories are committed from whatever
machine ran the bench, so an absolute tok/s comparison would flag every
slower CI box. Keys are split into two classes: DIMENSIONLESS ratios
(``*speedup*``, ``*_vs_*``, ``*ratio*``) are machine-independent and
compared raw, while ABSOLUTE rates (``*tok_s*``, ``*img_s*``) are
compared relative to the suite's machine-speed factor — the median
latest/baseline ratio across the absolute keys — so a key only fails
when it slowed down out of line with its siblings. A suite with a
single absolute key therefore can only fail un-normalized (its own
ratio IS the factor); pass ``--no-normalize`` to compare absolutes.

Usage::

    python -m benchmarks.check_regress                # gate every suite
    python -m benchmarks.check_regress --suites serve kv
    python -m benchmarks.check_regress --threshold 0.4 --no-normalize
"""
from __future__ import annotations

import argparse
import json
import pathlib
import statistics
import sys
from typing import Dict, List, Optional, Tuple

from .common import REPO_ROOT

ABSOLUTE_MARKERS = ("tok_s", "img_s")        # machine-speed dependent
RATIO_MARKERS = ("speedup", "_vs_", "ratio")  # dimensionless, compare raw


def key_class(key: str) -> Optional[str]:
    """'ratio' | 'absolute' | None (ungated)."""
    if any(m in key for m in RATIO_MARKERS):
        return "ratio"
    if any(m in key for m in ABSOLUTE_MARKERS):
        return "absolute"
    return None


def _flatten(summary: Dict) -> Dict[str, object]:
    """Top-level scalars only; nested lists/dicts (e.g. ``cluster_scaling``
    rows) are per-run shaped and compared via their flattened top-level
    mirrors (``cluster_speedup`` etc.), not structurally."""
    return {k: v for k, v in summary.items()
            if isinstance(v, (int, float, bool)) and k != "ts"}


def load_suite(path: pathlib.Path) -> Tuple[Dict, List[Dict]]:
    doc = json.loads(path.read_text())
    if not isinstance(doc, dict):
        raise ValueError(f"{path.name}: not a JSON object")
    if "history" not in doc:            # pre-history flat file: no baseline
        return doc, []
    return doc.get("latest", {}), list(doc.get("history", []))


def baseline_entries(latest: Dict, history: List[Dict],
                     window: int) -> List[Dict]:
    """The last ``window`` history entries EXCLUDING the one that mirrors
    ``latest`` (write_summary appends the latest run to history too)."""
    prior = list(history)
    if prior and {k: v for k, v in prior[-1].items() if k != "ts"} == latest:
        prior = prior[:-1]
    return prior[-window:]


def check_suite(suite: str, latest: Dict, baseline: List[Dict], *,
                threshold: float, normalize: bool) -> List[str]:
    """Return failure messages (empty == suite passes the gate)."""
    if not baseline:
        print(f"  {suite}: no prior history — nothing to gate against")
        return []
    lat = _flatten(latest)
    base: Dict[str, List[float]] = {}
    for entry in baseline:
        for k, v in _flatten(entry).items():
            base.setdefault(k, []).append(float(v))

    # per-key latest/baseline-median ratios, split by class
    ratios: Dict[str, Tuple[str, float, float]] = {}
    for k, v in lat.items():
        cls = key_class(k)
        if cls is None or isinstance(v, bool) or k not in base:
            continue
        ref = statistics.median(base[k])
        if ref <= 0:
            continue
        ratios[k] = (cls, float(v) / ref, ref)
    abs_ratios = [r for cls, r, _ in ratios.values() if cls == "absolute"]
    factor = (statistics.median(abs_ratios)
              if (normalize and abs_ratios) else 1.0)

    failures: List[str] = []
    for k, (cls, ratio, ref) in sorted(ratios.items()):
        rel = ratio / factor if cls == "absolute" else ratio
        ok = rel >= 1.0 - threshold
        mark = "ok" if ok else "REGRESSED"
        print(f"  {suite}: {k:40s} x{ratio:.3f} vs median "
              f"({cls}, norm x{rel:.3f}) {mark}")
        if not ok:
            failures.append(
                f"{suite}.{k}: {lat[k]:.4g} vs baseline median {ref:.4g} "
                f"(x{rel:.3f} after machine factor x{factor:.3f}, "
                f"floor x{1.0 - threshold:.2f})")
    for k, v in sorted(lat.items()):
        if not isinstance(v, bool) or k not in base:
            continue
        held = all(base[k])             # only gate assertions that held
        if held and not v:
            failures.append(
                f"{suite}.{k}: equality assertion drifted True -> False")
        else:
            print(f"  {suite}: {k:40s} {v} (baseline "
                  f"{'held' if held else 'mixed'}) "
                  f"{'ok' if (not held or v) else 'DRIFTED'}")
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="fail CI when BENCH_*.json latest regresses vs history")
    ap.add_argument("--threshold", type=float, default=0.25,
                    help="max tolerated normalized throughput drop "
                         "(default 0.25 = 25%%)")
    ap.add_argument("--window", type=int, default=5,
                    help="baseline = median of the last N prior runs")
    ap.add_argument("--no-normalize", action="store_true",
                    help="compare absolute throughput (flags every "
                         "machine-speed change, not just drift)")
    ap.add_argument("--suites", nargs="*", default=None,
                    help="suite names (serve, kv, ...); default: all "
                         "BENCH_*.json at the repo root")
    ap.add_argument("--root", type=pathlib.Path, default=REPO_ROOT,
                    help="directory holding BENCH_*.json (for tests)")
    args = ap.parse_args(argv)

    paths = (sorted(args.root.glob("BENCH_*.json")) if args.suites is None
             else [args.root / f"BENCH_{s}.json" for s in args.suites])
    failures: List[str] = []
    seen = 0
    for path in paths:
        suite = path.stem.removeprefix("BENCH_")
        if not path.exists():
            failures.append(f"{suite}: {path} missing")
            continue
        seen += 1
        latest, history = load_suite(path)
        baseline = baseline_entries(latest, history, args.window)
        failures += check_suite(suite, latest, baseline,
                                threshold=args.threshold,
                                normalize=not args.no_normalize)
    if not seen and not failures:
        print("no BENCH_*.json trajectories found — nothing to gate")
    if failures:
        print(f"\nREGRESSION GATE FAILED ({len(failures)}):")
        for f in failures:
            print(f"  - {f}")
        return 1
    print(f"\nregression gate passed ({seen} suite(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())
