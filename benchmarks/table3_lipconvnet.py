"""Paper Table 3/4 (LipConvnet-15, CIFAR-100) — scaled reproduction.

Synthetic 32x32 images (no CIFAR offline), LipConvnet-10 at reduced width:
  * conv-parameter compression SOC -> GS-SOC (paper: 24.1M -> 6.81M, 3.5x)
  * forward speedup of GS-SOC groups (4,-) / (4,1) vs SOC
  * certified-robust-accuracy machinery end-to-end (margin / sqrt(2))
  * Table 4 ablation direction: paired shuffle + MaxMinPermuted >= MaxMin
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.models.lipconvnet import (LipConvnetConfig, apply_lipconvnet,
                                     count_conv_params, init_lipconvnet,
                                     lipconvnet_loss)
from .common import emit, time_fn

BASE = dict(depth=10, base_width=8, num_classes=10, image_size=32, terms=4)


def _cfg(conv_layer, groups, activation="maxmin_permuted", paired=True):
    return LipConvnetConfig(conv_layer=conv_layer, groups=groups,
                            activation=activation, paired_shuffle=paired,
                            **BASE)


def _data(key, n=128):
    x = jax.random.normal(key, (n, 32, 32, 3)) * 0.5
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 10))
    feats = x[:, :8, :8].mean(axis=(1, 2))          # (n, 3)
    labels = jnp.argmax(feats @ w, axis=-1)
    return x, labels


def run():
    rows = {}
    x, labels = _data(jax.random.PRNGKey(0))
    variants = [
        ("SOC", _cfg("soc", (1, 0), activation="maxmin", paired=False)),
        ("GS-SOC_4-", _cfg("gs", (4, 0))),
        ("GS-SOC_4-1", _cfg("gs", (4, 1))),
        ("GS-SOC_4-2", _cfg("gs", (4, 2))),
        ("GS-SOC_4-_maxmin_unpaired",
         _cfg("gs", (4, 0), activation="maxmin", paired=False)),
    ]
    soc_params = soc_us = None
    for name, cfg in variants:
        params = init_lipconvnet(cfg, jax.random.PRNGKey(1))
        fwd = jax.jit(lambda p, v: apply_lipconvnet(cfg, p, v))
        us = time_fn(fwd, params, x[:32], iters=5)
        n_conv = count_conv_params(cfg)

        # few training steps: loss must go down, certified acc computable
        # (LR conservative: the margin loss destabilizes plain SOC above 1e-3)
        ocfg = optim.OptimizerConfig(learning_rate=1e-3, weight_decay=0.0,
                                     grad_clip=0.5)
        opt = optim.init(ocfg, params)

        @jax.jit
        def step(p, o):
            (l, m), g = jax.value_and_grad(
                lambda q: lipconvnet_loss(cfg, q, x[:64], labels[:64]),
                has_aux=True)(p)
            p, o, _ = optim.update(ocfg, g, o, p)
            return p, o, l, m

        l0 = None
        for s in range(15):
            params, opt, l, m = step(params, opt)
            l0 = float(l) if l0 is None else l0
        derived = (f"conv_params={n_conv};loss0={l0:.3f};"
                   f"loss={float(l):.3f};cert_acc={float(m['certified']):.3f}")
        if name == "SOC":
            soc_params, soc_us = n_conv, us
        else:
            derived += (f";param_ratio={soc_params / n_conv:.2f}x"
                        f";speedup={soc_us / us:.2f}x")
        rows[name] = dict(us=us, params=n_conv, loss=float(l))
        emit(f"table3/{name}", us, derived)

    assert rows["SOC"]["params"] / rows["GS-SOC_4-"]["params"] > 3.0, \
        "GS-SOC (4,-) should compress conv params > 3x (paper: 3.5x)"
    return rows
