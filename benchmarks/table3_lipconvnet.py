"""Paper Table 3/4 (LipConvnet-15, CIFAR-100) — scaled reproduction on the
REGISTERED ``image`` family (ISSUE 9: no direct LipConvnet calls — every
variant builds a ``ModelConfig`` and runs through ``ModelRuntime``, the
same path the serving lane uses).

Synthetic 32x32 images (no CIFAR offline), LipConvnet-10 at reduced width:
  * conv-parameter compression SOC -> GS-SOC (paper: 24.1M -> 6.81M, 3.5x)
  * forward speedup of GS-SOC groups (4,-) / (4,1) vs SOC
  * certified-robust-accuracy machinery end-to-end (margin / sqrt(2))
  * Table 4 ablation direction: paired shuffle + MaxMinPermuted >= MaxMin

Training trains the LipConvnet weights only — the identity ``wc``
channel-mix leaves (the adapter/quant attachment points) stay FROZEN, as
in the serving story: base training never moves them, orthogonal adapters
rotate them per tenant.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import optim
from repro.config import get_smoke_config
from repro.core.runtime import ModelRuntime
from repro.data.synthetic import image_batch
from repro.models import registry
from repro.models.image import lip_cfg
from repro.models.lipconvnet import count_conv_params
from .common import emit, time_fn

BASE = get_smoke_config("lipconvnet-15")     # depth 10 / width 8 / 10 classes


def _cfg(conv_layer, groups, activation="maxmin_permuted", paired=True):
    return BASE.with_overrides(conv_layer=conv_layer, conv_groups=groups,
                               conv_activation=activation,
                               paired_shuffle=paired)


def _freeze_wc(grads):
    """Zero the identity channel-mix grads: ``wc`` is an adapter
    attachment point, not a base-training weight (unconstrained training
    would break the 1-Lipschitz bound the certificate needs)."""
    from repro.core.peft import path_str
    return jax.tree_util.tree_map_with_path(
        lambda p, g: jnp.zeros_like(g) if path_str(p).endswith("/wc") else g,
        grads)


def run():
    rows = {}
    batch = image_batch(BASE, 64, seed=0)
    variants = [
        ("SOC", _cfg("soc", (1, 0), activation="maxmin", paired=False)),
        ("GS-SOC_4-", _cfg("gs_soc", (4, 0))),
        ("GS-SOC_4-1", _cfg("gs_soc", (4, 1))),
        ("GS-SOC_4-2", _cfg("gs_soc", (4, 2))),
        ("GS-SOC_4-_maxmin_unpaired",
         _cfg("gs_soc", (4, 0), activation="maxmin", paired=False)),
    ]
    soc_params = soc_us = None
    for name, cfg in variants:
        ops = registry.get(cfg.family)
        rt = ModelRuntime(cfg, key=jax.random.PRNGKey(1))
        fwd = rt.infer_fn()
        us = time_fn(fwd, rt.params, None, batch["images"][:32], iters=5)
        n_conv = count_conv_params(lip_cfg(cfg))

        # few training steps: loss must go down, certified acc computable
        # (LR conservative: the margin loss destabilizes plain SOC above 1e-3)
        params = rt.params
        ocfg = optim.OptimizerConfig(learning_rate=1e-3, weight_decay=0.0,
                                     grad_clip=0.5)
        opt = optim.init(ocfg, params)

        @jax.jit
        def step(p, o, cfg=cfg, ops=ops, ocfg=ocfg):
            (l, m), g = jax.value_and_grad(
                lambda q: ops.loss(cfg, q, batch), has_aux=True)(p)
            p, o, _ = optim.update(ocfg, _freeze_wc(g), o, p)
            return p, o, l, m

        l0 = None
        for _ in range(15):
            params, opt, l, m = step(params, opt)
            l0 = float(l) if l0 is None else l0
        derived = (f"conv_params={n_conv};loss0={l0:.3f};"
                   f"loss={float(l):.3f};cert_acc={float(m['certified']):.3f}")
        if name == "SOC":
            soc_params, soc_us = n_conv, us
        else:
            derived += (f";param_ratio={soc_params / n_conv:.2f}x"
                        f";speedup={soc_us / us:.2f}x")
        rows[name] = dict(us=us, params=n_conv, loss=float(l))
        emit(f"table3/{name}", us, derived)

    assert rows["SOC"]["params"] / rows["GS-SOC_4-"]["params"] > 3.0, \
        "GS-SOC (4,-) should compress conv params > 3x (paper: 3.5x)"
    return rows
