"""Benchmark harness — one module per paper table + kernel/GS micro-benches.
Prints ``name,us_per_call,derived`` CSV rows (assignment contract).

    PYTHONPATH=src python -m benchmarks.run [--only table1,micro,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-list of {table1,table2,table3,micro,kernels,"
                         "serve,quant,methods,store,kv,image}")
    args = ap.parse_args()
    want = set(args.only.split(",")) if args.only else None

    from . import table1_glue, table2_subject, table3_lipconvnet
    from . import image_bench, kernels_bench, kv_bench, method_bench, \
        micro_gs, quant_bench, serve_bench, store_bench

    suites = [
        ("table1", table1_glue.run),
        ("table2", table2_subject.run),
        ("table3", table3_lipconvnet.run),
        ("micro", micro_gs.run),
        ("kernels", kernels_bench.run),
        ("serve", serve_bench.run),
        ("quant", quant_bench.run),
        ("methods", method_bench.run),
        ("store", store_bench.run),
        ("kv", kv_bench.run),
        ("image", image_bench.run),
    ]
    print("name,us_per_call,derived")
    t0 = time.time()
    failures = []
    for name, fn in suites:
        if want and name not in want:
            continue
        try:
            fn()
        except Exception as e:  # keep the harness going; report at the end
            failures.append((name, repr(e)))
            print(f"{name}/SUITE_FAILED,0.0,{e!r}", flush=True)
    print(f"# total {time.time() - t0:.1f}s", file=sys.stderr)
    if failures:
        raise SystemExit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
