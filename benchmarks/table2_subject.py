"""Paper Table 2 (subject-driven generation, SD attention layers) — proxy.

No StableDiffusion offline; the table's transferable claims are about the
*adapter mechanics* on attention-shaped weights (d=320..1280 in SD; scaled
here):

  * parameter budgets per method / hyperparameter (paper: GSOFT r=32 ~
    6.8M ~ LoRA r=32's 6.6M; Double GSOFT r=64 ~ 6.5M)
  * training-time ordering: LoRA < GSOFT < Double GSOFT << BOFT (m=5,6)
    (paper: 1.3 / 1.5-1.8 / 1.7-2.0 / 2.0-2.3 h)
  * merged inference == zero overhead for all orthogonal methods
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro import optim
from repro.core import peft as peft_lib
from .common import emit, time_fn

D, FF, L = 256, 512, 4          # scaled SD-attention-block proxy
BATCH, SEQ = 8, 64


def make_params(key):
    ks = jax.random.split(key, 4)
    return {"blocks": {
        "attn": {"wq": jax.random.normal(ks[0], (L, D, D)) * 0.05,
                 "wk": jax.random.normal(ks[1], (L, D, D)) * 0.05,
                 "wv": jax.random.normal(ks[2], (L, D, D)) * 0.05,
                 "wo": jax.random.normal(ks[3], (L, D, D)) * 0.05}}}


def forward(params, x):
    def body(h, lp):
        q, k = h @ lp["wq"], h @ lp["wk"]
        a = jax.nn.softmax(q @ jnp.swapaxes(k, -1, -2) / jnp.sqrt(D))
        h = h + a @ (h @ lp["wv"]) @ lp["wo"]
        return h, None
    h, _ = jax.lax.scan(body, x, params["blocks"]["attn"])
    return h


METHODS = {
    "LoRA_r4": peft_lib.PEFTConfig(method="lora", rank=4),
    "LoRA_r32": peft_lib.PEFTConfig(method="lora", rank=32),
    "BOFT_m4_b32": peft_lib.PEFTConfig(method="boft", block_size=32,
                                       boft_factors=4),
    "BOFT_m6_b32": peft_lib.PEFTConfig(method="boft", block_size=32,
                                       boft_factors=6),
    "GSOFT_b32": peft_lib.PEFTConfig(method="gsoft", block_size=32),
    "GSOFT_b16": peft_lib.PEFTConfig(method="gsoft", block_size=16),
    "DoubleGSOFT_b32": peft_lib.PEFTConfig(method="double_gsoft",
                                           block_size=32),
}


def run():
    key = jax.random.PRNGKey(0)
    params = make_params(key)
    x = jax.random.normal(jax.random.PRNGKey(1), (BATCH, SEQ, D))
    target = jax.random.normal(jax.random.PRNGKey(2), (BATCH, SEQ, D))

    times = {}
    for name, pcfg in METHODS.items():
        adapters = peft_lib.init_peft(pcfg, params, jax.random.PRNGKey(3))
        ocfg = optim.OptimizerConfig(learning_rate=1e-3)
        opt = optim.init(ocfg, adapters)

        @jax.jit
        def step(ad, op):
            def loss(a):
                eff = peft_lib.materialize_tree(pcfg, params, a)
                return jnp.mean((forward(eff, x) - target) ** 2)
            l, g = jax.value_and_grad(loss)(ad)
            ad, op, _ = optim.update(ocfg, g, op, ad)
            return ad, op, l

        us = time_fn(lambda: step(adapters, opt), iters=5)
        times[name] = us
        emit(f"table2/{name}", us,
             f"trainable_params={peft_lib.count_params(adapters)}")

        # merged inference has zero overhead (paper §6.1); params passed as
        # jit arguments so XLA cannot constant-fold the forward away
        merged = peft_lib.materialize_tree(pcfg, params, adapters,
                                           merged=True)
        fwd = jax.jit(forward)
        us_merged = time_fn(fwd, merged, x, iters=5)
        us_base = time_fn(fwd, params, x, iters=5)
        emit(f"table2/{name}/merged_overhead", us_merged,
             f"base_us={us_base:.1f};overhead={us_merged / us_base - 1:+.2%}")

    # paper's time ordering: GSOFT (m=2) cheaper than BOFT (m=4/6)
    emit("table2/claim_gsoft_faster_than_boft", 0.0,
         f"gsoft_b32={times['GSOFT_b32']:.0f}us;"
         f"boft_m4={times['BOFT_m4_b32']:.0f}us;"
         f"boft_m6={times['BOFT_m6_b32']:.0f}us;"
         f"ok={times['GSOFT_b32'] < times['BOFT_m4_b32']}")
    return times
