"""Paged-KV serving benchmark (ISSUE 7): residency + scheduling lanes.

Three lanes, all over the same smoke model:

  * ``bytes``  — mixed-length multi-tenant traffic with one shared system
    prompt: paged vs contiguous KV bytes per request (the contiguous engine
    pays ``max_len`` rows per slot; the paged engine pays the pages it
    touches, and shared-prefix pages are paid ONCE across tenants). Greedy
    tokens are asserted identical to the contiguous engine on the way.
  * ``slots``  — a burst (the high-variance limit of Poisson arrivals) into
    a FIXED KV budget worth two contiguous worst-case slots: the paged
    engine fits >= 2x more concurrent requests in the same HBM (asserted;
    the schedule is deterministic, no timing involved).
  * ``hol``    — chunked vs whole-prompt admission on long prompts: wall
    p99 of the gap between consecutive decode steps (what a decoding slot
    actually waits through) plus the deterministic worst-case prefill
    tokens a single tick can interpose.
  * ``tp``     — the 1->N tensor-parallel scaling curve (ISSUE 8): the
    paged engine re-run per mesh geometry in a fresh subprocess
    (``scaling_child`` — XLA_FLAGS must precede backend init) on fake CPU
    devices. Greedy tokens are asserted identical across geometries; tok/s
    per tp rides the summary. On fake devices the curve measures GSPMD
    partition overhead, not speedup — real scaling needs real chips.

``REPRO_BENCH_TINY=1`` shrinks the workload and writes ``BENCH_kv.json``
at the repo root (uploaded as a CI artifact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core.runtime import ModelRuntime
from repro.serve.engine import PagedServeEngine, ServeEngine
from repro.serve.kv import kv_page_bytes

from .common import REPO_ROOT, emit, write_summary

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))

PAGE = 8
CHUNK = 16


def _tenant_workload(n_req, sys_len, priv_hi, new_hi, seed=0):
    """Every tenant shares one ``sys_len``-token system prompt and appends
    a private U[4, priv_hi] suffix; budgets U[2, new_hi]."""
    rng = np.random.default_rng(seed)
    sys_prompt = rng.integers(1, 200, size=sys_len).tolist()
    reqs = []
    for _ in range(n_req):
        suffix = rng.integers(
            1, 200, size=int(rng.integers(4, priv_hi + 1))).tolist()
        reqs.append({"prompt": sys_prompt + suffix,
                     "max_new_tokens": int(rng.integers(2, new_hi + 1))})
    return reqs


def _run_all(eng, workload):
    rids = [eng.add_request(**r) for r in workload]
    res = eng.run()
    return {i: res[rid] for i, rid in enumerate(rids)}


def _lane_bytes(rt, cfg, summary):
    n_req = 12 if TINY else 32
    sys_len, priv_hi, new_hi = 64, 32, 16
    max_len = sys_len + priv_hi + new_hi + 8
    wl = _tenant_workload(n_req, sys_len, priv_hi, new_hi)

    # max_batch=2 keeps the first admission wave small: a prefix is only
    # claimable once a finished prefill has published it.
    ref = ServeEngine(rt, max_batch=2, max_len=max_len, eos_id=-1)
    out_ref = _run_all(ref, wl)
    pg = PagedServeEngine(rt, max_batch=2, max_len=max_len, eos_id=-1,
                          page_size=PAGE, prefill_chunk=CHUNK)
    out_pg = _run_all(pg, wl)
    assert out_pg == out_ref, "paged engine diverged from contiguous tokens"

    ptb = kv_page_bytes(cfg, 1)                      # bytes per KV token
    st = pg.kv_stats()
    contig_req = max_len * ptb
    paged_req = st["alloc"] * PAGE * ptb / n_req     # fresh pages only
    ratio = contig_req / max(paged_req, 1e-9)
    assert ratio >= 2.0, f"kv bytes/request ratio {ratio:.2f} < 2x"
    emit("kv/bytes_per_request", 0.0,
         f"contig_kb={contig_req / 1e3:.1f};paged_kb={paged_req / 1e3:.1f};"
         f"ratio=x{ratio:.2f};prefix_hits={st['prefix_hits']};"
         f"tokens_equal=1")
    summary.update(kv_bytes_per_request_contiguous=contig_req,
                   kv_bytes_per_request_paged=paged_req,
                   kv_bytes_per_request_ratio=ratio,
                   prefix_hits=st["prefix_hits"], tokens_equal=True)


def _lane_slots(rt, cfg, summary):
    """Burst admission into a pool worth TWO contiguous worst-case slots."""
    n_req = 12 if TINY else 24
    prompt_hi, new_hi = 24, 12
    max_len = prompt_hi + new_hi + 8
    max_pages = -(-max_len // PAGE)
    budget_pages = 2 * max_pages                 # == 2 contiguous slots
    contig_slots = budget_pages // max_pages     # what contiguous affords
    rng = np.random.default_rng(1)
    wl = [{"prompt": rng.integers(
               1, 200, size=int(rng.integers(4, prompt_hi + 1))).tolist(),
           "max_new_tokens": int(rng.integers(2, new_hi + 1))}
          for _ in range(n_req)]

    eng = PagedServeEngine(rt, max_batch=8, max_len=max_len, eos_id=-1,
                           page_size=PAGE, prefill_chunk=CHUNK,
                           num_pages=budget_pages + 1)   # +1 garbage page
    for r in wl:
        eng.add_request(**r)
    max_conc = 0
    while eng.step():
        max_conc = max(max_conc, eng.num_active)
    max_conc = max(max_conc, eng.num_active)
    budget_bytes = budget_pages * kv_page_bytes(cfg, PAGE)
    st = eng.kv_stats()
    assert max_conc >= 2 * contig_slots, \
        f"paged fits {max_conc} concurrent slots, contiguous {contig_slots}"
    emit("kv/slots_at_fixed_budget", 0.0,
         f"budget_kb={budget_bytes / 1e3:.1f};contig_slots={contig_slots};"
         f"paged_max_concurrent={max_conc};kv_stalls={st['kv_stalls']}")
    summary.update(kv_budget_bytes=budget_bytes,
                   contiguous_slots_at_budget=contig_slots,
                   paged_max_concurrent_slots=max_conc,
                   kv_stalls=st["kv_stalls"])


def _decode_gaps(eng, workload):
    """Wall-clock gaps between consecutive decode steps (ms); the gap a
    decoding slot sits through, including any interleaved prefill work."""
    for r in workload:
        eng.add_request(**r)
    gaps, t_last = [], None
    more = True
    while more:
        before = eng.stats["decode_steps"]
        more = eng.step()
        if eng.stats["decode_steps"] > before:
            now = time.perf_counter()
            if t_last is not None:
                gaps.append((now - t_last) * 1e3)
            t_last = now
    return gaps


def _lane_hol(rt, summary):
    """Head-of-line: long prompts admitted whole vs in chunks."""
    n_req = 8 if TINY else 16
    plo, phi, new_hi = 64, 96, 12
    max_len = phi + new_hi + 8
    rng = np.random.default_rng(2)
    wl = [{"prompt": rng.integers(
               1, 200, size=int(rng.integers(plo, phi + 1))).tolist(),
           "max_new_tokens": int(rng.integers(4, new_hi + 1))}
          for _ in range(n_req)]

    res = {}
    for name, chunk in (("whole", max_len), ("chunked", CHUNK)):
        mk = lambda: PagedServeEngine(rt, max_batch=4, max_len=max_len,
                                      eos_id=-1, page_size=PAGE,
                                      prefill_chunk=chunk)
        _decode_gaps(mk(), wl)                       # warmup (compile)
        gaps = _decode_gaps(mk(), wl)
        p99 = float(np.percentile(gaps, 99)) if gaps else 0.0
        p50 = float(np.percentile(gaps, 50)) if gaps else 0.0
        res[name] = {"p99_ms": p99, "p50_ms": p50,
                     "hol_tokens": min(chunk, phi)}
        emit(f"kv/decode_gap_{name}", 1e3 * p99,
             f"p50_ms={p50:.2f};p99_ms={p99:.2f};"
             f"max_prefill_tokens_per_tick={min(chunk, phi)}")
    ratio = res["whole"]["p99_ms"] / max(res["chunked"]["p99_ms"], 1e-9)
    emit("kv/chunked_prefill_p99_speedup", 0.0, f"x{ratio:.2f}")
    summary.update(
        decode_gap_p99_ms_whole=res["whole"]["p99_ms"],
        decode_gap_p99_ms_chunked=res["chunked"]["p99_ms"],
        chunked_prefill_p99_speedup=ratio,
        hol_tokens_whole=res["whole"]["hol_tokens"],
        hol_tokens_chunked=res["chunked"]["hol_tokens"])


def _lane_tp(summary):
    """Paged decode under serve-time TP, one fresh process per geometry."""
    n_req = 8 if TINY else 16
    rows = []
    for tp in (1, 2):
        cmd = [sys.executable, "-m", "benchmarks.scaling_child",
               "--tp", str(tp), "--n-req", str(n_req),
               "--page-size", str(PAGE), "--prefill-chunk", str(CHUNK)]
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (str(REPO_ROOT / "src"), env.get("PYTHONPATH")) if p)
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              cwd=REPO_ROOT, env=env, timeout=900)
        lines = [l for l in proc.stdout.splitlines()
                 if l.startswith("RESULT ")]
        if proc.returncode != 0 or not lines:
            raise RuntimeError(
                f"scaling child tp={tp} failed (rc={proc.returncode}):\n"
                f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
        rows.append(json.loads(lines[-1][len("RESULT "):]))
    for r in rows:
        assert r["outputs"] == rows[0]["outputs"], \
            f"tp={r['tp']} tokens diverged from tp=1"
        emit(f"kv/paged_tp{r['tp']}", 0.0,
             f"tok/s={r['tok_s']:.1f};devices={r['devices']};"
             f"decode_steps={r['decode_steps']};tokens_equal=1")
    summary["tp_scaling"] = [{k: r[k] for k in
                              ("tp", "devices", "tok_s", "tokens")}
                             for r in rows]


def run():
    cfg = get_smoke_config("qwen2-72b")
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    summary = {"backend": jax.default_backend(), "arch": cfg.name,
               "page_size": PAGE, "prefill_chunk": CHUNK}
    _lane_bytes(rt, cfg, summary)
    _lane_slots(rt, cfg, summary)
    _lane_hol(rt, summary)
    _lane_tp(summary)
    if TINY:
        write_summary("kv", summary)


if __name__ == "__main__":
    run()
