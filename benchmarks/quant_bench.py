"""Quantized-serving benchmark: int8 base weights vs the bf16 baseline.

Reports, on the tiny smoke config:
  * parameter HBM footprint (the decode path re-reads the whole weight
    tree per token — bytes ARE the roofline on a bandwidth-bound step);
  * decode throughput through ``ServeEngine`` for bf16 vs int8 runtimes
    (and int8 with a multi-adapter bank — rotations stay bf16);
  * greedy-token agreement and max prefill-logit error vs the bf16
    reference (the accuracy side of the trade);
  * q_matmul kernel-vs-reference microbenchmark timings.

NOTE on CPU results: this container benches on the CPU backend, where the
reference einsum path dequantizes explicitly and Pallas runs in interpret
mode, so int8 shows little or no wall-clock win here — the bandwidth win
the kernel exists for (int8 HBM reads + epilogue dequant on the MXU) only
materializes on TPU. The footprint and logit-error numbers are
backend-independent; ``BENCH_quant.json`` records both plus the backend
so the perf trajectory is comparable PR-over-PR.

``REPRO_BENCH_TINY=1`` shrinks the workload and writes BENCH_quant.json
at the repo root for the CI artifact lane.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.peft import PrefillRequest
from repro.core.runtime import ModelRuntime
from repro.kernels import dispatch, ops, ref
from repro.serve.engine import ServeEngine

from .common import emit, mixed_workload, run_engine_timed, time_fn, write_summary

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))

def _tok_s(rt, workload, max_batch, max_len):
    make = lambda: ServeEngine(rt, max_batch=max_batch, max_len=max_len,
                               eos_id=-1)
    return run_engine_timed(make, workload, workload)["tok_s"]


def run():
    cfg = get_smoke_config("qwen2-72b")
    n_req = 12 if TINY else 32
    prompt_hi = 12 if TINY else 24
    max_new_hi = 24 if TINY else 48
    max_batch = 4
    max_len = prompt_hi + max_new_hi + 8
    rollout = 64

    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    qrt = rt.quantized("int8")
    summary = {"backend": jax.default_backend(), "arch": cfg.name}

    # ---- HBM footprint -----------------------------------------------------
    b_bf16 = quant.tree_bytes(rt.params)
    b_int8 = quant.tree_bytes(qrt.params)
    summary["params_bytes_bf16"] = b_bf16
    summary["params_bytes_int8"] = b_int8
    summary["footprint_ratio"] = b_bf16 / max(b_int8, 1)
    emit("quant/hbm_footprint", 0.0,
         f"bf16={b_bf16};int8={b_int8};ratio={summary['footprint_ratio']:.2f}")

    # ---- accuracy: prefill logits + greedy rollout -------------------------
    toks = jnp.asarray(np.random.default_rng(0).integers(
        1, 200, size=(2, 16)), jnp.int32)
    req = PrefillRequest(batch={"tokens": toks},
                         last_idx=jnp.asarray([15, 15], jnp.int32))
    st = rt.decode_state(2, 32)
    logits, _ = rt.prefill(req, st)
    st = qrt.decode_state(2, 32)
    qlogits, _ = qrt.prefill(req, st)
    l32 = np.asarray(logits, np.float32)
    err = float(np.max(np.abs(l32 - np.asarray(qlogits, np.float32))))
    spread = float(np.std(l32))
    summary["prefill_logit_max_err"] = err
    summary["prefill_logit_std"] = spread
    emit("quant/logit_error", 0.0,
         f"max_abs={err:.4f};logit_std={spread:.3f}")

    prompt = [3, 4, 5, 6]
    outs = []
    for r in (rt, qrt):
        eng = ServeEngine(r, max_batch=1, max_len=rollout + 16, eos_id=-1)
        eng.add_request(prompt, max_new_tokens=rollout)
        outs.append(eng.run()[0])
    agree = sum(a == b for a, b in zip(*outs))
    first_div = next((i for i, (a, b) in enumerate(zip(*outs)) if a != b),
                     rollout)
    summary["rollout_tokens"] = rollout
    summary["rollout_agreement"] = agree
    summary["rollout_first_divergence"] = first_div
    emit("quant/greedy_rollout", 0.0,
         f"agree={agree}/{rollout};first_div={first_div}")

    # ---- decode throughput: bf16 vs int8 vs int8+bank ----------------------
    workload = mixed_workload(n_req, prompt_hi, max_new_hi)
    tok_bf16 = _tok_s(rt, workload, max_batch, max_len)
    tok_int8 = _tok_s(qrt, workload, max_batch, max_len)
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    ad = {f"a{i}": peft_lib.init_peft(pcfg, rt.params,
                                      jax.random.PRNGKey(i + 1))
          for i in range(2)}
    qrt_bank = rt.attach(ad, pcfg).quantized("int8")
    bank_workload = mixed_workload(n_req, prompt_hi, max_new_hi,
                                   adapters=list(ad) + [None])
    tok_bank = _tok_s(qrt_bank, bank_workload, max_batch, max_len)
    speedup = tok_int8 / max(tok_bf16, 1e-9)
    summary["decode_tok_s_bf16"] = tok_bf16
    summary["decode_tok_s_int8"] = tok_int8
    summary["decode_tok_s_int8_banked"] = tok_bank
    summary["decode_speedup_int8"] = speedup
    emit("quant/decode_bf16", 1e6 / max(tok_bf16, 1e-9),
         f"tok/s={tok_bf16:.1f}")
    emit("quant/decode_int8", 1e6 / max(tok_int8, 1e-9),
         f"tok/s={tok_int8:.1f};speedup=x{speedup:.2f}")
    emit("quant/decode_int8_banked", 1e6 / max(tok_bank, 1e-9),
         f"tok/s={tok_bank:.1f}")

    # ---- kernel micro: q_matmul ref vs pallas vs bf16 matmul ---------------
    t, k, n = (256, 256, 512) if TINY else (1024, 1024, 2048)
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(kx, (t, k), jnp.bfloat16)
    w = jax.random.normal(kw, (k, n), jnp.bfloat16)
    qw, scale = quant.quantize_int8(w, axis=-1)
    us_bf16 = time_fn(jax.jit(lambda a, b: a @ b), x, w)
    us_ref = time_fn(jax.jit(lambda a, b, s: ref.q_matmul_ref(a, b, s)),
                     x, qw, scale)
    tun = dispatch.autotune_qmm(k, n, t, jnp.bfloat16)
    us_pal = time_fn(jax.jit(
        lambda a, b, s: ops.q_matmul(a, b, s, use_pallas=True, tuning=tun)),
        x, qw, scale)
    summary["qmm_us_bf16_matmul"] = us_bf16
    summary["qmm_us_ref"] = us_ref
    summary["qmm_us_pallas"] = us_pal
    emit("quant/qmm_bf16_matmul", us_bf16, f"t={t};k={k};n={n}")
    emit("quant/qmm_ref", us_ref, f"t={t};k={k};n={n}")
    emit("quant/qmm_pallas", us_pal,
         f"t={t};k={k};n={n};tt={tun.token_tile};nt={tun.group_tile}")

    if TINY:
        write_summary("quant", summary)


if __name__ == "__main__":
    run()
