"""AdapterStore benchmark: thousand-tenant serving under a fixed HBM budget.

Builds N named adapters (methods round-robin over gsoft/boft/householder —
the mixed-method worst case for the padded representation), inserts them
into a host ``AdapterStore``, and serves them through ``ServeEngine`` over
a ``PagedAdapterBank`` holding far fewer resident:

  cold sweep   one request per tenant in shuffled order — every admission
               is a page-in; LRU eviction churns the compact regions
  hot revisit  a small tenant subset re-queried — measures the hit path
               and the host page cache (no bank_build on re-admission)

Correctness is checked in-line: a sample of tenants must produce greedy
tokens identical to a solo run with that tenant's adapter merged offline
(the paper's zero-overhead reference). The summary lands in
``BENCH_store.json``: hit rate, page-in p50/p95, max resident at the
budget, and resident-vs-padded bank bytes (slot compaction must be >= 2x
at N_methods=3).

``REPRO_BENCH_TINY=1``: 48 tenants / budget 12 for the CI smoke lane.
Full mode: 1000 tenants / budget 96 (<100 resident).
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.serve.engine import ServeEngine, StaticServeEngine
from repro.store import AdapterStore

from .common import emit, write_summary

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))

METHODS = ("gsoft", "boft", "householder")


def _tenant_adapters(params, cfg, seed, scale=0.25):
    ad = peft_lib.init_peft(cfg, params, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.fold_in(jax.random.PRNGKey(seed), 7), a.shape), ad)


def build_store(params, n_tenants):
    store = AdapterStore()
    cfgs = {m: peft_lib.PEFTConfig(method=m, block_size=8) for m in METHODS}
    for i in range(n_tenants):
        cfg = cfgs[METHODS[i % len(METHODS)]]
        store.add(f"tenant{i:04d}", _tenant_adapters(params, cfg, i + 1),
                  cfg)
    return store


def run():
    cfg = get_smoke_config("qwen2-72b")
    n_tenants = 48 if TINY else 1000
    budget = 12 if TINY else 96          # full mode: <100 resident of 1000
    hot = 6 if TINY else 32
    hot_rounds = 3
    check_sample = 4 if TINY else 8
    prompt = [3, 4, 5, 6]
    max_new = 4

    rt_base = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    store = build_store(rt_base.params, n_tenants)
    emit("store/build_host_store", 1e6 * (time.perf_counter() - t0),
         f"tenants={n_tenants};methods={len(METHODS)}")

    rt = rt_base.attach(store, hbm_budget=budget)
    eng = ServeEngine(rt, max_batch=4, max_len=32, eos_id=-1)

    rng = np.random.default_rng(0)
    order = rng.permutation(n_tenants)
    names = list(store.names)

    t0 = time.perf_counter()
    rids = {}
    for i in order:
        rids[names[i]] = eng.add_request(prompt, max_new_tokens=max_new,
                                         adapter=names[i])
    results = eng.run()
    cold_s = time.perf_counter() - t0
    emit("store/cold_sweep", 1e6 * cold_s / n_tenants,
         f"requests={n_tenants};evictions="
         f"{eng.adapter_stats()['evictions']};"
         f"stalls={eng.stats['admission_stalls']}")

    hot_names = [names[i] for i in rng.choice(n_tenants, size=hot,
                                              replace=False)]
    t0 = time.perf_counter()
    for _ in range(hot_rounds):
        hot_rids = [eng.add_request(prompt, max_new_tokens=max_new,
                                    adapter=n) for n in hot_names]
        hot_res = eng.run()
        for n, rid in zip(hot_names, hot_rids):
            assert hot_res[rid] == results[rids[n]], \
                f"tenant {n} diverged across evict->re-page cycles"
    hot_s = time.perf_counter() - t0
    stats = eng.adapter_stats()
    emit("store/hot_revisit", 1e6 * hot_s / (hot * hot_rounds),
         f"hit_rate={stats['hit_rate']:.2f};"
         f"build_cache_hits={stats['build_cache_hits']}")
    emit("store/page_in_latency", 1e3 * stats["page_in_ms_p50"],
         f"p95_ms={stats['page_in_ms_p95']:.1f};"
         f"builds={stats['builds']}")
    emit("store/residency", 0.0,
         f"max_resident={stats['max_resident']};capacity={stats['capacity']};"
         f"resident_mb={stats['resident_bank_bytes'] / 1e6:.2f};"
         f"padded_mb={stats['padded_bank_bytes'] / 1e6:.2f};"
         f"compaction={stats['compaction_ratio']:.2f}x")

    # -- correctness: sampled tenants vs solo offline-merged runs ------------
    sample = [names[i] for i in rng.choice(n_tenants, size=check_sample,
                                           replace=False)]
    for name in sample:
        solo = ModelRuntime(cfg, rt_base.params,
                            adapters=store.adapters_for(name),
                            peft_cfg=store.cfg_for(name))
        seng = StaticServeEngine(solo, max_batch=1, max_len=32, eos_id=-1)
        srid = seng.add_request(prompt, max_new_tokens=max_new)
        assert seng.run()[srid] == results[rids[name]], \
            f"tenant {name}: paged tokens != solo merged reference"
    emit("store/solo_equality", 0.0, f"sampled={check_sample};ok=1")

    assert stats["max_resident"] <= budget < n_tenants
    assert stats["compaction_ratio"] >= 2.0, \
        f"compaction {stats['compaction_ratio']:.2f}x < 2x at 3 methods"

    summary = {"backend": jax.default_backend(), "arch": cfg.name,
               "tenants": n_tenants, "hbm_budget": budget,
               "cold_sweep_s": cold_s, "hot_revisit_s": hot_s}
    summary.update({k: v for k, v in stats.items() if k != "methods"})
    write_summary("store", summary)


if __name__ == "__main__":
    run()
