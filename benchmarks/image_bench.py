"""Image serving benchmark: the 1-Lipschitz GS-SOC convnet served as a
registered stateless family with per-request orthogonal conv adapters
(ISSUE 9 acceptance).

Multi-tenant batched workload through ``ImageServeEngine`` (methods
round-robin over gsoft/givens/householder — channel-axis rotations of the
conv feature stream), measured AND verified:

  throughput   warmup-then-timed mixed-tenant run (images/s at the tick
               batch size), single engine and a 2-replica EngineCluster
  equality     every request's banked logits match its tenant's solo
               offline-merged run — argmax (predicted class) EQUAL, logits
               allclose — in f32, bf16, and over int8 base weights (the
               identity ``wc`` quantizes exactly; gsoft rides the fused
               rotate+quantized-matmul path)
  store-paged  the same workload over an AdapterStore-backed bank at a
               resident budget below the tenant count: outputs must equal
               the eager bank's bit for bit
  certified    margin-certified accuracy (radius 36/255) of the banked
               base (identity slot) must EQUAL the unbanked model's — the
               bank attaches without touching the Lipschitz certificate

Summary lands in ``BENCH_image.json``; ``REPRO_BENCH_TINY=1`` shrinks the
workload for the CI smoke lane.
"""
from __future__ import annotations

import collections
import os
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.conv import certified_radius
from repro.core.runtime import ModelRuntime
from repro.data.synthetic import image_batch
from repro.distrib import EngineCluster
from repro.models import registry
from repro.models.image import CERT_EPS
from repro.serve.image import ImageServeEngine
from repro.store import AdapterStore

from .common import emit, run_engine_timed, write_summary
from .table3_lipconvnet import _freeze_wc

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))

METHODS = ("gsoft", "givens", "householder")


def _tenants(params, n, scale=0.3):
    """n named conv-adapter tenants, methods round-robin (mixed bank)."""
    cfgs = {f"t{i}": peft_lib.PEFTConfig(method=METHODS[i % len(METHODS)],
                                         block_size=4)
            for i in range(n)}
    adapters = {}
    for i, (name, cfg) in enumerate(cfgs.items()):
        key = jax.random.PRNGKey(i + 1)
        ad = peft_lib.init_peft(cfg, params, key)
        adapters[name] = jax.tree.map(
            lambda a, k=key: a + scale * jax.random.normal(
                jax.random.fold_in(k, 7), a.shape), ad)
    return adapters, cfgs


def _workload(cfg, n_req, names, seed=0) -> List[Dict]:
    """Template-plus-noise images (the learnable class manifold) so a
    trained model's top-2 margins are decisive at every precision."""
    imgs = np.asarray(image_batch(cfg, n_req, seed=seed)["images"],
                      np.float32)
    return [{"prompt": imgs[i], "max_new_tokens": 1,
             "adapter": names[i % len(names)]} for i in range(n_req)]


def _pretrain(cfg, rt, steps=12):
    """A few margin-loss steps on the class manifold (``wc`` attachment
    points frozen, as in table3) — enough for nonzero certified accuracy,
    so the banked-vs-unbanked certificate check is not vacuously 0 == 0."""
    ops = registry.get(cfg.family)
    train = image_batch(cfg, 64, seed=2)
    ocfg = optim.OptimizerConfig(learning_rate=1e-3, weight_decay=0.0,
                                 grad_clip=0.5)
    params = rt.params
    opt = optim.init(ocfg, params)

    @jax.jit
    def step(p, o):
        (_, _), g = jax.value_and_grad(
            lambda q: ops.loss(cfg, q, train), has_aux=True)(p)
        p, o, _ = optim.update(ocfg, _freeze_wc(g), o, p)
        return p, o

    for _ in range(steps):
        params, opt = step(params, opt)
    return ModelRuntime(cfg, params)


def _serve_logits(eng, workload) -> Dict[int, np.ndarray]:
    """{workload index: logits} through an engine (or cluster of them)."""
    rids = [eng.add_request(**req) for req in workload]
    eng.run()
    if isinstance(eng, EngineCluster):
        by_rid = {r.rid: r.logits for r in eng.drain_finished()}
    else:
        by_rid = dict(eng.result_logits)
        eng.drain_finished()
    return {i: by_rid[rid] for i, rid in enumerate(rids)}


def _solo_logits(cfg, params, adapters, cfgs, workload,
                 quantize: Optional[str] = None) -> Dict[int, np.ndarray]:
    """Per-tenant offline-merged reference: one ModelRuntime per adapter
    (identity slot -> the bare model), whole tenant batch in one forward."""
    by_name = collections.defaultdict(list)
    for i, req in enumerate(workload):
        by_name[req["adapter"]].append(i)
    out = {}
    for name, idxs in by_name.items():
        rt = (ModelRuntime(cfg, params) if name is None else
              ModelRuntime(cfg, params, adapters=adapters[name],
                           peft_cfg=cfgs[name]))
        if quantize:
            rt = rt.quantized(quantize)
        imgs = np.stack([workload[i]["prompt"] for i in idxs])
        logits = np.asarray(rt.infer(jnp.asarray(imgs)))
        for j, i in enumerate(idxs):
            out[i] = logits[j]
    return out


def _assert_equal(banked: Dict[int, np.ndarray], solo: Dict[int, np.ndarray],
                  atol: float, tag: str):
    """Logits within ``atol``; predicted class EQUAL on every request whose
    solo top-2 margin exceeds ``2*atol`` — below that the argmax is
    undetermined at this precision (the same margin-beats-radius rule the
    Lipschitz certificate applies). Returns (max |diff|, decisive count)."""
    worst, decisive = 0.0, 0
    for i, b in banked.items():
        b = b.astype(np.float32)
        s = solo[i].astype(np.float32)
        worst = max(worst, float(np.abs(b - s).max()))
        top2 = np.sort(s)[-2:]
        if top2[1] - top2[0] > 2 * atol:
            decisive += 1
            assert int(b.argmax()) == int(s.argmax()), \
                f"{tag}: request {i} class {b.argmax()} != solo {s.argmax()}"
    assert worst <= atol, f"{tag}: max logits diff {worst:.2e} > {atol}"
    return worst, decisive


def _cert_acc(logits: np.ndarray, labels: np.ndarray) -> float:
    correct = logits.argmax(-1) == labels
    radii = np.asarray(certified_radius(jnp.asarray(logits)))
    return float(np.mean((radii > CERT_EPS) & correct))


def run():
    cfg = get_smoke_config("lipconvnet-15")          # f32
    n_tenants = 6 if TINY else 12
    n_req = 24 if TINY else 96
    max_batch = 4 if TINY else 8
    budget = 3 if TINY else 6                        # < n_tenants: paging

    base = _pretrain(cfg, ModelRuntime(cfg, key=jax.random.PRNGKey(0)))
    adapters, cfgs = _tenants(base.params, n_tenants)
    names = [None] + list(cfgs)                      # identity slot serves
    workload = _workload(cfg, n_req, names)          # the base model
    warmup = _workload(cfg, max_batch, names, seed=1)

    # -- throughput: eager mixed-method bank ---------------------------------
    brt = base.attach(adapters, cfgs)
    res = run_engine_timed(lambda: ImageServeEngine(brt, max_batch=max_batch),
                           warmup, workload)
    emit("image/eager_serve", 1e6 / max(res["tok_s"], 1e-9),
         f"img_s={res['tok_s']:.1f};ticks={res['decode_steps']};"
         f"util={res['util']:.2f};p95_ms={res['p95_ms']:.0f}")

    # -- equality vs solo merged: f32, bf16, int8 ----------------------------
    banked = _serve_logits(ImageServeEngine(brt, max_batch=max_batch),
                           workload)
    d32, n32 = _assert_equal(banked, _solo_logits(cfg, base.params, adapters,
                                                  cfgs, workload),
                             1e-5, "f32")
    assert n32 == n_req, "f32 margins must all be decisive"
    emit("image/banked_vs_solo_f32", 0.0,
         f"requests={n_req};max_diff={d32:.2e};decisive={n32}")

    bf16 = cfg.with_overrides(dtype="bf16")
    brt16 = ModelRuntime(bf16, base.params).attach(adapters, cfgs)
    banked16 = _serve_logits(ImageServeEngine(brt16, max_batch=max_batch),
                             workload)
    d16, n16 = _assert_equal(banked16, _solo_logits(bf16, base.params,
                                                    adapters, cfgs, workload),
                             0.06, "bf16")
    emit("image/banked_vs_solo_bf16", 0.0,
         f"max_diff={d16:.2e};decisive={n16}")

    qrt = brt.quantized("int8")
    bankedq = _serve_logits(ImageServeEngine(qrt, max_batch=max_batch),
                            workload)
    dq, nq = _assert_equal(bankedq, _solo_logits(cfg, base.params, adapters,
                                                 cfgs, workload,
                                                 quantize="int8"),
                           0.08, "int8")
    emit("image/banked_vs_solo_int8", 0.0, f"max_diff={dq:.2e};decisive={nq}")

    # -- store-paged bank below tenant count ---------------------------------
    store = AdapterStore.from_adapters(adapters, cfgs)
    srt = base.attach(store, hbm_budget=budget)
    seng = ImageServeEngine(srt, max_batch=max_batch)
    paged = _serve_logits(seng, workload)
    for i in range(n_req):
        np.testing.assert_array_equal(
            paged[i], banked[i],
            err_msg=f"request {i}: store-paged logits != eager bank")
    astats = seng.adapter_stats()
    emit("image/store_paged", 0.0,
         f"budget={budget};tenants={n_tenants};"
         f"evictions={astats['evictions']};"
         f"stalls={seng.stats['admission_stalls']};exact=1")

    # -- certified accuracy: banked base == unbanked -------------------------
    labeled = image_batch(cfg, 32 if TINY else 128, seed=3)
    imgs = np.asarray(labeled["images"])
    labels = np.asarray(labeled["labels"])
    plain = np.asarray(ModelRuntime(cfg, base.params).infer(
        jnp.asarray(imgs)))
    base_load = [{"prompt": imgs[i], "max_new_tokens": 1, "adapter": None}
                 for i in range(len(imgs))]
    banked_base = _serve_logits(ImageServeEngine(brt, max_batch=max_batch),
                                base_load)
    stack = np.stack([banked_base[i] for i in range(len(imgs))])
    np.testing.assert_array_equal(
        stack, plain, err_msg="identity-slot banked logits != unbanked")
    cert = _cert_acc(plain, labels)
    assert cert > 0.0, "pretrained base should certify some of the manifold"
    assert _cert_acc(stack, labels) == cert
    emit("image/certified_base", 0.0,
         f"cert_acc={cert:.3f};radius={CERT_EPS:.4f};exact=1")

    # -- 2-replica cluster over the shared eager bank ------------------------
    cluster = EngineCluster([ImageServeEngine(brt, max_batch=max_batch)
                             for _ in range(2)])
    clustered = _serve_logits(cluster, workload)
    for i in range(n_req):
        np.testing.assert_array_equal(
            clustered[i], banked[i],
            err_msg=f"request {i}: cluster logits != single engine")
    emit("image/cluster_2x", 0.0,
         f"routed={cluster.routing['routed']};"
         f"hits={cluster.routing['affinity_hits']};exact=1")

    write_summary("image", {
        "backend": jax.default_backend(), "arch": cfg.name,
        "tenants": n_tenants, "requests": n_req, "max_batch": max_batch,
        "img_s": res["tok_s"], "p50_ms": res["p50_ms"],
        "p95_ms": res["p95_ms"], "util": res["util"],
        "max_diff_f32": d32, "max_diff_bf16": d16, "max_diff_int8": dq,
        "decisive_f32": n32, "decisive_bf16": n16, "decisive_int8": nq,
        "store_budget": budget, "store_evictions": astats["evictions"],
        "cert_acc_base": cert,
    })


if __name__ == "__main__":
    run()
