"""Adapter-method comparison benchmark — one row set per registered
``core.methods`` entry (the registry is the source of truth; a newly
registered parametrization shows up here with zero edits):

  * adapter parameter count on the smoke config's adapted weights
    (the PEFT-efficiency axis the paper's Table 1 argues about),
  * merged-rotation orthogonality error ``max |Q^T Q - I|`` on random
    params (orthogonal methods; the correctness axis),
  * banked serving throughput (tok/s) through ``ServeEngine`` for every
    bankable method — each method serves a single-tenant bank over the
    same mixed-length workload — plus one MIXED bank row where all
    bankable methods serve side by side (the heterogeneous-bank path).

``REPRO_BENCH_TINY=1`` shrinks the workload for the CI smoke lane and
writes a ``BENCH_methods.json`` summary at the repo root (uploaded as a CI
artifact so the per-method trajectory is tracked PR-over-PR).
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.config import get_smoke_config
from repro.core import adapters as ad
from repro.core import methods as methods_lib
from repro.core import peft as peft_lib
from repro.core.orthogonal import orthogonality_error
from repro.core.runtime import ModelRuntime
from repro.kernels.dispatch import banked_key_fn
from repro.serve.engine import ServeEngine

from .common import emit, mixed_workload, run_engine_timed, write_summary

TINY = bool(os.environ.get("REPRO_BENCH_TINY"))


def _method_cfg(method: str) -> peft_lib.PEFTConfig:
    return peft_lib.PEFTConfig(method=method, block_size=8, reflections=4)


def _tuned_adapters(cfg, params, seed, scale=0.2):
    adp = peft_lib.init_peft(cfg, params, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.PRNGKey(seed + 31), a.shape), adp)


def run():
    cfg = get_smoke_config("qwen2-72b")
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0))
    summary = {"backend": jax.default_backend(), "arch": cfg.name,
               "methods": {}}

    n_req = 8 if TINY else 24
    prompt_hi, max_new_hi = (10, 8) if TINY else (24, 24)
    max_len = prompt_hi + max_new_hi + 8
    d = 64
    workload = mixed_workload(n_req, prompt_hi, max_new_hi, seed=0)

    for method in methods_lib.registered():
        ops = methods_lib.get(method)
        mcfg = _method_cfg(method)
        row = {"orthogonal": ops.orthogonal,
               "bankable": ops.bank_build is not None,
               "quant_compatible": ops.quant_compatible,
               # which dispatch key family the banked transform rides
               # (None = reference-einsum fallback, nothing to autotune)
               "banked_kernel": (ops.banked_kernel
                                 if banked_key_fn(ops.banked_kernel)
                                 else None)}

        # parameter count over the smoke config's adapted weights
        specs = peft_lib.adapted_paths(mcfg, rt.params)
        row["params"] = sum(ad.num_adapter_params(s) for s in specs.values())
        emit(f"methods/{method}_params", 0.0, f"n={row['params']}")

        # merged orthogonality error on random (non-identity) params
        if ops.orthogonal:
            spec = peft_lib.spec_for(mcfg, (d, d))
            p = ad.init_adapter(spec, jax.random.PRNGKey(1))
            p = jax.tree.map(
                lambda a: a + 0.3 * jax.random.normal(
                    jax.random.PRNGKey(2), a.shape), p)
            err = float(orthogonality_error(
                ad.merge(spec, p, jnp.eye(d, dtype=jnp.float32))))
            row["orthogonality_error"] = err
            emit(f"methods/{method}_orth_err", 0.0, f"err={err:.2e}")

        # banked serving throughput (single-tenant bank per method)
        if ops.bank_build is not None:
            adapters = {"t": _tuned_adapters(mcfg, rt.params, seed=5)}
            brt = rt.attach(adapters, mcfg)
            wl = [dict(req, adapter="t") for req in workload]
            r = run_engine_timed(
                lambda: ServeEngine(brt, max_batch=4, max_len=max_len,
                                    eos_id=-1), wl, wl)
            row["banked_tok_s"] = r["tok_s"]
            emit(f"methods/{method}_banked",
                 1e6 * r["dt"] / max(r["tokens"], 1),
                 f"tok/s={r['tok_s']:.1f};decode_steps={r['decode_steps']}")
        summary["methods"][method] = row

    # heterogeneous bank: every bankable method serves side by side
    mixed_cfgs = {f"t_{m}": _method_cfg(m)
                  for m in methods_lib.registered()
                  if methods_lib.get(m).bank_build is not None}
    adapters = {name: _tuned_adapters(c, rt.params, seed=11 + i)
                for i, (name, c) in enumerate(mixed_cfgs.items())}
    brt = rt.attach(adapters, mixed_cfgs)
    tenants = list(adapters) + [None]
    wl = [dict(req, adapter=tenants[i % len(tenants)])
          for i, req in enumerate(workload)]
    r = run_engine_timed(
        lambda: ServeEngine(brt, max_batch=4, max_len=max_len, eos_id=-1),
        wl, wl)
    summary["mixed_bank"] = {"methods": sorted(brt.bank.bank_methods),
                             "tok_s": r["tok_s"]}
    emit("methods/mixed_bank", 1e6 * r["dt"] / max(r["tokens"], 1),
         f"tok/s={r['tok_s']:.1f};methods={'+'.join(summary['mixed_bank']['methods'])}")

    if TINY:
        write_summary("methods", summary)


if __name__ == "__main__":
    run()
