"""Subprocess child for the serve-time TP scaling lane (ISSUE 8).

JAX reads ``XLA_FLAGS`` once at backend init, so every mesh geometry
needs a FRESH process: the parent lane (``kv_bench._lane_tp``) launches
this module once per ``--tp`` and parses the single ``RESULT {json}``
line. The flag is set here, before the first ``import jax``, so the lane
works no matter how the parent was launched. All geometries run under the
same forced device count — tp=1 is the same backend minus the mesh, so
the curve compares sharding, not backend configuration.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--devices", type=int, default=8)
    ap.add_argument("--n-req", type=int, default=12)
    ap.add_argument("--page-size", type=int, default=8)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    args = ap.parse_args()

    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={args.devices}").strip()

    import jax  # noqa: E402  (after XLA_FLAGS — this initializes the backend)

    from repro.config import get_smoke_config
    from repro.core.runtime import ModelRuntime
    from repro.distrib import serve_mesh
    from repro.serve.engine import PagedServeEngine

    from benchmarks.common import mixed_workload, run_engine_timed

    cfg = get_smoke_config("qwen2-72b")
    mesh = serve_mesh(args.tp) if args.tp > 1 else None
    rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0), mesh=mesh)

    prompt_hi, new_hi = 24, 12
    max_len = prompt_hi + new_hi + 8
    wl = mixed_workload(args.n_req, prompt_hi, new_hi, seed=7)
    make = lambda: PagedServeEngine(rt, max_batch=4, max_len=max_len,
                                    eos_id=-1, page_size=args.page_size,
                                    prefill_chunk=args.prefill_chunk)
    r = run_engine_timed(make, wl, wl)

    # a full greedy transcript rides along so the parent can assert the
    # sharded computation is token-identical to the single-device one
    probe = make()
    rids = [probe.add_request(**req) for req in wl]
    res = probe.run()
    sys.stdout.flush()
    print("RESULT " + json.dumps({
        "tp": args.tp, "devices": jax.device_count(),
        "tok_s": r["tok_s"], "tokens": r["tokens"],
        "decode_steps": r["decode_steps"],
        "outputs": [res[rid] for rid in rids]}), flush=True)


if __name__ == "__main__":
    main()
