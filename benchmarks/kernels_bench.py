"""Kernel-layer benchmarks (CPU container: XLA ref path timed for the
structural win; Pallas bodies validated in interpret mode + VMEM budgets
reported from BlockSpec math — real speed is a TPU measurement).

Forward AND forward+backward are timed for both dispatch paths, so the
"kernels are training primitives" claim is measured, not asserted.  Set
REPRO_BENCH_TINY=1 (the CI smoke lane) to shrink shapes/iters to
seconds-scale — the point of the smoke run is that every benchmark still
*executes*, not the numbers.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, ops, ref
from .common import emit, time_fn


def _tiny() -> bool:
    return bool(os.environ.get("REPRO_BENCH_TINY"))


def _pallas_label() -> str:
    # off-TPU the kernel path runs in the Pallas interpreter: correctness
    # coverage, not a speed claim
    return "pallas" if jax.default_backend() == "tpu" else "pallas_interpret"


def gs_vs_dense():
    """GS rotation (2*d*b*T flops) vs dense rotation (d^2*T flops).
    Arrays are passed as jit ARGUMENTS (closing over them lets XLA
    constant-fold the entire benchmark away)."""
    cases = [(256, 16)] if _tiny() else [(1024, 32), (4096, 64)]
    iters = 3 if _tiny() else 10
    for d, b in cases:
        r = d // b
        T = 64 if _tiny() else 256
        key = jax.random.PRNGKey(0)
        L = jax.random.normal(key, (r, b, b))
        R = jax.random.normal(jax.random.fold_in(key, 1), (r, b, b))
        x = jax.random.normal(jax.random.fold_in(key, 2), (T, d))
        Q = jax.random.normal(jax.random.fold_in(key, 3), (d, d))
        us_gs = time_fn(jax.jit(lambda l, rr, xx:
                                ops.gs_transform(l, rr, xx)), L, R, x,
                        iters=iters)
        us_dense = time_fn(jax.jit(lambda xx, q: xx @ q), x, Q, iters=iters)
        emit(f"kernels/gs_vs_dense_d{d}_b{b}", us_gs,
             f"dense_us={us_dense:.1f};speedup={us_dense / us_gs:.2f}x;"
             f"flop_ratio={d / (2 * b):.0f}x")


def gs_fwd_bwd():
    """Forward and forward+backward GSOFT rotation through both dispatch
    paths (ref = XLA autodiff; pallas = custom-VJP kernels)."""
    cases = [(128, 8, 32)] if _tiny() else [(1024, 32, 256), (2048, 64, 256)]
    iters = 3 if _tiny() else 10
    label = _pallas_label()
    for d, b, T in cases:
        r = d // b
        key = jax.random.PRNGKey(1)
        L = jax.random.normal(key, (r, b, b))
        R = jax.random.normal(jax.random.fold_in(key, 1), (r, b, b))
        x = jax.random.normal(jax.random.fold_in(key, 2), (T, d))

        for up, path in ((False, "ref"), (True, label)):
            fwd = jax.jit(lambda l, rr, xx, _up=up:
                          ops.gs_transform(l, rr, xx, use_pallas=_up))
            us_f = time_fn(fwd, L, R, x, iters=iters)

            def loss(l, rr, xx, _up=up):
                return jnp.sum(ops.gs_transform(l, rr, xx,
                                                use_pallas=_up) ** 2)
            bwd = jax.jit(jax.grad(loss, argnums=(0, 1, 2)))
            us_fb = time_fn(bwd, L, R, x, iters=iters)
            emit(f"kernels/gs_fwd_d{d}_b{b}_{path}", us_f, f"T={T}")
            emit(f"kernels/gs_fwdbwd_d{d}_b{b}_{path}", us_fb,
                 f"T={T};fwd_us={us_f:.1f}")


def bdmm_fwd_bwd():
    """Forward and forward+backward block-diagonal matmul, both paths."""
    cases = [(8, 8, 64)] if _tiny() else [(32, 32, 512), (64, 64, 512)]
    iters = 3 if _tiny() else 10
    label = _pallas_label()
    for r, b, T in cases:
        key = jax.random.PRNGKey(2)
        blocks = jax.random.normal(key, (r, b, b))
        x = jax.random.normal(jax.random.fold_in(key, 1), (T, r * b))
        for up, path in ((False, "ref"), (True, label)):
            fwd = jax.jit(lambda w, xx, _up=up:
                          ops.bdmm(w, xx, use_pallas=_up))
            us_f = time_fn(fwd, blocks, x, iters=iters)

            def loss(w, xx, _up=up):
                return jnp.sum(ops.bdmm(w, xx, use_pallas=_up) ** 2)
            bwd = jax.jit(jax.grad(loss, argnums=(0, 1)))
            us_fb = time_fn(bwd, blocks, x, iters=iters)
            emit(f"kernels/bdmm_fwd_r{r}_b{b}_{path}", us_f, f"T={T}")
            emit(f"kernels/bdmm_fwdbwd_r{r}_b{b}_{path}", us_fb,
                 f"T={T};fwd_us={us_f:.1f}")


def autotune_smoke():
    """Exercise the dispatch autotuner (eager timing search + cache)."""
    r, b, T = (2, 4, 16) if _tiny() else (8, 32, 128)
    tun = dispatch.autotune_gs(r, b, T, token_tiles=(8, 32), iters=1)
    emit(f"kernels/autotune_gs_r{r}_b{b}", 0.0,
         f"token_tile={tun.token_tile}")
    tun_b = dispatch.autotune_bdmm(r, b, b, T, token_tiles=(8, 32), iters=1)
    emit(f"kernels/autotune_bdmm_r{r}_b{b}", 0.0,
         f"token_tile={tun_b.token_tile};group_tile={tun_b.group_tile}")
    dispatch.clear_tunings()


def ssd_vs_quadratic():
    """Chunked SSD scan vs materialized quadratic attention-form."""
    T, H, P, N = (256, 2, 16, 16) if _tiny() else (2048, 4, 64, 64)
    iters = 2 if _tiny() else 5
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (T, H, P))
    loga = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (T, H))) * .1
    B = jax.random.normal(jax.random.fold_in(key, 2), (T, H, N)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 3), (T, H, N)) * 0.3
    us_chunk = time_fn(
        jax.jit(lambda *a: ops.ssd(*a, chunk=128)), x, loga, B, C, iters=iters)

    def quad(xx, la, Bm, Cm):
        cum = jnp.cumsum(la, 0)
        gam = jnp.tril(jnp.exp(cum[:, None] - cum[None, :]).transpose(2, 0, 1))
        s = jnp.einsum("thn,shn->hts", Cm, Bm) * gam
        return jnp.einsum("hts,shp->thp", s, xx)
    us_quad = time_fn(jax.jit(quad), x, loga, B, C, iters=iters)
    emit("kernels/ssd_chunk_vs_quadratic", us_chunk,
         f"quadratic_us={us_quad:.1f};speedup={us_quad / us_chunk:.2f}x;T={T}")


def vmem_budgets():
    """Static VMEM working sets implied by the kernels' BlockSpecs."""
    for name, bytes_ in [
        ("bdmm_tt128_b32_g4", 128 * 4 * 32 * 4 * 2 + 4 * 32 * 32 * 4),
        ("gs_fused_tt128_d8192_b64",
         128 * 8192 * 4 * 2 + 2 * 8192 * 64 * 4),
        ("gs_bwd_tt128_d8192_b64",      # dy + x slabs, dx out, 2 fp32 grads
         128 * 8192 * 4 * 3 + 4 * 8192 * 64 * 4),
        ("ssd_q64_n128_p64", 64 * (64 + 2 * 128) * 4 + 128 * 64 * 4),
    ]:
        emit(f"kernels/vmem_{name}", 0.0,
             f"vmem_bytes={bytes_};fits_16MiB={bytes_ < 16 * 2**20}")


def run():
    gs_vs_dense()
    gs_fwd_bwd()
    bdmm_fwd_bwd()
    autotune_smoke()
    ssd_vs_quadratic()
    vmem_budgets()
