"""Kernel-layer benchmarks (CPU container: XLA ref path timed for the
structural win; Pallas bodies validated in interpret mode + VMEM budgets
reported from BlockSpec math — real speed is a TPU measurement)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .common import emit, time_fn


def gs_vs_dense():
    """GS rotation (2*d*b*T flops) vs dense rotation (d^2*T flops).
    Arrays are passed as jit ARGUMENTS (closing over them lets XLA
    constant-fold the entire benchmark away)."""
    for d, b in [(1024, 32), (4096, 64)]:
        r = d // b
        T = 256
        key = jax.random.PRNGKey(0)
        L = jax.random.normal(key, (r, b, b))
        R = jax.random.normal(jax.random.fold_in(key, 1), (r, b, b))
        x = jax.random.normal(jax.random.fold_in(key, 2), (T, d))
        Q = jax.random.normal(jax.random.fold_in(key, 3), (d, d))
        us_gs = time_fn(jax.jit(lambda l, rr, xx:
                                ops.gs_transform(l, rr, xx)), L, R, x,
                        iters=10)
        us_dense = time_fn(jax.jit(lambda xx, q: xx @ q), x, Q, iters=10)
        emit(f"kernels/gs_vs_dense_d{d}_b{b}", us_gs,
             f"dense_us={us_dense:.1f};speedup={us_dense / us_gs:.2f}x;"
             f"flop_ratio={d / (2 * b):.0f}x")


def ssd_vs_quadratic():
    """Chunked SSD scan vs materialized quadratic attention-form."""
    T, H, P, N = 2048, 4, 64, 64
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (T, H, P))
    loga = -jnp.abs(jax.random.normal(jax.random.fold_in(key, 1), (T, H))) * .1
    B = jax.random.normal(jax.random.fold_in(key, 2), (T, H, N)) * 0.3
    C = jax.random.normal(jax.random.fold_in(key, 3), (T, H, N)) * 0.3
    us_chunk = time_fn(
        jax.jit(lambda *a: ops.ssd(*a, chunk=128)), x, loga, B, C, iters=5)

    def quad(xx, la, Bm, Cm):
        cum = jnp.cumsum(la, 0)
        gam = jnp.tril(jnp.exp(cum[:, None] - cum[None, :]).transpose(2, 0, 1))
        s = jnp.einsum("thn,shn->hts", Cm, Bm) * gam
        return jnp.einsum("hts,shp->thp", s, xx)
    us_quad = time_fn(jax.jit(quad), x, loga, B, C, iters=5)
    emit("kernels/ssd_chunk_vs_quadratic", us_chunk,
         f"quadratic_us={us_quad:.1f};speedup={us_quad / us_chunk:.2f}x;T={T}")


def vmem_budgets():
    """Static VMEM working sets implied by the kernels' BlockSpecs."""
    for name, bytes_ in [
        ("bdmm_tt128_b32_g4", 128 * 4 * 32 * 4 * 2 + 4 * 32 * 32 * 4),
        ("gs_fused_tt128_d8192_b64",
         128 * 8192 * 4 * 2 + 2 * 8192 * 64 * 4),
        ("ssd_q64_n128_p64", 64 * (64 + 2 * 128) * 4 + 128 * 64 * 4),
    ]:
        emit(f"kernels/vmem_{name}", 0.0,
             f"vmem_bytes={bytes_};fits_16MiB={bytes_ < 16 * 2**20}")


def run():
    gs_vs_dense()
    ssd_vs_quadratic()
    vmem_budgets()
