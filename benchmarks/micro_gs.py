"""GS micro-benchmarks: the paper's §5.2 density/efficiency claims.

  * Theorem 2 factor counts: m_GS = 1 + ceil(log_b r) vs
    m_butterfly = 1 + ceil(log2 r) (verified by materializing supports)
  * paper's 1024/b=32 example: 2 factors (2*32^3*32 params) vs 6 butterfly
    factors (6x params) — measured apply time GS vs BOFT vs dense Q
  * orthogonality error of the Cayley-GS parametrization at bf16/f32
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import adapters as ad
from repro.core import gs
from repro.core.orthogonal import orthogonal_blocks, orthogonality_error
from .common import emit, time_fn


def density_table():
    rows = []
    for b, r in [(4, 16), (8, 64), (32, 32), (16, 256)]:
        m_gs = gs.min_factors_dense(b, r)
        m_bf = 1 + math.ceil(math.log2(r))
        dense = gs.is_dense_class(gs.gs_order_layout(b * r, b, m_gs))
        thin = (not gs.is_dense_class(gs.gs_order_layout(b * r, b, m_gs - 1))
                if m_gs > 1 else True)
        rows.append((b, r, m_gs, m_bf, dense, thin))
        emit(f"micro/density_b{b}_r{r}", 0.0,
             f"m_gs={m_gs};m_butterfly={m_bf};dense_at_m={dense};"
             f"not_dense_below={thin}")
    return rows


def apply_time():
    d, b = 1024, 32
    T = 512
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (T, d))
    W = jax.random.normal(jax.random.fold_in(key, 9), (d, d))

    spec_gs = ad.AdapterSpec("gsoft", d, d, block_size=b)
    spec_oft = ad.AdapterSpec("oft", d, d, block_size=b)
    spec_bf = ad.AdapterSpec("boft", d, d, block_size=b, boft_factors=6)
    results = {}
    for name, spec in [("gsoft_m2", spec_gs), ("oft", spec_oft),
                       ("boft_m6", spec_bf)]:
        p = ad.init_adapter(spec, key)
        p = jax.tree.map(lambda v: jax.random.normal(
            jax.random.fold_in(key, 7), v.shape) * 0.1, p)
        f = jax.jit(lambda pp: ad.materialize(spec, pp, W))
        us = time_fn(f, p, iters=10)
        n = ad.num_adapter_params(spec)
        Q = np.asarray(ad.materialize(spec, p, jnp.eye(d)))
        dense_frac = float((np.abs(Q) > 1e-9).mean())
        results[name] = us
        emit(f"micro/apply_{name}", us,
             f"params={n};dense_frac={dense_frac:.3f}")
    emit("micro/claim_m2_cheaper_than_m6", 0.0,
         f"ok={results['gsoft_m2'] < results['boft_m6']};"
         f"speedup={results['boft_m6'] / results['gsoft_m2']:.2f}x")
    return results


def orthogonality():
    for dtype, name in [(jnp.float32, "f32"), (jnp.bfloat16, "bf16")]:
        k = jax.random.normal(jax.random.PRNGKey(2), (32, 32, 32),
                              jnp.float32) * 0.3
        q = orthogonal_blocks(k.astype(dtype))
        err = float(orthogonality_error(q.astype(jnp.float32)))
        emit(f"micro/orthogonality_{name}", 0.0, f"max_err={err:.2e}")


def run():
    density_table()
    apply_time()
    orthogonality()
