"""Benchmark utilities: timing, the assignment's CSV contract
(``name,us_per_call,derived``), the shared serving-benchmark protocol
(mixed-length workload generation + warmup-then-timed engine runs) so the
serve and quant lanes measure with ONE methodology, and the
``BENCH_*.json`` trajectory writer (``write_summary`` — every run APPENDS
to a per-suite history instead of overwriting it, so the perf trajectory
across PRs is actually recorded)."""
from __future__ import annotations

import datetime
import json
import pathlib
import time
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

ROWS: List[str] = []

REPO_ROOT = pathlib.Path(__file__).resolve().parents[1]


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def write_summary(suite: str, summary: Dict) -> pathlib.Path:
    """Persist one suite run to ``BENCH_<suite>.json`` WITHOUT discarding
    prior runs: ``latest`` mirrors the newest summary (what dashboards and
    quick greps read) and ``history`` accumulates timestamped entries —
    the PR-over-PR perf trajectory. A pre-history flat file (one bare
    summary dict) is adopted as the history's first entry."""
    out = REPO_ROOT / f"BENCH_{suite}.json"
    history: List[Dict] = []
    if out.exists():
        try:
            prev = json.loads(out.read_text())
        except ValueError:
            prev = None
        if isinstance(prev, dict):
            if "history" in prev:
                history = list(prev["history"])
            else:                       # migrate the old wholesale format
                history = [prev]
    entry = dict(summary)
    entry["ts"] = datetime.datetime.now(
        datetime.timezone.utc).isoformat(timespec="seconds")
    history.append(entry)
    out.write_text(json.dumps({"latest": summary, "history": history},
                              indent=2, sort_keys=True))
    print(f"# wrote {out} ({len(history)} run(s) in history)", flush=True)
    return out


def mixed_workload(n_req: int, prompt_hi: int, max_new_hi: int, seed: int = 0,
                   adapters: Optional[List] = None) -> List[Dict]:
    """Ragged prompts U[4, prompt_hi] + ragged budgets U[2, max_new_hi] —
    the traffic shape continuous batching exists for. ``adapters`` (bank
    names, may include None) round-robin over the requests."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_req):
        req = {"prompt": rng.integers(
                   1, 200, size=int(rng.integers(4, prompt_hi + 1))).tolist(),
               "max_new_tokens": int(rng.integers(2, max_new_hi + 1))}
        if adapters:
            req["adapter"] = adapters[i % len(adapters)]
        reqs.append(req)
    return reqs


def run_engine_timed(make_engine: Callable, warmup: List[Dict],
                     workload: List[Dict]) -> Dict:
    """The serving-bench protocol: run ``warmup`` first so every shape the
    scheduler will see (prefill buckets / per-batch pads) is compiled, then
    time ``workload`` — the measurement is scheduling + math, not
    retracing. Returns tok/s, decode-step and latency stats."""
    from repro.serve.engine import latency_percentiles
    eng = make_engine()
    for req in warmup:
        eng.add_request(**req)
    eng.run()
    eng.drain_finished()
    steps0, toks0 = eng.stats["decode_steps"], eng.stats["tokens_generated"]
    for req in workload:
        eng.add_request(**req)
    t0 = time.perf_counter()
    eng.run()
    dt = time.perf_counter() - t0
    toks = eng.stats["tokens_generated"] - toks0
    steps = eng.stats["decode_steps"] - steps0
    lat = latency_percentiles(eng.drain_finished())
    return {"tok_s": toks / max(dt, 1e-9), "dt": dt, "tokens": toks,
            "decode_steps": steps,
            "util": toks / max(steps * eng.max_batch, 1),
            "p50_ms": lat[50] * 1e3, "p95_ms": lat[95] * 1e3}


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
