"""Benchmark utilities: timing + the assignment's CSV contract
(``name,us_per_call,derived``)."""
from __future__ import annotations

import time
from typing import Callable, List

import jax

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_fn(fn: Callable, *args, iters: int = 10, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6
