"""Paper Table 1 (GLUE, RoBERTa-base) — proxy reproduction.

Offline container => no GLUE/pretrained RoBERTa; we reproduce the table's
*measurable* claims on a scaled-down encoder + synthetic classification
task with a FROZEN random backbone (PEFT must rotate frozen features):

  * all five methods (FT / LoRA / OFT / BOFT / GSOFT) train through the
    same engine; eval accuracy after a fixed budget is the figure of merit
  * adapter parameter budgets match the paper's formulas exactly
    (GSOFT_b == BOFT_{m=2,b} == 2*d*b per weight; LoRA_r = r*(din+dout))
  * GSOFT >= OFT at equal parameter budget (dense vs block-diag Q) is the
    paper's central comparison
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import peft as peft_lib
from repro.models.encoder import (classifier_loss, encoder_config,
                                  init_encoder_classifier)
from .common import emit, time_fn

CFG = encoder_config(num_layers=2, d_model=64, num_heads=4, d_ff=128,
                     vocab_size=64)
NUM_CLASSES = 4
STEPS = 250
BATCH = 64
SEQ = 12


def make_task(key, n):
    """Synthetic 'GLUE' task: label = last-token class, read out at the CLS
    position. The rule is trivial; the *routing* (moving last-token identity
    across the frozen backbone to the CLS readout) is what the adapters must
    re-wire — the paper's feature-rotation story. Batches stream fresh from
    the key (no memorization shortcut)."""
    toks = jax.random.randint(key, (n, SEQ), 0, CFG.vocab_size)
    labels = toks[:, -1] % NUM_CLASSES
    return {"tokens": toks, "labels": labels}


METHODS = {
    "FT": None,
    "LoRA_r8": peft_lib.PEFTConfig(method="lora", rank=8, alpha=16),
    "OFT_b16": peft_lib.PEFTConfig(method="oft", block_size=16),
    "BOFT_m2_b8": peft_lib.PEFTConfig(method="boft", block_size=8,
                                      boft_factors=2),
    "GSOFT_b8": peft_lib.PEFTConfig(method="gsoft", block_size=8),
}


def run_method(name, pcfg):
    key = jax.random.PRNGKey(0)
    params = init_encoder_classifier(CFG, NUM_CLASSES, key)
    test = make_task(jax.random.PRNGKey(2), 512)

    if pcfg is None:
        trainable, frozen = params, {}
        def materialize(t):
            return t
        n_params = peft_lib.count_params(params)
    else:
        adapters = peft_lib.init_peft(pcfg, params, jax.random.PRNGKey(3))
        # head must always train for classification
        trainable = {"adapters": adapters, "head": params["head"]}
        frozen = params

        def materialize(t):
            eff = peft_lib.materialize_tree(pcfg, frozen, t["adapters"])
            return {**eff, "head": t["head"]}
        n_params = peft_lib.count_params(adapters)

    ocfg = optim.OptimizerConfig(learning_rate=5e-3 if pcfg else 1e-3)
    opt_state = optim.init(ocfg, trainable)

    @jax.jit
    def step(tr, opt, batch):
        def loss_fn(t):
            return classifier_loss(CFG, materialize(t), batch)
        (l, m), g = jax.value_and_grad(loss_fn, has_aux=True)(tr)
        tr, opt, _ = optim.update(ocfg, g, opt, tr)
        return tr, opt, m

    @jax.jit
    def evaluate(tr, batch):
        return classifier_loss(CFG, materialize(tr), batch)[1]["accuracy"]

    for s in range(STEPS):
        mb = make_task(jax.random.fold_in(jax.random.PRNGKey(1), s), BATCH)
        trainable, opt_state, metrics = step(trainable, opt_state, mb)
    acc = float(evaluate(trainable, test))
    us = time_fn(lambda: step(trainable, opt_state, mb), iters=5)
    return acc, n_params, us


def run():
    results = {}
    for name, pcfg in METHODS.items():
        acc, n_params, us = run_method(name, pcfg)
        results[name] = acc
        emit(f"table1/{name}", us,
             f"eval_acc={acc:.3f};trainable_params={n_params}")
    # paper claims to validate structurally:
    assert results["GSOFT_b8"] >= results["OFT_b16"] - 0.05, \
        "GSOFT should match/beat OFT (dense vs block-diagonal Q)"
    emit("table1/claim_gsoft_vs_oft", 0.0,
         f"gsoft={results['GSOFT_b8']:.3f};oft={results['OFT_b16']:.3f}")
    return results
