"""Deterministic synthetic batches (shape-correct for every family).

Used by smoke tests, benchmarks, and the end-to-end examples when no corpus
is mounted. Token streams come from a fixed-seed PRNG with a learnable
structure (Zipf-ish marginals + copy patterns) so small models can actually
reduce loss on it.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.models import registry

Array = jnp.ndarray


def _token_stream(key, batch: int, seq: int, vocab: int) -> Array:
    """Learnable synthetic tokens: Zipf marginals + deterministic bigram."""
    k1, k2 = jax.random.split(key)
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    probs = (1.0 / ranks)
    probs = probs / probs.sum()
    first = jax.random.categorical(
        k1, jnp.log(probs)[None, :].repeat(batch, 0))        # (B,)
    noise = jax.random.categorical(
        k2, jnp.broadcast_to(jnp.log(probs), (batch, seq, vocab)))

    def step(prev, n):
        # deterministic bigram with occasional noise resets
        nxt = jnp.where(n % 7 == 0, n, (prev * 31 + 7) % vocab)
        return nxt, nxt

    _, toks = jax.lax.scan(step, first, noise.swapaxes(0, 1))
    return toks.swapaxes(0, 1).astype(jnp.int32)             # (B, S)


def lm_batch(cfg: ModelConfig, batch: int, seq: int,
             seed: int = 0) -> Dict[str, Array]:
    key = jax.random.PRNGKey(seed)
    t = registry.get(cfg.family)
    if t.has_patches:
        p = cfg.frontend_tokens
        s_text = max(seq - p, 8)
        toks = _token_stream(key, batch, s_text + 1, cfg.vocab_size)
        patches = jax.random.normal(jax.random.fold_in(key, 1),
                                    (batch, p, cfg.frontend_dim), jnp.float32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                "mask": jnp.ones((batch, s_text), jnp.float32),
                "patches": patches.astype(cfg.act_dtype)}
    if t.has_encoder:
        frames = jax.random.normal(jax.random.fold_in(key, 2),
                                   (batch, max(seq // 4, 8), cfg.d_model),
                                   jnp.float32)
        toks = _token_stream(key, batch, seq + 1, cfg.vocab_size)
        return {"frames": frames.astype(cfg.act_dtype),
                "tokens": toks[:, :-1], "labels": toks[:, 1:],
                "mask": jnp.ones((batch, seq), jnp.float32)}
    toks = _token_stream(key, batch, seq + 1, cfg.vocab_size)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
            "mask": jnp.ones((batch, seq), jnp.float32)}


def image_batch(cfg: ModelConfig, batch: int,
                seed: int = 0) -> Dict[str, Array]:
    """Learnable synthetic images for the stateless image family: each
    class c gets a fixed random template; a sample is its class template
    plus noise, so a 1-Lipschitz classifier can separate the classes while
    inputs stay O(1)-normalized (certified radii are meaningful)."""
    key = jax.random.PRNGKey(seed)
    k_lbl, k_noise = jax.random.split(key)
    shape = (cfg.image_size, cfg.image_size, cfg.in_channels)
    # class templates from a seed-independent key: every image_batch draw
    # of one config samples the SAME class manifold
    templates = jax.random.normal(jax.random.PRNGKey(17),
                                  (cfg.num_classes,) + shape, jnp.float32)
    labels = jax.random.randint(k_lbl, (batch,), 0, cfg.num_classes)
    noise = jax.random.normal(k_noise, (batch,) + shape, jnp.float32)
    images = templates[labels] + 0.5 * noise
    return {"images": images, "labels": labels.astype(jnp.int32)}
