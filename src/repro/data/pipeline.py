"""Deterministic, host-sharded, exactly-resumable LM data pipeline.

Principles for 1000+ node runs:
  * every batch is a pure function of (seed, step, host_slice) — no iterator
    state beyond the integer ``step``, so checkpoint/restore replays exactly
    and elastic restarts with a different host count stay consistent (the
    global batch is always materialized by global index, each host takes its
    addressable slice)
  * corpus mode: byte-level tokenization of any file tree, windows sampled
    by a counter-based RNG (no shuffling state to lose)
  * synthetic mode: learnable Zipf+bigram stream (data/synthetic.py)
"""
from __future__ import annotations

import dataclasses
import hashlib
import os
from typing import Dict, Iterator, Optional, Tuple

import numpy as np

from repro.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    corpus_path: Optional[str] = None     # None -> synthetic
    vocab_size: int = 256                 # byte tokenizer default


class ByteCorpus:
    """Memory-mapped byte-level corpus over a file or directory."""

    def __init__(self, path: str):
        if os.path.isdir(path):
            files = sorted(
                os.path.join(r, f) for r, _, fs in os.walk(path) for f in fs)
            blobs = [np.fromfile(f, dtype=np.uint8) for f in files]
            self.data = np.concatenate(blobs) if blobs else np.zeros(1, np.uint8)
        else:
            self.data = np.memmap(path, dtype=np.uint8, mode="r")
        if len(self.data) < 2:
            raise ValueError(f"corpus at {path} is empty")

    def window(self, start: int, length: int) -> np.ndarray:
        n = len(self.data)
        idx = (start + np.arange(length)) % (n - 1)
        return np.asarray(self.data[idx], dtype=np.int32)


def _counter_rng(seed: int, step: int, row: int) -> np.random.Generator:
    h = hashlib.blake2s(f"{seed}/{step}/{row}".encode(), digest_size=8)
    return np.random.default_rng(int.from_bytes(h.digest(), "little"))


class LMDataSource:
    """Stateless batch factory; ``state`` is just the step counter."""

    def __init__(self, cfg: DataConfig, corpus: Optional[ByteCorpus] = None):
        self.cfg = cfg
        self.corpus = corpus or (ByteCorpus(cfg.corpus_path)
                                 if cfg.corpus_path else None)

    def batch_at(self, step: int, lo: int = 0, hi: Optional[int] = None
                 ) -> Dict[str, np.ndarray]:
        """Rows [lo, hi) of the global batch for ``step`` (host slicing)."""
        cfg = self.cfg
        hi = cfg.global_batch if hi is None else hi
        s = cfg.seq_len
        toks = np.empty((hi - lo, s + 1), np.int32)
        for i, row in enumerate(range(lo, hi)):
            rng = _counter_rng(cfg.seed, step, row)
            if self.corpus is not None:
                start = int(rng.integers(0, len(self.corpus.data) - 1))
                toks[i] = self.corpus.window(start, s + 1)
            else:
                toks[i] = _synthetic_row(rng, s + 1, cfg.vocab_size)
        return {"tokens": toks[:, :-1],
                "labels": toks[:, 1:],
                "mask": np.ones((hi - lo, s), np.float32)}

    def iterate(self, start_step: int = 0) -> Iterator[Tuple[int, Dict]]:
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1


def _synthetic_row(rng: np.random.Generator, n: int, vocab: int) -> np.ndarray:
    """Zipf marginals + deterministic bigram (mirrors data/synthetic.py)."""
    out = np.empty(n, np.int64)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = (1.0 / ranks); p /= p.sum()
    prev = int(rng.choice(vocab, p=p))
    for t in range(n):
        if t % 7 == 0:
            prev = int(rng.choice(vocab, p=p))
        else:
            prev = (prev * 31 + 7) % vocab
        out[t] = prev
    return out.astype(np.int32)
