"""Data substrate: deterministic resumable pipeline + synthetic streams."""
from .pipeline import DataConfig, LMDataSource, ByteCorpus
from .synthetic import lm_batch
