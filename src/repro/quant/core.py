"""Shared int8/fp8 quantization primitives (weights AND gradients).

``quantize_int8`` / ``dequantize_int8`` are THE one implementation of
symmetric int8 quantization in the repo: gradient compression
(``optim.compression``, per-tensor, error feedback) and the serving-side
weight quantization (``quant.weights``, per-channel) both call them. The
``axis`` argument selects the granularity:

  * ``axis=None`` — per-tensor: one scalar scale (the gradient-compression
    setting; matches the historical ``optim.compression.quantize_int8``).
  * ``axis=k``    — per-channel: one scale per slice along axis ``k``,
    computed with ``keepdims`` so the scale broadcasts against ``q``
    (and survives ``lax.scan`` slicing of stacked layer weights).

``QuantTensor`` is the pytree node a quantized weight becomes: int8 (or
fp8) codes + fp32 scales as children, the logical dtype/mode/kernel-path
as static aux data — so quantized parameter trees flow through ``jit``,
``lax.scan`` and the checkpoint manager like any other params.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

INT8_MAX = 127.0
# fp8 e4m3 finite max (jax calls it float8_e4m3fn); the fp8 path is a
# STUB: it exists so the scale/metadata plumbing is exercised, but only
# runs where jax exposes the dtype, and only via the reference matmul.
FP8_MAX = 448.0


def fp8_supported() -> bool:
    return hasattr(jnp, "float8_e4m3fn")


def _absmax_scale(x32: Array, axis: Optional[int], qmax: float,
                  batch_dims: int = 0) -> Array:
    if axis is None and batch_dims == 0:
        amax = jnp.max(jnp.abs(x32))                 # per-tensor scalar
    else:
        keep = {axis % x32.ndim} if axis is not None else set()
        reduce_axes = tuple(a for a in range(batch_dims, x32.ndim)
                            if a not in keep)
        amax = jnp.max(jnp.abs(x32), axis=reduce_axes or None, keepdims=True)
    return jnp.maximum(amax, 1e-12) / qmax


def quantize_int8(x: Array, axis: Optional[int] = None,
                  batch_dims: int = 0) -> Tuple[Array, Array]:
    """Symmetric int8 quantization -> (q int8, scale fp32).

    ``axis=None``: per-tensor scalar scale (gradient compression).
    ``axis=k``: per-channel scales along ``k`` (keepdims, broadcastable).
    ``batch_dims``: leading axes treated as independent tensors (stacked
    layer weights) — scales keep those dims so ``lax.scan`` slices them
    alongside the codes.
    """
    x32 = x.astype(jnp.float32)
    scale = _absmax_scale(x32, axis, INT8_MAX, batch_dims)
    q = jnp.clip(jnp.round(x32 / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


def quantize_fp8(x: Array, axis: Optional[int] = None,
                 batch_dims: int = 0) -> Tuple[Array, Array]:
    """fp8 (e4m3) cast with absmax scaling — stub path, gated on dtype
    support in the installed jax/backend."""
    if not fp8_supported():
        raise NotImplementedError(
            "fp8 quantization needs jnp.float8_e4m3fn, which this jax "
            "build does not expose — use mode='int8'")
    x32 = x.astype(jnp.float32)
    scale = _absmax_scale(x32, axis, FP8_MAX, batch_dims)
    q = (x32 / scale).astype(jnp.float8_e4m3fn)
    return q, scale


def dequantize_fp8(q: Array, scale: Array) -> Array:
    return q.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# QuantTensor: the pytree node a quantized weight becomes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class QuantMeta:
    """Static (hashable, jit-cache-key) description of a QuantTensor."""
    mode: str = "int8"            # int8 | fp8
    dtype: str = "bfloat16"       # logical dtype of the original weight
    axis: Optional[int] = -1      # channel axis (None = per-tensor)
    use_pallas: bool = False      # matmuls via the q_matmul Pallas kernels


@jax.tree_util.register_pytree_with_keys_class
@dataclasses.dataclass(frozen=True)
class QuantTensor:
    """A quantized weight: codes + scales as pytree children, meta static.

    Mirrors the logical weight's ``shape``/``ndim`` so shape-driven code
    (PEFT spec inference, scan stacking) keeps working; ``scale`` keeps the
    same rank as ``q`` (keepdims) so ``lax.scan`` slices both coherently
    for stacked layer weights.
    """
    q: Array                      # int8 / fp8 codes, original weight shape
    scale: Array                  # fp32, keepdims-broadcastable against q
    meta: QuantMeta = QuantMeta()

    def tree_flatten_with_keys(self):
        return (((jax.tree_util.GetAttrKey("q"), self.q),
                 (jax.tree_util.GetAttrKey("scale"), self.scale)), self.meta)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(q=children[0], scale=children[1], meta=aux)

    # -- logical-weight mirror -------------------------------------------------
    @property
    def shape(self):
        return self.q.shape

    @property
    def ndim(self) -> int:
        return self.q.ndim

    @property
    def dtype(self):
        return jnp.dtype(self.meta.dtype)

    @property
    def nbytes(self) -> int:
        return (int(self.q.size) * self.q.dtype.itemsize
                + int(self.scale.size) * self.scale.dtype.itemsize)

    def dequantize(self, dtype=None) -> Array:
        w = self.q.astype(jnp.float32) * self.scale
        return w.astype(dtype or self.dtype)


def is_quant_tensor(x: Any) -> bool:
    return isinstance(x, QuantTensor)


def quantize_tensor(w: Array, mode: str = "int8",
                    axis: Optional[int] = -1,
                    use_pallas: bool = False) -> QuantTensor:
    """One weight -> QuantTensor (per-channel along ``axis`` by default).
    Leading dims beyond the trailing (d_in, d_out) matrix are stacked
    layers — each gets independent scales (scan-sliceable keepdims)."""
    batch_dims = max(w.ndim - 2, 0)
    if mode == "int8":
        q, scale = quantize_int8(w, axis=axis, batch_dims=batch_dims)
    elif mode == "fp8":
        q, scale = quantize_fp8(w, axis=axis, batch_dims=batch_dims)
    else:
        raise ValueError(f"unknown quantization mode {mode!r} "
                         "(have: int8, fp8)")
    meta = QuantMeta(mode=mode, dtype=jnp.dtype(w.dtype).name, axis=axis,
                     use_pallas=use_pallas)
    return QuantTensor(q=q, scale=scale, meta=meta)
