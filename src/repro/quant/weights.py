"""Weight-tree quantization for serving: int8 base weights, bf16 adapters.

``quantize_params(params, cfg)`` walks a model parameter tree and replaces
every weight matching ``cfg.target_patterns`` with a ``QuantTensor``
(per-output-channel symmetric int8 by default, fp8 stub behind a dtype
gate). Everything else — norms, biases, embeddings, SSM/MoE internals that
are consumed by raw einsums rather than the ``qlinear`` hook — stays in its
original dtype, and GS adapter banks are never part of the params tree at
all, so per-request rotations stay bf16 by construction (the QOFT/OFTv2
recipe: memory-bandwidth-bound base matmuls quantize; the tiny orthogonal
factors, whose Cayley orthogonality int8 would destroy, do not).

The default targets are exactly the projections the model layers route
through the ``qlinear`` hook (attention q/k/v/o, MLP in/gate/out, the
patch frontend and the LM head). MoE expert stacks and Mamba projections
are deliberately excluded until their einsum call sites grow hooks.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from .core import QuantTensor, is_quant_tensor, quantize_tensor

Array = jnp.ndarray
Tree = Any

# weights consumed through the qlinear hook (models/layers.py): attention +
# cross-attention + dense-MLP projections (any nesting), the vlm patch
# frontend, the LM head, and the image-family conv channel mixers. NOT
# moe/mamba (raw-einsum call sites), NOT the spectral-normalized image
# head (power iteration needs the raw matrix), NOT the skew conv kernels
# (consumed by conv_general_dilated, not qlinear).
DEFAULT_QUANT_TARGETS: Tuple[str, ...] = (
    r"(.*/)?(attn|cross|mlp|patch_proj)/(wq|wk|wv|wo|wi|wg)$",
    r"lm_head/w$",
    r"(.*/)?(conv\d+|down)/wc$",
)


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """How to quantize a serving weight tree (hashable, jit-static)."""
    mode: str = "int8"             # int8 | fp8 (stub) | none
    per_channel: bool = True       # per-output-channel scales (axis -1)
    use_pallas: bool = False       # matmuls via kernels/q_matmul.py
    target_patterns: Tuple[str, ...] = DEFAULT_QUANT_TARGETS

    @property
    def axis(self) -> Optional[int]:
        return -1 if self.per_channel else None

    @property
    def enabled(self) -> bool:
        return self.mode != "none"


def _matches(cfg: QuantConfig, path: str) -> bool:
    from repro.core.peft import matches_patterns
    return matches_patterns(cfg.target_patterns, path)


def quantize_params(params: Tree, cfg: QuantConfig) -> Tree:
    """Replace every targeted >=2-D float weight with a QuantTensor."""
    if not cfg.enabled:
        return params
    from repro.core.peft import path_str

    def visit(path, leaf):
        if is_quant_tensor(leaf):
            raise ValueError(f"{path_str(path)} is already quantized — "
                             "quantize_params expects a float weight tree")
        if (leaf.ndim >= 2 and jnp.issubdtype(leaf.dtype, jnp.floating)
                and _matches(cfg, path_str(path))):
            return quantize_tensor(leaf, mode=cfg.mode, axis=cfg.axis,
                                   use_pallas=cfg.use_pallas)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params,
                                            is_leaf=is_quant_tensor)


def dequantize_params(params: Tree) -> Tree:
    """Back to a plain float tree (testing / debugging / export)."""
    return jax.tree_util.tree_map(
        lambda l: l.dequantize() if is_quant_tensor(l) else l,
        params, is_leaf=is_quant_tensor)


def is_quantized_tree(params: Tree) -> bool:
    return any(is_quant_tensor(l) for l in jax.tree_util.tree_leaves(
        params, is_leaf=is_quant_tensor))


def tree_bytes(params: Tree) -> int:
    """Parameter-memory footprint in bytes (QuantTensor-aware) — the
    HBM-residency number the quant benchmark reports."""
    total = 0
    for leaf in jax.tree_util.tree_leaves(params, is_leaf=is_quant_tensor):
        if is_quant_tensor(leaf):
            total += leaf.nbytes
        else:
            total += int(leaf.size) * jnp.dtype(leaf.dtype).itemsize
    return total


def quantized_abstract(base_abstract: Tree, cfg: QuantConfig) -> Tree:
    """Shape/dtype tree of ``quantize_params`` applied to an abstract base
    tree — what the checkpoint manager restores quantized trees into."""
    return jax.eval_shape(lambda t: quantize_params(t, cfg), base_abstract)
