"""repro.quant — serving-side weight quantization (int8 + fp8 stub).

One implementation of symmetric quantization shared with gradient
compression (``core``), plus the weight-tree layer (``weights``) that the
``ModelRuntime.quantized`` / ``--quantize int8`` serving path consumes.
Matmuls over quantized weights dispatch through ``kernels/q_matmul.py``
(Pallas, dequant fused in the MXU epilogue) or the reference einsums.
"""
from .core import (FP8_MAX, INT8_MAX, QuantMeta, QuantTensor, dequantize_fp8,
                   dequantize_int8, fp8_supported, is_quant_tensor,
                   quantize_fp8, quantize_int8, quantize_tensor)
from .weights import (DEFAULT_QUANT_TARGETS, QuantConfig, dequantize_params,
                      is_quantized_tree, quantize_params, quantized_abstract,
                      tree_bytes)

__all__ = [
    "FP8_MAX", "INT8_MAX", "QuantMeta", "QuantTensor", "QuantConfig",
    "DEFAULT_QUANT_TARGETS", "dequantize_fp8", "dequantize_int8",
    "dequantize_params", "fp8_supported", "is_quant_tensor",
    "is_quantized_tree", "quantize_fp8", "quantize_int8", "quantize_params",
    "quantize_tensor", "quantized_abstract", "tree_bytes",
]
