"""Sharded, atomic, async-capable checkpointing with elastic restore.

Layout:
    <dir>/step_00001230/           (atomic: written as .tmp_, then renamed)
        index.json                 pytree structure + per-leaf shape/dtype
        <leaf-path>.npy            one file per leaf (per host in multi-host)
    <dir>/LATEST                   text file with the newest committed step

Fault-tolerance properties:
  * commit is a single directory rename — a crash mid-write never corrupts
    the latest checkpoint
  * restore(..., sharding_tree=...) re-shards onto ANY mesh (elastic
    scale-up/down): arrays are loaded full and device_put with the new
    sharding — tested 8 -> 4 devices
  * async mode snapshots to host memory and writes in a daemon thread so the
    train loop never blocks on the filesystem
  * keep-last-k GC

Adapter banks: ``save_adapters`` / ``restore_adapters`` persist NAMED
adapter pytrees (any registered ``core.methods`` parametrization — mixed
methods per bank are fine) plus per-name ``PEFTConfig`` records as index
metadata (the index records adapter names, methods and weight paths —
restore needs no tree_like). ``adapter_index`` / ``load_adapter`` read
that index WITHOUT touching the leaves, so ``repro.store.AdapterStore
.open`` can back thousands of adapters by disk and pull each one's params
only when it first pages into HBM. Serving code reaches all of this
through ``ModelRuntime.attach`` / ``repro.store`` — e.g.
``launch/serve.py --store-dir`` serves a checkpoint directory directly.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Tree = Any

_SEP = "__"


def _flatten(tree: Tree) -> Dict[str, Any]:
    from repro.core.peft import flatten_paths
    return {p.replace("/", _SEP): v for p, v in flatten_paths(tree).items()}


def _unflatten_into(tree_like: Tree, flat: Dict[str, np.ndarray]) -> Tree:
    from repro.core.peft import path_str
    import jax.tree_util as jtu

    def visit(path, leaf):
        key = path_str(path).replace("/", _SEP)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        return flat[key]

    return jtu.tree_map_with_path(visit, tree_like)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Tree, blocking: bool = True,
             extra: Optional[Dict] = None):
        host = {k: np.asarray(jax.device_get(v)) for k, v in
                _flatten(tree).items()}
        if blocking:
            self._write(step, host, extra)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host, extra), daemon=True)
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Optional[Dict]):
        name = f"step_{step:010d}"
        tmp = os.path.join(self.dir, f".tmp_{name}")
        final = os.path.join(self.dir, name)
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {"step": step, "leaves": {}, "extra": extra or {}}
        for key, arr in host.items():
            np.save(os.path.join(tmp, key + ".npy"), arr)
            index["leaves"][key] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic commit
        with open(os.path.join(self.dir, "LATEST"), "w") as f:
            f.write(name)
        self._gc()

    def _gc(self):
        steps = sorted(d for d in os.listdir(self.dir)
                       if d.startswith("step_"))
        for d in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, d), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        path = os.path.join(self.dir, "LATEST")
        if not os.path.exists(path):
            return None
        with open(path) as f:
            name = f.read().strip()
        if not os.path.isdir(os.path.join(self.dir, name)):
            return None
        return int(name.split("_")[1])

    def restore(self, tree_like: Tree, step: Optional[int] = None,
                sharding_tree: Optional[Tree] = None) -> Tree:
        """Load into the structure of ``tree_like``; optionally re-shard
        every leaf with ``sharding_tree`` (elastic mesh change)."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        flat = {k: np.load(os.path.join(d, k + ".npy"))
                for k in index["leaves"]}
        tree = _unflatten_into(tree_like, flat)
        if sharding_tree is not None:
            tree = jax.tree.map(
                lambda v, s: jax.device_put(v, s), tree, sharding_tree)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def extra(self, step: Optional[int] = None) -> Dict:
        step = self.latest_step() if step is None else step
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "index.json")) as f:
            return json.load(f).get("extra", {})

    # -- quantized weight trees ----------------------------------------------
    def save_quantized(self, step: int, qparams: Tree, quant_cfg,
                       blocking: bool = True) -> None:
        """Persist a quantized parameter tree (``quant.quantize_params``
        output): int8/fp8 codes + fp32 scales as ordinary leaves, the
        QuantConfig as index metadata so restore is self-describing."""
        extra = {"kind": "quantized_params",
                 "quant": dataclasses.asdict(quant_cfg)}
        self.save(step, qparams, blocking=blocking, extra=extra)

    def restore_quantized(self, base_abstract: Tree, qcfg=None,
                          step: Optional[int] = None,
                          use_pallas: Optional[bool] = None):
        """-> (quantized tree, QuantConfig) from either checkpoint kind.

        ``base_abstract`` is the UNQUANTIZED abstract param tree (shapes
        only). A ``save_quantized`` checkpoint restores codes + scales
        directly under its saved config; a plain float checkpoint is
        restored and quantized ON LOAD with ``qcfg`` (default int8) — the
        migration path for pre-quantization checkpoints.

        ``use_pallas`` is execution strategy, not data layout: it is
        chosen by the LOADER (this argument, or ``qcfg.use_pallas`` when
        a full config is passed), never pinned by the checkpoint — saved
        trees stay portable across backends. Everything else in an
        explicit ``qcfg`` must match a quantized checkpoint's stored
        codes/scales.
        """
        import dataclasses as dc

        from repro import quant
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        ex = self.extra(step)
        if ex.get("kind") == "quantized_params":
            saved = dict(ex["quant"])
            saved["target_patterns"] = tuple(saved.get("target_patterns", ()))
            saved_cfg = quant.QuantConfig(**saved)
            if qcfg is not None:
                used_cfg = dc.replace(saved_cfg, use_pallas=qcfg.use_pallas)
                if qcfg != used_cfg:
                    raise ValueError(
                        f"checkpoint was quantized with {saved_cfg}, which "
                        f"conflicts with the requested {qcfg} — re-quantize "
                        "from a float checkpoint to change modes")
            elif use_pallas is not None:
                used_cfg = dc.replace(saved_cfg, use_pallas=use_pallas)
            else:
                used_cfg = saved_cfg
            like = quant.quantized_abstract(base_abstract, used_cfg)
            return self.restore(like, step=step), used_cfg
        qcfg = qcfg or quant.QuantConfig(use_pallas=bool(use_pallas))
        params = self.restore(base_abstract, step=step)
        return quant.quantize_params(params, qcfg), qcfg

    # -- named adapter banks --------------------------------------------------
    def save_adapters(self, step: int,
                      adapters_by_name: Dict[str, Dict[str, Dict[str, Any]]],
                      peft_cfg, blocking: bool = True) -> None:
        """Save named adapters {name: {weight_path: {param: arr}}} plus
        their PEFTConfig(s) as index metadata — the serving bank format.

        ``peft_cfg`` is a single PEFTConfig or (mixed-method banks) a
        {name: PEFTConfig} mapping; either way the index records the
        method NAME + full spec per adapter (``peft_by_name``), so restore
        can rebuild a heterogeneous bank without any python objects."""
        from repro.core.peft import normalize_bank_cfgs
        primary, cfg_by_name = normalize_bank_cfgs(adapters_by_name,
                                                   peft_cfg)
        extra = {
            "kind": "adapter_bank",
            "peft": dataclasses.asdict(primary),
            "peft_by_name": {name: dataclasses.asdict(c)
                             for name, c in cfg_by_name.items()},
            "adapter_methods": {name: c.method
                                for name, c in cfg_by_name.items()},
            "adapter_names": list(adapters_by_name),
            "weight_paths": sorted({p for ad in adapters_by_name.values()
                                    for p in ad}),
        }
        self.save(step, dict(adapters_by_name), blocking=blocking,
                  extra=extra)

    def restore_adapters(self, step: Optional[int] = None
                         ) -> Tuple[Dict[str, Dict[str, Dict[str, Any]]],
                                    Dict[str, Any]]:
        """-> (adapters_by_name, {name: PEFTConfig}) from a
        ``save_adapters`` checkpoint. Self-describing: names, weight paths
        and each adapter's method + spec come from the index (pre-mixed-
        method checkpoints carry one shared ``peft`` record — every name
        maps to it)."""
        from repro.core.peft import PEFTConfig

        def to_cfg(d_):
            pd = dict(d_)
            pd["target_patterns"] = tuple(pd.get("target_patterns", ()))
            return PEFTConfig(**pd)

        d, index, ex = self._adapter_ckpt(step)
        peft_cfg = to_cfg(ex["peft"])
        by_name = {name: to_cfg(c)
                   for name, c in ex.get("peft_by_name", {}).items()}
        flat = {k: np.load(os.path.join(d, k + ".npy"))
                for k in index["leaves"]}
        out: Dict[str, Dict[str, Dict[str, Any]]] = {}
        cfgs: Dict[str, Any] = {}
        for name in ex["adapter_names"]:
            out[name] = self._adapter_tree(name, ex["weight_paths"], flat)
            cfgs[name] = by_name.get(name, peft_cfg)
        return out, cfgs

    def _adapter_ckpt(self, step: Optional[int]):
        """-> (ckpt dir, index, extra) of an adapter-bank checkpoint."""
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "index.json")) as f:
            index = json.load(f)
        ex = index.get("extra", {})
        if ex.get("kind") != "adapter_bank":
            raise ValueError(f"{d} is not an adapter-bank checkpoint "
                             f"(kind={ex.get('kind')!r})")
        return d, index, ex

    @staticmethod
    def _adapter_tree(name: str, weight_paths, flat) -> Dict[str, Any]:
        tree: Dict[str, Dict[str, Any]] = {}
        for path in weight_paths:
            prefix = f"{name}{_SEP}{path.replace('/', _SEP)}{_SEP}"
            entry = {k[len(prefix):]: jax.numpy.asarray(v)
                     for k, v in flat.items() if k.startswith(prefix)}
            if entry:
                tree[path] = entry
        return tree

    def adapter_index(self, step: Optional[int] = None
                      ) -> Tuple[Tuple[str, ...], Dict[str, Any],
                                 Tuple[str, ...]]:
        """-> (names, {name: PEFTConfig}, weight_paths) from the index
        ALONE — no adapter leaves are read. The host-store fast path: a
        disk-backed ``AdapterStore`` opens a thousand-tenant checkpoint in
        one index read and defers each tenant's arrays to first page-in."""
        from repro.core.peft import PEFTConfig

        def to_cfg(d_):
            pd = dict(d_)
            pd["target_patterns"] = tuple(pd.get("target_patterns", ()))
            return PEFTConfig(**pd)

        _, _, ex = self._adapter_ckpt(step)
        peft_cfg = to_cfg(ex["peft"])
        by_name = {name: to_cfg(c)
                   for name, c in ex.get("peft_by_name", {}).items()}
        names = tuple(ex["adapter_names"])
        return (names, {n: by_name.get(n, peft_cfg) for n in names},
                tuple(ex["weight_paths"]))

    def load_adapter(self, name: str, step: Optional[int] = None
                     ) -> Dict[str, Dict[str, Any]]:
        """Load ONE named adapter's param tree, reading only its own
        ``.npy`` leaves (lazy page-in for disk-backed stores)."""
        d, index, ex = self._adapter_ckpt(step)
        if name not in ex["adapter_names"]:
            raise KeyError(f"{d} has adapters {ex['adapter_names']}, "
                           f"not {name!r}")
        mine = f"{name}{_SEP}"
        flat = {k: np.load(os.path.join(d, k + ".npy"))
                for k in index["leaves"] if k.startswith(mine)}
        return self._adapter_tree(name, ex["weight_paths"], flat)
