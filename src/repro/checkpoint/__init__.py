from .manager import CheckpointManager
