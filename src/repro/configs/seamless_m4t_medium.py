"""seamless-m4t-medium [audio]: 12L enc + 12L dec, d_model=1024 16H (kv=16)
d_ff=4096 vocab=256206 — enc-dec; speech frontend is a STUB providing
precomputed frame embeddings (B, F, d). [arXiv:2308.11596; hf]

Vocab 256206 pads to 256208 for 16-way vocab sharding (DESIGN §5).
"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=12, enc_layers=12,
    d_model=1024, num_heads=16, num_kv_heads=16, head_dim=64,
    d_ff=4096, vocab_size=256206,
    mlp_type="gelu", rope_theta=1e4,
    frontend="frames", frontend_dim=1024,
    source="arXiv:2308.11596",
)

SMOKE = ModelConfig(
    name="seamless-m4t-medium", family="encdec",
    num_layers=2, enc_layers=2,
    d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_type="gelu", rope_theta=1e4,
    frontend="frames", frontend_dim=64,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
