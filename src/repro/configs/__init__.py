"""Assigned architecture configs (one module per arch id). Importing this
package registers every config with repro.config."""
from . import (qwen2_72b, mistral_large_123b, granite_34b, gemma_7b,
               phi35_moe_42b, qwen3_moe_30b, zamba2_2p7b, pixtral_12b,
               mamba2_130m, seamless_m4t_medium, lipconvnet_15)
