"""qwen2-72b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — GQA with QKV bias. [arXiv:2407.10671; hf]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="qwen2-72b", family="decoder",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=29568, vocab_size=152064,
    mlp_type="swiglu", qkv_bias=True, rope_theta=1e6,
    source="arXiv:2407.10671",
)

SMOKE = ModelConfig(
    name="qwen2-72b", family="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_type="swiglu", qkv_bias=True, rope_theta=1e6,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
