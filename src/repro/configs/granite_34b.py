"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 — code model. [arXiv:2405.04324; hf]

Param-count note: 34B is only consistent with the GPTBigCode-style 2-matrix
GELU MLP (88 * 2 * 6144 * 24576 ~ 26.6B) + MQA attention + tied embeddings;
a SwiGLU MLP would put it at 47B. We follow the parameter math (and the
granite-code paper) over the assignment's "llama-arch" shorthand.
"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="granite-34b", family="decoder",
    num_layers=88, d_model=6144, num_heads=48, num_kv_heads=1, head_dim=128,
    d_ff=24576, vocab_size=49152,
    mlp_type="gelu", rope_theta=1e4, tie_embeddings=True,
    source="arXiv:2405.04324",
)

SMOKE = ModelConfig(
    name="granite-34b", family="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_type="gelu", rope_theta=1e4, tie_embeddings=True,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
