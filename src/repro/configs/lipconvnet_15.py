"""lipconvnet-15 [image]: the paper's Table 3 certified-robustness model —
5 blocks x 3 GS-SOC orthogonal conv layers, base width 32 doubling per
block, MaxMinPermuted activations, spectral-normalized head; CIFAR-100
geometry (32x32x3, 100 classes). GS groups (4, 1): grouped 3x3 exp-conv +
paired channel shuffle + ungrouped 1x1 exp-conv (Table 3 row "4-1").

The smoke variant shrinks to depth 10 / width 8 / 10 classes in f32 —
big enough to exercise every layer shape (conv + downsample per block,
head), small enough for CPU CI. 32x32 inputs are structural: five
space-to-depth halvings need image_size % 32 == 0.
"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="lipconvnet-15", family="image",
    num_layers=15, d_model=32, base_width=32,
    image_size=32, in_channels=3, num_classes=100,
    conv_layer="gs_soc", conv_groups=(4, 1), conv_terms=6,
    conv_activation="maxmin_permuted", paired_shuffle=True,
    source="GorbunovYSANR24 Table 3",
)

SMOKE = ModelConfig(
    name="lipconvnet-15", family="image",
    num_layers=10, d_model=8, base_width=8,
    image_size=32, in_channels=3, num_classes=10,
    conv_layer="gs_soc", conv_groups=(2, 1), conv_terms=4,
    conv_activation="maxmin_permuted", paired_shuffle=True,
    dtype="f32", param_dtype="f32", remat="none",
)

register(FULL, SMOKE)
