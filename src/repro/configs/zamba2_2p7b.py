"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 blocks + shared attention block every 6
layers (weights shared, caches per application). [arXiv:2411.15242; hf]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32, head_dim=80,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    attn_every=6, rope_theta=1e4, mlp_type="swiglu",
    source="arXiv:2411.15242",
)

SMOKE = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_groups=1,
    attn_every=2, rope_theta=1e4, mlp_type="swiglu",
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32, ssd_chunk=16,
)

register(FULL, SMOKE)
