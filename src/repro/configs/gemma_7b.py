"""gemma-7b [dense]: 28L d_model=3072 16H (kv=16, MHA) d_ff=24576
vocab=256000 — GeGLU, head_dim=256, tied + scaled embeddings.
[arXiv:2403.08295; hf]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="gemma-7b", family="decoder",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576, vocab_size=256000,
    mlp_type="geglu", rope_theta=1e4,
    tie_embeddings=True, embed_scale=True,
    source="arXiv:2403.08295",
)

SMOKE = ModelConfig(
    name="gemma-7b", family="decoder",
    num_layers=2, d_model=48, num_heads=4, num_kv_heads=4, head_dim=32,
    d_ff=96, vocab_size=256,
    mlp_type="geglu", rope_theta=1e4,
    tie_embeddings=True, embed_scale=True,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
