"""mamba2-130m [ssm]: 24L d_model=768 (attention-free) vocab=50280,
ssm_state=128 — SSD (state-space duality). [arXiv:2405.21060; unverified]

TP note (DESIGN §5): 24 SSD heads are not divisible by the 16-way model
axis; weights replicate over 'model' (vocab/embedding still shard) — the
roofline table reports the resulting under-utilization honestly.
"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=24, d_model=768,
    vocab_size=50280, tie_embeddings=True,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    source="arXiv:2405.21060",
)

SMOKE = ModelConfig(
    name="mamba2-130m", family="ssm",
    num_layers=2, d_model=64,
    vocab_size=256, tie_embeddings=True,
    ssm_state=16, ssm_expand=2, ssm_headdim=16, ssm_groups=1,
    dtype="f32", param_dtype="f32", remat="none", ssd_chunk=16,
)

register(FULL, SMOKE)
