"""pixtral-12b [vlm]: 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072 — pixtral-ViT frontend (STUB: precomputed patch embeddings) +
mistral-nemo decoder. [hf:mistralai/Pixtral-12B-2409; unverified]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    mlp_type="swiglu", rope_theta=1e6,
    frontend="patch", frontend_dim=1024, frontend_tokens=256,
    source="hf:mistralai/Pixtral-12B-2409",
)

SMOKE = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=256,
    mlp_type="swiglu", rope_theta=1e6,
    frontend="patch", frontend_dim=32, frontend_tokens=8,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
