"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) expert
d_ff=6400 vocab=32064, MoE 16 experts top-2.
[hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="decoder",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=6400, vocab_size=32064,
    moe_experts=16, moe_top_k=2, moe_d_ff=6400,
    mlp_type="swiglu", rope_theta=1e4,
    source="hf:microsoft/Phi-3.5-MoE-instruct",
)

SMOKE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=256,
    moe_experts=4, moe_top_k=2, moe_d_ff=64,
    mlp_type="swiglu", rope_theta=1e4,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
