"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert
d_ff=768 vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b", family="decoder",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    moe_experts=128, moe_top_k=8, moe_d_ff=768,
    mlp_type="swiglu", rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b", family="decoder",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
    d_ff=32, vocab_size=256,
    moe_experts=8, moe_top_k=2, moe_d_ff=32,
    mlp_type="swiglu", rope_theta=1e6,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
