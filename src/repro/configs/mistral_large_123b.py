"""mistral-large-123b [dense]: 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. [hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.config import ModelConfig, register

FULL = ModelConfig(
    name="mistral-large-123b", family="decoder",
    num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8, head_dim=128,
    d_ff=28672, vocab_size=32768,
    mlp_type="swiglu", rope_theta=1e6,
    source="hf:mistralai/Mistral-Large-Instruct-2407",
)

SMOKE = ModelConfig(
    name="mistral-large-123b", family="decoder",
    num_layers=2, d_model=96, num_heads=6, num_kv_heads=2, head_dim=16,
    d_ff=160, vocab_size=256,
    mlp_type="swiglu", rope_theta=1e6,
    dtype="f32", param_dtype="f32", remat="none", attn_chunk=32,
)

register(FULL, SMOKE)
