"""Config system: model / shape / run configuration + the arch registry.

Every assigned architecture registers a ``ModelConfig`` under its public id
(see src/repro/configs/*.py); shapes are the assignment's four input-shape
cells.  Configs are frozen dataclasses — hashable, jit-static, overridable
from the CLI via ``--set field=value``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

DTYPES = {"f32": jnp.float32, "bf16": jnp.bfloat16, "f16": jnp.float16}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # decoder | encdec | hybrid | ssm | vlm | image
    num_layers: int
    d_model: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    mlp_type: str = "swiglu"         # swiglu | geglu | gelu
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma: embeddings scaled by sqrt(d)
    logit_softcap: float = 0.0

    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_segment: int = 2048          # token segment for dispatch transients

    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    attn_every: int = 0              # hybrid: shared attn block every k layers

    # encoder-decoder
    enc_layers: int = 0

    # image family (1-Lipschitz GS-SOC convnet; models/image.py)
    image_size: int = 0              # input H = W
    in_channels: int = 3
    num_classes: int = 0
    base_width: int = 0              # stage-0 conv width (doubles per block)
    conv_layer: str = "gs_soc"       # gs_soc | soc
    conv_groups: Tuple[int, int] = (1, 1)   # GS group counts (g1, g2)
    conv_kernel: int = 3
    conv_terms: int = 6              # conv-exponential Taylor terms
    conv_activation: str = "maxmin"  # maxmin | maxmin_permuted
    paired_shuffle: bool = False

    # modality frontend stub ([vlm]/[audio]: precomputed embeddings)
    frontend: str = "none"           # none | patch | frames
    frontend_dim: int = 0
    frontend_tokens: int = 0         # patches prepended (vlm)

    # numerics / execution
    dtype: str = "bf16"
    param_dtype: str = "bf16"
    use_pallas: bool = False
    # kernel launch-geometry overrides, installed into kernels.dispatch by
    # the step builders: ("bdmm", r, bo, bi, token_tile, group_tile) or
    # ("gs", r, b, token_tile)
    kernel_tunings: Tuple[Tuple, ...] = ()
    remat: str = "full"              # full | dots | none
    attn_chunk: int = 1024
    ssd_chunk: int = 256
    attn_impl: str = "dense"         # dense | prefix_loop (perf option)
    seq_parallel: bool = False       # Megatron-SP: residual sharded on seq

    # notes for DESIGN/roofline
    source: str = ""

    @property
    def d_head(self) -> int:
        return self.head_dim or (self.d_model // max(self.num_heads, 1))

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def expert_d_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    @property
    def act_dtype(self):
        return DTYPES[self.dtype]

    @property
    def weight_dtype(self):
        return DTYPES[self.param_dtype]

    def padded_vocab(self, multiple: int = 16) -> int:
        v = self.vocab_size
        return ((v + multiple - 1) // multiple) * multiple

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


# the assignment's four shape cells (LM family)
SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs allowed to run long_500k (sub-quadratic decode); the 8 pure
# full-attention archs skip it (DESIGN §5)
SUBQUADRATIC = ("zamba2-2.7b", "mamba2-130m")


def shape_applicable(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return False, "full-attention arch: 500k decode skipped per assignment"
    return True, ""


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}
_SMOKE: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig, smoke: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    _SMOKE[cfg.name] = smoke
    return cfg


def get_config(name: str) -> ModelConfig:
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def get_smoke_config(name: str) -> ModelConfig:
    _load_all()
    return _SMOKE[name]


def list_archs():
    _load_all()
    return sorted(_REGISTRY)


_LOADED = False


def _load_all():
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro import configs  # noqa: F401  (registers everything)


def parse_overrides(pairs) -> dict:
    """--set key=value CLI overrides with literal-ish parsing."""
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        for cast in (int, float):
            try:
                out[k] = cast(v)
                break
            except ValueError:
                continue
        else:
            out[k] = {"true": True, "false": False}.get(v.lower(), v)
    return out
