"""Tensor-parallel serve meshes + the serving-side ``shard_map`` wrapper.

``serve_mesh(tp)`` is what ``launch/serve.py --tp N`` builds: a 1 x N
("data", "model") mesh. A ``ModelRuntime`` constructed with it commits
params / KV state / bank factors per ``sharding.specs`` and lets GSPMD
partition the jitted prefill/decode closures — no retracing, engines run
unchanged.

``head_shard_map`` is the explicit-collective escape hatch for kernels
whose launch geometry must see the LOCAL shard (Pallas paged attention
over the kv-head split): it maps a per-shard function over one named
axis of its array arguments. Together with ``sharding/``, this module is
the only place allowed to construct ``shard_map`` (CI grep guard) — the
point is that partitioning POLICY never leaks into kernels or engines.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.mesh import make_mesh


def serve_mesh(tp: int, dp: int = 1) -> Mesh:
    """The serving mesh for ``--tp N``: (dp, tp) over ("data", "model").
    tp=1 still yields a real (degenerate) mesh so the placement path is
    identical whether or not the model is actually split."""
    if tp < 1 or dp < 1:
        raise ValueError(f"tp={tp} and dp={dp} must be >= 1")
    n = tp * dp
    if n > len(jax.devices()):
        raise ValueError(
            f"serve mesh needs {n} devices, only {len(jax.devices())} "
            "visible — set XLA_FLAGS=--xla_force_host_platform_device_count "
            "for CPU testing")
    return make_mesh(dp, tp)


def head_shard_map(fn: Callable, mesh: Mesh,
                   head_axes: Sequence[int], *,
                   out_head_axis: int = 1,
                   axis: str = "model") -> Callable:
    """Wrap a per-shard kernel so it runs once per 'model'-axis shard of
    its head-split arguments.

    ``head_axes[i]`` names which dim of positional argument i carries
    heads (None = that argument is replicated — page tables, positions);
    the output's head dim is ``out_head_axis``. Inside the wrapper the
    kernel sees LOCAL shapes (kv_heads / tp), which is exactly what the
    tp-tagged ``kernels.dispatch`` keys resolve tunings for — the full
    array's launch geometry can be illegal for the shard.
    """
    from jax.experimental.shard_map import shard_map

    def spec(ax):
        if ax is None:
            return P()
        s = [None] * (ax + 1)
        s[ax] = axis
        return P(*s)

    in_specs = tuple(spec(ax) for ax in head_axes)
    return shard_map(fn, mesh=mesh, in_specs=in_specs,
                     out_specs=spec(out_head_axis), check_rep=False)
