"""Serving-side distribution (ISSUE 8).

Two layers over the single-device serving stack:

* ``distrib.tp`` — tensor-parallel serve meshes. ``serve_mesh(tp)``
  builds the 1 x tp mesh a ``ModelRuntime`` commits its params / KV /
  bank state onto (placement rules live in ``sharding.specs``); the
  module is also one of the two homes (with ``sharding/``) where
  ``shard_map`` construction is allowed by the CI grep guard.
* ``distrib.cluster`` — ``EngineCluster``: N engine replicas behind one
  engine-shaped surface, with adapter-affinity routing (repeat tenants
  land on the replica whose ``PagedAdapterBank`` already holds their
  factors — no duplicate page-ins), least-loaded spillover, queued-work
  rebalancing, and one aggregated ``cluster_stats()`` report whose N=1
  case is the plain single-engine report.
"""
from .cluster import EngineCluster, format_cluster_report
from .tp import head_shard_map, serve_mesh

__all__ = ["EngineCluster", "format_cluster_report", "head_shard_map",
           "serve_mesh"]
