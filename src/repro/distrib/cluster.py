"""EngineCluster: N serving-engine replicas behind one engine surface.

Data-parallel serving for the multi-tenant adapter story: each replica is
a full engine (continuous or paged) over its own ``ModelRuntime`` — same
weights, its own KV state and its own (usually store-paged) adapter bank.
The cluster routes streaming arrivals by ADAPTER AFFINITY: a tenant's
requests keep landing on the replica whose ``PagedAdapterBank`` already
holds their factors, so a working set that thrashes one replica's HBM
budget partitions cleanly across N — page-ins happen once per tenant per
home, not once per admission. Spillover (home replica overloaded while a
sibling idles) falls back to least-loaded, and queued-but-unadmitted work
rebalances off overloaded replicas each tick.

The surface duck-types a single engine (``add_request`` / ``step`` /
``run`` / ``idle`` / ``finished`` / ``drain_finished`` / ``stats`` /
``add_wall``), so ``launch.serve.drive_streaming`` and the benchmarks
drive 1 or N replicas with the same loop; ``cluster_stats()`` is the one
aggregated report, of which the single-replica launcher output is just
the N=1 case.

Each tick launches EVERY replica's decode step before syncing any of
them (``step_launch`` / ``step_commit``): JAX dispatch is async, so on a
multi-device host the replicas' device work overlaps while the host does
one replica's bookkeeping.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.obs.metrics import REGISTRY
from repro.obs.slo import SLOMonitor
from repro.serve.engine import Request
from repro.serve.kv import merge_pool_stats


def _bank_resident(eng, name: str) -> bool:
    """Is this adapter's factor set warm in the replica's bank? Eager
    banks have no ``resident`` surface — everything is resident."""
    bank = eng.rt.bank
    probe = getattr(bank, "is_resident", None)
    if probe is not None:
        return probe(name)
    return bank is not None


class EngineCluster:
    """Affinity-routing front over ``engines`` (all replicas must serve
    the same adapter universe — same store / same named bank)."""

    def __init__(self, engines: Sequence, *,
                 spill_depth: Optional[int] = None,
                 rebalance_margin: Optional[int] = None,
                 auto_rebalance: bool = True,
                 slo: Optional[SLOMonitor] = None):
        if not engines:
            raise ValueError("EngineCluster needs at least one engine")
        self.engines = list(engines)
        b0 = self.engines[0].max_batch
        # a home replica counts as overloaded once its backlog exceeds a
        # full extra batch; spilling earlier would shred affinity for a
        # queue that one tick of decode progress will absorb anyway
        self.spill_depth = 2 * b0 if spill_depth is None else spill_depth
        self.rebalance_margin = (b0 if rebalance_margin is None
                                 else rebalance_margin)
        self.auto_rebalance = auto_rebalance
        self._affinity: Dict[str, int] = {}          # adapter -> home replica
        self._rid_map: Dict[Tuple[int, int], int] = {}
        self._next_crid = 0
        self._results: Dict[int, List[int]] = {}
        self.finished: List[Request] = []
        self._wall = 0.0
        # routing counters live in the process metrics plane; the
        # `routing` property keeps the pre-obs dict read surface
        self._routing = REGISTRY.scope("cluster").counters(
            "routed", "base", "fresh", "affinity_hits",
            "affinity_spills", "rebalanced")
        # SLO-driven admission backpressure: when the monitor's thresholds
        # breach, `accepting` drops and streaming drivers hold arrivals
        # until it clears (transition callbacks — no per-request polling)
        self.slo = slo
        self.accepting = True
        if slo is not None and slo.thresholds:
            slo.on_breach(lambda *a: setattr(self, "accepting", False))
            slo.on_clear(
                lambda *a: setattr(self, "accepting",
                                   not slo.any_breached))

    # -- routing --------------------------------------------------------------
    def _least_loaded(self, exclude: Optional[int] = None) -> int:
        cands = [i for i in range(len(self.engines)) if i != exclude]
        return min(cands, key=lambda i: (self.engines[i].load, i))

    def _route(self, adapter: Optional[str]) -> Tuple[int, str]:
        """(replica, kind) for one arrival. kind is the routing-counter
        key: 'base' (no adapter — pure load balancing), 'fresh' (first
        sighting — establishes the home), 'affinity_hits' (repeat tenant
        on its warm home), 'affinity_spills' (home overloaded, sent to
        least-loaded; the home stays sticky so the tenant returns)."""
        if adapter is None:
            return self._least_loaded(), "base"
        home = self._affinity.get(adapter)
        if home is None:
            # pre-warmed somewhere (earlier traffic, pre-seeded store)?
            home = next((i for i, e in enumerate(self.engines)
                         if _bank_resident(e, adapter)), None)
            if home is None:
                home = self._least_loaded()
            self._affinity[adapter] = home
            return home, "fresh"
        if self.engines[home].load >= self.spill_depth:
            alt = self._least_loaded()
            if (alt != home and self.engines[alt].load
                    + self.rebalance_margin <= self.engines[home].load):
                return alt, "affinity_spills"
        return home, "affinity_hits"

    def add_request(self, prompt: List[int], max_new_tokens: int = 16,
                    adapter: Optional[str] = None) -> int:
        i, kind = self._route(adapter)
        local = self.engines[i].add_request(prompt, max_new_tokens,
                                            adapter=adapter)
        self._routing["routed"].inc()
        self._routing[kind].inc()
        crid = self._next_crid
        self._next_crid += 1
        self._rid_map[(i, local)] = crid
        return crid

    # -- rebalance / drain ----------------------------------------------------
    def rebalance(self) -> int:
        """Move queued (never-admitted) requests from the most- to the
        least-loaded replica until the spread is within
        ``rebalance_margin``. Moves only backlog — in-flight slots stay."""
        moved = 0
        while True:
            hi = max(range(len(self.engines)),
                     key=lambda i: (self.engines[i].load, -i))
            lo = self._least_loaded(exclude=hi)
            if (lo == hi or self.engines[hi].queue_depth == 0 or
                    self.engines[hi].load - self.engines[lo].load
                    <= self.rebalance_margin):
                return moved
            req = self.engines[hi].steal_queued()
            if req is None:
                return moved
            crid = self._rid_map.pop((hi, req.rid))
            self._rid_map[(lo, self.engines[lo].submit(req))] = crid
            self._routing["rebalanced"].inc()
            moved += 1

    def drain(self, idx: int) -> int:
        """Drain replica ``idx``'s whole backlog onto its siblings
        (overload relief / taking a replica out of rotation)."""
        if len(self.engines) < 2:
            return 0
        moved = 0
        while self.engines[idx].queue_depth:
            req = self.engines[idx].steal_queued()
            crid = self._rid_map.pop((idx, req.rid))
            lo = self._least_loaded(exclude=idx)
            self._rid_map[(lo, self.engines[lo].submit(req))] = crid
            self._routing["rebalanced"].inc()
            moved += 1
        return moved

    # -- engine surface -------------------------------------------------------
    @property
    def idle(self) -> bool:
        return all(e.idle for e in self.engines)

    @property
    def num_active(self) -> int:
        return sum(e.num_active for e in self.engines)

    @property
    def queue_depth(self) -> int:
        return sum(e.queue_depth for e in self.engines)

    def add_wall(self, dt: float) -> None:
        self._wall += dt

    def _collect(self) -> None:
        """Pull finished requests out of the replicas, re-keyed to cluster
        rids (per-engine rids collide across replicas by construction)."""
        for i, eng in enumerate(self.engines):
            for r in eng.drain_finished():
                crid = self._rid_map.pop((i, r.rid))
                r.rid = crid
                self.finished.append(r)
                self._results[crid] = r.output

    def step(self) -> bool:
        """One cluster tick: rebalance backlog, LAUNCH every replica's
        decode step, then commit them in launch order — device work
        overlaps across replicas while the host syncs one at a time."""
        if self.auto_rebalance and len(self.engines) > 1:
            self.rebalance()
        pending = [eng.step_launch() for eng in self.engines]
        alive = [eng.step_commit(p)
                 for eng, p in zip(self.engines, pending)]
        self._collect()
        return any(alive)

    def run(self) -> Dict[int, List[int]]:
        """Drain all replicas to completion; {cluster rid: tokens}."""
        t0 = time.perf_counter()
        while self.step():
            pass
        self.add_wall(time.perf_counter() - t0)
        out, self._results = self._results, {}
        return out

    def drain_finished(self) -> List[Request]:
        out, self.finished = self.finished, []
        for r in out:
            self._results.pop(r.rid, None)
        return out

    # -- stats ----------------------------------------------------------------
    @property
    def routing(self) -> Dict[str, int]:
        """Read-only value view of the routing counters (pre-obs keys)."""
        return {k: c.value for k, c in self._routing.items()}

    @property
    def stats(self) -> Dict[str, Any]:
        """Single-engine-shaped aggregate (the keys ``describe`` and the
        benches read). Computed on access — mutate via ``add_wall``."""
        agg = {"requests": 0, "tokens_generated": 0, "decode_steps": 0,
               "prefills": 0, "admission_stalls": 0}
        for eng in self.engines:
            for k in agg:
                agg[k] += eng.stats[k]
        agg["wall_s"] = self._wall
        return agg

    def adapter_stats(self) -> Optional[Dict[str, Any]]:
        per = [eng.adapter_stats() for eng in self.engines]
        per = [p for p in per if p is not None]
        if not per:
            return None
        n = len(per)
        out = {"hits": sum(p["hits"] for p in per),
               "misses": sum(p["misses"] for p in per),
               "evictions": sum(p["evictions"] for p in per),
               "max_resident": sum(p["max_resident"] for p in per),
               "capacity": sum(p["capacity"] for p in per),
               "page_in_ms_p95": max(p["page_in_ms_p95"] for p in per),
               "compaction_ratio": sum(p["compaction_ratio"]
                                       for p in per) / n}
        seen = out["hits"] + out["misses"]
        out["hit_rate"] = out["hits"] / seen if seen else 0.0
        return out

    def kv_stats(self) -> Optional[Dict[str, int]]:
        per = [eng.kv_stats() for eng in self.engines
               if hasattr(eng, "kv_stats")]
        return merge_pool_stats(per) if per else None

    def affinity_hit_rate(self) -> float:
        """Fraction of REPEAT-adapter arrivals routed to their warm home.
        First sightings are compulsory cold starts and 'base' traffic has
        no affinity to hit — neither belongs in the denominator."""
        h = self.routing["affinity_hits"]
        s = self.routing["affinity_spills"]
        return h / (h + s) if h + s else 1.0

    def cluster_stats(self) -> Dict[str, Any]:
        """The one serving report: aggregate + routing + per-replica rows.
        The launcher prints this for N=1 too — single-engine output is
        the degenerate case, not a separate formatter."""
        agg = dict(self.stats)
        wall = agg["wall_s"]
        agg["tok_s"] = agg["tokens_generated"] / wall if wall > 0 else 0.0
        routing = dict(self.routing)
        routing["affinity_hit_rate"] = self.affinity_hit_rate()
        per = []
        for eng in self.engines:
            per.append({
                "queue_depth": eng.queue_depth,
                "active": eng.num_active,
                "requests": eng.stats["requests"],
                "tokens_generated": eng.stats["tokens_generated"],
                "decode_steps": eng.stats["decode_steps"],
                "prefills": eng.stats["prefills"],
                "admission_stalls": eng.stats["admission_stalls"],
                "adapter": eng.adapter_stats(),
                "kv": (eng.kv_stats() if hasattr(eng, "kv_stats")
                       else None),
            })
        return {"replicas": len(self.engines), "aggregate": agg,
                "routing": routing, "per_replica": per,
                "slo": self.slo.report() if self.slo is not None else None}


def format_cluster_report(cs: Dict[str, Any]) -> str:
    """Human-readable ``cluster_stats()`` — shared by the launcher (N>=1)
    and the bench logs."""
    agg, routing = cs["aggregate"], cs["routing"]
    lines = [f"cluster: {cs['replicas']} replica(s), "
             f"{agg['requests']} requests, {agg['tokens_generated']} tokens "
             f"in {agg['wall_s']:.2f}s ({agg['tok_s']:.1f} tok/s, "
             f"{agg['decode_steps']} decode steps, "
             f"{agg['prefills']} prefills, "
             f"{agg['admission_stalls']} stalls)"]
    if routing["routed"]:
        lines.append(
            f"routing: {routing['routed']} routed "
            f"(base={routing['base']} fresh={routing['fresh']} "
            f"hits={routing['affinity_hits']} "
            f"spills={routing['affinity_spills']} "
            f"rebalanced={routing['rebalanced']}) "
            f"affinity_hit_rate={routing['affinity_hit_rate']:.2f}")
    for i, row in enumerate(cs["per_replica"]):
        lines.append(f"  replica[{i}]: requests={row['requests']} "
                     f"tokens={row['tokens_generated']} "
                     f"steps={row['decode_steps']} "
                     f"stalls={row['admission_stalls']}")
        ad = row["adapter"]
        if ad is not None:
            lines.append(f"    bank: hit_rate={ad['hit_rate']:.2f} "
                         f"page_ins={ad['misses']} "
                         f"evictions={ad['evictions']} "
                         f"resident<={ad['max_resident']}/{ad['capacity']}")
        kv = row["kv"]
        if kv is not None:
            lines.append(f"    kv: pool={kv['num_pages']}x"
                         f"{kv['page_size']}tok alloc={kv['alloc']} "
                         f"prefix_hits={kv['prefix_hits']} "
                         f"kv_stalls={kv['kv_stalls']}")
    if cs.get("slo") is not None:
        lines.append(SLOMonitor.format_report(cs["slo"]))
    return "\n".join(lines)
