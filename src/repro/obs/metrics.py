"""The metrics plane: one process-wide registry of typed instruments.

Before ISSUE 10 every serving layer kept a private ``counters`` dict —
``KVPagePool``, ``PagedAdapterBank``, the three engines' ``stats``, the
cluster's ``routing`` — four ad-hoc schemas with no way to ask "what is
this process doing" in one query, and one of them (the bank's
``page_in_ms`` list) grew without bound under long-running traffic.

This module replaces all of them with three instrument types registered
into a :class:`MetricsRegistry`:

``Counter``     monotonically increasing int/float (``inc``).
``Gauge``       last-written value (``set`` / ``set_max``).
``Histogram``   BOUNDED observation reservoir: a ``deque(maxlen=cap)``
                keeps the most recent ``cap`` samples for percentile
                queries while ``count``/``sum`` stream exactly — constant
                memory no matter how long the process serves.

Owners of instruments (a KV pool, a bank, an engine) take a
:class:`MetricsScope` from the process registry: ``REGISTRY.scope("kvpool")``
hands back a namespace whose instruments land in the registry under
``kvpool/...`` (auto-uniquified ``kvpool:1/...`` for the second pool, so
N replicas never collide). The owners' pre-existing ``stats()`` /
``kv_stats()`` / ``adapter_stats()`` surfaces become THIN VIEWS over
their instruments — same keys, one source of truth — and
``REGISTRY.snapshot()`` is the whole process in one flat dict.

Everything here is plain single-threaded host bookkeeping (the engines
are single-threaded schedulers); there are deliberately no locks and no
background threads, so an instrument update is an attribute add — cheap
enough for the decode hot loop's per-token accounting.
"""
from __future__ import annotations

import collections
from typing import Dict, Iterable, List, Optional, Union

import numpy as np

Number = Union[int, float]

#: default Histogram reservoir size — large enough for stable p99s, small
#: enough that a histogram can never be a leak
DEFAULT_HIST_CAP = 1024


class Counter:
    """Monotonic accumulator (ints or float seconds both welcome)."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str):
        self.name = name
        self._v: Number = 0

    def inc(self, n: Number = 1) -> None:
        self._v += n

    @property
    def value(self) -> Number:
        return self._v

    def __repr__(self) -> str:
        return f"Counter({self.name}={self._v})"


class Gauge:
    """Last-written value (resident counts, high-water marks via set_max)."""

    __slots__ = ("name", "_v")

    def __init__(self, name: str):
        self.name = name
        self._v: Number = 0

    def set(self, v: Number) -> None:
        self._v = v

    def set_max(self, v: Number) -> None:
        """High-water mark: keep the larger of current and ``v``."""
        if v > self._v:
            self._v = v

    @property
    def value(self) -> Number:
        return self._v

    def __repr__(self) -> str:
        return f"Gauge({self.name}={self._v})"


class Histogram:
    """Bounded-reservoir histogram: exact streaming count/sum, percentiles
    over the most recent ``cap`` observations. Replaces the append-forever
    latency lists (the ``page_in_ms`` leak) with constant memory."""

    __slots__ = ("name", "cap", "_buf", "_count", "_sum")

    def __init__(self, name: str, cap: int = DEFAULT_HIST_CAP):
        if cap < 1:
            raise ValueError("histogram cap must be >= 1")
        self.name = name
        self.cap = cap
        self._buf: "collections.deque[float]" = collections.deque(maxlen=cap)
        self._count = 0
        self._sum = 0.0

    def observe(self, v: Number) -> None:
        self._buf.append(float(v))
        self._count += 1
        self._sum += float(v)

    @property
    def count(self) -> int:
        """Total observations ever (not capped)."""
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    @property
    def mean(self) -> float:
        return self._sum / self._count if self._count else 0.0

    def __len__(self) -> int:
        """Samples currently held — never exceeds ``cap``."""
        return len(self._buf)

    def percentile(self, q: Number) -> float:
        if not self._buf:
            return 0.0
        return float(np.percentile(np.asarray(self._buf), q))

    def percentiles(self, qs: Iterable[Number] = (50, 95, 99)
                    ) -> Dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}

    def __repr__(self) -> str:
        return (f"Histogram({self.name}, n={self._count}, "
                f"held={len(self._buf)}/{self.cap})")


Instrument = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Flat name -> instrument map. ``REGISTRY`` (below) is the process-
    wide instance every serving component registers into; fresh registries
    exist for tests and for isolated tooling."""

    def __init__(self):
        self._instruments: Dict[str, Instrument] = {}
        self._prefixes: Dict[str, int] = {}

    # -- instrument constructors (idempotent per name) ------------------------
    def _make(self, name: str, factory, kind) -> Instrument:
        inst = self._instruments.get(name)
        if inst is None:
            inst = self._instruments[name] = factory(name)
        elif not isinstance(inst, kind):
            raise TypeError(f"instrument {name!r} already registered as "
                            f"{type(inst).__name__}, not {kind.__name__}")
        return inst

    def counter(self, name: str) -> Counter:
        return self._make(name, Counter, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._make(name, Gauge, Gauge)

    def histogram(self, name: str, cap: int = DEFAULT_HIST_CAP) -> Histogram:
        return self._make(name, lambda n: Histogram(n, cap), Histogram)

    # -- namespacing ----------------------------------------------------------
    def scope(self, prefix: str) -> "MetricsScope":
        """A namespaced view whose instruments land under ``prefix/``.
        Repeat prefixes auto-uniquify (``kvpool``, ``kvpool:1``, ...) so N
        replicas of the same component never share instruments."""
        n = self._prefixes.get(prefix, 0)
        self._prefixes[prefix] = n + 1
        return MetricsScope(self, prefix if n == 0 else f"{prefix}:{n}")

    # -- queries --------------------------------------------------------------
    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def names(self) -> List[str]:
        return sorted(self._instruments)

    def snapshot(self, prefix: str = "") -> Dict[str, Number]:
        """The whole plane as one flat dict. Histograms expand into
        ``name.count`` / ``name.mean`` / ``name.p50|p95|p99``."""
        out: Dict[str, Number] = {}
        for name, inst in sorted(self._instruments.items()):
            if prefix and not name.startswith(prefix):
                continue
            if isinstance(inst, Histogram):
                out[f"{name}.count"] = inst.count
                out[f"{name}.mean"] = inst.mean
                for k, v in inst.percentiles().items():
                    out[f"{name}.{k}"] = v
            else:
                out[name] = inst.value
        return out

    def reset(self) -> None:
        """Drop every instrument and prefix (test isolation)."""
        self._instruments.clear()
        self._prefixes.clear()


class MetricsScope:
    """Prefix-qualified instrument constructor bound to one registry."""

    def __init__(self, registry: MetricsRegistry, prefix: str):
        self.registry = registry
        self.prefix = prefix

    def _q(self, name: str) -> str:
        return f"{self.prefix}/{name}"

    def counter(self, name: str) -> Counter:
        return self.registry.counter(self._q(name))

    def gauge(self, name: str) -> Gauge:
        return self.registry.gauge(self._q(name))

    def histogram(self, name: str, cap: int = DEFAULT_HIST_CAP) -> Histogram:
        return self.registry.histogram(self._q(name), cap)

    def counters(self, *names: str) -> Dict[str, Counter]:
        """A batch of counters keyed by their SHORT names — the migration
        shim for what used to be an ad-hoc ``{"alloc": 0, ...}`` dict."""
        return {n: self.counter(n) for n in names}


#: the process-wide plane — serving components register into this one
REGISTRY = MetricsRegistry()
