"""Sliding-window SLO monitor over finished request traces.

The :class:`SLOMonitor` keeps the last ``window`` finished requests
(deque — constant memory like every obs buffer) and answers the serving
questions operators actually ask:

* TTFT p50/p95/p99 (ms) — how long until a request streams?
* TPOT p50/p95/p99 (ms) — how smooth is decode once it starts?
* tok/s over the window — is the fleet keeping up?
* stall rate and per-reason stall counts — WHICH resource is the
  bottleneck when it is not?

``report()`` renders all of that as one flat-ish dict that
``format_cluster_report`` and ``launch/serve.py --report-interval``
print, and that ``serve_bench`` records next to its throughput numbers.

Thresholds turn the monitor into a control input: register
``on_breach`` / ``on_clear`` callbacks and the cluster can shed or
re-admit load when p95 TTFT crosses a line (admission backpressure).
Callbacks fire only on TRANSITIONS (ok→breach, breach→ok), not every
observation, so a hovering metric does not flap the caller.
"""
from __future__ import annotations

import collections
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

#: report percentiles for both TTFT and TPOT
SLO_PERCENTILES = (50, 95, 99)


def _pcts(samples_ms: List[float]) -> Dict[str, float]:
    if not samples_ms:
        return {f"p{q}": 0.0 for q in SLO_PERCENTILES}
    arr = np.asarray(samples_ms)
    return {f"p{q}": float(np.percentile(arr, q)) for q in SLO_PERCENTILES}


class SLOMonitor:
    """Window of the last ``window`` finished :class:`RequestTrace`-likes.

    Anything with ``ttft_s``, ``tpot_s``, ``n_tokens``, ``t_submit``,
    ``t_finish`` and ``stalls`` duck-types in; in practice it is fed by
    ``TraceRecorder.finish`` (pass the monitor as ``TraceRecorder(slo=...)``).

    ``thresholds`` maps a metric path (``"ttft_ms.p95"``, ``"tpot_ms.p99"``,
    ``"stall_rate"``, ``"tok_s"``) to a ceiling — except ``tok_s``, which
    is a FLOOR (too slow is the breach). Breach state is re-evaluated per
    ``observe``.
    """

    def __init__(self, window: int = 256,
                 thresholds: Optional[Dict[str, float]] = None):
        if window < 1:
            raise ValueError("SLO window must be >= 1")
        self.window = window
        self.thresholds = dict(thresholds or {})
        self._traces: "collections.deque" = collections.deque(maxlen=window)
        self._total = 0
        self._breached: Dict[str, bool] = {m: False for m in self.thresholds}
        self._on_breach: List[Callable[[str, float, float], None]] = []
        self._on_clear: List[Callable[[str, float, float], None]] = []

    # -- feeding --------------------------------------------------------------
    def observe(self, trace) -> None:
        self._traces.append(trace)
        self._total += 1
        if self.thresholds:
            self._check()

    @property
    def total_observed(self) -> int:
        """Requests ever observed (the window only bounds retention)."""
        return self._total

    def __len__(self) -> int:
        return len(self._traces)

    # -- thresholds / backpressure --------------------------------------------
    def on_breach(self, fn: Callable[[str, float, float], None]) -> None:
        """``fn(metric, value, threshold)`` fires when a metric FIRST
        crosses its threshold (and again only after it clears)."""
        self._on_breach.append(fn)

    def on_clear(self, fn: Callable[[str, float, float], None]) -> None:
        self._on_clear.append(fn)

    @property
    def breached(self) -> Dict[str, bool]:
        return dict(self._breached)

    @property
    def any_breached(self) -> bool:
        return any(self._breached.values())

    def _metric(self, path: str, rep: Dict) -> float:
        cur = rep
        for part in path.split("."):
            cur = cur[part]
        return float(cur)

    def _check(self) -> None:
        rep = self.report()
        for metric, limit in self.thresholds.items():
            value = self._metric(metric, rep)
            # tok_s is a floor (breach = too slow); everything else a ceiling
            bad = value < limit if metric == "tok_s" else value > limit
            was = self._breached.get(metric, False)
            if bad and not was:
                self._breached[metric] = True
                for fn in self._on_breach:
                    fn(metric, value, limit)
            elif was and not bad:
                self._breached[metric] = False
                for fn in self._on_clear:
                    fn(metric, value, limit)

    # -- reporting ------------------------------------------------------------
    def _window_span(self) -> Tuple[float, int]:
        """(wall seconds covered by the window, tokens in it)."""
        if not self._traces:
            return 0.0, 0
        t0 = min(tr.t_submit for tr in self._traces)
        t1 = max(tr.t_finish for tr in self._traces)
        toks = sum(tr.n_tokens for tr in self._traces)
        return max(t1 - t0, 1e-9), toks

    def report(self) -> Dict:
        """The SLO surface: percentile latencies, window throughput, stall
        attribution, and current breach flags."""
        ttft = [tr.ttft_s * 1e3 for tr in self._traces]
        tpot = [g * 1e3 for tr in self._traces for g in tr.tpot_s]
        stalls: Dict[str, int] = {}
        stalled_reqs = 0
        for tr in self._traces:
            if tr.stalls:
                stalled_reqs += 1
            for reason, n in tr.stalls.items():
                stalls[reason] = stalls.get(reason, 0) + n
        span_s, toks = self._window_span()
        n = len(self._traces)
        return {
            "window_requests": n,
            "total_requests": self._total,
            "ttft_ms": _pcts(ttft),
            "tpot_ms": _pcts(tpot),
            "tok_s": toks / span_s if n else 0.0,
            "stall_rate": stalled_reqs / n if n else 0.0,
            "stalls": stalls,
            "breached": [m for m, b in self._breached.items() if b],
        }

    @staticmethod
    def format_report(rep: Dict) -> str:
        """One human line per concern — what --report-interval prints."""
        t, p = rep["ttft_ms"], rep["tpot_ms"]
        lines = [
            f"slo: {rep['window_requests']} req in window "
            f"({rep['total_requests']} total), {rep['tok_s']:.1f} tok/s",
            f"  ttft_ms p50={t['p50']:.2f} p95={t['p95']:.2f} "
            f"p99={t['p99']:.2f}",
            f"  tpot_ms p50={p['p50']:.2f} p95={p['p95']:.2f} "
            f"p99={p['p99']:.2f}",
            f"  stall_rate={rep['stall_rate']:.3f} stalls={rep['stalls']}",
        ]
        if rep["breached"]:
            lines.append(f"  BREACH: {', '.join(rep['breached'])}")
        return "\n".join(lines)
