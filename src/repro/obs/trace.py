"""Per-request trace spans: where a token's time goes.

A :class:`TraceRecorder` follows every request through the engine tick
loop as a sequence of SPANS and EVENTS on one wall clock
(``time.perf_counter``):

    submit ──(queue/adapter/kv stalls)──> prefill chunk(s) ──> first token
           ──> decode token ... decode token ──> finish

From those it derives the two serving latencies the SLO monitor and the
benches report:

    TTFT  time-to-first-token   = t_first  - t_submit
    TPOT  per-token decode gap  = diffs of the token timestamps

and STALL ATTRIBUTION — each tick an engine cannot admit the queue head
it records why (``kv`` pool exhausted, ``adapter`` bank fully pinned, or
plain ``queue`` head-of-line waiting on a slot), so a latency regression
names the resource that caused it.

Engines call the recorder only when one is attached (``tracer=None`` is
the default and costs nothing); every hook is a couple of float appends,
which is what keeps tracing-on throughput within 5% of off — a bound
``benchmarks/serve_bench.py`` asserts.

Keys are ``(engine_tag, rid)``: each engine registers itself once
(:meth:`TraceRecorder.register_engine`) so a cluster of replicas records
into ONE recorder without rid collisions. A rebalanced request is
``drop``-ed by the engine it is stolen from and re-``submit``-ed (with
its original submit timestamp) by the engine that receives it.

Finished traces export as JSON-lines (one event per line — greppable,
streamable) or as the Chrome ``trace_event`` format readable by
``chrome://tracing`` / Perfetto. An opt-in ``jax.profiler`` hook
(``annotate``) wraps the jitted prefill/decode dispatches in named
``TraceAnnotation`` blocks so device profiles line up with host spans.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import time
from typing import Any, Dict, List, Optional, TextIO, Tuple

from .metrics import REGISTRY, MetricsRegistry
from .slo import SLOMonitor

#: stall attribution reasons engines may record
STALL_REASONS = ("kv", "adapter", "queue")


@dataclasses.dataclass
class RequestTrace:
    """One request's lifecycle. Times are ``perf_counter`` seconds."""

    engine: str
    rid: int
    adapter: Optional[str] = None
    prompt_len: int = 0
    t_submit: float = 0.0
    t_first: float = 0.0
    t_finish: float = 0.0
    #: (start, end) of each prefill dispatch — one span for whole-prompt
    #: prefill, one per chunk under chunked prefill
    prefill_spans: List[Tuple[float, float]] = \
        dataclasses.field(default_factory=list)
    #: commit timestamp of every generated token (first token included)
    token_times: List[float] = dataclasses.field(default_factory=list)
    #: ticks spent stalled at admission, by reason
    stalls: Dict[str, int] = dataclasses.field(default_factory=dict)

    # -- derived latencies ----------------------------------------------------
    @property
    def ttft_s(self) -> float:
        return self.t_first - self.t_submit

    @property
    def tpot_s(self) -> List[float]:
        """Decode gaps between consecutive token commits (n_tokens - 1
        entries; empty for single-token requests)."""
        tt = self.token_times
        return [tt[i + 1] - tt[i] for i in range(len(tt) - 1)]

    @property
    def n_tokens(self) -> int:
        return len(self.token_times)

    @property
    def prefill_s(self) -> float:
        return sum(t1 - t0 for t0, t1 in self.prefill_spans)

    @property
    def complete(self) -> bool:
        """Did this request record its full lifecycle? (submit, at least
        one prefill span, a first token, and a finish, in order)."""
        return (self.t_submit > 0.0 and bool(self.prefill_spans)
                and self.t_first >= self.t_submit
                and self.t_finish >= self.t_first
                and bool(self.token_times))

    # -- export ---------------------------------------------------------------
    def events(self) -> List[Dict[str, Any]]:
        """Flat event records (JSONL rows), times in absolute seconds."""
        base = {"engine": self.engine, "rid": self.rid}
        if self.adapter is not None:
            base["adapter"] = self.adapter
        ev = [dict(base, event="submit", t=self.t_submit,
                   prompt_len=self.prompt_len)]
        for reason, n in sorted(self.stalls.items()):
            ev.append(dict(base, event="stall", reason=reason, ticks=n))
        for t0, t1 in self.prefill_spans:
            ev.append(dict(base, event="prefill", t=t0, dur_s=t1 - t0))
        if self.t_first:
            ev.append(dict(base, event="first_token", t=self.t_first,
                           ttft_ms=self.ttft_s * 1e3))
        for t in self.token_times[1:]:
            ev.append(dict(base, event="token", t=t))
        if self.t_finish:
            ev.append(dict(base, event="finish", t=self.t_finish,
                           n_tokens=self.n_tokens))
        return ev


class TraceRecorder:
    """Collects :class:`RequestTrace` records from one or more engines.

    ``slo``: an optional :class:`SLOMonitor` fed every finished trace.
    ``jax_annotations``: wrap ``annotate``-d dispatches in
    ``jax.profiler.TraceAnnotation`` so a ``jax.profiler.trace`` capture
    shows named prefill/decode blocks (off by default — it is only useful
    under an active profiler session).
    ``max_finished`` bounds the finished-trace buffer (ring semantics)
    the same way histograms bound their reservoirs; drivers that export
    should call ``drain`` or ``export_*`` periodically.
    """

    def __init__(self, *, slo: Optional[SLOMonitor] = None,
                 jax_annotations: bool = False,
                 registry: Optional[MetricsRegistry] = None,
                 max_finished: int = 65536,
                 clock=time.perf_counter):
        self.slo = slo
        self.jax_annotations = jax_annotations
        self.clock = clock
        self.max_finished = max_finished
        self._pending: Dict[Tuple[str, int], RequestTrace] = {}
        self.finished: List[RequestTrace] = []
        self._tags: Dict[str, int] = {}
        scope = (registry or REGISTRY).scope("trace")
        self._c = scope.counters(
            "submitted", "finished", "dropped", "tokens",
            *(f"stalls_{r}" for r in STALL_REASONS))

    # -- engine registration --------------------------------------------------
    def register_engine(self, kind: str = "engine") -> str:
        """A unique tag for one engine's requests (``serve0``, ``serve1``,
        ``paged0``...): rids are per-engine, tags make them global."""
        n = self._tags.get(kind, 0)
        self._tags[kind] = n + 1
        return f"{kind}{n}"

    # -- lifecycle hooks (engines call these) ---------------------------------
    def submit(self, tag: str, rid: int, adapter: Optional[str] = None,
               prompt_len: int = 0,
               t_submit: Optional[float] = None) -> None:
        """New request. ``t_submit`` carries the ORIGINAL timestamp when a
        rebalanced request re-enters on another engine."""
        self._pending[(tag, rid)] = RequestTrace(
            engine=tag, rid=rid, adapter=adapter, prompt_len=prompt_len,
            t_submit=self.clock() if t_submit is None else t_submit)
        self._c["submitted"].inc()

    def stall(self, tag: str, rid: int, reason: str) -> None:
        """The engine could not admit this (queue-head) request this tick:
        ``kv`` = page pool exhausted, ``adapter`` = bank slots all pinned,
        ``queue`` = no free decode slot."""
        tr = self._pending.get((tag, rid))
        if tr is not None:
            tr.stalls[reason] = tr.stalls.get(reason, 0) + 1
        self._c[f"stalls_{reason}"].inc()

    def prefill_start(self, tag: str, rid: int) -> None:
        tr = self._pending.get((tag, rid))
        if tr is not None:
            tr.prefill_spans.append((self.clock(), 0.0))

    def prefill_end(self, tag: str, rid: int) -> None:
        tr = self._pending.get((tag, rid))
        if tr is not None and tr.prefill_spans:
            t0, _ = tr.prefill_spans[-1]
            tr.prefill_spans[-1] = (t0, self.clock())

    def first_token(self, tag: str, rid: int) -> None:
        tr = self._pending.get((tag, rid))
        if tr is not None:
            tr.t_first = self.clock()
            tr.token_times.append(tr.t_first)
            self._c["tokens"].inc()

    def token(self, tag: str, rid: int) -> None:
        tr = self._pending.get((tag, rid))
        if tr is not None:
            tr.token_times.append(self.clock())
            self._c["tokens"].inc()

    def drop(self, tag: str, rid: int) -> None:
        """Forget a pending trace — the request left this engine (cluster
        rebalance steals it from the queue; it re-submits elsewhere)."""
        if self._pending.pop((tag, rid), None) is not None:
            self._c["dropped"].inc()

    def finish(self, tag: str, rid: int) -> Optional[RequestTrace]:
        tr = self._pending.pop((tag, rid), None)
        if tr is None:
            return None
        tr.t_finish = self.clock()
        self.finished.append(tr)
        if len(self.finished) > self.max_finished:     # bounded ring
            del self.finished[:-self.max_finished // 2]
        self._c["finished"].inc()
        if self.slo is not None:
            self.slo.observe(tr)
        return tr

    @property
    def pending_count(self) -> int:
        return len(self._pending)

    def drain(self) -> List[RequestTrace]:
        out, self.finished = self.finished, []
        return out

    # -- jax profiler hook ----------------------------------------------------
    def annotate(self, name: str):
        """Context manager for a jitted dispatch: a named
        ``jax.profiler.TraceAnnotation`` when ``jax_annotations`` is on,
        otherwise a no-op."""
        if not self.jax_annotations:
            return contextlib.nullcontext()
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)

    # -- export ---------------------------------------------------------------
    def export_jsonl(self, path_or_file) -> int:
        """One JSON event per line for every finished trace, in finish
        order; returns the number of lines written."""
        n = 0
        with _open(path_or_file, "w") as f:
            for tr in self.finished:
                for ev in tr.events():
                    f.write(json.dumps(ev, sort_keys=True) + "\n")
                    n += 1
        return n

    def export_chrome(self, path_or_file) -> int:
        """Chrome ``trace_event`` JSON (load in chrome://tracing or
        Perfetto): one row (tid) per engine, an X span per request and per
        prefill chunk, instant events for tokens. Returns event count."""
        if not self.finished:
            t0 = 0.0
        else:
            t0 = min(tr.t_submit for tr in self.finished)
        tids = {tag: i + 1 for i, tag in
                enumerate(sorted({tr.engine for tr in self.finished}))}

        def us(t: float) -> float:
            return (t - t0) * 1e6

        events: List[Dict[str, Any]] = [
            {"name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
             "args": {"name": tag}} for tag, tid in tids.items()]
        for tr in self.finished:
            tid = tids[tr.engine]
            args = {"rid": tr.rid, "adapter": tr.adapter,
                    "prompt_len": tr.prompt_len, "n_tokens": tr.n_tokens,
                    "ttft_ms": tr.ttft_s * 1e3, "stalls": tr.stalls}
            events.append({"name": f"request {tr.rid}", "cat": "request",
                           "ph": "X", "pid": 1, "tid": tid,
                           "ts": us(tr.t_submit),
                           "dur": us(tr.t_finish) - us(tr.t_submit),
                           "args": args})
            for t0s, t1s in tr.prefill_spans:
                events.append({"name": "prefill", "cat": "prefill",
                               "ph": "X", "pid": 1, "tid": tid,
                               "ts": us(t0s), "dur": us(t1s) - us(t0s),
                               "args": {"rid": tr.rid}})
            for t in tr.token_times:
                events.append({"name": "token", "cat": "decode", "ph": "i",
                               "s": "t", "pid": 1, "tid": tid, "ts": us(t),
                               "args": {"rid": tr.rid}})
        with _open(path_or_file, "w") as f:
            json.dump({"traceEvents": events,
                       "displayTimeUnit": "ms"}, f)
        return len(events)


@contextlib.contextmanager
def _open(path_or_file, mode: str):
    if hasattr(path_or_file, "write"):
        yield path_or_file                       # caller-owned handle
    else:
        f: TextIO = open(path_or_file, mode)
        try:
            yield f
        finally:
            f.close()
