"""repro.obs — the stack's one observability plane.

Three small pieces, one rule: every serving-layer statistic lives in the
process-wide :data:`REGISTRY`, and the pre-existing ``stats()`` surfaces
are thin views over it.

* :mod:`repro.obs.metrics` — typed instruments (Counter / Gauge /
  bounded Histogram) in a :class:`MetricsRegistry`.
* :mod:`repro.obs.trace` — per-request lifecycle spans with TTFT/TPOT
  and stall attribution; JSONL + Chrome ``trace_event`` export.
* :mod:`repro.obs.slo` — sliding-window percentile monitor with
  threshold callbacks for admission backpressure.

The bench-regression gate lives with the benches it gates:
``benchmarks/check_regress.py``.
"""
from .metrics import (
    DEFAULT_HIST_CAP,
    REGISTRY,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    MetricsScope,
)
from .slo import SLO_PERCENTILES, SLOMonitor
from .trace import STALL_REASONS, RequestTrace, TraceRecorder

__all__ = [
    "DEFAULT_HIST_CAP",
    "REGISTRY",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "MetricsScope",
    "SLO_PERCENTILES",
    "SLOMonitor",
    "STALL_REASONS",
    "RequestTrace",
    "TraceRecorder",
]
