"""Attention: GQA/MQA/MHA with chunked online-softmax (long-context-safe),
RoPE, KV caches for serving, optional exact-triangular prefill schedule.

Memory behaviour: the KV sequence is processed in ``attn_chunk`` slices with
running (max, denom, acc) statistics — peak score memory is
O(Sq * chunk * heads) instead of O(Sq * Sk * heads), which is what makes
prefill_32k and the 500k-token decode lowerable.  GQA never materializes
repeated KV heads (grouped einsum).

``attn_impl="prefix_loop"`` is the beyond-paper perf variant: an unrolled
query-chunk loop where chunk i only contracts against keys [0 : (i+1)*c],
cutting causal-attention FLOPs ~2x vs the dense-mask schedule (§Perf).
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels.ops import paged_attention
from .layers import (Shard, apply_rope, dense_init, no_shard, qlinear,
                     stacked_dense_init)

Array = jnp.ndarray

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# params
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, stacked: int = 0, d: int = 0,
                   dtype=None) -> Dict[str, Array]:
    d = d or cfg.d_model
    dtype = dtype or cfg.weight_dtype
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    ks = jax.random.split(key, 4)
    mk = (lambda k, di, do: stacked_dense_init(k, stacked, di, do, dtype)
          if stacked else dense_init(k, di, do, dtype))
    p = {"wq": mk(ks[0], d, H * hd),
         "wk": mk(ks[1], d, K * hd),
         "wv": mk(ks[2], d, K * hd),
         "wo": mk(ks[3], H * hd, d)}
    if cfg.qkv_bias:
        zeros = (lambda do: jnp.zeros((stacked, do) if stacked else (do,), dtype))
        p["bq"], p["bk"], p["bv"] = zeros(H * hd), zeros(K * hd), zeros(K * hd)
    return p


# ---------------------------------------------------------------------------
# chunked online-softmax core
# ---------------------------------------------------------------------------

def _gqa_scores(q: Array, k: Array) -> Array:
    """q: (B,Sq,K,G,D), k: (B,C,K,D) -> (B,Sq,K,G,C) without repeating KV."""
    return jnp.einsum("bqkgd,bckd->bqkgc", q, k,
                      preferred_element_type=jnp.float32)


def online_attention(q: Array, k: Array, v: Array, q_pos: Array,
                     k_start: int, kv_len, *, causal: bool, chunk: int,
                     scale: float) -> Array:
    """Chunked-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, K, D); q_pos: (B, Sq) absolute positions;
    k positions are k_start + arange(Sk); kv_len (scalar or (B,)) bounds the
    valid KV region (for partially-filled caches). Returns (B, Sq, H, D).
    """
    b, sq, h, dh = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    # §Perf iteration B: q/k stay in model dtype (MXU bf16 in, f32 out via
    # preferred_element_type) — halves the score-stage read traffic vs the
    # old fp32 upcast; max/denominator statistics remain fp32.
    qg = (q * scale).reshape(b, sq, kh, g, dh)
    nchunks = max(1, math.ceil(sk / chunk))
    c = math.ceil(sk / nchunks)
    pad = nchunks * c - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(b, nchunks, c, kh, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, nchunks, c, kh, dh).transpose(1, 0, 2, 3, 4)

    kv_len_arr = jnp.asarray(kv_len)
    if kv_len_arr.ndim == 0:
        kv_len_arr = jnp.broadcast_to(kv_len_arr, (b,))

    m0 = jnp.full((b, sq, kh, g), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, g), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, g, dh), jnp.float32)

    def step(carry, inp):
        m, l, acc, ci = carry
        kj, vj = inp
        kpos = k_start + ci * c + jnp.arange(c)                   # (c,)
        s = _gqa_scores(qg, kj)                                   # f32 out
        valid = kpos[None, None, :] < kv_len_arr[:, None, None]   # (B,1,c)
        if causal:
            valid = valid & (kpos[None, None, :] <= q_pos[:, :, None])
        s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
        mj = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m, mj)
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        # §Perf B: probabilities stored/multiplied in model dtype (halves the
        # P-stage traffic); the PV accumulator stays fp32.
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bqkgc,bckd->bqkgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new, ci + 1), None

    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, a0, 0), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, h, dh).astype(q.dtype)


def prefix_loop_attention(q: Array, k: Array, v: Array, *, chunk: int,
                          scale: float) -> Array:
    """Exact-triangular causal attention: query chunk i contracts only with
    keys [0:(i+1)c]. ~2x fewer FLOPs than the dense-mask schedule; unrolled
    (one dot shape per chunk), used for prefill (§Perf hillclimb)."""
    b, s, h, dh = q.shape
    if s % chunk:
        return online_attention(q, k, v, _positions(b, s), 0, s,
                                causal=True, chunk=chunk, scale=scale)
    nq = s // chunk
    outs = []
    for i in range(nq):
        qi = q[:, i * chunk:(i + 1) * chunk]
        kv_hi = (i + 1) * chunk
        pos = _positions(b, chunk) + i * chunk
        outs.append(online_attention(
            qi, k[:, :kv_hi], v[:, :kv_hi], pos, 0, kv_hi,
            causal=True, chunk=chunk, scale=scale))
    return jnp.concatenate(outs, axis=1)


def _positions(b: int, s: int) -> Array:
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))


# ---------------------------------------------------------------------------
# full attention block (projections + rope + core + output)
# ---------------------------------------------------------------------------

def _proj(x, w, bias=None, rot=None, name=""):
    y = qlinear(x, w, rot, name)
    if bias is not None:
        y = y + bias.astype(y.dtype)
    return y


def attention_block(p: Dict[str, Array], x: Array, cfg: ModelConfig, *,
                    positions: Optional[Array] = None,
                    kv_x: Optional[Array] = None,
                    cache: Optional[Dict[str, Array]] = None,
                    cache_pos: Optional[Array] = None,
                    causal: bool = True,
                    use_rope: bool = True,
                    shard: Shard = no_shard,
                    rot: Optional[Callable[[str, Array], Array]] = None,
                    ) -> Tuple[Array, Optional[Dict]]:
    """Self/cross attention with optional KV cache.

    ``rot(name, x)`` optionally rotates the input activations of projection
    ``name`` (wq/wk/wv/wo) — the activation-side GSOFT path used by the
    multi-adapter serving engine (x Q instead of merging Q into W).

    * training / prefill: cache=None or cache written from scratch
    * decode: x is (B, 1, D), cache holds (B, S, K, D), cache_pos = write idx
      — a scalar (lockstep batch) or an int32 (B,) array of per-row write
      positions (continuous batching: each slot carries its own counter)
    Returns (output, new_cache).
    """
    b, sq, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    src = x if kv_x is None else kv_x
    q = _proj(x, p["wq"], p.get("bq"), rot, "wq").reshape(b, sq, H, hd)
    k = _proj(src, p["wk"], p.get("bk"), rot, "wk").reshape(b, src.shape[1],
                                                           K, hd)
    v = _proj(src, p["wv"], p.get("bv"), rot, "wv").reshape(b, src.shape[1],
                                                            K, hd)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")

    if cache_pos is not None:
        cache_pos = jnp.asarray(cache_pos, jnp.int32)
    per_row = cache_pos is not None and cache_pos.ndim == 1
    if positions is None:
        positions = _positions(b, sq)
        if cache_pos is not None:
            positions = positions + (cache_pos[:, None] if per_row
                                     else cache_pos)
    if use_rope and kv_x is None:
        # self-attention: new K entries share the query positions (decode
        # writes exactly one key at position cache_pos == positions[:, 0])
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)

    scale = 1.0 / math.sqrt(hd)
    new_cache = None
    if cache is not None and cache_pos is not None and sq == 1:
        # decode: write this step's K/V, attend over the filled prefix
        if per_row:
            # per-slot write index: vmap the row update (lowered as scatter)
            upd = jax.vmap(
                lambda c, new, pp: jax.lax.dynamic_update_slice(
                    c, new, (pp, 0, 0)))
            ck = upd(cache["k"], k.astype(cache["k"].dtype), cache_pos)
            cv = upd(cache["v"], v.astype(cache["v"].dtype), cache_pos)
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, cache_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        out = online_attention(q, ck, cv, positions, 0, cache_pos + 1,
                               causal=False, chunk=cfg.attn_chunk, scale=scale)
    else:
        if cache is not None:  # prefill into cache
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            new_cache = {"k": ck, "v": cv}
        if causal and cfg.attn_impl == "prefix_loop" and kv_x is None:
            out = prefix_loop_attention(q, k, v, chunk=cfg.attn_chunk,
                                        scale=scale)
        else:
            out = online_attention(q, k, v, positions, 0, k.shape[1],
                                   causal=causal, chunk=cfg.attn_chunk,
                                   scale=scale)
    out = out.reshape(b, sq, H * hd)
    return shard(qlinear(out, p["wo"], rot, "wo"), "act_d"), new_cache


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> Dict[str, Array]:
    dtype = dtype or cfg.act_dtype
    K, hd = cfg.num_kv_heads, cfg.d_head
    return {"k": jnp.zeros((batch, max_len, K, hd), dtype),
            "v": jnp.zeros((batch, max_len, K, hd), dtype)}


# ---------------------------------------------------------------------------
# paged KV cache (ISSUE 7): fixed-size pages + per-slot page tables
# ---------------------------------------------------------------------------

def init_paged_kv(cfg: ModelConfig, num_pages: int, page_size: int,
                  dtype=None) -> Dict[str, Array]:
    """One layer's shared page pools. Page 0 is the GARBAGE page: parked /
    out-of-range table entries resolve there, so full-batch decode can write
    through every row's table unconditionally."""
    dtype = dtype or cfg.act_dtype
    K, hd = cfg.num_kv_heads, cfg.d_head
    return {"k": jnp.zeros((num_pages, page_size, K, hd), dtype),
            "v": jnp.zeros((num_pages, page_size, K, hd), dtype)}


def paged_attention_block(p: Dict[str, Array], x: Array, cfg: ModelConfig, *,
                          pages: Dict[str, Array], table: Array, pos: Array,
                          shard: Shard = no_shard,
                          rot: Optional[Callable] = None,
                          ) -> Tuple[Array, Dict[str, Array]]:
    """One decode step through the paged KV cache.

    x: (B, 1, D); pages: this layer's {"k","v"} (P, page, K, D) pools;
    table: (B, max_pages + 1) int32 — the LAST column is a sentinel that is
    always the garbage page, so a parked row (pos == max_pages * page) routes
    its write there and full-batch decode never needs masking; pos: (B,)
    int32 write positions. Returns (out, new_pages).
    """
    b, sq, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    q = _proj(x, p["wq"], p.get("bq"), rot, "wq").reshape(b, sq, H, hd)
    k = _proj(x, p["wk"], p.get("bk"), rot, "wk").reshape(b, sq, K, hd)
    v = _proj(x, p["wv"], p.get("bv"), rot, "wv").reshape(b, sq, K, hd)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")
    pos = jnp.asarray(pos, jnp.int32)
    positions = pos[:, None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    page = pages["k"].shape[1]
    pid = jnp.take_along_axis(table, (pos // page)[:, None], axis=1)[:, 0]
    off = pos % page
    kd = pages["k"].dtype
    new_pages = {"k": pages["k"].at[pid, off].set(k[:, 0].astype(kd)),
                 "v": pages["v"].at[pid, off].set(v[:, 0].astype(kd))}

    scale = 1.0 / math.sqrt(hd)
    attend_table = table[:, :-1]                    # drop the sentinel column
    if cfg.use_pallas:
        out = paged_attention(q[:, 0], new_pages["k"], new_pages["v"],
                              attend_table, pos + 1, scale=scale,
                              use_pallas=True)[:, None]
    else:
        # reference path: gather through the table, then the SAME chunked
        # online-softmax core as the contiguous cache (numerics parity)
        kt = new_pages["k"][attend_table].reshape(b, -1, K, hd)
        vt = new_pages["v"][attend_table].reshape(b, -1, K, hd)
        out = online_attention(q, kt, vt, positions, 0, pos + 1,
                               causal=False, chunk=cfg.attn_chunk,
                               scale=scale)
    out = out.reshape(b, sq, H * hd)
    return shard(qlinear(out, p["wo"], rot, "wo"), "act_d"), new_pages


def paged_prefill_chunk_block(p: Dict[str, Array], x: Array,
                              cfg: ModelConfig, *, pages: Dict[str, Array],
                              table_row: Array, start: Array,
                              shard: Shard = no_shard,
                              rot: Optional[Callable] = None,
                              ) -> Tuple[Array, Dict[str, Array]]:
    """One prompt CHUNK for one slot (batch of 1) through the paged cache.

    x: (1, C, D) chunk activations; table_row: (max_pages + 1,) int32 this
    slot's page table; start: int32 absolute position of the chunk's first
    token (previous chunks — and any shared-prefix pages claimed from the
    KV cache — already occupy [0, start)). Writes the chunk's K/V through
    the table and attends causally over [0, start + C).
    """
    b, c, _ = x.shape
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.d_head
    q = _proj(x, p["wq"], p.get("bq"), rot, "wq").reshape(b, c, H, hd)
    k = _proj(x, p["wk"], p.get("bk"), rot, "wk").reshape(b, c, K, hd)
    v = _proj(x, p["wv"], p.get("bv"), rot, "wv").reshape(b, c, K, hd)
    q = shard(q, "act_heads")
    k = shard(k, "act_kv_heads")
    v = shard(v, "act_kv_heads")
    start = jnp.asarray(start, jnp.int32)
    positions = start + _positions(b, c)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)

    page = pages["k"].shape[1]
    idx = start + jnp.arange(c)
    pid = table_row[idx // page]
    off = idx % page
    kd = pages["k"].dtype
    new_pages = {"k": pages["k"].at[pid, off].set(k[0].astype(kd)),
                 "v": pages["v"].at[pid, off].set(v[0].astype(kd))}

    # batch-1 chunk: gathering the whole row is cheap and reuses the chunked
    # online-softmax core (shared-prefix pages are read, never rewritten)
    kt = new_pages["k"][table_row[:-1]].reshape(1, -1, K, hd)
    vt = new_pages["v"][table_row[:-1]].reshape(1, -1, K, hd)
    out = online_attention(q, kt, vt, positions, 0, start + c,
                           causal=True, chunk=cfg.attn_chunk,
                           scale=1.0 / math.sqrt(hd))
    out = out.reshape(b, c, H * hd)
    return shard(qlinear(out, p["wo"], rot, "wo"), "act_d"), new_pages
