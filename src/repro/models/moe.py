"""Token-choice top-k Mixture-of-Experts with capacity (GShard/Switch style).

The dispatch/combine are expressed as one-hot einsums — the formulation GSPMD
was built for: with experts sharded over the ``model`` axis the two dispatch
einsums lower to all-to-alls, giving expert parallelism without manual
collectives.  Tokens are processed in segments (scan) so the (B, Sc, E, C)
dispatch tensor stays a bounded transient regardless of sequence length.

Router math in fp32; dropped tokens (beyond capacity) pass through the
residual (standard behaviour).  Load-balance aux loss per Switch §2.2.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from .layers import Shard, no_shard, stacked_dense_init

Array = jnp.ndarray


def init_moe(key, cfg: ModelConfig, stacked: int, dtype) -> Dict[str, Array]:
    d, fe, E = cfg.d_model, cfg.expert_d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)

    def experts(k, di, do):
        w = jax.random.normal(k, (stacked, E, di, do), jnp.float32)
        return (w / math.sqrt(di)).astype(dtype)

    p = {"router": stacked_dense_init(ks[0], stacked, d, E, jnp.float32),
         "wi": experts(ks[1], d, fe),
         "wo": experts(ks[3], fe, d)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        p["wg"] = experts(ks[2], d, fe)
    return p


def _capacity(cfg: ModelConfig, seg: int) -> int:
    return max(1, int(math.ceil(seg * cfg.moe_top_k * cfg.capacity_factor
                                / cfg.moe_experts)))


def moe_layer(p: Dict[str, Array], x: Array, cfg: ModelConfig,
              shard: Shard = no_shard, segment: int = 2048
              ) -> Tuple[Array, Array]:
    """x: (B, S, d) -> (y, aux_loss). p holds single-layer slices:
    router (d, E), wi/wg/wo (E, d, fe)/(E, fe, d)."""
    b, s, d = x.shape
    E, k = cfg.moe_experts, cfg.moe_top_k
    seg = min(segment, s)
    while s % seg:
        seg -= 1
    nseg = s // seg
    cap = _capacity(cfg, seg)
    xs = x.reshape(b, nseg, seg, d).transpose(1, 0, 2, 3)   # (nseg, B, seg, d)

    def one_segment(_, xseg):
        logits = (xseg @ p["router"].astype(xseg.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)             # (B, seg, E)
        gate, idx = jax.lax.top_k(probs, k)                 # (B, seg, k)
        gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)
        # §Perf iteration F: build dispatch/combine one top-k choice at a
        # time (GShard k-major priority) in bf16 — the transient is
        # (B, seg, E, C) instead of (B, seg*k, E, C) fp32: 8-16x smaller.
        hot = xseg.dtype
        dispatch = jnp.zeros((b, seg, E, cap), hot)
        combine = jnp.zeros((b, seg, E, cap), hot)
        count = jnp.zeros((b, 1, E), jnp.float32)
        for ki in range(k):
            oh = jax.nn.one_hot(idx[..., ki], E, dtype=jnp.float32)
            pos = jnp.cumsum(oh, axis=1) - 1.0 + count      # (B, seg, E)
            keep = (pos < cap) * oh
            posc = jnp.clip(pos, 0, cap - 1).astype(jnp.int32)
            slot = jax.nn.one_hot(posc, cap, dtype=hot) * \
                keep[..., None].astype(hot)
            dispatch = dispatch + slot
            combine = combine + gate[..., ki, None, None].astype(hot) * slot
            count = count + oh.sum(axis=1, keepdims=True)

        xin = jnp.einsum("bsec,bsd->ebcd", dispatch, xseg)
        xin = shard(xin, "moe_expert_in")                   # E on 'model'
        h = jnp.einsum("ebcd,edf->ebcf", xin, p["wi"])
        if "wg" in p:
            act = jax.nn.silu if cfg.mlp_type == "swiglu" else \
                (lambda v: jax.nn.gelu(v, approximate=True))
            h = act(jnp.einsum("ebcd,edf->ebcf", xin, p["wg"])) * h
        else:
            h = jax.nn.gelu(h, approximate=True)
        out_e = jnp.einsum("ebcf,efd->ebcd", h, p["wo"])
        out_e = shard(out_e, "moe_expert_out")
        y = jnp.einsum("ebcd,bsec->bsd", out_e,
                       combine.astype(out_e.dtype))

        # Switch load-balance loss: E * sum_e f_e * P_e
        f = dispatch.astype(jnp.float32).sum((1, 3)) / float(seg * k)
        pm = probs.mean(1)                                  # (B, E)
        aux = E * jnp.mean(jnp.sum(f * pm, axis=-1))
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(one_segment, None, xs)
    y = ys.transpose(1, 0, 2, 3).reshape(b, s, d)
    return shard(y, "act_d"), jnp.mean(auxs)
