"""Model zoo: decoder-only LMs (dense/MoE), SSM, hybrid, VLM/audio backbones,
LipConvnet. Use repro.models.api for family-agnostic access."""
from . import api
