"""Bidirectional encoder classifier (RoBERTa-style) — the paper's GLUE
fine-tuning setting (Table 1).  Used by benchmarks/table1 and the
finetune example: a frozen backbone + classification head, adapted with
GSOFT / OFT / BOFT / LoRA through the same PEFT engine as the LM zoo."""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from .attention import attention_block, init_attention
from .layers import (Shard, apply_mlp, embed_init, init_stacked_mlp, no_shard,
                     rms_norm, stacked_dense_init)

Array = jnp.ndarray


def encoder_config(name="roberta-proxy", num_layers=2, d_model=64,
                   num_heads=4, d_ff=128, vocab_size=128,
                   num_classes=2) -> ModelConfig:
    return ModelConfig(
        name=name, family="decoder",  # reuses decoder layer params
        num_layers=num_layers, d_model=d_model, num_heads=num_heads,
        num_kv_heads=num_heads, head_dim=d_model // num_heads, d_ff=d_ff,
        vocab_size=vocab_size, mlp_type="gelu", rope_theta=1e4,
        dtype="f32", param_dtype="f32", remat="none", attn_chunk=64,
    )


def init_encoder_classifier(cfg: ModelConfig, num_classes: int,
                            key: jax.Array) -> Dict:
    ks = jax.random.split(key, 6)
    L = cfg.num_layers
    return {
        "embed": {"table": embed_init(ks[0], cfg.vocab_size, cfg.d_model,
                                      jnp.float32)},
        "layers": {
            "attn_norm": jnp.zeros((L, cfg.d_model)),
            "attn": init_attention(ks[1], cfg, stacked=L, dtype=jnp.float32),
            "mlp_norm": jnp.zeros((L, cfg.d_model)),
            "mlp": init_stacked_mlp(ks[2], L, cfg.d_model, cfg.d_ff,
                                    cfg.mlp_type, jnp.float32),
        },
        "final_norm": jnp.zeros((cfg.d_model,)),
        "head": {"w": stacked_dense_init(ks[3], 1, cfg.d_model,
                                         num_classes, jnp.float32)[0],
                 "b": jnp.zeros((num_classes,))},
    }


def encoder_forward(cfg: ModelConfig, params, tokens: Array,
                    shard: Shard = no_shard) -> Array:
    h = jnp.take(params["embed"]["table"], tokens, axis=0)

    def body(hc, lp):
        a, _ = attention_block(lp["attn"],
                               rms_norm(hc, lp["attn_norm"], cfg.norm_eps),
                               cfg, causal=False, shard=shard)
        hc = hc + a
        m = apply_mlp(lp["mlp"], rms_norm(hc, lp["mlp_norm"], cfg.norm_eps),
                      cfg.mlp_type, shard)
        return hc + m, None

    h, _ = jax.lax.scan(body, h, params["layers"])
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    pooled = h[:, 0]                       # CLS-style pooling (RoBERTa)
    return pooled @ params["head"]["w"] + params["head"]["b"]


def classifier_loss(cfg: ModelConfig, params, batch, shard: Shard = no_shard):
    logits = encoder_forward(cfg, params, batch["tokens"], shard)
    onehot = jax.nn.one_hot(batch["labels"], logits.shape[-1])
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == batch["labels"])
    return loss, {"loss": loss, "accuracy": acc}
