"""Mamba2 (SSD — state-space duality) blocks: train scan + O(1) decode.

Faithful to the Mamba2 block structure (Dao & Gu 2024, arXiv:2405.21060):
separate z/x/B/C/dt projections (kept unfused so tensor-parallel sharding
never slices across component boundaries — DESIGN §4), short causal
depthwise conv over (x, B, C), softplus dt with bias, negative-exponential
A, SSD scan (kernels/ssd.py with pure-jnp oracle), per-head skip D, gated
RMSNorm, output projection.

Train/prefill use the chunk-parallel SSD; decode advances the (H, N, P)
state recurrently per token — this is what makes long_500k an O(1)-per-token
shape for mamba2/zamba2 (the assignment's sub-quadratic cells).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.kernels import ops
from .layers import Shard, no_shard, stacked_dense_init

Array = jnp.ndarray


def _conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state


def init_mamba(key, cfg: ModelConfig, stacked, dtype) -> Dict[str, Array]:
    """stacked: tuple of leading dims (e.g. (L,) or (nsuper, per_super))."""
    d, di = cfg.d_model, cfg.d_inner
    G, N, H = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    lead = tuple(stacked)
    ks = jax.random.split(key, 8)

    def w(k, di_, do_):
        v = jax.random.normal(k, lead + (di_, do_), jnp.float32)
        return (v / math.sqrt(di_)).astype(dtype)

    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1] (mamba2)
    u = jax.random.uniform(ks[6], lead + (H,), jnp.float32)
    dt0 = jnp.exp(u * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt0 + jnp.log(-jnp.expm1(-dt0))
    a0 = jax.random.uniform(ks[7], lead + (H,), jnp.float32, 1.0, 16.0)

    return {
        "wz": w(ks[0], d, di), "wx": w(ks[1], d, di),
        "wb": w(ks[2], d, G * N), "wc": w(ks[3], d, G * N),
        "wdt": w(ks[4], d, H),
        "conv_w": (jax.random.normal(ks[5], lead + (cfg.ssm_conv, _conv_dim(cfg)),
                                     jnp.float32) / math.sqrt(cfg.ssm_conv)
                   ).astype(dtype),
        "conv_b": jnp.zeros(lead + (_conv_dim(cfg),), dtype),
        "A_log": jnp.log(a0),
        "D": jnp.ones(lead + (H,), jnp.float32),
        "dt_bias": dt_bias,
        "gate_norm": jnp.zeros(lead + (di,), dtype),
        "out_proj": {"wo": w(jax.random.fold_in(key, 9), di, d)},
    }


def _causal_conv(x: Array, w: Array, b: Array) -> Array:
    """Depthwise causal conv, width W (static shift-and-sum unroll).
    x: (B, S, C); w: (W, C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    s = x.shape[1]
    y = jnp.zeros_like(x)
    for i in range(W):
        y = y + xp[:, i:i + s, :] * w[i][None, None, :].astype(x.dtype)
    return y + b[None, None, :].astype(x.dtype)


def _gated_rms_norm(y: Array, z: Array, scale: Array, eps: float) -> Array:
    dt = y.dtype
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + eps)
    return (g * (1.0 + scale.astype(jnp.float32))).astype(dt)


def _project(p, u, cfg: ModelConfig, shard: Shard):
    """Shared pre-SSD computation: projections + conv + head reshape."""
    b, s, _ = u.shape
    di, G, N, H, P = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_headdim)
    z = shard(u @ p["wz"], "act_inner")
    xin = shard(u @ p["wx"], "act_inner")
    Bc = u @ p["wb"]
    Cc = u @ p["wc"]
    dt = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    return z, xin, Bc, Cc, dt


def _heads(cfg, xin, Bc, Cc):
    b, s = xin.shape[:2]
    G, N, H, P = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    xh = xin.reshape(b, s, H, P)
    rep = H // G
    Bh = jnp.repeat(Bc.reshape(b, s, G, N), rep, axis=2)
    Ch = jnp.repeat(Cc.reshape(b, s, G, N), rep, axis=2)
    return xh, Bh, Ch


def mamba_block(p: Dict[str, Array], u: Array, cfg: ModelConfig,
                shard: Shard = no_shard) -> Array:
    """Train/prefill path. u: (B, S, d) (already normed) -> (B, S, d)."""
    b, s, _ = u.shape
    di, N = cfg.d_inner, cfg.ssm_state
    z, xin, Bc, Cc, dt = _project(p, u, cfg, shard)
    xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xin = xbc[..., :di]
    Bc = xbc[..., di:di + cfg.ssm_groups * N]
    Cc = xbc[..., di + cfg.ssm_groups * N:]
    xh, Bh, Ch = _heads(cfg, xin, Bc, Cc)

    loga = (-jnp.exp(p["A_log"].astype(jnp.float32)))[None, None, :] * dt
    xs = (xh.astype(jnp.float32) * dt[..., None])
    y = ops.ssd(xs, loga, Bh.astype(jnp.float32), Ch.astype(jnp.float32),
                chunk=cfg.ssd_chunk, use_pallas=cfg.use_pallas)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * \
        xh.astype(jnp.float32)
    y = y.reshape(b, s, di).astype(u.dtype)
    y = _gated_rms_norm(y, z, p["gate_norm"], cfg.norm_eps)
    return shard(y @ p["out_proj"]["wo"], "act_d")


# ---------------------------------------------------------------------------
# decode (recurrent, O(1) per token)
# ---------------------------------------------------------------------------

def init_mamba_state(cfg: ModelConfig, batch: int, lead=()):
    H, N, P = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    return {
        "conv": jnp.zeros(tuple(lead) + (batch, cfg.ssm_conv - 1,
                                         _conv_dim(cfg)), cfg.act_dtype),
        "ssm": jnp.zeros(tuple(lead) + (batch, H, N, P), jnp.float32),
    }


def mamba_decode_step(p, u: Array, state: Dict[str, Array], cfg: ModelConfig,
                      shard: Shard = no_shard) -> Tuple[Array, Dict[str, Array]]:
    """u: (B, 1, d) -> (y (B,1,d), new_state)."""
    b = u.shape[0]
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    z, xin, Bc, Cc, dt = _project(p, u, cfg, shard)
    xbc = jnp.concatenate([xin, Bc, Cc], axis=-1)        # (B,1,C)
    hist = jnp.concatenate([state["conv"], xbc], axis=1)  # (B,W,C)
    w = p["conv_w"]
    conv_out = jnp.einsum("bwc,wc->bc", hist.astype(jnp.float32),
                          w.astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xbc_t = jax.nn.silu(conv_out)[:, None, :].astype(u.dtype)
    new_conv = hist[:, 1:, :]

    xin = xbc_t[..., :di]
    Bc = xbc_t[..., di:di + cfg.ssm_groups * N]
    Cc = xbc_t[..., di + cfg.ssm_groups * N:]
    xh, Bh, Ch = _heads(cfg, xin, Bc, Cc)                 # (B,1,H,*)

    la = (-jnp.exp(p["A_log"].astype(jnp.float32)))[None, :] * dt[:, 0]  # (B,H)
    S = state["ssm"]
    xt = (xh[:, 0].astype(jnp.float32) * dt[:, 0][..., None])   # (B,H,P)
    S = jnp.exp(la)[..., None, None] * S + \
        Bh[:, 0].astype(jnp.float32)[..., None] * xt[:, :, None, :]
    yt = jnp.einsum("bhn,bhnp->bhp", Ch[:, 0].astype(jnp.float32), S)
    yt = yt + p["D"].astype(jnp.float32)[None, :, None] * \
        xh[:, 0].astype(jnp.float32)
    y = yt.reshape(b, 1, di).astype(u.dtype)
    y = _gated_rms_norm(y, z, p["gate_norm"], cfg.norm_eps)
    y = shard(y @ p["out_proj"]["wo"], "act_d")
    return y, {"conv": new_conv, "ssm": S}
