"""``image`` family — the 1-Lipschitz GS-SOC LipConvnet as a registered,
servable ``FamilyOps`` entry (paper §7.3 meets the serving stack).

The family is STATELESS: there is no token-level decode state, no KV — a
request is one image and the whole decode surface is ``None``; inference
goes through ``FamilyOps.infer`` (one batched forward), which is what
``ImageServeEngine`` drives.

Adapter attachment points: every orthogonal conv layer carries an explicit
identity-initialized ``(c, c)`` channel-mix weight ``wc`` applied as a 1x1
(im2col-free) matmul over flattened ``(N, H*W, C)`` activations, routed
through the same ``qlinear`` hook as every transformer projection. That
gives the conv trunk the full adapter stack for free:

* merged serving — ``materialize`` folds an orthogonal adapter ``Q`` into
  ``wc`` (identity base -> the effective weight IS ``Q``: a channel-axis
  GS rotation of the conv feature stream);
* banked serving — activation-side ``x·Q`` per request via any bankable
  ``core.methods`` entry, identical math since ``(xQ)·I == x·(QI)``;
* int8 — ``wc`` quantizes per-output-channel (the identity quantizes
  EXACTLY), and the banked GSOFT rotation fuses into
  ``gs_q_matmul_banked`` on the flattened 1x1 path;
* certification — orthogonal ``Q`` keeps every layer an isometry, so the
  end-to-end Lipschitz constant (and the margin certificate) survives
  adapter attachment untouched.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.conv import (ACTIVATIONS, certified_radius, gs_soc_layer,
                             power_iteration_sn, space_to_depth)
from repro.core.peft import AdapterContext
from . import registry
from .layers import Shard, no_shard, qlinear
from .lipconvnet import LipConvnetConfig, init_lipconvnet

Array = jnp.ndarray

# margin used by SOC-style certified training; 36/255 is the CIFAR
# certification radius the paper's Table 3 reports at
CERT_EPS = 36.0 / 255.0


def lip_cfg(cfg: ModelConfig) -> LipConvnetConfig:
    """ModelConfig -> the LipConvnet hyperparameter record."""
    return LipConvnetConfig(
        depth=cfg.num_layers,
        base_width=cfg.base_width or cfg.d_model,
        num_classes=cfg.num_classes,
        image_size=cfg.image_size,
        in_channels=cfg.in_channels,
        groups=tuple(cfg.conv_groups),
        activation=cfg.conv_activation,
        terms=cfg.conv_terms,
        conv_layer="soc" if cfg.conv_layer == "soc" else "gs",
        paired_shuffle=cfg.paired_shuffle,
    )


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_image(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    """LipConvnet params + identity ``wc`` channel-mix at every conv layer
    (the adapter/quant attachment points — see module docstring)."""
    lc = lip_cfg(cfg)
    params = init_lipconvnet(lc, key)
    per_block = lc.depth // 5
    for bi, width in enumerate(lc.block_widths()):
        block = params[f"block{bi}"]
        for li in range(per_block - 1):
            block[f"conv{li}"]["wc"] = jnp.eye(width, dtype=jnp.float32)
        block["down"]["wc"] = jnp.eye(2 * width, dtype=jnp.float32)
    return params


def abstract_params(cfg: ModelConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_image, cfg), key)


def active_param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return sum(int(math.prod(l.shape)) for l in jax.tree.leaves(tree))


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def _channel_mix(x: Array, w, rot, name: str) -> Array:
    """The 1x1 channel-mix hook: flatten NHWC -> (N, H*W, C) so the banked
    rotation (``(B, T, d)`` contract) and the quantized matmul ride the
    same machinery as every transformer projection, then restore NHWC."""
    n, h, wd, c = x.shape
    y = qlinear(x.reshape(n, h * wd, c), w, rot, name, cast=True)
    return y.reshape(n, h, wd, c)


def _cast_conv(lp: Dict[str, Array], dtype) -> Dict[str, Array]:
    return {k: lp[k].astype(dtype) for k in ("m1", "m2") if k in lp}


def apply_image(cfg: ModelConfig, params: Dict[str, Any], images: Array,
                shard: Shard = no_shard,
                ctx: Optional[AdapterContext] = None) -> Array:
    """images (N, H, W, C_in) -> logits (N, num_classes); 1-Lipschitz end
    to end (orthogonal convs, isometric activations, orthogonal ``wc``
    rotations, spectral-normalized head).

    ``ctx`` is the same per-request ``AdapterContext`` the decode path
    takes: row i of the batch rotates its channel stream with adapter
    ``ctx.slots[i]`` before each ``wc`` matmul (slot 0 = identity)."""
    lc = lip_cfg(cfg)
    act = ACTIVATIONS[lc.activation]
    per_block = lc.depth // 5
    x = images.astype(cfg.act_dtype)
    pad = lc.base_width - x.shape[-1]
    if pad > 0:                       # norm-preserving channel injection
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
    x = shard(x, "act_bhwc")
    for bi, width in enumerate(lc.block_widths()):
        block = params[f"block{bi}"]
        grp = (lambda n: ctx.rotator(ctx.group(f"block{bi}", n))
               ) if ctx is not None else (lambda n: None)
        spec = lc.layer_spec(width)
        for li in range(per_block - 1):
            name = f"conv{li}"
            x = gs_soc_layer(spec, _cast_conv(block[name], x.dtype), x)
            x = _channel_mix(x, block[name]["wc"], grp(name), "wc")
            x = act(x)
        # downsample: orthogonal space-to-depth, orthogonal conv on 4w,
        # select 2w channels (semi-orthogonal), then the 2w channel mix
        x = space_to_depth(x, 2)
        spec_dn = lc.layer_spec(4 * width)
        x = gs_soc_layer(spec_dn, _cast_conv(block["down"], x.dtype), x)
        x = act(x[..., : 2 * width])
        x = _channel_mix(x, block["down"]["wc"], grp("down"), "wc")
    x = x.reshape(x.shape[0], -1)
    w = params["head"]["w"]
    sn = jax.lax.stop_gradient(
        power_iteration_sn(w.astype(jnp.float32))) + 1e-6
    wn = (w.astype(jnp.float32) / sn).astype(x.dtype)
    return shard(x @ wn, "logits")


def forward(cfg: ModelConfig, params, batch: Dict[str, Array],
            shard: Shard = no_shard) -> Tuple[Array, Array]:
    """FamilyOps.forward: batch["images"] -> (logits, aux=0)."""
    logits = apply_image(cfg, params, batch["images"], shard)
    return logits, jnp.zeros((), jnp.float32)


def infer(cfg: ModelConfig, params, images: Array, shard: Shard = no_shard,
          ctx: Optional[AdapterContext] = None) -> Array:
    """FamilyOps.infer — the stateless serving entry point."""
    return apply_image(cfg, params, images, shard, ctx=ctx)


def image_loss(cfg: ModelConfig, params, batch: Dict[str, Array],
               shard: Shard = no_shard, margin: float = 0.7071):
    """Margin cross-entropy of SOC-style certified training, plus the
    certified-accuracy metric at radius ``CERT_EPS``."""
    logits = apply_image(cfg, params, batch["images"], shard)
    labels = batch["labels"]
    onehot = jax.nn.one_hot(labels, cfg.num_classes, dtype=logits.dtype)
    adjusted = logits - margin * np.sqrt(2.0) * onehot
    logp = jax.nn.log_softmax(adjusted.astype(jnp.float32))
    loss = -jnp.mean(jnp.sum(onehot.astype(jnp.float32) * logp, axis=-1))
    correct = jnp.argmax(logits, -1) == labels
    acc = jnp.mean(correct)
    cert = jnp.mean((certified_radius(logits) > CERT_EPS) & correct)
    return loss, {"loss": loss, "accuracy": acc, "certified": cert}


registry.register(registry.FamilyOps(
    family="image",
    init_params=init_image,
    forward=forward,
    loss=image_loss,
    active_param_count=active_param_count,
    infer=infer,
    mixer="none",
))
