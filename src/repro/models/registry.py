"""Family registry: each model family registers a ``FamilyOps`` record and
``repro.models.api`` / ``ModelRuntime`` dispatch on ``ModelConfig.family`` —
no hardcoded family booleans, and new families (or new orthogonal-FT
variants that need their own serve path) plug in without touching every
call-site signature.

``transformer`` registers explicit entries for decoder / vlm / ssm / hybrid
(previously the last three were silently routed through the decoder path);
``encdec`` and ``image`` register themselves. Importing
``repro.models.api`` (or the ``repro.models`` package) triggers
registration.

This module is the ONLY place family strings are compared (CI greps for
``family ==`` leaking elsewhere). Everything a call-site used to branch on
is a trait on the record:

* ``mixer`` — "attention" | "ssm" | "hybrid": which sequence mixer the
  transformer stack runs (hybrid alternates ssm/attention by layer).
* ``has_patches`` — the batch carries a vision-frontend ``patches`` field
  and the stream begins with ``cfg.frontend_tokens`` patch positions.
* ``has_encoder`` — encoder-decoder: the batch carries ``frames`` and
  decode needs an encoder pass + cross-attention state.
* ``stateless`` (property) — no token-level decode state at all: the
  family serves whole inputs through ``infer`` (one batched forward per
  request set, no KV), e.g. the ``image`` family.  Stateless families
  must provide ``init_params``/``forward``/``loss``/
  ``active_param_count``/``infer`` and may leave the whole decode and
  paged surfaces ``None``; ``ServeEngine``/``PagedServeEngine`` refuse
  them up front and ``ImageServeEngine`` is their lane.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class FamilyOps:
    """The per-family call surface. Uniform signatures:

    * ``init_params(cfg, key) -> params``
    * ``forward(cfg, params, batch, shard=no_shard) -> (logits, aux)``
    * ``loss(cfg, params, batch, shard=no_shard) -> (loss, metrics)``
    * ``active_param_count(cfg) -> int``

    Token-decode surface (None -> the family is stateless and token
    engines refuse it):

    * ``init_decode_state(cfg, batch, max_len, enc_len=0) -> state``
    * ``prefill(cfg, params, req: PrefillRequest, state, shard=no_shard)
      -> (last_logits, state)``
    * ``decode_step(cfg, params, tokens, state, pos, shard=no_shard,
      ctx: AdapterContext | None = None) -> (logits, state)``

    Stateless-inference surface (required iff the decode surface is
    absent):

    * ``infer(cfg, params, batch_inputs, shard=no_shard, ctx=None)
      -> logits`` — one whole-input batched forward; ``ctx`` is the same
      ``AdapterContext`` the decode path takes, so banked per-request
      adapters work identically.

    Optional paged-KV surface (None -> the family has no paged serve path
    and ``PagedServeEngine`` refuses it up front):

    * ``init_paged_state(cfg, batch, num_pages, page_size, max_pages)
      -> state`` — pytree {"pages", "table"}; table width is max_pages + 1
      (sentinel garbage column)
    * ``paged_chunk_prefill(cfg, params, req, state, slot, start,
      shard=no_shard) -> (logits, state)`` — one prompt chunk, one slot
    * ``paged_decode_step(cfg, params, tokens, state, pos, shard=no_shard,
      ctx=None) -> (logits, state)`` — full-batch decode through tables
    """
    family: str
    init_params: Callable
    forward: Callable
    loss: Callable
    active_param_count: Callable
    init_decode_state: Optional[Callable] = None
    prefill: Optional[Callable] = None
    decode_step: Optional[Callable] = None
    infer: Optional[Callable] = None
    init_paged_state: Optional[Callable] = None
    paged_chunk_prefill: Optional[Callable] = None
    paged_decode_step: Optional[Callable] = None
    # traits — the registry-owned answers to what used to be family
    # string comparisons at call sites ("none": no sequence mixer at all,
    # e.g. the stateless image family)
    mixer: str = "attention"
    has_patches: bool = False
    has_encoder: bool = False

    @property
    def stateless(self) -> bool:
        """No token-level decode state: serve through ``infer``."""
        return self.init_decode_state is None

    def __post_init__(self):
        if self.mixer not in ("attention", "ssm", "hybrid", "none"):
            raise ValueError(f"family {self.family!r}: unknown mixer "
                             f"{self.mixer!r}")
        if self.init_decode_state is None and self.infer is None:
            raise ValueError(
                f"family {self.family!r} registers neither a decode "
                f"surface nor a stateless ``infer`` entry point")


_FAMILIES: Dict[str, FamilyOps] = {}


def register(ops: FamilyOps) -> FamilyOps:
    _FAMILIES[ops.family] = ops
    return ops


def get(family: str) -> FamilyOps:
    if family not in _FAMILIES:
        raise KeyError(f"unknown model family {family!r}; registered "
                       f"families: {sorted(_FAMILIES)}")
    return _FAMILIES[family]


def families() -> List[str]:
    return sorted(_FAMILIES)


def is_family(cfg, family: str) -> bool:
    """Registry-owned label check (CLI lane assertions and the like) —
    call sites must not compare ``cfg.family`` strings themselves."""
    return cfg.family == family
