"""Family registry: each model family registers a ``FamilyOps`` record and
``repro.models.api`` / ``ModelRuntime`` dispatch on ``ModelConfig.family`` —
no hardcoded family booleans, and new families (or new orthogonal-FT
variants that need their own serve path) plug in without touching every
call-site signature.

``transformer`` registers explicit entries for decoder / vlm / ssm / hybrid
(previously the last three were silently routed through the decoder path);
``encdec`` registers itself. Importing ``repro.models.api`` (or the
``repro.models`` package) triggers registration.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional


@dataclasses.dataclass(frozen=True)
class FamilyOps:
    """The per-family call surface. Uniform signatures:

    * ``init_params(cfg, key) -> params``
    * ``forward(cfg, params, batch, shard=no_shard) -> (logits, aux)``
    * ``loss(cfg, params, batch, shard=no_shard) -> (loss, metrics)``
    * ``init_decode_state(cfg, batch, max_len, enc_len=0) -> state``
    * ``prefill(cfg, params, req: PrefillRequest, state, shard=no_shard)
      -> (last_logits, state)``
    * ``decode_step(cfg, params, tokens, state, pos, shard=no_shard,
      ctx: AdapterContext | None = None) -> (logits, state)``
    * ``active_param_count(cfg) -> int``

    Optional paged-KV surface (None -> the family has no paged serve path
    and ``PagedServeEngine`` refuses it up front):

    * ``init_paged_state(cfg, batch, num_pages, page_size, max_pages)
      -> state`` — pytree {"pages", "table"}; table width is max_pages + 1
      (sentinel garbage column)
    * ``paged_chunk_prefill(cfg, params, req, state, slot, start,
      shard=no_shard) -> (logits, state)`` — one prompt chunk, one slot
    * ``paged_decode_step(cfg, params, tokens, state, pos, shard=no_shard,
      ctx=None) -> (logits, state)`` — full-batch decode through tables
    """
    family: str
    init_params: Callable
    forward: Callable
    loss: Callable
    init_decode_state: Callable
    prefill: Callable
    decode_step: Callable
    active_param_count: Callable
    init_paged_state: Optional[Callable] = None
    paged_chunk_prefill: Optional[Callable] = None
    paged_decode_step: Optional[Callable] = None


_FAMILIES: Dict[str, FamilyOps] = {}


def register(ops: FamilyOps) -> FamilyOps:
    _FAMILIES[ops.family] = ops
    return ops


def get(family: str) -> FamilyOps:
    if family not in _FAMILIES:
        raise KeyError(f"unknown model family {family!r}; registered "
                       f"families: {sorted(_FAMILIES)}")
    return _FAMILIES[family]


def families() -> List[str]:
    return sorted(_FAMILIES)
