"""Family-dispatched model API — a thin lookup over the family registry.

Dispatch is keyed on ``ModelConfig.family`` via ``repro.models.registry``
(every family registers a ``FamilyOps`` record; there is no hardcoded
family boolean here). Serving entry points live on
``repro.core.runtime.ModelRuntime``; per-request adapter state travels
only as ``AdapterContext``/``PrefillRequest`` pytrees. The PR-3 era
module-level ``prefill``/``decode_step`` shims (and their loose
``bank``/``adapter_ids``/``bank_cfg`` kwargs) are GONE — CI greps them
out so they cannot return.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from . import encdec, image, transformer  # noqa: F401  (register FamilyOps)
from . import registry
from .layers import no_shard

Array = jnp.ndarray


def family_ops(cfg: ModelConfig) -> registry.FamilyOps:
    """The FamilyOps record for ``cfg.family`` (KeyError on unknown family,
    listing what IS registered)."""
    return registry.get(cfg.family)


def init_params(cfg: ModelConfig, key: jax.Array):
    return family_ops(cfg).init_params(cfg, key)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def forward(cfg: ModelConfig, params, batch, shard=no_shard):
    return family_ops(cfg).forward(cfg, params, batch, shard)


def loss_fn(cfg: ModelConfig, params, batch, shard=no_shard):
    return family_ops(cfg).loss(cfg, params, batch, shard)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int = 0):
    return family_ops(cfg).init_decode_state(cfg, batch, max_len, enc_len)


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                          enc_len: int = 0):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_len, enc_len))


def param_count(cfg: ModelConfig) -> int:
    return sum(int(math.prod(l.shape))
               for l in jax.tree.leaves(abstract_params(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    return family_ops(cfg).active_param_count(cfg)
