"""Family-dispatched model API — a thin lookup over the family registry.

Dispatch is keyed on ``ModelConfig.family`` via ``repro.models.registry``
(every family registers a ``FamilyOps`` record; there is no hardcoded
family boolean here). Serving entry points live on
``repro.core.runtime.ModelRuntime``; the module-level ``prefill`` /
``decode_step`` wrappers below are DEPRECATED shims that accept the old
``bank``/``adapter_ids``/``bank_cfg`` kwarg triple and forward to the
registry ops through an ``AdapterContext``.
"""
from __future__ import annotations

import math
import warnings

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.peft import AdapterContext, PrefillRequest
from . import encdec, transformer  # noqa: F401  (register their FamilyOps)
from . import registry
from .layers import no_shard

Array = jnp.ndarray


def family_ops(cfg: ModelConfig) -> registry.FamilyOps:
    """The FamilyOps record for ``cfg.family`` (KeyError on unknown family,
    listing what IS registered)."""
    return registry.get(cfg.family)


def init_params(cfg: ModelConfig, key: jax.Array):
    return family_ops(cfg).init_params(cfg, key)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def forward(cfg: ModelConfig, params, batch, shard=no_shard):
    return family_ops(cfg).forward(cfg, params, batch, shard)


def loss_fn(cfg: ModelConfig, params, batch, shard=no_shard):
    return family_ops(cfg).loss(cfg, params, batch, shard)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int = 0):
    return family_ops(cfg).init_decode_state(cfg, batch, max_len, enc_len)


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                          enc_len: int = 0):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_len, enc_len))


def param_count(cfg: ModelConfig) -> int:
    return sum(int(math.prod(l.shape))
               for l in jax.tree.leaves(abstract_params(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    return family_ops(cfg).active_param_count(cfg)


# ---------------------------------------------------------------------------
# DEPRECATED call surface — the old kwarg-threading prefill/decode_step.
# Kept one release as shims: they accept the retired loose kwargs, bundle
# them into an AdapterContext/PrefillRequest, and forward to the registry.
# ---------------------------------------------------------------------------

_LEGACY_KWARGS = ("bank", "adapter_ids", "bank_cfg")
_legacy_warned = False


def _warn_legacy(name: str) -> None:
    global _legacy_warned
    if not _legacy_warned:
        warnings.warn(
            f"repro.models.api.{name} is deprecated: use "
            "repro.core.runtime.ModelRuntime (or the family registry ops) "
            "with AdapterContext/PrefillRequest instead of the "
            "bank/adapter_ids/bank_cfg kwargs",
            DeprecationWarning, stacklevel=3)
        _legacy_warned = True


def _legacy_context(name: str, legacy: dict):
    unknown = set(legacy) - set(_LEGACY_KWARGS)
    if unknown:
        raise TypeError(f"{name}() got unexpected keyword arguments "
                        f"{sorted(unknown)}")
    tree, ids, cfg = (legacy.get(k) for k in _LEGACY_KWARGS)
    if (tree is None) != (ids is None):
        raise ValueError(
            f"{name}(): per-request rotation needs both the stacked adapter "
            "tree and the slot ids — got half the legacy triple, which "
            "would silently serve the un-adapted base model; migrate to "
            "AdapterContext")
    if tree is None:
        return None
    return AdapterContext(tree, jnp.asarray(ids, jnp.int32), cfg)


def prefill(cfg: ModelConfig, params, batch, state, shard=no_shard,
            last_idx=None, **legacy):
    """DEPRECATED — build a PrefillRequest and call the registry prefill
    (or use ModelRuntime). Old kwargs are forwarded once with a warning."""
    _warn_legacy("prefill")
    req = PrefillRequest(batch=batch, last_idx=last_idx,
                         ctx=_legacy_context("prefill", legacy))
    return family_ops(cfg).prefill(cfg, params, req, state, shard)


def decode_step(cfg: ModelConfig, params, tokens, state, pos, shard=no_shard,
                **legacy):
    """DEPRECATED — call the registry decode_step with an AdapterContext
    (or use ModelRuntime). Old kwargs are forwarded once with a warning."""
    _warn_legacy("decode_step")
    return family_ops(cfg).decode_step(
        cfg, params, tokens, state, pos, shard,
        ctx=_legacy_context("decode_step", legacy))
