"""Family-dispatched model API — the single entry point the trainer, server,
dry-run and tests use.  Everything downstream is family-agnostic."""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from . import encdec, transformer
from .layers import no_shard

Array = jnp.ndarray


def _is_encdec(cfg: ModelConfig) -> bool:
    return cfg.family == "encdec"


def init_params(cfg: ModelConfig, key: jax.Array):
    return (encdec.init_encdec if _is_encdec(cfg) else transformer.init_lm)(cfg, key)


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))


def forward(cfg: ModelConfig, params, batch, shard=no_shard):
    return (encdec.forward if _is_encdec(cfg) else transformer.forward)(
        cfg, params, batch, shard)


def loss_fn(cfg: ModelConfig, params, batch, shard=no_shard):
    return (encdec.lm_loss if _is_encdec(cfg) else transformer.lm_loss)(
        cfg, params, batch, shard)


def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int = 0):
    if _is_encdec(cfg):
        return encdec.init_decode_state(cfg, batch, max_len, enc_len)
    return transformer.init_decode_state(cfg, batch, max_len)


def abstract_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                          enc_len: int = 0):
    return jax.eval_shape(
        lambda: init_decode_state(cfg, batch, max_len, enc_len))


def prefill(cfg: ModelConfig, params, batch, state, shard=no_shard,
            last_idx=None, bank=None, adapter_ids=None, bank_cfg=None):
    """``last_idx`` gathers each row's logits at its own last valid prompt
    position (ragged-prompt fix); ``bank``/``adapter_ids``/``bank_cfg``
    apply per-request GS adapter rotations (multi-adapter serving)."""
    return (encdec.prefill if _is_encdec(cfg) else transformer.prefill)(
        cfg, params, batch, state, shard, last_idx=last_idx, bank=bank,
        adapter_ids=adapter_ids, bank_cfg=bank_cfg)


def decode_step(cfg: ModelConfig, params, tokens, state, pos, shard=no_shard,
                bank=None, adapter_ids=None, bank_cfg=None):
    """``pos`` may be a scalar (lockstep batch) or an int32 (B,) array of
    per-slot write positions (continuous batching)."""
    return (encdec.decode_step if _is_encdec(cfg) else transformer.decode_step)(
        cfg, params, tokens, state, pos, shard, bank=bank,
        adapter_ids=adapter_ids, bank_cfg=bank_cfg)


def param_count(cfg: ModelConfig) -> int:
    import math
    return sum(int(math.prod(l.shape))
               for l in jax.tree.leaves(abstract_params(cfg)))


def active_param_count(cfg: ModelConfig) -> int:
    if _is_encdec(cfg):
        return param_count(cfg)
    return transformer.active_param_count(cfg)
