"""Shared neural building blocks (functional, tree-of-arrays params).

Conventions:
  * activations (B, S, D); weights (d_in, d_out) used as y = x @ W
    (scan-stacked weights get a leading layer dim)
  * param init in fp32-computed numpy-free jax PRNG, cast to cfg.param_dtype
  * every function takes an explicit ``shard`` callback
    (activation-name -> sharding constraint), identity by default
"""
from __future__ import annotations

import math
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.quant.core import QuantTensor

Array = jnp.ndarray
Shard = Callable[[Array, str], Array]


def no_shard(x: Array, name: str) -> Array:
    return x


def qlinear(x: Array, w, rot: Optional[Callable[[str, Array], Array]] = None,
            name: str = "", cast: bool = False) -> Array:
    """The QuantizedLinear hook — every base-weight projection on the
    attention/MLP/head path routes through here.

    ``w`` is either a plain weight array (y = x @ w, unchanged numerics)
    or a ``QuantTensor`` (int8/fp8 codes + scales), in which case the
    matmul dispatches through ``kernels.ops.q_matmul`` with the dequant in
    the epilogue. ``rot(name, x)`` is the optional per-request adapter
    rotation — method-generic (any banked ``core.methods`` entry), bf16,
    never quantized. When the weight is quantized, the rotator's
    ``quant_rotation`` hook splits the work: methods with a fused kernel
    (GSOFT) hand back per-row factors so rotation + base matmul collapse
    into one ``gs_q_matmul_banked`` call — the rotated slab never leaves
    VMEM on the Pallas path — while the other method stacks (OFT / BOFT /
    Householder) apply to the activations first.

    ``cast=True`` pre-casts a PLAIN weight to the activation dtype (the
    lm_head/patch_proj call sites, whose weights may be wider than the
    activations); quantized matmuls already return ``x.dtype``.
    """
    if isinstance(w, QuantTensor):
        factors = None
        if rot is not None:
            if hasattr(rot, "quant_rotation"):
                x, factors = rot.quant_rotation(name, x, x.dtype)
            else:
                x = rot(name, x)
        if factors is not None:
            return kernel_ops.gs_q_matmul_banked(
                factors[0], factors[1], x, w.q, w.scale,
                use_pallas=w.meta.use_pallas)
        return kernel_ops.q_matmul(x, w.q, w.scale,
                                   use_pallas=w.meta.use_pallas)
    if rot is not None:
        x = rot(name, x)
    return x @ (w.astype(x.dtype) if cast else w)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


def stacked_dense_init(key, n: int, d_in: int, d_out: int, dtype,
                       scale: Optional[float] = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    w = jax.random.normal(key, (n, d_in, d_out), jnp.float32) * s
    return w.astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    """fp32 statistics; the normalized tensor drops to the input dtype
    BEFORE the scale multiply (§Perf iteration H2b: one fewer fp32
    activation-sized pass per norm; scale is a per-channel vector so the
    bf16 multiply loses < 1 ulp of bf16)."""
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = (x32 * jax.lax.rsqrt(var + eps)).astype(dt)
    return y * (1.0 + scale).astype(dt)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, positions: Array, theta: float) -> Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                            # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, D/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, f: int, mlp_type: str, dtype) -> Dict[str, Array]:
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": dense_init(k1, d, f, dtype),
         "wo": dense_init(k3, f, d, dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["wg"] = dense_init(k2, d, f, dtype)
    return p


def init_stacked_mlp(key, n: int, d: int, f: int, mlp_type: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"wi": stacked_dense_init(k1, n, d, f, dtype),
         "wo": stacked_dense_init(k3, n, f, d, dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["wg"] = stacked_dense_init(k2, n, d, f, dtype)
    return p


def apply_mlp(p: Dict[str, Array], x: Array, mlp_type: str,
              shard: Shard = no_shard,
              rot: Optional[Callable[[str, Array], Array]] = None) -> Array:
    """``rot(name, x)`` optionally rotates the inputs of projection ``name``
    (wi/wg/wo) — activation-side GSOFT for per-request adapters. Every
    projection goes through the ``qlinear`` hook, so int8-quantized base
    weights (``ModelRuntime.quantized``) serve transparently."""
    h = shard(qlinear(x, p["wi"], rot, "wi"), "act_ff")
    if mlp_type == "swiglu":
        h = jax.nn.silu(shard(qlinear(x, p["wg"], rot, "wg"), "act_ff")) * h
    elif mlp_type == "geglu":
        h = jax.nn.gelu(shard(qlinear(x, p["wg"], rot, "wg"), "act_ff"),
                        approximate=True) * h
    elif mlp_type == "gelu":
        h = jax.nn.gelu(h, approximate=True)
    else:
        raise ValueError(mlp_type)
    return shard(qlinear(h, p["wo"], rot, "wo"), "act_d")


# ---------------------------------------------------------------------------
# vocab-sharded cross entropy
# ---------------------------------------------------------------------------

def softcap(logits: Array, cap: float) -> Array:
    if cap <= 0:
        return logits
    return jnp.tanh(logits / cap) * cap


def cross_entropy(logits: Array, labels: Array, valid: Optional[Array] = None,
                  vocab_size: int = 0) -> Tuple[Array, Array]:
    """Mean CE over valid tokens. logits (B, S, Vp) may be vocab-padded and
    vocab-sharded (sharding-friendly: max/logsumexp reduce over the sharded
    axis lower to small all-reduces, never a full-vocab gather).

    Returns (loss, accuracy)."""
    b, s, vp = logits.shape
    l32 = logits.astype(jnp.float32)
    if vocab_size and vocab_size < vp:
        pad_mask = jnp.arange(vp) >= vocab_size
        l32 = jnp.where(pad_mask[None, None, :], -1e30, l32)
    m = jax.lax.stop_gradient(jnp.max(l32, axis=-1, keepdims=True))
    shifted = l32 - m
    lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1)) + m[..., 0]
    gold = jnp.take_along_axis(l32, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    pred = jnp.argmax(l32, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if valid is None:
        valid = jnp.ones_like(nll)
    valid = valid.astype(jnp.float32)
    denom = jnp.maximum(valid.sum(), 1.0)
    return (nll * valid).sum() / denom, (correct * valid).sum() / denom
