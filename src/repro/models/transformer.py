"""Language models: decoder-only (dense + MoE), pure-SSM, hybrid
(mamba + shared attention), and VLM-backbone variants.

Structure decisions that matter at scale:
  * scan-over-layers with configurable remat -> compact HLO (compile time is
    O(1) in depth) and activation memory bounded by one layer
  * hybrid (zamba2) is scanned over *super-blocks* (attn_every mamba layers +
    one shared-weight attention application) so FLOP accounting stays exact
  * KV caches / SSM states are pytrees with a stacked layer dim, scanned
    alongside the layer weights during decode
  * all activations pass through the ``shard`` callback for GSPMD constraints
"""
from __future__ import annotations

import functools
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.peft import AdapterContext, PrefillRequest
from . import registry
from .attention import (attention_block, init_attention, init_cache,
                        init_paged_kv, paged_attention_block,
                        paged_prefill_chunk_block)
from .layers import (Shard, apply_mlp, cross_entropy, embed_init, init_mlp,
                     init_stacked_mlp, no_shard, qlinear, rms_norm, softcap,
                     stacked_dense_init)
from .moe import init_moe, moe_layer
from .ssm import init_mamba, init_mamba_state, mamba_block, mamba_decode_step

Array = jnp.ndarray


def _traits(cfg: ModelConfig) -> registry.FamilyOps:
    """The registry record for this config's family — all structural
    branching in this module reads traits off it (``mixer`` /
    ``has_patches``), never the family string."""
    return registry.get(cfg.family)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_lm(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    wd = cfg.weight_dtype
    vp = cfg.padded_vocab()
    ks = jax.random.split(key, 12)
    params: Dict[str, Any] = {
        "embed": {"table": embed_init(ks[0], vp, cfg.d_model, wd)},
        "final_norm": jnp.zeros((cfg.d_model,), wd),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = {"w": stacked_dense_init(
            ks[1], 1, cfg.d_model, vp, wd)[0]}

    L = cfg.num_layers
    t = _traits(cfg)
    if t.mixer == "attention":
        layers: Dict[str, Any] = {
            "attn_norm": jnp.zeros((L, cfg.d_model), wd),
            "attn": init_attention(ks[2], cfg, stacked=L),
            "mlp_norm": jnp.zeros((L, cfg.d_model), wd),
        }
        if cfg.is_moe:
            layers["moe"] = init_moe(ks[3], cfg, L, wd)
        else:
            layers["mlp"] = init_stacked_mlp(ks[3], L, cfg.d_model, cfg.d_ff,
                                             cfg.mlp_type, wd)
        params["layers"] = layers
        if t.has_patches:
            params["patch_proj"] = {"wi": stacked_dense_init(
                ks[4], 1, cfg.frontend_dim, cfg.d_model, wd)[0]}
    elif t.mixer == "ssm":
        params["layers"] = {
            "norm": jnp.zeros((L, cfg.d_model), wd),
            "mamba": init_mamba(ks[2], cfg, (L,), wd),
        }
    elif t.mixer == "hybrid":
        per = cfg.attn_every
        assert L % per == 0, "attn_every must divide num_layers"
        nsuper = L // per
        params["blocks"] = {
            "norm": jnp.zeros((nsuper, per, cfg.d_model), wd),
            "mamba": init_mamba(ks[2], cfg, (nsuper, per), wd),
        }
        params["shared_attn"] = {
            "norm": jnp.zeros((cfg.d_model,), wd),
            "attn": init_attention(ks[3], cfg, stacked=0),
            "mlp_norm": jnp.zeros((cfg.d_model,), wd),
            "mlp": init_mlp(ks[4], cfg.d_model, cfg.d_ff, cfg.mlp_type, wd),
        }
    else:
        raise ValueError(f"init_lm: unsupported family {cfg.family}")
    return params


def abstract_params(cfg: ModelConfig, key=None):
    """Shape tree without allocation (dry-run)."""
    key = key if key is not None else jax.random.PRNGKey(0)
    return jax.eval_shape(functools.partial(init_lm, cfg), key)


def param_count(cfg: ModelConfig) -> int:
    tree = abstract_params(cfg)
    return sum(int(math.prod(l.shape)) for l in jax.tree.leaves(tree))


def active_param_count(cfg: ModelConfig) -> int:
    """6*N_active*D accounting for MoE (top-k of the experts per token)."""
    total = param_count(cfg)
    if not cfg.is_moe:
        return total
    tree = abstract_params(cfg)
    expert = sum(int(math.prod(l.shape))
                 for p, l in _walk(tree) if "/moe/w" in p)
    active = expert * cfg.moe_top_k // cfg.moe_experts
    return total - expert + active


def _walk(tree):
    from repro.core.peft import flatten_paths
    return flatten_paths(tree).items()


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------

def _decoder_layer(cfg: ModelConfig, lp, h: Array, shard: Shard,
                   cache=None, cache_pos=None, rot_attn=None, rot_mlp=None):
    a, new_cache = attention_block(
        lp["attn"], rms_norm(h, lp["attn_norm"], cfg.norm_eps), cfg,
        cache=cache, cache_pos=cache_pos, causal=True, shard=shard,
        rot=rot_attn)
    h = h + a
    hin = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    if "moe" in lp:
        m, aux = moe_layer(lp["moe"], hin, cfg, shard,
                           segment=cfg.moe_segment)
    else:
        m, aux = apply_mlp(lp["mlp"], hin, cfg.mlp_type, shard,
                           rot=rot_mlp), jnp.zeros((), jnp.float32)
    return h + m, aux, new_cache


def _paged_decoder_layer(cfg: ModelConfig, lp, h: Array, shard: Shard,
                         pages, table, pos, rot_attn=None, rot_mlp=None):
    """Decoder layer body with the KV write/read routed through a page
    table (decode step: full batch, one token per row)."""
    a, new_pages = paged_attention_block(
        lp["attn"], rms_norm(h, lp["attn_norm"], cfg.norm_eps), cfg,
        pages=pages, table=table, pos=pos, shard=shard, rot=rot_attn)
    h = h + a
    hin = rms_norm(h, lp["mlp_norm"], cfg.norm_eps)
    if "moe" in lp:
        m, _ = moe_layer(lp["moe"], hin, cfg, shard, segment=cfg.moe_segment)
    else:
        m = apply_mlp(lp["mlp"], hin, cfg.mlp_type, shard, rot=rot_mlp)
    return h + m, new_pages


def _shared_attn_layer(cfg: ModelConfig, sp, h: Array, shard: Shard,
                       cache=None, cache_pos=None):
    a, new_cache = attention_block(
        sp["attn"], rms_norm(h, sp["norm"], cfg.norm_eps), cfg,
        cache=cache, cache_pos=cache_pos, causal=True, shard=shard)
    h = h + a
    m = apply_mlp(sp["mlp"], rms_norm(h, sp["mlp_norm"], cfg.norm_eps),
                  cfg.mlp_type, shard)
    return h + m, new_cache


def _remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# forward (train / scoring)
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params, tokens: Array, shard: Shard) -> Array:
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.act_dtype)
    if cfg.embed_scale:
        h = h * jnp.asarray(math.sqrt(cfg.d_model), h.dtype)
    return shard(h, "act_btd")


def _unembed(cfg: ModelConfig, params, h: Array, shard: Shard) -> Array:
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].T.astype(h.dtype)
    else:
        logits = qlinear(h, params["lm_head"]["w"], cast=True)
    logits = softcap(logits, cfg.logit_softcap)
    return shard(logits, "logits")


def forward(cfg: ModelConfig, params, batch: Dict[str, Array],
            shard: Shard = no_shard) -> Tuple[Array, Array]:
    """-> (logits (B, S, Vp), moe_aux). batch["tokens"]: (B, S) int32;
    vlm adds batch["patches"] (B, P, frontend_dim) prepended to the stream."""
    tokens = batch["tokens"]
    t = _traits(cfg)
    h = _embed(cfg, params, tokens, shard)
    n_prefix = 0
    if t.has_patches and "patches" in batch:
        pe = qlinear(batch["patches"].astype(cfg.act_dtype),
                     params["patch_proj"]["wi"], cast=True)
        h = jnp.concatenate([shard(pe, "act_btd"), h], axis=1)
        n_prefix = pe.shape[1]

    if t.mixer == "attention":
        def body(hc, lp):
            hc, aux, _ = _decoder_layer(cfg, lp, hc, shard)
            return hc, aux
        h, auxs = jax.lax.scan(_remat(cfg, body), h, params["layers"])
        aux = jnp.mean(auxs)
    elif t.mixer == "ssm":
        def body(hc, lp):
            y = mamba_block(lp["mamba"], rms_norm(hc, lp["norm"], cfg.norm_eps),
                            cfg, shard)
            return hc + y, jnp.zeros((), jnp.float32)
        h, _ = jax.lax.scan(_remat(cfg, body), h, params["layers"])
        aux = jnp.zeros((), jnp.float32)
    elif t.mixer == "hybrid":
        sp = params["shared_attn"]

        def super_body(hc, bp):
            def inner(hc2, mp):
                y = mamba_block(mp["mamba"],
                                rms_norm(hc2, mp["norm"], cfg.norm_eps),
                                cfg, shard)
                return hc2 + y, None
            hc, _ = jax.lax.scan(
                inner, hc, {"mamba": bp["mamba"], "norm": bp["norm"]})
            hc, _ = _shared_attn_layer(cfg, sp, hc, shard)
            return hc, jnp.zeros((), jnp.float32)
        h, _ = jax.lax.scan(_remat(cfg, super_body), h, params["blocks"])
        aux = jnp.zeros((), jnp.float32)
    else:
        raise ValueError(cfg.family)

    logits = _unembed(cfg, params, h, shard)
    if n_prefix:
        # keep only text positions so logits align with batch["labels"]
        logits = logits[:, n_prefix:n_prefix + tokens.shape[1]]
    return logits, aux


MOE_AUX_COEF = 0.01


def lm_loss(cfg: ModelConfig, params, batch: Dict[str, Array],
            shard: Shard = no_shard):
    """Contract: batch["labels"][:, t] is the target for logits position t
    (i.e. the next token), with batch["mask"] zeroing padded/final slots."""
    logits, aux = forward(cfg, params, batch, shard)
    loss, acc = cross_entropy(logits, batch["labels"], batch.get("mask"),
                              cfg.vocab_size)
    loss = loss + MOE_AUX_COEF * aux
    return loss, {"loss": loss, "accuracy": acc, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving: prefill + decode with caches / states
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int):
    L = cfg.num_layers
    t = _traits(cfg)
    if t.mixer == "attention":
        c = init_cache(cfg, batch, max_len)
        return {"kv": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (L,) + v.shape).copy(), c)}
    if t.mixer == "ssm":
        return {"mamba": init_mamba_state(cfg, batch, (L,))}
    if t.mixer == "hybrid":
        per = cfg.attn_every
        nsuper = L // per
        c = init_cache(cfg, batch, max_len)
        return {
            "mamba": init_mamba_state(cfg, batch, (nsuper, per)),
            "kv": jax.tree.map(
                lambda v: jnp.broadcast_to(v[None], (nsuper,) + v.shape).copy(), c),
        }
    raise ValueError(cfg.family)


def decode_step(cfg: ModelConfig, params, tokens: Array, state,
                pos, shard: Shard = no_shard,
                ctx: Optional[AdapterContext] = None):
    """One token for the whole batch. tokens: (B, 1); pos: scalar int32
    (current write index) or an int32 (B,) array of per-slot positions
    (continuous batching). Returns (logits (B, 1, Vp), new_state).

    ``ctx``: per-request AdapterContext (bank subtree + (B,) slot ids +
    PEFT config as one pytree) — row i rotates its activations with adapter
    ``ctx.slots[i]`` before every adapted projection (activation-side x Q;
    slot 0 is the identity).
    """
    h = _embed(cfg, params, tokens, shard)
    t = _traits(cfg)

    if t.mixer == "attention":
        bl_tree = ctx.group("layers") if ctx is not None else None
        if bl_tree is not None:
            def body(hc, xs):
                lp, cache, bl = xs
                hc, _, new_cache = _decoder_layer(
                    cfg, lp, hc, shard, cache=cache, cache_pos=pos,
                    rot_attn=ctx.rotator(bl.get("attn")),
                    rot_mlp=ctx.rotator(bl.get("mlp")))
                return hc, new_cache
            h, new_kv = jax.lax.scan(
                body, h, (params["layers"], state["kv"], bl_tree))
        else:
            def body(hc, xs):
                lp, cache = xs
                hc, _, new_cache = _decoder_layer(cfg, lp, hc, shard,
                                                  cache=cache, cache_pos=pos)
                return hc, new_cache
            h, new_kv = jax.lax.scan(body, h, (params["layers"], state["kv"]))
        new_state = {"kv": new_kv}
    elif ctx is not None:
        raise ValueError(f"adapter bank serving not supported for "
                         f"family {cfg.family}")
    elif t.mixer == "ssm":
        def body(hc, xs):
            lp, st = xs
            y, new_st = mamba_decode_step(
                lp["mamba"], rms_norm(hc, lp["norm"], cfg.norm_eps), st, cfg,
                shard)
            return hc + y, new_st
        h, new_m = jax.lax.scan(body, h, (params["layers"], state["mamba"]))
        new_state = {"mamba": new_m}
    elif t.mixer == "hybrid":
        sp = params["shared_attn"]

        def super_body(hc, xs):
            bp, mst, kvc = xs

            def inner(hc2, ys):
                mp, st = ys
                y, new_st = mamba_decode_step(
                    mp["mamba"], rms_norm(hc2, mp["norm"], cfg.norm_eps),
                    st, cfg, shard)
                return hc2 + y, new_st
            hc, new_mst = jax.lax.scan(
                inner, hc, ({"mamba": bp["mamba"], "norm": bp["norm"]}, mst))
            hc, new_kv = _shared_attn_layer(cfg, sp, hc, shard,
                                            cache=kvc, cache_pos=pos)
            return hc, (new_mst, new_kv)
        h, (new_m, new_kv) = jax.lax.scan(
            super_body, h, (params["blocks"], state["mamba"], state["kv"]))
        new_state = {"mamba": new_m, "kv": new_kv}
    else:
        raise ValueError(cfg.family)

    logits = _unembed(cfg, params, h, shard)
    return logits, new_state


def _gather_last(h: Array, last_idx) -> Array:
    """h[:, last_idx[i]] per row, keepdims — the ragged-prompt fix: each
    row's logits come from its OWN last valid prompt position, not the
    padded batch max."""
    if last_idx is None:
        return h[:, -1:]
    idx = jnp.asarray(last_idx, jnp.int32)
    idx = jnp.broadcast_to(idx, (h.shape[0],))
    return jnp.take_along_axis(h, idx[:, None, None], axis=1)


def prefill(cfg: ModelConfig, params, req: PrefillRequest, state,
            shard: Shard = no_shard):
    """Full-prompt forward that fills caches; returns (last_logits, state).

    ``req`` bundles the input batch, ``last_idx`` (scalar or (B,) int32:
    index of each row's last valid position in the processed stream —
    prompt_len - 1, plus the patch-prefix offset for vlm; logits are
    gathered there instead of at the padded batch max) and the optional
    per-request AdapterContext, as in ``decode_step``.

    For attention families the KV cache is written; SSM/hybrid prefill runs
    the scan then (for brevity) re-derives the final state via decode of the
    last token — states for SSD prefill are produced by the chunked scan in
    a production setting; here the decode path is the state authority."""
    batch, last_idx, ctx = req.batch, req.last_idx, req.ctx
    tokens = batch["tokens"]
    t = _traits(cfg)
    h = _embed(cfg, params, tokens, shard)
    if t.mixer == "attention":
        if t.has_patches and "patches" in batch:
            patches = batch["patches"].astype(cfg.act_dtype)
            prot = (ctx.rotator(ctx.group("patch_proj"))
                    if ctx is not None else None)
            pe = qlinear(patches, params["patch_proj"]["wi"], prot, "wi",
                         cast=True)
            h = jnp.concatenate([shard(pe, "act_btd"), h], axis=1)

        bl_tree = ctx.group("layers") if ctx is not None else None
        if bl_tree is not None:
            def body(hc, xs):
                lp, cache, bl = xs
                hc, _, new_cache = _decoder_layer(
                    cfg, lp, hc, shard, cache=cache,
                    rot_attn=ctx.rotator(bl.get("attn")),
                    rot_mlp=ctx.rotator(bl.get("mlp")))
                return hc, new_cache
            h, new_kv = jax.lax.scan(_remat(cfg, body), h,
                                     (params["layers"], state["kv"], bl_tree))
        else:
            def body(hc, xs):
                lp, cache = xs
                hc, _, new_cache = _decoder_layer(cfg, lp, hc, shard,
                                                  cache=cache)
                return hc, new_cache
            h, new_kv = jax.lax.scan(_remat(cfg, body), h,
                                     (params["layers"], state["kv"]))
        logits = _unembed(cfg, params, _gather_last(h, last_idx), shard)
        return logits, {"kv": new_kv}
    if ctx is not None:
        raise ValueError(f"adapter bank serving not supported for "
                         f"family {cfg.family}")
    # ssm / hybrid: run the train-path forward for logits; advance states by
    # scanning decode steps is O(S) — production uses the SSD state output.
    logits, _ = forward(cfg, params, batch, shard)
    return _gather_last(logits, last_idx), state


# ---------------------------------------------------------------------------
# serving: paged KV cache + chunked prefill (decoder family; ISSUE 7)
# ---------------------------------------------------------------------------

def init_paged_state(cfg: ModelConfig, batch: int, num_pages: int,
                     page_size: int, max_pages: int):
    """Decode-state pytree for the paged engine: per-layer page pools plus
    one int32 page table per slot. The table has ``max_pages + 1`` columns —
    the extra SENTINEL column always holds the garbage page 0, so a parked
    row (pos == max_pages * page_size) writes into garbage and jitted
    full-batch decode never retraces or masks on slot liveness."""
    if _traits(cfg).init_paged_state is not init_paged_state:
        raise ValueError(f"family {cfg.family!r} has no paged serve path "
                         f"through this module")
    L = cfg.num_layers
    pools = init_paged_kv(cfg, num_pages, page_size)
    pages = jax.tree.map(
        lambda v: jnp.broadcast_to(v[None], (L,) + v.shape).copy(), pools)
    table = jnp.zeros((batch, max_pages + 1), jnp.int32)
    return {"pages": pages, "table": table}


def paged_decode_step(cfg: ModelConfig, params, tokens: Array, state,
                      pos, shard: Shard = no_shard,
                      ctx: Optional[AdapterContext] = None):
    """One token for the whole batch through per-slot page tables.

    tokens: (B, 1); pos: int32 (B,) per-slot write positions (parked rows
    carry max_pages * page_size); state: {"pages", "table"} from
    ``init_paged_state``. Returns (logits, new_state) — the table passes
    through unchanged (host code owns table edits at admission/finish)."""
    if _traits(cfg).paged_decode_step is not paged_decode_step:
        raise ValueError(f"family {cfg.family!r} has no paged decode path "
                         f"through this module")
    h = _embed(cfg, params, tokens, shard)
    table = state["table"]
    bl_tree = ctx.group("layers") if ctx is not None else None
    if bl_tree is not None:
        def body(hc, xs):
            lp, pages, bl = xs
            hc, new_pages = _paged_decoder_layer(
                cfg, lp, hc, shard, pages, table, pos,
                rot_attn=ctx.rotator(bl.get("attn")),
                rot_mlp=ctx.rotator(bl.get("mlp")))
            return hc, new_pages
        h, new_pages = jax.lax.scan(
            body, h, (params["layers"], state["pages"], bl_tree))
    else:
        def body(hc, xs):
            lp, pages = xs
            hc, new_pages = _paged_decoder_layer(cfg, lp, hc, shard, pages,
                                                 table, pos)
            return hc, new_pages
        h, new_pages = jax.lax.scan(body, h, (params["layers"],
                                              state["pages"]))
    logits = _unembed(cfg, params, h, shard)
    return logits, {"pages": new_pages, "table": table}


def paged_chunk_prefill(cfg: ModelConfig, params, req: PrefillRequest,
                        state, slot, start, shard: Shard = no_shard):
    """One prompt CHUNK for one slot through the paged cache.

    req.batch["tokens"]: (1, C) — C is the static chunk width (jit traces
    once per width); req.last_idx: local index of the chunk's last valid
    token (only meaningful on the final chunk, where the returned logits
    seed the first generated token); slot / start: traced int32 scalars.
    Earlier chunks — and shared-prefix pages claimed from the KV cache —
    already occupy positions [0, start)."""
    if _traits(cfg).paged_chunk_prefill is not paged_chunk_prefill:
        raise ValueError(f"family {cfg.family!r} has no chunked-prefill "
                         f"path through this module")
    batch, last_idx, ctx = req.batch, req.last_idx, req.ctx
    h = _embed(cfg, params, batch["tokens"], shard)
    table_row = jax.lax.dynamic_index_in_dim(state["table"], slot, axis=0,
                                             keepdims=False)
    bl_tree = ctx.group("layers") if ctx is not None else None

    def _layer(hc, lp, pages, rot_attn=None, rot_mlp=None):
        a, new_pages = paged_prefill_chunk_block(
            lp["attn"], rms_norm(hc, lp["attn_norm"], cfg.norm_eps), cfg,
            pages=pages, table_row=table_row, start=start, shard=shard,
            rot=rot_attn)
        hc = hc + a
        hin = rms_norm(hc, lp["mlp_norm"], cfg.norm_eps)
        if "moe" in lp:
            m, _ = moe_layer(lp["moe"], hin, cfg, shard,
                             segment=cfg.moe_segment)
        else:
            m = apply_mlp(lp["mlp"], hin, cfg.mlp_type, shard, rot=rot_mlp)
        return hc + m, new_pages

    if bl_tree is not None:
        def body(hc, xs):
            lp, pages, bl = xs
            return _layer(hc, lp, pages,
                          rot_attn=ctx.rotator(bl.get("attn")),
                          rot_mlp=ctx.rotator(bl.get("mlp")))
        h, new_pages = jax.lax.scan(
            body, h, (params["layers"], state["pages"], bl_tree))
    else:
        def body(hc, xs):
            lp, pages = xs
            return _layer(hc, lp, pages)
        h, new_pages = jax.lax.scan(body, h, (params["layers"],
                                              state["pages"]))
    logits = _unembed(cfg, params, _gather_last(h, last_idx), shard)
    return logits, {"pages": new_pages, "table": state["table"]}


# ---------------------------------------------------------------------------
# registry entries — one EXPLICIT record per family this module implements
# (ssm / hybrid / vlm used to be silently routed through the decoder path)
# ---------------------------------------------------------------------------

def _init_decode_state_ops(cfg: ModelConfig, batch: int, max_len: int,
                           enc_len: int = 0):
    del enc_len  # uniform FamilyOps signature; no encoder stream here
    return init_decode_state(cfg, batch, max_len)


# the per-family traits live HERE, on the registry record — call sites
# branch on ``mixer`` / ``has_patches``, never on the family string
_FAMILY_TRAITS = {
    "decoder": dict(mixer="attention", paged=True),  # paged KV: decoder-only
    "vlm": dict(mixer="attention", has_patches=True),
    "ssm": dict(mixer="ssm"),
    "hybrid": dict(mixer="hybrid"),
}

for _family, _tr in _FAMILY_TRAITS.items():
    _tr = dict(_tr)
    _paged = _tr.pop("paged", False)
    registry.register(registry.FamilyOps(
        family=_family,
        init_params=init_lm,
        forward=forward,
        loss=lm_loss,
        init_decode_state=_init_decode_state_ops,
        prefill=prefill,
        decode_step=decode_step,
        active_param_count=active_param_count,
        init_paged_state=init_paged_state if _paged else None,
        paged_chunk_prefill=paged_chunk_prefill if _paged else None,
        paged_decode_step=paged_decode_step if _paged else None,
        **_tr,
    ))
