"""Encoder-decoder transformer (seamless-m4t backbone).

The speech frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, F, d_model); the transformer backbone —
bidirectional encoder, causal decoder with cross-attention — is fully
implemented.  Cross-attention K/V are precomputed once at prefill and stored
in the decode state (standard serving optimization).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core.peft import AdapterContext, PrefillRequest
from . import registry
from .attention import attention_block, init_attention, init_cache, online_attention
from .layers import (Shard, apply_mlp, cross_entropy, embed_init,
                     init_stacked_mlp, no_shard, qlinear, rms_norm, softcap,
                     stacked_dense_init)
from .transformer import MOE_AUX_COEF, _gather_last, _remat

Array = jnp.ndarray


def init_encdec(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    wd = cfg.weight_dtype
    vp = cfg.padded_vocab()
    ks = jax.random.split(key, 10)
    Le, Ld = cfg.enc_layers, cfg.num_layers
    enc = {
        "attn_norm": jnp.zeros((Le, cfg.d_model), wd),
        "attn": init_attention(ks[0], cfg, stacked=Le),
        "mlp_norm": jnp.zeros((Le, cfg.d_model), wd),
        "mlp": init_stacked_mlp(ks[1], Le, cfg.d_model, cfg.d_ff,
                                cfg.mlp_type, wd),
    }
    dec = {
        "attn_norm": jnp.zeros((Ld, cfg.d_model), wd),
        "attn": init_attention(ks[2], cfg, stacked=Ld),
        "cross_norm": jnp.zeros((Ld, cfg.d_model), wd),
        "cross": init_attention(ks[3], cfg, stacked=Ld),
        "mlp_norm": jnp.zeros((Ld, cfg.d_model), wd),
        "mlp": init_stacked_mlp(ks[4], Ld, cfg.d_model, cfg.d_ff,
                                cfg.mlp_type, wd),
    }
    return {
        "embed": {"table": embed_init(ks[5], vp, cfg.d_model, wd)},
        "lm_head": {"w": stacked_dense_init(ks[6], 1, cfg.d_model, vp, wd)[0]},
        "enc_norm": jnp.zeros((cfg.d_model,), wd),
        "final_norm": jnp.zeros((cfg.d_model,), wd),
        "encoder": enc,
        "decoder": dec,
    }


def encode(cfg: ModelConfig, params, frames: Array,
           shard: Shard = no_shard) -> Array:
    """frames: (B, F, d_model) stub embeddings -> encoder output."""
    h = shard(frames.astype(cfg.act_dtype), "act_btd")

    def body(hc, lp):
        a, _ = attention_block(lp["attn"],
                               rms_norm(hc, lp["attn_norm"], cfg.norm_eps),
                               cfg, causal=False, shard=shard)
        hc = hc + a
        m = apply_mlp(lp["mlp"], rms_norm(hc, lp["mlp_norm"], cfg.norm_eps),
                      cfg.mlp_type, shard)
        return hc + m, None

    h, _ = jax.lax.scan(_remat(cfg, body), h, params["encoder"])
    return rms_norm(h, params["enc_norm"], cfg.norm_eps)


def _decoder_pass(cfg, params, h, enc_out, shard, cache=None, cache_pos=None):
    def body(hc, xs):
        lp, kvc = xs
        a, new_kv = attention_block(
            lp["attn"], rms_norm(hc, lp["attn_norm"], cfg.norm_eps), cfg,
            cache=kvc, cache_pos=cache_pos, causal=True, shard=shard)
        hc = hc + a
        c, _ = attention_block(
            lp["cross"], rms_norm(hc, lp["cross_norm"], cfg.norm_eps), cfg,
            kv_x=enc_out, causal=False, shard=shard)
        hc = hc + c
        m = apply_mlp(lp["mlp"], rms_norm(hc, lp["mlp_norm"], cfg.norm_eps),
                      cfg.mlp_type, shard)
        return hc + m, new_kv

    xs = (params["decoder"], cache) if cache is not None else \
        (params["decoder"], None)
    if cache is None:
        h, _ = jax.lax.scan(_remat(cfg, lambda hc, lp: body(hc, (lp, None))),
                            h, params["decoder"])
        return h, None
    h, new_kv = jax.lax.scan(body, h, xs)
    return h, new_kv


def _unembed(cfg, params, h, shard):
    h = rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = qlinear(h, params["lm_head"]["w"], cast=True)
    return shard(softcap(logits, cfg.logit_softcap), "logits")


def forward(cfg: ModelConfig, params, batch: Dict[str, Array],
            shard: Shard = no_shard) -> Tuple[Array, Array]:
    enc_out = encode(cfg, params, batch["frames"], shard)
    h = jnp.take(params["embed"]["table"], batch["tokens"], axis=0
                 ).astype(cfg.act_dtype)
    h = shard(h, "act_btd")
    h, _ = _decoder_pass(cfg, params, h, enc_out, shard)
    return _unembed(cfg, params, h, shard), jnp.zeros((), jnp.float32)


def lm_loss(cfg: ModelConfig, params, batch: Dict[str, Array],
            shard: Shard = no_shard):
    logits, aux = forward(cfg, params, batch, shard)
    loss, acc = cross_entropy(logits, batch["labels"], batch.get("mask"),
                              cfg.vocab_size)
    return loss, {"loss": loss, "accuracy": acc, "moe_aux": aux}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, batch: int, max_len: int,
                      enc_len: int):
    L = cfg.num_layers
    kv = init_cache(cfg, batch, max_len)
    return {
        "kv": jax.tree.map(
            lambda v: jnp.broadcast_to(v[None], (L,) + v.shape).copy(), kv),
        "enc_out": jnp.zeros((batch, enc_len, cfg.d_model), cfg.act_dtype),
    }


def prefill(cfg: ModelConfig, params, req: PrefillRequest, state,
            shard: Shard = no_shard):
    if req.ctx is not None:
        raise ValueError("adapter bank serving not supported for encdec")
    batch = req.batch
    enc_out = encode(cfg, params, batch["frames"], shard)
    h = jnp.take(params["embed"]["table"], batch["tokens"], axis=0
                 ).astype(cfg.act_dtype)
    h, new_kv = _decoder_pass(cfg, params, shard(h, "act_btd"), enc_out,
                              shard, cache=state["kv"])
    logits = _unembed(cfg, params, _gather_last(h, req.last_idx), shard)
    return logits, {"kv": new_kv, "enc_out": enc_out}


def decode_step(cfg: ModelConfig, params, tokens: Array, state, pos,
                shard: Shard = no_shard,
                ctx: Optional[AdapterContext] = None):
    if ctx is not None:
        raise ValueError("adapter bank serving not supported for encdec")
    h = jnp.take(params["embed"]["table"], tokens, axis=0).astype(cfg.act_dtype)
    h = shard(h, "act_btd")
    h, new_kv = _decoder_pass(cfg, params, h, state["enc_out"], shard,
                              cache=state["kv"], cache_pos=pos)
    logits = _unembed(cfg, params, h, shard)
    return logits, {"kv": new_kv, "enc_out": state["enc_out"]}


def _active_param_count(cfg: ModelConfig) -> int:
    from . import api  # lazy: api imports this module at load time
    return api.param_count(cfg)  # encdec is dense — all params active


registry.register(registry.FamilyOps(
    family="encdec",
    init_params=init_encdec,
    forward=forward,
    loss=lm_loss,
    init_decode_state=init_decode_state,
    prefill=prefill,
    decode_step=decode_step,
    active_param_count=_active_param_count,
    has_encoder=True,
))
