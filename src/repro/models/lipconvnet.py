"""LipConvnet-n — 1-Lipschitz CNN with GS-SOC / SOC orthogonal convolutions.

Architecture (paper §7.3, following Singla & Feizi 2021): 5 blocks of n/5
orthogonal conv layers; the last layer of each block downsamples (invertible
space-to-depth + orthogonal conv + channel selection — semi-orthogonal,
1-Lipschitz) and doubles the channel count.  Gradient-preserving MaxMin /
MaxMinPermuted activations; spectral-normalized dense head.  The margin
certificate (top1-top2)/sqrt(2) gives provable L2 robustness.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.conv import (ACTIVATIONS, GSSOCSpec, certified_radius,
                             gs_soc_layer, init_gs_soc, power_iteration_sn,
                             soc_layer_spec, space_to_depth)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class LipConvnetConfig:
    depth: int = 15                     # n; 5 blocks x n/5 layers
    base_width: int = 32
    num_classes: int = 100
    image_size: int = 32
    in_channels: int = 3
    groups: Tuple[int, int] = (4, 0)    # (a, b) of Table 3; b=0 -> single conv
    activation: str = "maxmin_permuted"
    terms: int = 6
    conv_layer: str = "gs"              # "gs" | "soc"
    paired_shuffle: bool = True

    def __post_init__(self):
        if self.depth % 5:
            raise ValueError("LipConvnet depth must be divisible by 5")

    def layer_spec(self, channels: int) -> GSSOCSpec:
        if self.conv_layer == "soc":
            return soc_layer_spec(channels, self.terms)
        a, b = self.groups
        a = a if channels % a == 0 else 1
        b = b if (b and channels % b == 0) else (0 if not b else 1)
        return GSSOCSpec(channels=channels, groups1=a, groups2=b,
                         terms=self.terms, paired=self.paired_shuffle)

    def block_widths(self):
        w = self.base_width
        return [w * (2 ** i) for i in range(5)]


def init_lipconvnet(cfg: LipConvnetConfig, key: jax.Array) -> Dict:
    params: Dict = {}
    per_block = cfg.depth // 5
    for bi, width in enumerate(cfg.block_widths()):
        block: Dict = {}
        for li in range(per_block - 1):
            spec = cfg.layer_spec(width)
            block[f"conv{li}"] = init_gs_soc(
                spec, jax.random.fold_in(key, bi * 100 + li))
        # downsampling layer operates on 4*width channels post space-to-depth
        spec_dn = cfg.layer_spec(4 * width)
        block["down"] = init_gs_soc(spec_dn, jax.random.fold_in(key, bi * 100 + 99))
        params[f"block{bi}"] = block
    feat = cfg.block_widths()[-1] * 2
    spatial = cfg.image_size // (2 ** 5)
    flat = feat * max(spatial, 1) * max(spatial, 1)
    params["head"] = {
        "w": jax.random.normal(jax.random.fold_in(key, 10_000),
                               (flat, cfg.num_classes)) / np.sqrt(flat),
    }
    return params


def apply_lipconvnet(cfg: LipConvnetConfig, params: Dict, x: Array) -> Array:
    """x: (N, H, W, C_in) -> logits (N, num_classes). 1-Lipschitz end to end."""
    act = ACTIVATIONS[cfg.activation]
    per_block = cfg.depth // 5
    # channel zero-pad to base width (norm-preserving injection)
    pad = cfg.base_width - x.shape[-1]
    if pad > 0:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
    for bi, width in enumerate(cfg.block_widths()):
        block = params[f"block{bi}"]
        for li in range(per_block - 1):
            spec = cfg.layer_spec(width)
            x = act(gs_soc_layer(spec, block[f"conv{li}"], x))
        # downsample: orthogonal space-to-depth, orthogonal conv on 4w,
        # then select 2w channels (semi-orthogonal, 1-Lipschitz)
        x = space_to_depth(x, 2)
        spec_dn = cfg.layer_spec(4 * width)
        x = gs_soc_layer(spec_dn, block["down"], x)
        x = act(x[..., : 2 * width])
    x = x.reshape(x.shape[0], -1)
    w = params["head"]["w"]
    sn = jax.lax.stop_gradient(power_iteration_sn(w)) + 1e-6
    return x @ (w / sn)


def count_conv_params(cfg: LipConvnetConfig) -> int:
    per_block = cfg.depth // 5
    total = 0
    for width in cfg.block_widths():
        total += (per_block - 1) * cfg.layer_spec(width).num_params
        total += cfg.layer_spec(4 * width).num_params
    return total


def lipconvnet_loss(cfg: LipConvnetConfig, params: Dict, images: Array,
                    labels: Array, margin: float = 0.7071):
    """Margin cross-entropy used by SOC-style certified training."""
    logits = apply_lipconvnet(cfg, params, images)
    onehot = jax.nn.one_hot(labels, cfg.num_classes)
    adjusted = logits - margin * np.sqrt(2.0) * onehot
    logp = jax.nn.log_softmax(adjusted)
    loss = -jnp.mean(jnp.sum(onehot * logp, axis=-1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    cert = jnp.mean((certified_radius(logits) > 36.0 / 255.0)
                    & (jnp.argmax(logits, -1) == labels))
    return loss, {"accuracy": acc, "certified": cert}
