"""Batched serving for STATELESS families (``FamilyOps.stateless`` — one
whole-input forward, no KV): the image-classification lane.

``ImageServeEngine`` is a tick-batched driver over ``ModelRuntime.infer_fn``
— the same runtime surface the token engines drive, so everything attached
there rides along unchanged: per-request adapter banks (eager OR
AdapterStore-paged, any bankable ``core.methods`` entry), int8-quantized
base weights, sharded params. Each scheduler tick admits up to
``max_batch`` queued requests (claiming their bank slots; a store-paged
acquire may STALL exactly like token admission), stacks their images into
one fixed-shape batch, and dispatches ONE jitted forward whose
``AdapterContext`` routes row i through adapter ids[i] — row-level
multi-tenancy with O(m*d)-per-pixel-row rotation cost, never a per-request
weight re-merge.

The engine speaks the full ``EngineCluster`` duck-type surface
(``add_request`` / ``step_launch`` / ``step_commit`` / ``steal_queued`` /
``submit`` / ``stats`` / ``adapter_stats``), so multi-replica image serving
needs no cluster changes: a classification "token" is the argmax class, one
per request. Full logits are kept per request (``Request.logits`` and
``result_logits``) — the certified-robustness checks in
``benchmarks/image_bench.py`` need the top-2 margin, not just the class.

Token engines refuse stateless families up front (``serve.engine``); this
engine refuses families WITH a decode surface symmetrically.
"""
from __future__ import annotations

import collections
import time
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.runtime import ModelRuntime
from repro.models import registry
from .engine import EngineMetrics, Request


def _check_image(cfg: ModelConfig, image) -> np.ndarray:
    img = np.asarray(image, np.float32)
    want = (cfg.image_size, cfg.image_size, cfg.in_channels)
    if img.shape != want:
        raise ValueError(f"image shape {img.shape} != {want} "
                         f"(config {cfg.name!r})")
    return img


class ImageServeEngine:
    """Tick-batched stateless serving over one ``ModelRuntime``."""

    _kind = "image"

    def __init__(self, runtime: ModelRuntime, *, max_batch: int = 8,
                 tracer=None):
        if not registry.get(runtime.cfg.family).stateless:
            raise ValueError(
                f"family {runtime.cfg.family!r} has a prefill/decode "
                "surface — serve it through ServeEngine/PagedServeEngine")
        self.rt = runtime
        self.cfg = runtime.cfg
        self.max_batch = max_batch
        self.tracer = tracer
        self._ttag = (tracer.register_engine(self._kind)
                      if tracer is not None else "")
        self._infer = runtime.infer_fn()
        self._queue: "collections.deque[Request]" = collections.deque()
        self._active: List[Request] = []     # launched, not yet committed
        self._next_id = 0
        self._results: Dict[int, List[int]] = {}
        self.result_logits: Dict[int, np.ndarray] = {}
        self.finished: List[Request] = []
        self.stats = EngineMetrics(self._kind)

    # -- submission -----------------------------------------------------------
    def add_request(self, prompt, max_new_tokens: int = 1,
                    adapter: Optional[str] = None) -> int:
        """Enqueue one image (the ``prompt`` field carries the (H, W, C)
        array — field names match the token engines so cluster routing and
        workload drivers need no image-specific casing); the response is a
        single class "token". ``max_new_tokens`` is accepted for surface
        uniformity and ignored."""
        del max_new_tokens
        self.rt.validate_adapter(adapter)
        img = _check_image(self.cfg, prompt)
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, img, max_new_tokens=1, adapter=adapter,
                      t_submit=time.perf_counter())
        self._queue.append(req)
        if self.tracer is not None:
            self.tracer.submit(self._ttag, rid, adapter=adapter,
                               t_submit=req.t_submit)
        return rid

    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def load(self) -> int:
        return self.queue_depth + self.num_active

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active

    def add_wall(self, dt: float) -> None:
        self.stats.add_wall(dt)

    # -- cluster hooks --------------------------------------------------------
    def steal_queued(self) -> Optional[Request]:
        """Pop the YOUNGEST queued request for cluster rebalancing."""
        if not self._queue:
            return None
        req = self._queue.pop()
        if self.tracer is not None:        # re-submits on the new engine
            self.tracer.drop(self._ttag, req.rid)
        return req

    def submit(self, req: Request) -> int:
        """Enqueue an existing Request under a fresh local rid (rebalanced
        arrivals keep their image/adapter/submit timestamp)."""
        self.rt.validate_adapter(req.adapter)
        _check_image(self.cfg, req.prompt)
        req.rid = self._next_id
        self._next_id += 1
        self._queue.append(req)
        if self.tracer is not None:        # keeps the ORIGINAL submit time
            self.tracer.submit(self._ttag, req.rid, adapter=req.adapter,
                               t_submit=req.t_submit)
        return req.rid

    # -- scheduling -----------------------------------------------------------
    def step_launch(self):
        """Admit up to ``max_batch`` queued requests (pinning their bank
        slots; a store-paged acquire stall stops admission for this tick —
        committing the partial batch is what unpins slots) and dispatch ONE
        jitted batched forward. Returns the pending logits array without
        syncing, so a cluster can launch every replica before blocking."""
        admitted: List[Request] = []
        ids: List[int] = []
        while self._queue and len(admitted) < self.max_batch:
            req = self._queue[0]
            aid = self.rt.acquire_adapter(req.adapter)
            if aid is None:                  # admission stall, not an error
                self.stats.inc("admission_stalls")
                if self.tracer is not None:
                    self.tracer.stall(self._ttag, req.rid, "adapter")
                break
            self._queue.popleft()
            admitted.append(req)
            ids.append(aid)
        if not admitted:
            if self._queue and not self._active:
                raise RuntimeError(
                    "image admission deadlock: nothing in flight and the "
                    "bank cannot admit the queue head — the HBM budget is "
                    "too small for even one adapter of its method")
            return None
        # fixed batch shape: ONE compile; empty rows are zero images on the
        # identity slot (their logits are computed and discarded)
        batch = np.zeros((self.max_batch, self.cfg.image_size,
                          self.cfg.image_size, self.cfg.in_channels),
                         np.float32)
        slot_ids = np.zeros(self.max_batch, np.int32)
        for i, req in enumerate(admitted):
            batch[i] = req.prompt
            slot_ids[i] = ids[i]
        ctx = self.rt.context(slot_ids)
        if self.tracer is not None:          # the forward IS the prefill
            for r in admitted:
                self.tracer.prefill_start(self._ttag, r.rid)
        logits = self._infer(self.rt.params, ctx, jnp.asarray(batch))
        self._active = admitted
        self.stats.inc("decode_steps")
        for r in admitted:
            self.stats.log_admission(r.rid)
        return logits

    def step_commit(self, pending) -> bool:
        """Sync the launched batch, record each request's class + logits,
        release bank pins. Returns True while work remains."""
        if pending is not None:
            vals = np.asarray(pending)       # (max_batch, num_classes)
            now = time.perf_counter()
            for i, req in enumerate(self._active):
                logits = vals[i]
                req.output = [int(logits.argmax())]
                req.logits = logits
                req.t_first = req.t_done = now
                self._results[req.rid] = req.output
                self.result_logits[req.rid] = logits
                self.finished.append(req)
                self.stats.inc("requests")
                self.stats.inc("tokens_generated")
                if self.tracer is not None:
                    self.tracer.prefill_end(self._ttag, req.rid)
                    self.tracer.first_token(self._ttag, req.rid)
                    self.tracer.finish(self._ttag, req.rid)
                self.rt.release_adapter(req.adapter)
            self._active = []
        return not self.idle

    def step(self) -> bool:
        return self.step_commit(self.step_launch())

    def drain_finished(self) -> List[Request]:
        """Hand over (and forget) everything completed so far."""
        out, self.finished = self.finished, []
        for r in out:
            self._results.pop(r.rid, None)
            self.result_logits.pop(r.rid, None)
        return out

    def adapter_stats(self) -> Optional[Dict[str, Any]]:
        """Residency counters of a store-backed bank (None on eager)."""
        stats = getattr(self.rt.bank, "stats", None)
        return stats() if callable(stats) else None

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue; {rid: [class]}. Full logits stay readable in
        ``result_logits`` until ``drain_finished``."""
        t0 = time.perf_counter()
        while self.step():
            pass
        self.stats.add_wall(time.perf_counter() - t0)
        res, self._results = self._results, {}
        return res
