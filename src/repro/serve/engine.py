"""Serving engines: continuous batching with slot-based KV cache + the
static-batch reference engine.

``ServeEngine`` (the default) is a scheduler over ``max_batch`` persistent
decode slots:

  * requests are admitted into free slots as others finish (EOS or token
    budget) — no lockstep ``max(max_new_tokens)`` barrier;
  * each slot carries its own position counter; decode runs ONE jitted step
    over the full slot array with per-slot write positions and per-slot
    ``kv_len`` masks (the online-attention kv_len argument);
  * admission prefills a single request (batch 1, prompt padded to a
    power-of-two bucket to bound recompiles) and scatters the fresh state
    row into the slot (``train.steps.build_slot_prefill_step``);
  * each slot carries an adapter id into a per-request GS adapter bank
    (``core.peft.AdapterBank``): row i rotates its activations with its own
    GSOFT rotation x Q_i before every adapted matmul — O(b*d) per token,
    versus O(d^2) to re-merge a dense rotation per request. Slot 0 of the
    bank is the identity (serves the base model).

``StaticServeEngine`` is the drain-queue -> pad -> prefill -> lockstep
decode reference (the paper's merged-weight serving story, §6.1): one
adapter merged into the weights offline, zero per-token overhead. Use it
when every request shares one fine-tune; use ``ServeEngine`` + a bank when
requests carry different adapters.

Both engines sample each row's first token at its OWN last valid prompt
index (ragged prompts — shorter rows no longer read a padded position) and
decode with per-row positions. Sharding-ready: pass a mesh to shard
params/caches like the dry-run does.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.models import api
from repro.train.steps import (build_decode_step, build_prefill_step,
                               build_slot_prefill_step)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    adapter: Optional[str] = None        # bank adapter name (None = base)
    output: Optional[List[int]] = None
    # timing (perf_counter seconds; filled by the engines)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


def _new_stats() -> Dict[str, Any]:
    return {"requests": 0, "tokens_generated": 0, "decode_steps": 0,
            "prefills": 0, "wall_s": 0.0, "admission_log": []}


def _stream_prefix(cfg: ModelConfig) -> int:
    """Non-text positions prepended to the decode stream (vlm patches)."""
    return cfg.frontend_tokens if cfg.family == "vlm" else 0


def _check_capacity(cfg: ModelConfig, prompt: List[int], max_new: int,
                    max_len: int) -> None:
    plen = len(prompt) + _stream_prefix(cfg)
    if plen + max_new > max_len:
        raise ValueError(f"prompt ({plen}) + max_new ({max_new}) "
                         f"exceeds max_len={max_len}")


def latency_percentiles(requests: List[Request],
                        qs=(50, 95)) -> Dict[int, float]:
    """{q: seconds} request-latency percentiles over finished Requests."""
    lats = [r.latency_s for r in requests]
    if not lats:
        return {q: 0.0 for q in qs}
    return {q: float(np.percentile(lats, q)) for q in qs}


class ServeEngine:
    """Continuous-batching engine over ``max_batch`` persistent slots."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0, mesh=None,
                 adapters=None, peft_cfg: Optional[peft_lib.PEFTConfig] = None,
                 bank: Optional[peft_lib.AdapterBank] = None):
        self.cfg = cfg
        if adapters and peft_cfg is not None:
            if bank is not None:
                raise ValueError(
                    "pass EITHER merged adapters (adapters + peft_cfg) OR a "
                    "per-request bank — merging and then rotating per "
                    "request would apply adapters twice")
            params = peft_lib.merge_tree(peft_cfg, params, adapters)  # offline
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.mesh = mesh
        self.bank = bank
        self._bank_tree = bank.tree if bank is not None else {}
        bank_cfg = bank.cfg if bank is not None else None
        self._enc_len = max(max_len // 4, 8)
        self._prefix = _stream_prefix(cfg)

        self._slot_prefill = jax.jit(
            build_slot_prefill_step(cfg, mesh, max_len=max_len,
                                    enc_len=self._enc_len, bank_cfg=bank_cfg),
            donate_argnums=(3,))
        self._banked = bank_cfg is not None
        self._decode = jax.jit(
            build_decode_step(cfg, mesh, bank_cfg=bank_cfg),
            donate_argnums=(3,) if self._banked else (2,))

        self._state = api.init_decode_state(cfg, max_batch, max_len,
                                            enc_len=self._enc_len)
        # per-slot bookkeeping (host side)
        self._pos = np.zeros(max_batch, np.int32)
        self._last = np.zeros(max_batch, np.int32)
        self._adapter_ids = np.zeros(max_batch, np.int32)
        self._slot_req: List[Optional[Request]] = [None] * max_batch
        self._outs: List[List[int]] = [[] for _ in range(max_batch)]

        self._queue: "collections.deque[Request]" = collections.deque()
        self._next_id = 0
        self._results: Dict[int, List[int]] = {}
        # completed Requests (latency accounting). Grows until drained —
        # long-running streaming drivers should call drain_finished()
        # periodically instead of letting history accumulate.
        self.finished: List[Request] = []
        self.stats = _new_stats()

    # -- submission -----------------------------------------------------------
    def add_request(self, prompt: List[int], max_new_tokens: int = 16,
                    adapter: Optional[str] = None) -> int:
        if self.bank is None and adapter is not None:
            raise ValueError("engine has no adapter bank; build one with "
                             "core.peft.build_adapter_bank")
        if self.bank is not None:
            self.bank.slot(adapter)          # validate the name eagerly
        _check_capacity(self.cfg, prompt, max_new_tokens, self.max_len)
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, list(prompt), max_new_tokens, adapter=adapter,
                      t_submit=time.perf_counter())
        self._queue.append(req)
        return rid

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    @property
    def idle(self) -> bool:
        return not self._queue and self.num_active == 0

    # -- internals ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        """Power-of-two prompt pad length (bounds prefill recompiles);
        clamped so prefix + bucket always fits the slot cache."""
        b = 8
        while b < plen:
            b *= 2
        return min(b, self.max_len - self._prefix)

    def _feed(self, prompt: List[int]) -> Dict[str, Any]:
        bucket = self._bucket(len(prompt))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        feed: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            feed["frames"] = jnp.zeros((1, self._enc_len, self.cfg.d_model),
                                       self.cfg.act_dtype)
        if self.cfg.family == "vlm":
            feed["patches"] = jnp.zeros(
                (1, self.cfg.frontend_tokens, self.cfg.frontend_dim),
                self.cfg.act_dtype)
        return feed

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        req.output = self._outs[slot][:req.max_new_tokens]
        req.t_done = time.perf_counter()
        self._results[req.rid] = req.output
        self.finished.append(req)
        self.stats["requests"] += 1
        self.stats["tokens_generated"] += len(req.output)
        self._slot_req[slot] = None

    def _admit(self) -> None:
        """Fill free slots from the queue: single-request prefill, scatter
        the fresh state into the slot, sample the first token."""
        for slot in range(self.max_batch):
            if not self._queue:
                return
            if self._slot_req[slot] is not None:
                continue
            req = self._queue.popleft()
            aid = self.bank.slot(req.adapter) if self.bank is not None else 0
            last_idx = self._prefix + len(req.prompt) - 1
            first, self._state = self._slot_prefill(
                self.params, self._bank_tree, self._feed(req.prompt),
                self._state, jnp.asarray(slot, jnp.int32),
                jnp.asarray(aid, jnp.int32),
                jnp.asarray(last_idx, jnp.int32))
            first = int(first)
            req.t_first = time.perf_counter()
            self.stats["prefills"] += 1
            log = self.stats["admission_log"]
            log.append((req.rid, self.stats["decode_steps"]))
            if len(log) > 4096:          # diagnostics ring, not a ledger
                del log[:-2048]
            self._slot_req[slot] = req
            self._outs[slot] = [first]
            self._pos[slot] = self._prefix + len(req.prompt)
            self._last[slot] = first
            self._adapter_ids[slot] = aid
            if first == self.eos_id or req.max_new_tokens <= 1:
                self._finish(slot)

    def _decode_tick(self) -> None:
        """One jitted decode step over the full slot array."""
        tokens = jnp.asarray(self._last[:, None])
        pos = jnp.asarray(self._pos)
        if self._banked:
            nt, _, self._state = self._decode(
                self.params, self._bank_tree, tokens, self._state, pos,
                jnp.asarray(self._adapter_ids))
        else:
            nt, _, self._state = self._decode(self.params, tokens,
                                              self._state, pos)
        self.stats["decode_steps"] += 1
        vals = np.asarray(nt[:, 0])
        for slot in range(self.max_batch):
            req = self._slot_req[slot]
            if req is None:
                continue
            tok = int(vals[slot])
            self._outs[slot].append(tok)
            self._pos[slot] += 1
            self._last[slot] = tok
            if tok == self.eos_id or len(self._outs[slot]) >= req.max_new_tokens:
                self._finish(slot)

    def step(self) -> bool:
        """One scheduler tick: admit into free slots, then one decode step
        over all active slots. Returns True if any work remains queued or
        in flight (the streaming driver loop condition)."""
        self._admit()
        if self.num_active:
            self._decode_tick()
        return not self.idle

    def drain_finished(self) -> List[Request]:
        """Hand over (and forget) everything completed so far — the
        bounded-memory accessor for long-running streaming loops (also
        releases the corresponding pending run() results)."""
        out, self.finished = self.finished, []
        for r in out:
            self._results.pop(r.rid, None)
        return out

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue to completion; returns {rid: tokens}."""
        t0 = time.perf_counter()
        while self.step():
            pass
        self.stats["wall_s"] += time.perf_counter() - t0
        res, self._results = self._results, {}
        return res


class StaticServeEngine:
    """Static-batch reference: drain queue -> pad -> prefill -> lockstep
    decode. Adapters (one per deployment) are merged into the weights
    offline — the paper's zero-overhead serving mode."""

    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0, mesh=None,
                 adapters=None, peft_cfg: Optional[peft_lib.PEFTConfig] = None):
        self.cfg = cfg
        if adapters and peft_cfg is not None:
            params = peft_lib.merge_tree(peft_cfg, params, adapters)  # offline
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.mesh = mesh
        self._queue: List[Request] = []
        self._next_id = 0
        self.finished: List[Request] = []    # completed Requests (latency)
        self._prefill = jax.jit(build_prefill_step(cfg, mesh, ragged=True))
        self._decode = jax.jit(build_decode_step(cfg, mesh),
                               donate_argnums=(2,))
        self.stats = _new_stats()

    def add_request(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        _check_capacity(self.cfg, prompt, max_new_tokens, self.max_len)
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, list(prompt), max_new_tokens,
                                   t_submit=time.perf_counter()))
        return rid

    def drain_finished(self) -> List[Request]:
        """Hand over (and forget) the completed-Request history."""
        out, self.finished = self.finished, []
        return out

    # -- internals ------------------------------------------------------------
    def _run_batch(self, batch: List[Request]) -> None:
        b = len(batch)
        prefix = _stream_prefix(self.cfg)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt          # right-padded
        state = api.init_decode_state(self.cfg, b, self.max_len,
                                      enc_len=max(plen // 4, 8))
        feed: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            feed["frames"] = jnp.zeros((b, max(plen // 4, 8),
                                        self.cfg.d_model), self.cfg.act_dtype)
        if self.cfg.family == "vlm":
            feed["patches"] = jnp.zeros(
                (b, self.cfg.frontend_tokens, self.cfg.frontend_dim),
                self.cfg.act_dtype)
        # ragged fix: each row samples at its OWN last prompt position and
        # decodes from its own position counter — padded rows no longer read
        # (or attend over) the pad tail
        last_idx = np.asarray([prefix + len(r.prompt) - 1 for r in batch],
                              np.int32)
        logits, state = self._prefill(self.params, feed, state,
                                      jnp.asarray(last_idx))
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        self.stats["prefills"] += 1
        for r in batch:
            r.t_first = time.perf_counter()

        max_new = max(r.max_new_tokens for r in batch)
        outs = [[int(last[i, 0])] for i in range(b)]
        done = np.asarray([outs[i][0] == self.eos_id or
                           r.max_new_tokens <= 1
                           for i, r in enumerate(batch)])
        pos0 = np.asarray([prefix + len(r.prompt) for r in batch], np.int32)
        for t in range(max_new - 1):
            if done.all():
                break
            nt, logits, state = self._decode(self.params, last, state,
                                             jnp.asarray(pos0 + t))
            self.stats["decode_steps"] += 1
            last = nt
            vals = np.asarray(nt[:, 0])
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(vals[i]))
                    done[i] |= vals[i] == self.eos_id or \
                        len(outs[i]) >= batch[i].max_new_tokens
            if done.all():
                break
        for i, r in enumerate(batch):
            r.output = outs[i][:r.max_new_tokens]
            r.t_done = time.perf_counter()
            self.stats["tokens_generated"] += len(r.output)

    def run(self) -> Dict[int, List[int]]:
        t0 = time.perf_counter()
        results: Dict[int, List[int]] = {}
        while self._queue:
            batch = self._queue[:self.max_batch]
            self._queue = self._queue[self.max_batch:]
            self._run_batch(batch)
            for r in batch:
                results[r.rid] = r.output
                self.finished.append(r)
                self.stats["requests"] += 1
        self.stats["wall_s"] += time.perf_counter() - t0
        return results
