"""Batched serving engine: merged GSOFT weights, prefill + decode loop.

Flow: merge adapters into the base weights offline (paper §6.1 — zero
inference overhead), group queued requests into same-capacity batches,
prefill with per-row validity masks (ragged prompts supported through the
online-attention kv_len argument), then decode greedily with per-row EOS
tracking.  Sharding-ready: pass a mesh to shard params/caches like the
dry-run does.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.models import api
from repro.train.steps import build_decode_step, build_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    output: Optional[List[int]] = None


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0, mesh=None,
                 adapters=None, peft_cfg: Optional[peft_lib.PEFTConfig] = None):
        self.cfg = cfg
        if adapters and peft_cfg is not None:
            params = peft_lib.merge_tree(peft_cfg, params, adapters)  # offline
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.mesh = mesh
        self._queue: List[Request] = []
        self._next_id = 0
        self._prefill = jax.jit(build_prefill_step(cfg, mesh))
        self._decode = jax.jit(build_decode_step(cfg, mesh),
                               donate_argnums=(2,))
        self.stats = {"requests": 0, "tokens_generated": 0,
                      "decode_steps": 0, "wall_s": 0.0}

    def add_request(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        rid = self._next_id
        self._next_id += 1
        self._queue.append(Request(rid, list(prompt), max_new_tokens))
        return rid

    # -- internals ------------------------------------------------------------
    def _run_batch(self, batch: List[Request]) -> None:
        b = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt          # right-padded
        state = api.init_decode_state(self.cfg, b, self.max_len,
                                      enc_len=max(plen // 4, 8))
        feed: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
        if self.cfg.family == "encdec":
            feed["frames"] = jnp.zeros((b, max(plen // 4, 8),
                                        self.cfg.d_model), self.cfg.act_dtype)
        if self.cfg.family == "vlm":
            feed["patches"] = jnp.zeros(
                (b, self.cfg.frontend_tokens, self.cfg.frontend_dim),
                self.cfg.act_dtype)
        logits, state = self._prefill(self.params, feed, state)
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]

        max_new = max(r.max_new_tokens for r in batch)
        outs = [[int(last[i, 0])] for i in range(b)]
        done = np.zeros(b, bool)
        pos = plen + (self.cfg.frontend_tokens
                      if self.cfg.family == "vlm" else 0)
        for t in range(max_new - 1):
            nt, logits, state = self._decode(self.params, last, state,
                                             jnp.asarray(pos + t, jnp.int32))
            self.stats["decode_steps"] += 1
            last = nt
            vals = np.asarray(nt[:, 0])
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(vals[i]))
                    done[i] |= vals[i] == self.eos_id or \
                        len(outs[i]) >= batch[i].max_new_tokens
            if done.all():
                break
        for i, r in enumerate(batch):
            r.output = outs[i][:r.max_new_tokens]
            self.stats["tokens_generated"] += len(r.output)

    def run(self) -> Dict[int, List[int]]:
        t0 = time.perf_counter()
        results: Dict[int, List[int]] = {}
        while self._queue:
            batch = self._queue[:self.max_batch]
            self._queue = self._queue[self.max_batch:]
            self._run_batch(batch)
            for r in batch:
                results[r.rid] = r.output
                self.stats["requests"] += 1
        self.stats["wall_s"] += time.perf_counter() - t0
        return results
