"""Serving engines: continuous batching with slot-based KV cache + the
static-batch reference engine. Both are drivers over ONE ``ModelRuntime``
(`repro.core.runtime`), which owns the jitted prefill/decode closures and
the optional per-request adapter bank.

``ServeEngine`` (the default) is a scheduler over ``max_batch`` persistent
decode slots:

  * requests are admitted into free slots as others finish (EOS or token
    budget) — no lockstep ``max(max_new_tokens)`` barrier;
  * each slot carries its own position counter; decode runs ONE jitted step
    over the full slot array with per-slot write positions and per-slot
    ``kv_len`` masks (the online-attention kv_len argument);
  * admission prefills a single request (batch 1, prompt padded to a
    power-of-two bucket to bound recompiles) and scatters the fresh state
    row into the slot (``train.steps.build_slot_prefill_step``);
  * when the runtime carries an ``AdapterBank``, each slot's id flows
    through an ``AdapterContext`` pytree: row i rotates its activations
    with its own orthogonal adapter x Q_i before every adapted matmul —
    O(b*d) per token, versus O(d^2) to re-merge a dense rotation per
    request. The bank is method-generic (any bankable ``core.methods``
    entry: GSOFT, OFT, BOFT, Householder) and may be HETEROGENEOUS —
    each named adapter declares its own method, so one deployment serves
    gsoft and boft and householder tenants side by side. Slot 0 of the
    bank is the universal identity (serves the base model).

``StaticServeEngine`` is the drain-queue -> pad -> prefill -> lockstep
decode reference (the paper's merged-weight serving story, §6.1): one
adapter merged into the weights offline (``ModelRuntime(adapters=...,
peft_cfg=...)``), zero per-token overhead. Use it when every request shares
one fine-tune; use ``ServeEngine`` over a banked runtime when requests
carry different adapters.

Both engines sample each row's first token at its OWN last valid prompt
index (ragged prompts — shorter rows no longer read a padded position) and
decode with per-row positions. Sharding-ready: build the runtime with a
mesh to shard params/caches like the dry-run does.
"""
from __future__ import annotations

import collections
import contextlib
import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from repro.config import ModelConfig
from repro.core.peft import PrefillRequest
from repro.core.runtime import ModelRuntime
from repro.models import registry
from repro.obs.metrics import REGISTRY
from .kv import KVPagePool, SlotPages, pages_for_budget


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    adapter: Optional[str] = None        # bank adapter name (None = base)
    output: Optional[List[int]] = None
    # timing (perf_counter seconds; filled by the engines)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit


class EngineMetrics:
    """An engine's stats surface, backed by the process metrics plane.

    Writes go through the typed methods below (only engines call those);
    reads keep the ``eng.stats["requests"]`` dict-style surface every
    test, bench and driver already uses — same keys as the pre-obs dict,
    one source of truth in ``repro.obs.REGISTRY``. ``admission_log``
    stays a live bounded list: it is a diagnostics ring of
    ``(rid, decode_step)`` tuples, not a scalar instrument.
    """

    COUNTER_KEYS = ("requests", "tokens_generated", "decode_steps",
                    "prefills", "admission_stalls")

    def __init__(self, kind: str = "serve"):
        scope = REGISTRY.scope(kind)
        self._c = scope.counters(*self.COUNTER_KEYS)
        self._wall = scope.counter("wall_s")
        self.admission_log: List[Any] = []

    # -- writes (engine-internal) ---------------------------------------------
    def inc(self, key: str, n: int = 1) -> None:
        self._c[key].inc(n)

    def add_wall(self, dt: float) -> None:
        self._wall.inc(dt)

    def log_admission(self, rid: int) -> None:
        log = self.admission_log
        log.append((rid, self._c["decode_steps"].value))
        if len(log) > 4096:          # diagnostics ring, not a ledger
            del log[:-2048]

    # -- dict-style reads ------------------------------------------------------
    def __getitem__(self, key: str) -> Any:
        if key == "admission_log":
            return self.admission_log
        if key == "wall_s":
            return self._wall.value
        return self._c[key].value

    def __contains__(self, key: str) -> bool:
        return (key in self.COUNTER_KEYS
                or key in ("wall_s", "admission_log"))

    def as_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {k: c.value for k, c in self._c.items()}
        out["wall_s"] = self._wall.value
        out["admission_log"] = list(self.admission_log)
        return out


def _stream_prefix(cfg: ModelConfig) -> int:
    """Non-text positions prepended to the decode stream (vlm patches)."""
    return cfg.frontend_tokens if registry.get(cfg.family).has_patches else 0


def _check_token_family(cfg: ModelConfig) -> None:
    """Token engines need a prefill/decode surface; stateless families
    (``FamilyOps.stateless`` — whole-input forward, no KV) are served by
    ``serve.image.ImageServeEngine`` instead."""
    if registry.get(cfg.family).stateless:
        raise ValueError(
            f"family {cfg.family!r} is stateless (no prefill/decode "
            "surface) — serve it through serve.image.ImageServeEngine")


def _check_capacity(cfg: ModelConfig, prompt: List[int], max_new: int,
                    max_len: int) -> None:
    plen = len(prompt) + _stream_prefix(cfg)
    if plen + max_new > max_len:
        raise ValueError(f"prompt ({plen}) + max_new ({max_new}) "
                         f"exceeds max_len={max_len}")


def _family_feed(cfg: ModelConfig, toks: np.ndarray,
                 enc_len: int) -> Dict[str, Any]:
    """Prefill feed for a (B, S) token block, plus the per-family extra
    streams (encdec frames / vlm patches) — shared by both engines."""
    feed: Dict[str, Any] = {"tokens": jnp.asarray(toks)}
    b = toks.shape[0]
    t = registry.get(cfg.family)
    if t.has_encoder:
        feed["frames"] = jnp.zeros((b, enc_len, cfg.d_model), cfg.act_dtype)
    if t.has_patches:
        feed["patches"] = jnp.zeros(
            (b, cfg.frontend_tokens, cfg.frontend_dim), cfg.act_dtype)
    return feed


def latency_percentiles(requests: List[Request],
                        qs=(50, 95)) -> Dict[int, float]:
    """{q: seconds} request-latency percentiles over finished Requests."""
    lats = [r.latency_s for r in requests]
    if not lats:
        return {q: 0.0 for q in qs}
    return {q: float(np.percentile(lats, q)) for q in qs}


class ServeEngine:
    """Continuous-batching engine over ``max_batch`` persistent slots,
    driving one ``ModelRuntime``.

    ``tracer``: an optional ``repro.obs.TraceRecorder``; when attached the
    tick loop records each request's lifecycle spans (submit / stalls /
    prefill / tokens / finish). ``tracer=None`` (the default) skips every
    hook — tracing costs nothing when off and <5% when on (serve_bench
    asserts the bound).
    """

    _kind = "serve"          # metrics-scope prefix + tracer tag family

    def __init__(self, runtime: ModelRuntime, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0, tracer=None):
        _check_token_family(runtime.cfg)
        self.rt = runtime
        self.cfg = runtime.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.tracer = tracer
        self._ttag = (tracer.register_engine(self._kind)
                      if tracer is not None else "")
        self._annot = (tracer.annotate if tracer is not None
                       else lambda name: contextlib.nullcontext())
        self._enc_len = max(max_len // 4, 8)
        self._prefix = _stream_prefix(self.cfg)

        self._setup_compute()

        # per-slot bookkeeping (host side)
        self._pos = np.zeros(max_batch, np.int32)
        self._last = np.zeros(max_batch, np.int32)
        self._slot_ids = np.zeros(max_batch, np.int32)
        self._slot_req: List[Optional[Request]] = [None] * max_batch
        self._outs: List[List[int]] = [[] for _ in range(max_batch)]

        self._queue: "collections.deque[Request]" = collections.deque()
        self._next_id = 0
        self._results: Dict[int, List[int]] = {}
        # completed Requests (latency accounting). Grows until drained —
        # long-running streaming drivers should call drain_finished()
        # periodically instead of letting history accumulate.
        self.finished: List[Request] = []
        self.stats = EngineMetrics(self._kind)
        # decode-loop AdapterContext cache (satellite: the store-paged lane
        # used to rebuild the context — host LUT indexing + H2D per method —
        # on EVERY decode step; see _context())
        self._ctx_key: Any = None
        self._ctx_val = None

    def _setup_compute(self) -> None:
        """Jitted closures + device state (overridden by the paged engine)."""
        self._slot_prefill = self.rt.slot_prefill_fn(self.max_len,
                                                     self._enc_len)
        self._decode = self.rt.decode_fn()
        self._state = self.rt.decode_state(self.max_batch, self.max_len,
                                           enc_len=self._enc_len)

    # -- submission -----------------------------------------------------------
    def add_request(self, prompt: List[int], max_new_tokens: int = 16,
                    adapter: Optional[str] = None) -> int:
        # validate eagerly: raises on a name neither resident nor in the
        # host store, or on naming an adapter when the runtime has no bank
        self.rt.validate_adapter(adapter)
        _check_capacity(self.cfg, prompt, max_new_tokens, self.max_len)
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, list(prompt), max_new_tokens, adapter=adapter,
                      t_submit=time.perf_counter())
        self._queue.append(req)
        if self.tracer is not None:
            self.tracer.submit(self._ttag, rid, adapter=adapter,
                               prompt_len=len(prompt),
                               t_submit=req.t_submit)
        return rid

    @property
    def num_active(self) -> int:
        return sum(r is not None for r in self._slot_req)

    @property
    def queue_depth(self) -> int:
        """Requests admitted but not yet slotted (router load signal)."""
        return len(self._queue)

    @property
    def load(self) -> int:
        """Queued + in-flight work — the cluster router's balance metric."""
        return self.queue_depth + self.num_active

    @property
    def idle(self) -> bool:
        return not self._queue and self.num_active == 0

    def add_wall(self, dt: float) -> None:
        """Account driver wall time (drivers call this instead of poking
        ``stats`` so the cluster can aggregate it the same way)."""
        self.stats.add_wall(dt)

    # -- cluster hooks (distrib.cluster) --------------------------------------
    def steal_queued(self) -> Optional[Request]:
        """Pop the YOUNGEST queued (never-admitted) request so the cluster
        can rebalance it onto a less-loaded replica; None when empty.
        Stealing from the tail keeps FIFO order for what stays."""
        if not self._queue:
            return None
        req = self._queue.pop()
        if self.tracer is not None:        # re-submits on the new engine
            self.tracer.drop(self._ttag, req.rid)
        return req

    def submit(self, req: Request) -> int:
        """Enqueue an existing Request under a FRESH local rid (rebalanced
        arrivals keep their submit timestamp/adapter; rids are per-engine,
        so a moved request must be re-keyed by the caller)."""
        self.rt.validate_adapter(req.adapter)
        _check_capacity(self.cfg, req.prompt, req.max_new_tokens,
                        self.max_len)
        req.rid = self._next_id
        self._next_id += 1
        self._queue.append(req)
        if self.tracer is not None:        # keeps the ORIGINAL submit time
            self.tracer.submit(self._ttag, req.rid, adapter=req.adapter,
                               prompt_len=len(req.prompt),
                               t_submit=req.t_submit)
        return req.rid

    # -- internals ------------------------------------------------------------
    def _bucket(self, plen: int) -> int:
        """Power-of-two prompt pad length (bounds prefill recompiles);
        clamped so prefix + bucket always fits the slot cache."""
        b = 8
        while b < plen:
            b *= 2
        return min(b, self.max_len - self._prefix)

    def _feed(self, prompt: List[int]) -> Dict[str, Any]:
        bucket = self._bucket(len(prompt))
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :len(prompt)] = prompt
        return _family_feed(self.cfg, toks, self._enc_len)

    def _finish(self, slot: int) -> None:
        req = self._slot_req[slot]
        req.output = self._outs[slot][:req.max_new_tokens]
        req.t_done = time.perf_counter()
        self._results[req.rid] = req.output
        self.finished.append(req)
        self.stats.inc("requests")
        self.stats.inc("tokens_generated", len(req.output))
        if self.tracer is not None:
            self.tracer.finish(self._ttag, req.rid)
        self._slot_req[slot] = None
        self._slot_ids[slot] = 0            # identity until re-admitted
        self.rt.release_adapter(req.adapter)   # unpin (store-backed banks)

    def _admit(self) -> None:
        """Fill free slots from the queue: single-request prefill, scatter
        the fresh state into the slot, sample the first token. On a
        store-backed runtime admission may page the adapter into HBM;
        when every page of its method is pinned by in-flight requests the
        acquire STALLS (FIFO head-of-line) — we stop admitting and keep
        decoding resident slots, which is what eventually unpins pages."""
        for slot in range(self.max_batch):
            if not self._queue:
                return
            if self._slot_req[slot] is not None:
                continue
            req = self._queue[0]
            aid = self.rt.acquire_adapter(req.adapter)
            if aid is None:                  # admission stall, not an error
                self.stats.inc("admission_stalls")
                if self.tracer is not None:
                    self.tracer.stall(self._ttag, req.rid, "adapter")
                return
            self._queue.popleft()
            last_idx = self._prefix + len(req.prompt) - 1
            feed = PrefillRequest(batch=self._feed(req.prompt),
                                  last_idx=jnp.asarray(last_idx, jnp.int32),
                                  ctx=self.rt.context([aid]))
            if self.tracer is not None:
                self.tracer.prefill_start(self._ttag, req.rid)
            with self._annot("prefill"):
                first, self._state = self._slot_prefill(
                    self.rt.params, feed, self._state,
                    jnp.asarray(slot, jnp.int32))
            first = int(first)
            req.t_first = time.perf_counter()
            if self.tracer is not None:
                self.tracer.prefill_end(self._ttag, req.rid)
                self.tracer.first_token(self._ttag, req.rid)
            self.stats.inc("prefills")
            self.stats.log_admission(req.rid)
            self._slot_req[slot] = req
            self._outs[slot] = [first]
            self._pos[slot] = self._prefix + len(req.prompt)
            self._last[slot] = first
            self._slot_ids[slot] = aid
            if first == self.eos_id or req.max_new_tokens <= 1:
                self._finish(slot)
        # every slot is occupied and work is still queued: head-of-line
        # wait on a decode slot, not on a resource
        if self._queue and self.tracer is not None:
            self.tracer.stall(self._ttag, self._queue[0].rid, "queue")

    def _context(self):
        """AdapterContext for the current slot ids, cached across decode
        steps. Rebuilding it is host work (numpy LUT indexing + one H2D per
        method) that used to run EVERY step — the store-paged serve
        regression. The cache key is (slot ids, bank version): page-in /
        eviction bumps ``bank.version`` so a stale gather can never serve."""
        key = (tuple(int(i) for i in self._slot_ids),
               getattr(self.rt.bank, "version", 0))
        if key != self._ctx_key:
            self._ctx_val = self.rt.context(self._slot_ids)
            self._ctx_key = key
        return self._ctx_val

    def _row_active(self, slot: int) -> bool:
        """Is this slot decoding? (The paged engine parks slots that are
        still mid-chunked-prefill.)"""
        return self._slot_req[slot] is not None

    def _decode_launch(self):
        """Dispatch one jitted decode step over the full slot array and
        return the PENDING next-token array without syncing it. JAX
        dispatch is async: the device crunches while the host moves on —
        which is exactly what lets an ``EngineCluster`` launch every
        replica's tick before blocking on any of them."""
        tokens = jnp.asarray(self._last[:, None])
        pos = jnp.asarray(self._pos)
        ctx = self._context()
        with self._annot("decode"):
            nt, _, self._state = self._decode(self.rt.params, ctx, tokens,
                                              self._state, pos)
        self.stats.inc("decode_steps")
        return nt

    def _decode_commit(self, nt) -> None:
        """Sync the launched step's tokens and advance slot bookkeeping."""
        vals = np.asarray(nt[:, 0])
        for slot in range(self.max_batch):
            if not self._row_active(slot):
                continue
            req = self._slot_req[slot]
            tok = int(vals[slot])
            self._outs[slot].append(tok)
            self._pos[slot] += 1
            self._last[slot] = tok
            if self.tracer is not None:
                self.tracer.token(self._ttag, req.rid)
            if tok == self.eos_id or len(self._outs[slot]) >= req.max_new_tokens:
                self._finish(slot)

    def _decode_tick(self) -> None:
        """One jitted decode step over the full slot array."""
        self._decode_commit(self._decode_launch())

    def step_launch(self):
        """First half of ``step``: admit into free slots, dispatch the
        decode step, return the pending token array (None when no slot is
        decoding). Pass the result to ``step_commit`` — splitting the tick
        lets a multi-replica driver overlap every replica's device work."""
        self._admit()
        if self.num_active:
            return self._decode_launch()
        return None

    def step_commit(self, pending) -> bool:
        """Second half of ``step``: sync + bookkeep a launched tick.
        Returns True if any work remains queued or in flight."""
        if pending is not None:
            self._decode_commit(pending)
        return not self.idle

    def step(self) -> bool:
        """One scheduler tick: admit into free slots, then one decode step
        over all active slots. Returns True if any work remains queued or
        in flight (the streaming driver loop condition)."""
        return self.step_commit(self.step_launch())

    def drain_finished(self) -> List[Request]:
        """Hand over (and forget) everything completed so far — the
        bounded-memory accessor for long-running streaming loops (also
        releases the corresponding pending run() results)."""
        out, self.finished = self.finished, []
        for r in out:
            self._results.pop(r.rid, None)
        return out

    def adapter_stats(self) -> Optional[Dict[str, Any]]:
        """Residency counters of a store-backed bank — hit rate, page-in
        latency, evictions, resident/padded bytes (None on eager banks)."""
        stats = getattr(self.rt.bank, "stats", None)
        return stats() if callable(stats) else None

    def run(self) -> Dict[int, List[int]]:
        """Drain the queue to completion; returns {rid: tokens}."""
        t0 = time.perf_counter()
        while self.step():
            pass
        self.stats.add_wall(time.perf_counter() - t0)
        res, self._results = self._results, {}
        return res


class StaticServeEngine:
    """Static-batch reference: drain queue -> pad -> prefill -> lockstep
    decode. Adapters (one per deployment) are merged into the runtime's
    weights offline — the paper's zero-overhead serving mode."""

    _kind = "static"

    def __init__(self, runtime: ModelRuntime, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0, tracer=None):
        _check_token_family(runtime.cfg)
        if runtime.banked:
            raise ValueError(
                "static serving merges ONE adapter offline "
                "(ModelRuntime(adapters=..., peft_cfg=...)); per-request "
                "banks need the continuous ServeEngine")
        self.rt = runtime
        self.cfg = runtime.cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.tracer = tracer
        self._ttag = (tracer.register_engine(self._kind)
                      if tracer is not None else "")
        self._annot = (tracer.annotate if tracer is not None
                       else lambda name: contextlib.nullcontext())
        self._queue: List[Request] = []
        self._next_id = 0
        self.finished: List[Request] = []    # completed Requests (latency)
        self._prefill = runtime.prefill_fn()
        self._decode = runtime.decode_fn()
        self.stats = EngineMetrics(self._kind)

    def add_request(self, prompt: List[int], max_new_tokens: int = 16) -> int:
        _check_capacity(self.cfg, prompt, max_new_tokens, self.max_len)
        rid = self._next_id
        self._next_id += 1
        req = Request(rid, list(prompt), max_new_tokens,
                      t_submit=time.perf_counter())
        self._queue.append(req)
        if self.tracer is not None:
            self.tracer.submit(self._ttag, rid, prompt_len=len(prompt),
                               t_submit=req.t_submit)
        return rid

    def drain_finished(self) -> List[Request]:
        """Hand over (and forget) the completed-Request history."""
        out, self.finished = self.finished, []
        return out

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    def add_wall(self, dt: float) -> None:
        self.stats.add_wall(dt)

    # -- internals ------------------------------------------------------------
    def _run_batch(self, batch: List[Request]) -> None:
        b = len(batch)
        prefix = _stream_prefix(self.cfg)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((b, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, :len(r.prompt)] = r.prompt          # right-padded
        enc_len = max(plen // 4, 8)
        state = self.rt.decode_state(b, self.max_len, enc_len=enc_len)
        feed = _family_feed(self.cfg, toks, enc_len)
        # ragged fix: each row samples at its OWN last prompt position and
        # decodes from its own position counter — padded rows no longer read
        # (or attend over) the pad tail
        last_idx = np.asarray([prefix + len(r.prompt) - 1 for r in batch],
                              np.int32)
        if self.tracer is not None:
            for r in batch:
                self.tracer.prefill_start(self._ttag, r.rid)
        req = PrefillRequest(batch=feed, last_idx=jnp.asarray(last_idx))
        with self._annot("prefill"):
            logits, state = self._prefill(self.rt.params, req, state)
        last = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        self.stats.inc("prefills")
        for r in batch:
            r.t_first = time.perf_counter()
            if self.tracer is not None:
                self.tracer.prefill_end(self._ttag, r.rid)
                self.tracer.first_token(self._ttag, r.rid)

        max_new = max(r.max_new_tokens for r in batch)
        outs = [[int(last[i, 0])] for i in range(b)]
        done = np.asarray([outs[i][0] == self.eos_id or
                           r.max_new_tokens <= 1
                           for i, r in enumerate(batch)])
        pos0 = np.asarray([prefix + len(r.prompt) for r in batch], np.int32)
        for t in range(max_new - 1):
            if done.all():
                break
            with self._annot("decode"):
                nt, logits, state = self._decode(self.rt.params, None, last,
                                                 state, jnp.asarray(pos0 + t))
            self.stats.inc("decode_steps")
            last = nt
            vals = np.asarray(nt[:, 0])
            for i in range(b):
                if not done[i]:
                    outs[i].append(int(vals[i]))
                    if self.tracer is not None:
                        self.tracer.token(self._ttag, batch[i].rid)
                    done[i] |= vals[i] == self.eos_id or \
                        len(outs[i]) >= batch[i].max_new_tokens
            if done.all():
                break
        for i, r in enumerate(batch):
            r.output = outs[i][:r.max_new_tokens]
            r.t_done = time.perf_counter()
            self.stats.inc("tokens_generated", len(r.output))
            if self.tracer is not None:
                self.tracer.finish(self._ttag, r.rid)

    def run(self) -> Dict[int, List[int]]:
        t0 = time.perf_counter()
        results: Dict[int, List[int]] = {}
        while self._queue:
            batch = self._queue[:self.max_batch]
            self._queue = self._queue[self.max_batch:]
            self._run_batch(batch)
            for r in batch:
                results[r.rid] = r.output
                self.finished.append(r)
                self.stats.inc("requests")
        self.stats.add_wall(time.perf_counter() - t0)
        return results


@dataclasses.dataclass
class _PrefillPlan:
    """One admitted request's remaining chunked-prefill work."""
    slot: int
    req: Request
    sp: SlotPages
    next_start: int          # absolute position of the next chunk's 1st token


class PagedServeEngine(ServeEngine):
    """Continuous batching over a PAGED KV cache with chunked prefill.

    Three changes against the contiguous parent (ISSUE 7):

      * HBM: slots own fixed-size pages from one static pool (sized by
        ``hbm_kv_budget`` or ``num_pages``) through per-slot int32 page
        tables — a short request pays ceil(len / page_size) pages, not
        ``max_len`` rows; when the pool is exhausted admission STALLS
        (``kv_stalls`` counter) instead of over-subscribing.
      * Admission: prompts prefill in ``prefill_chunk``-token chunks, ONE
        chunk per scheduler tick, interleaved with decode — a long prompt
        delays decoding slots by one chunk per tick instead of
        head-of-line-blocking them for its whole prefill.
      * Shared prefixes: full prompt pages are content-hashed (seeded by
        the adapter name) and refcount-shared across requests — N tenants
        with one system prompt pin ONE set of pages, and their prefill
        skips the cached tokens entirely (``prefix_hits``). Divergent
        suffixes are private by construction (see serve/kv.py).

    Greedy tokens are identical to ``ServeEngine`` (tests pin this); only
    residency and scheduling change. Decoder-family runtimes only.
    """

    _kind = "paged"

    def __init__(self, runtime: ModelRuntime, *, max_batch: int = 8,
                 max_len: int = 256, eos_id: int = 0, page_size: int = 8,
                 prefill_chunk: int = 16, num_pages: Optional[int] = None,
                 hbm_kv_budget: Optional[int] = None, tracer=None):
        if runtime._ops.init_paged_state is None:
            raise ValueError(
                f"family {runtime.cfg.family!r} has no paged KV serve path "
                "— use the contiguous ServeEngine")
        if page_size < 1 or prefill_chunk < 1:
            raise ValueError("page_size and prefill_chunk must be >= 1")
        self.page_size = page_size
        self.prefill_chunk = prefill_chunk
        self.max_pages = -(-max_len // page_size)
        self._parked = self.max_pages * page_size   # sentinel write position
        if num_pages is None:
            if hbm_kv_budget is not None:
                num_pages = pages_for_budget(runtime.cfg, page_size,
                                             hbm_kv_budget)
            else:                       # stall-free default: worst case + 1
                num_pages = max_batch * self.max_pages + 1
        self.num_pages = num_pages
        super().__init__(runtime, max_batch=max_batch, max_len=max_len,
                         eos_id=eos_id, tracer=tracer)
        self._pos[:] = self._parked
        self._decoding = np.zeros(max_batch, bool)
        self._slot_pages: List[Optional[SlotPages]] = [None] * max_batch
        self._prefill_q: "collections.deque[_PrefillPlan]" = \
            collections.deque()
        self._zero_row = jnp.zeros(self.max_pages + 1, jnp.int32)

    def _setup_compute(self) -> None:
        self._decode = self.rt.paged_decode_fn()
        self._chunk_prefill = self.rt.chunk_prefill_fn()
        self.pool = KVPagePool(self.num_pages, self.page_size)
        self._state = self.rt.paged_state(self.max_batch, self.num_pages,
                                          self.page_size, self.max_pages)

    # -- scheduling -----------------------------------------------------------
    def _row_active(self, slot: int) -> bool:
        return bool(self._decoding[slot])

    def _admit(self) -> None:
        """Claim a slot + adapter + KV pages per queued request; the prompt
        itself is fed later, one chunk per tick (``_feed_one_chunk``).
        Either resource exhausted -> stall (stop admitting, keep decoding:
        finishing requests is what frees pages and unpins adapters)."""
        for slot in range(self.max_batch):
            if not self._queue:
                return
            if self._slot_req[slot] is not None:
                continue
            req = self._queue[0]
            aid = self.rt.acquire_adapter(req.adapter)
            if aid is None:
                self.stats.inc("admission_stalls")
                if self.tracer is not None:
                    self.tracer.stall(self._ttag, req.rid, "adapter")
                return
            sp = self.pool.admit(req.adapter, req.prompt, req.max_new_tokens)
            if sp is None:                        # KV stall, not an error
                self.rt.release_adapter(req.adapter)
                self.stats.inc("admission_stalls")
                if self.tracer is not None:
                    self.tracer.stall(self._ttag, req.rid, "kv")
                return
            self._queue.popleft()
            row = self.pool.table_row(sp, self.max_pages + 1)
            self._state["table"] = \
                self._state["table"].at[slot].set(jnp.asarray(row))
            self._slot_req[slot] = req
            self._slot_ids[slot] = aid
            self._slot_pages[slot] = sp
            self._outs[slot] = []
            self._decoding[slot] = False
            self._pos[slot] = self._parked        # writes park in garbage
            self._prefill_q.append(_PrefillPlan(slot, req, sp,
                                                next_start=sp.n_cached))
        if self._queue and self.tracer is not None:     # all slots occupied
            self.tracer.stall(self._ttag, self._queue[0].rid, "queue")

    def _feed_one_chunk(self) -> None:
        """Advance the HEAD prefill plan by one fixed-width chunk. The last
        chunk yields the request's first token and flips the slot to
        decoding; cached-prefix tokens were never fed at all."""
        if not self._prefill_q:
            return
        plan = self._prefill_q[0]
        req, slot = plan.req, plan.slot
        plen = len(req.prompt)
        start = plan.next_start
        end = min(start + self.prefill_chunk, plen)
        toks = np.zeros((1, self.prefill_chunk), np.int32)
        toks[0, :end - start] = req.prompt[start:end]
        final = end == plen
        last_local = (plen - 1) - start if final else end - start - 1
        feed = PrefillRequest(
            batch={"tokens": jnp.asarray(toks)},
            last_idx=jnp.asarray(last_local, jnp.int32),
            ctx=self.rt.context([self._slot_ids[slot]]))
        if self.tracer is not None:                # span per prompt chunk
            self.tracer.prefill_start(self._ttag, req.rid)
        with self._annot("prefill_chunk"):
            first, self._state = self._chunk_prefill(
                self.rt.params, feed, self._state,
                jnp.asarray(slot, jnp.int32), jnp.asarray(start, jnp.int32))
        if self.tracer is not None:
            self.tracer.prefill_end(self._ttag, req.rid)
        plan.next_start = end
        if not final:
            return
        self._prefill_q.popleft()
        self.pool.register(plan.sp)               # publish full prompt pages
        first = int(first)
        req.t_first = time.perf_counter()
        if self.tracer is not None:
            self.tracer.first_token(self._ttag, req.rid)
        self.stats.inc("prefills")
        self.stats.log_admission(req.rid)
        self._outs[slot] = [first]
        self._pos[slot] = plen
        self._last[slot] = first
        self._decoding[slot] = True
        if first == self.eos_id or req.max_new_tokens <= 1:
            self._finish(slot)

    def _finish(self, slot: int) -> None:
        sp = self._slot_pages[slot]
        super()._finish(slot)
        self._slot_pages[slot] = None
        self._decoding[slot] = False
        self._pos[slot] = self._parked
        self._last[slot] = 0
        self._state["table"] = \
            self._state["table"].at[slot].set(self._zero_row)
        self.pool.finish(sp)

    def step_launch(self):
        """One tick's dispatch half: admit, feed ONE prompt chunk, launch
        one decode step over the decoding slots. Decode latency is bounded
        by one chunk of prefill per tick — never a whole prompt. (``step``
        composes this with the inherited ``step_commit``.)"""
        self._admit()
        self._feed_one_chunk()
        if self._decoding.any():
            return self._decode_launch()
        return None

    def kv_stats(self) -> Dict[str, int]:
        """Page-pool residency counters (allocs, prefix hits, KV stalls,
        cache evictions, pages in use)."""
        return self.pool.stats()
