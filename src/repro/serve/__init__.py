from .engine import ServeEngine, Request
