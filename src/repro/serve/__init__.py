from .engine import Request, ServeEngine, StaticServeEngine
