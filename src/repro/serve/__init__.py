from .engine import PagedServeEngine, Request, ServeEngine, StaticServeEngine
from .image import ImageServeEngine
from .kv import KVPagePool, SlotPages, kv_page_bytes, pages_for_budget
