"""KV page pool: fixed-size pages + refcounted shared-prefix cache.

The paged engine's HBM story (ISSUE 7, the vLLM PagedAttention idea —
cf. S-LoRA's unified paging): instead of one contiguous ``max_len`` KV
region per slot, every slot owns a list of fixed-size PAGES drawn from one
static pool sized by ``--hbm-kv-budget``. Allocation/free is a host-side
free list touched only at admission and finish — never on the decode hot
loop — and the device side sees nothing but an int32 page table per slot.

Page 0 is the GARBAGE page: it is never allocated, and every unused table
entry points at it, so parked rows of the full decode batch write there
harmlessly (see ``models.attention.paged_attention_block``).

Shared-prefix cache: FULL prompt pages are content-hashed with a chained
hash seeded by the adapter name (K/V depend on the adapter's rotations, so
the same tokens under different adapters must NOT share pages). After a
prompt's prefill completes, its full pages are published hash -> page;
a later request claims the longest prefix of its own page hashes that is
already published (refcount++, prefill skips those tokens entirely).
Divergence is handled by CONSTRUCTION rather than copy-on-write at decode
time: only full, completed prompt pages are ever shared, a request claims
at most ``(plen - 1) // page_size`` pages (the suffix that produces the
first-token logits is always prefilled privately), and decode writes land
strictly after the prompt — so a shared page is read-only for its whole
lifetime and the "first divergent page" is always a private fresh page.
Pages whose refcount drops to zero park in an LRU cache and are evicted
(hash retired) only when the free list runs dry.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.config import ModelConfig
from repro.obs.metrics import REGISTRY

GARBAGE_PAGE = 0


def kv_page_bytes(cfg: ModelConfig, page_size: int) -> int:
    """HBM bytes one page costs across ALL layers (k + v)."""
    try:
        itemsize = np.dtype(cfg.act_dtype).itemsize
    except TypeError:            # bfloat16 & other non-numpy dtypes
        itemsize = 2
    return (2 * cfg.num_layers * page_size * cfg.num_kv_heads * cfg.d_head
            * itemsize)


def pages_for_budget(cfg: ModelConfig, page_size: int, budget: int) -> int:
    """Static pool size from an HBM byte budget (>= garbage + 1 real)."""
    return max(2, budget // kv_page_bytes(cfg, page_size))


@dataclasses.dataclass
class SlotPages:
    """One admitted request's page claim (host bookkeeping only)."""
    pages: List[int]                 # in sequence order, cached prefix first
    n_cached: int                    # tokens already materialized from cache
    hashes: List[str]                # chained hashes of the FULL prompt pages
    n_prompt_full: int               # how many leading pages are full-prompt
    registered: bool = False


class KVPagePool:
    """Host-side allocator for the shared KV page pool.

    ``num_pages`` INCLUDES the garbage page 0; capacity is num_pages - 1.
    All methods are O(pages touched) python — called at admission / finish
    only, never per decode step.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages < 2:
            raise ValueError("need >= 2 pages (garbage + 1 allocatable)")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self._refs = np.zeros(num_pages, np.int32)
        self._by_hash: Dict[str, int] = {}
        self._page_hash: Dict[int, str] = {}
        # refcount-0 pages with still-published content, LRU order
        self._reusable: "OrderedDict[int, None]" = OrderedDict()
        # instruments live in the process metrics plane; stats() is a view
        scope = REGISTRY.scope("kvpool")
        self._c = scope.counters("alloc", "freed", "prefix_queries",
                                 "prefix_hits", "cache_evictions",
                                 "kv_stalls")
        self._g_in_use = scope.gauge("in_use")
        self._g_free = scope.gauge("free")
        self._g_cached = scope.gauge("cached")

    # -- capacity -------------------------------------------------------------
    @property
    def available(self) -> int:
        """Pages obtainable right now (free + evictable cached)."""
        return len(self._free) + len(self._reusable)

    @property
    def in_use(self) -> int:
        return int((self._refs > 0).sum())

    def pages_needed(self, prompt_len: int, max_new: int) -> int:
        return -(-(prompt_len + max_new) // self.page_size)

    # -- shared-prefix hashing ------------------------------------------------
    def prefix_hashes(self, adapter: Optional[str],
                      tokens: Sequence[int]) -> List[str]:
        """Chained content hashes of the FULL pages of ``tokens``. Seeded by
        the adapter name — identical prompts under different adapters hash
        apart because their K/V differ under the adapter rotations."""
        ps = self.page_size
        h = hashlib.sha1(f"adapter:{adapter or ''}".encode()).hexdigest()
        out = []
        for i in range(len(tokens) // ps):
            blob = h + ":" + ",".join(str(t) for t in tokens[i*ps:(i+1)*ps])
            h = hashlib.sha1(blob.encode()).hexdigest()
            out.append(h)
        return out

    # -- admission / finish ---------------------------------------------------
    def admit(self, adapter: Optional[str], tokens: Sequence[int],
              max_new: int) -> Optional[SlotPages]:
        """Claim pages for a request: reuse the longest published prefix of
        its full prompt pages, allocate the rest fresh. Returns None when
        the pool cannot satisfy it right now (admission stall — keep
        decoding, retry after a finish)."""
        ps = self.page_size
        plen = len(tokens)
        total = self.pages_needed(plen, max_new)
        hashes = self.prefix_hashes(adapter, tokens)
        # never claim the page holding the prompt's last token: its logits
        # seed generation, so at least one suffix token is always prefilled
        n_claimable = min(len(hashes), (plen - 1) // ps) if plen else 0
        self._c["prefix_queries"].inc()
        claim: List[int] = []
        for h in hashes[:n_claimable]:
            pid = self._by_hash.get(h)
            if pid is None:
                break
            claim.append(pid)
        n_fresh = total - len(claim)
        if n_fresh > len(self._free) + len(self._reusable) - sum(
                1 for p in claim if p in self._reusable):
            self._c["kv_stalls"].inc()
            return None
        # commit: pin cached pages, then allocate fresh ones
        for pid in claim:
            if self._refs[pid] == 0:
                self._reusable.pop(pid, None)
            self._refs[pid] += 1
        pages = list(claim)
        for _ in range(n_fresh):
            pages.append(self._take_free())
        self._c["prefix_hits"].inc(len(claim))
        self._c["alloc"].inc(n_fresh)
        return SlotPages(pages=pages, n_cached=len(claim) * ps,
                         hashes=hashes, n_prompt_full=len(hashes))

    def _take_free(self) -> int:
        if self._free:
            pid = self._free.pop()
        else:
            # evict the least-recently-parked cached page
            pid, _ = self._reusable.popitem(last=False)
            h = self._page_hash.pop(pid, None)
            if h is not None:
                self._by_hash.pop(h, None)
            self._c["cache_evictions"].inc()
        self._refs[pid] = 1
        return pid

    def register(self, sp: SlotPages) -> None:
        """Publish a finished prefill's full prompt pages into the prefix
        cache (idempotent; duplicate hashes keep the first publisher)."""
        if sp.registered:
            return
        sp.registered = True
        for i in range(sp.n_prompt_full):
            h = sp.hashes[i]
            if h in self._by_hash:
                continue                      # someone else published it
            pid = sp.pages[i]
            self._by_hash[h] = pid
            self._page_hash[pid] = h

    def finish(self, sp: SlotPages) -> None:
        """Release a request's claim. Published pages with no remaining
        users park in the LRU cache; private pages return to the free
        list."""
        for pid in sp.pages:
            self._refs[pid] -= 1
            if self._refs[pid] > 0:
                continue
            if pid in self._page_hash:
                self._reusable[pid] = None
                self._reusable.move_to_end(pid)
            else:
                self._free.append(pid)
                self._c["freed"].inc()
        sp.pages = []

    # -- device view ----------------------------------------------------------
    def table_row(self, sp: SlotPages, width: int) -> np.ndarray:
        """(width,) int32 table row: the claim's pages in order, garbage
        everywhere else (including the sentinel last column)."""
        if len(sp.pages) > width - 1:
            raise ValueError(f"claim of {len(sp.pages)} pages exceeds table "
                             f"width {width} (max_pages {width - 1})")
        row = np.full(width, GARBAGE_PAGE, np.int32)
        row[:len(sp.pages)] = sp.pages
        return row

    def stats(self) -> Dict[str, int]:
        """Thin view over the pool's registry instruments — same keys the
        pre-obs counters dict exposed, plus live occupancy (mirrored into
        gauges so ``REGISTRY.snapshot()`` sees it too)."""
        self._g_in_use.set(self.in_use)
        self._g_free.set(len(self._free))
        self._g_cached.set(len(self._reusable))
        out = {k: c.value for k, c in self._c.items()}
        out.update(in_use=self.in_use, free=len(self._free),
                   cached=len(self._reusable), num_pages=self.num_pages,
                   page_size=self.page_size)
        return out


def merge_pool_stats(stats: "List[Dict[str, int]]") -> Dict[str, int]:
    """Aggregate N replicas' ``KVPagePool.stats()`` into one cluster view:
    counters and capacities sum (a cluster of two 64-page pools IS a
    128-page budget); ``page_size`` must agree — mixed geometries would
    make the summed page counts meaningless."""
    if not stats:
        raise ValueError("merge_pool_stats needs at least one stats dict")
    sizes = {s["page_size"] for s in stats}
    if len(sizes) > 1:
        raise ValueError(f"cannot merge pools with mixed page sizes {sizes}")
    out = dict(stats[0])
    for s in stats[1:]:
        for k, v in s.items():
            if k != "page_size":
                out[k] += v
    return out
