"""Algorithm 1 — Frobenius projection onto the GS(P_L, P, P_R) class.

Via Proposition 1, P_L^T A P_R^T is a block matrix whose (k1, k2) block is a
sum of outer products u_{sigma(i)} v_i^T over a rank budget r_{k1,k2}
determined by the middle permutation.  The optimal projection truncates the
SVD of each block (Eckart–Young) and packs the factors back into the L / R
block-diagonal tensors at positions dictated by sigma.

Used for: (a) initializing GS adapters from a dense target (e.g. distilling a
full orthogonal fine-tune into GSOFT form), (b) tests of Theorem 1, (c) the
projected-orthogonalization utilities.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from .gs import GSLayout, block_ranks
from .permutations import inverse_sigma

__all__ = ["project_to_gs", "gs_reconstruction_error"]


def project_to_gs(a: np.ndarray, layout: GSLayout) -> Tuple[np.ndarray, np.ndarray]:
    """Project dense ``a`` (out_dim x in_dim) onto GS(P_L, P, P_R).

    Returns stacked block tensors (L, R) with shapes
    (k_L, b_L, b_L2) and (k_R, b_R, b_R2) minimizing ||A - P_L L P R P_R||_F.
    """
    a = np.asarray(a, dtype=np.float64)
    if a.shape != (layout.out_dim, layout.in_dim):
        raise ValueError(f"expected {(layout.out_dim, layout.in_dim)}, got {a.shape}")

    # strip the outer permutations:  B = P_L^T A P_R^T.
    # With gather semantics P[i, sigma(i)] = 1:
    #   P_L^T A  permutes rows by inv(sigma_L);  A P_R^T  takes columns [sigma_R].
    sig_l = layout.perm_left.sigma(layout.out_dim)
    sig_r = layout.perm_right.sigma(layout.in_dim)
    b = a[inverse_sigma(sig_l), :][:, sig_r]

    kL, bL1, bL2 = layout.lspec.param_shape
    kR, bR1, bR2 = layout.rspec.param_shape
    sigma = layout.perm_mid.sigma(layout.inner_dim)

    L = np.zeros((kL, bL1, bL2), dtype=np.float64)
    R = np.zeros((kR, bR1, bR2), dtype=np.float64)

    # bucket inner indices j (L column / R' row) by
    # (k1, k2) = (j // b_L2, sigma(j) // b_R1)  [gather convention]
    buckets: dict = {}
    for j in range(layout.inner_dim):
        key = (j // bL2, sigma[j] // bR1)
        buckets.setdefault(key, []).append(j)

    for (k1, k2), idxs in buckets.items():
        blk = b[k1 * bL1:(k1 + 1) * bL1, k2 * bR2:(k2 + 1) * bR2]
        r = len(idxs)
        u, s, vt = np.linalg.svd(blk, full_matrices=False)
        r = min(r, s.shape[0])
        ssqrt = np.sqrt(s[:r])
        ucols = u[:, :r] * ssqrt[None, :]          # columns of L_{k1}
        vrows = vt[:r, :] * ssqrt[:, None]         # rows of R_{k2}
        for t, j in enumerate(idxs[:r]):
            L[k1][:, j % bL2] = ucols[:, t]
            R[k2][sigma[j] % bR1, :] = vrows[t, :]
        # surplus budget (r_{k1,k2} > matrix rank bound) stays zero-filled.
    return L, R


def gs_reconstruction_error(a: np.ndarray, layout: GSLayout,
                            L: np.ndarray, R: np.ndarray) -> float:
    from .gs import gs_materialize
    return float(np.linalg.norm(np.asarray(a) - gs_materialize(layout, L, R)))
