"""ModelRuntime — the single serving/eval entry point.

Binds ``ModelConfig + params + mesh (shard rules) + optional AdapterBank``
into one object that owns its jitted ``prefill`` / ``decode`` / ``loss``
closures, so engines, launchers, examples and benchmarks stop re-plumbing
``(cfg, params, mesh, bank, peft_cfg, adapter_ids, ...)`` through every
call. Per-request adapter state flows exclusively through
``AdapterContext`` pytrees built by ``runtime.context(slot_ids)``.

Adapters attach through ONE surface — ``runtime.attach(source)`` — which
accepts an ``AdapterStore`` (host-offloaded, LRU-paged under an HBM
budget), a pre-built eager ``AdapterBank``, named adapter trees + their
PEFTConfig(s), a checkpoint directory, or ``name=dir`` entry lists; the
serving side never touches raw checkpoint layout. The PR-5 trio
(``with_bank`` / ``save_bank`` / ``load_named_adapters``) survives as
warn-once deprecation shims over ``attach`` / ``repro.store``.

Serve-time tensor parallelism (ISSUE 8): building the runtime with a mesh
COMMITS its state onto it — params (quantized trees included) under the
Megatron column/row splits of ``sharding.specs``, contiguous and paged KV
state with kv-heads over the 'model' axis, eager bank factor stacks
replicated (or method-sharded via ``MethodOps.bank_shard_axes``). The
jitted prefill/slot-prefill/decode/chunk-prefill closures are built once
per geometry and GSPMD-partition against the committed input shardings,
so the serving engines run unchanged on 1..N devices; kernel dispatch
keys grow a ``tp`` tag so per-shard tunings never collide with
single-device ones.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, List, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.models import api

Tree = Any

_deprecation_warned: set = set()


def _warn_once(old: str, new: str) -> None:
    """One DeprecationWarning per process per retired name (mirrors the
    PR-3 api-shim pattern; the names themselves go away next cycle)."""
    if old in _deprecation_warned:
        return
    _deprecation_warned.add(old)
    warnings.warn(f"{old} is deprecated; use {new}", DeprecationWarning,
                  stacklevel=3)


def _check_bank_quant_compatible(bank: peft_lib.AdapterBank) -> None:
    """Registry-driven capability gate: every method in the bank must be
    flagged ``quant_compatible`` (its rotations apply activation-side in
    bf16 BEFORE the int8 base matmul) to serve over quantized weights."""
    from repro.core import methods as methods_lib
    bad = [m for m in bank.bank_methods
           if not methods_lib.get(m).quant_compatible]
    if bad:
        raise ValueError(
            f"bank methods {bad} are not quantization-compatible — they "
            "cannot serve over quantized base weights (see the "
            "quant_compatible flag on their core.methods records)")


class ModelRuntime:
    """``ModelRuntime(cfg)`` initializes params; pass ``params=`` to reuse
    a tree. ``adapters``+``peft_cfg`` merge ONE adapter into the weights
    offline (the paper's zero-overhead static serving mode, §6.1); a
    ``bank`` serves per-request adapters activation-side. The two are
    mutually exclusive — merging and then rotating would apply adapters
    twice."""

    def __init__(self, cfg: ModelConfig, params: Optional[Tree] = None, *,
                 key: Optional[jax.Array] = None, mesh=None,
                 bank: Optional[peft_lib.AdapterBank] = None,
                 adapters: Optional[Tree] = None,
                 peft_cfg: Optional[peft_lib.PEFTConfig] = None,
                 abstract: bool = False):
        self.cfg = cfg
        self._ops = api.family_ops(cfg)      # fails fast on unknown family
        if params is None:
            params = (api.abstract_params(cfg) if abstract else
                      api.init_params(cfg, key if key is not None
                                      else jax.random.PRNGKey(0)))
        if adapters is not None:
            from repro import quant
            if quant.is_quantized_tree(params):
                raise ValueError(
                    "cannot merge adapters into already-quantized weights — "
                    "merge first, then call runtime.quantized() (quantizing "
                    "the merged tree keeps the rotation at full precision)")
        if (adapters is None) != (peft_cfg is None):
            raise ValueError(
                "offline merge needs BOTH adapters and peft_cfg — passing "
                "only one would silently serve the un-adapted base model")
        if adapters is not None and not adapters:
            raise ValueError(
                "empty adapter tree (target_patterns matched no weights?) — "
                "refusing a no-op merge that would silently serve the "
                "un-adapted base model")
        self._merged = adapters is not None
        if self._merged:
            if bank is not None:
                raise ValueError(
                    "pass EITHER merged adapters (adapters + peft_cfg) OR a "
                    "per-request bank — merging and then rotating per "
                    "request would apply adapters twice")
            params = peft_lib.materialize_tree(peft_cfg, params, adapters,
                                               merged=True)
        self.mesh = mesh
        if mesh is not None and not abstract and any(
                not isinstance(l, jax.ShapeDtypeStruct)
                for l in jax.tree.leaves(params)):
            from repro.sharding import specs as shard_specs
            rules = shard_specs.ShardingRules(cfg, mesh)
            params = shard_specs.place(mesh, params,
                                       rules.serve_params_tree(params))
            from repro.kernels import dispatch as kernel_dispatch
            kernel_dispatch.set_serve_tp(shard_specs.tp_size(mesh))
        self.params = params
        self.bank = bank
        self.quant_cfg = None        # set by .quantized() / load_quantized
        # jitted-closure cache. A plain dict (not attributes) so derived
        # runtimes (attach/detach — same cfg+mesh, closures take params as
        # arguments) can SHARE it by reference via ``_adopt_jit``: traces
        # land in the cache once, whichever runtime triggers them.
        self._jit: Dict[str, Any] = {"slot_prefill": {}}

    @classmethod
    def abstract(cls, cfg: ModelConfig, mesh=None) -> "ModelRuntime":
        """Runtime over ShapeDtypeStruct params (dry-run lowering)."""
        return cls(cfg, mesh=mesh, abstract=True)

    # -- adapter bank ---------------------------------------------------------
    @property
    def banked(self) -> bool:
        return self.bank is not None

    def slot(self, name: Optional[str]) -> int:
        """Bank slot id for an adapter name (0 = identity). Naming an
        adapter on a bankless runtime raises — silently serving the base
        model instead of the requested fine-tune is the failure mode this
        API exists to prevent."""
        if self.bank is None:
            if name is not None:
                raise KeyError(f"runtime has no adapter bank; cannot serve "
                               f"adapter {name!r} — attach one with "
                               "ModelRuntime.attach")
            return 0
        return self.bank.slot(name)

    def context(self, slot_ids) -> Optional[peft_lib.AdapterContext]:
        """AdapterContext binding the bank to a batch of slot ids
        (None when this runtime serves the bare/merged model)."""
        if self.bank is None:
            return None
        return self.bank.context(slot_ids)

    # -- residency surface (engines call these; trivial on eager banks) -------
    def validate_adapter(self, name: Optional[str]) -> None:
        """Submission-time check: the name must be servable (resident OR
        host-side). Unknown names raise listing both tiers; naming any
        adapter on a bankless runtime raises — silently serving the base
        model instead of the requested fine-tune is the failure mode this
        API exists to prevent."""
        if self.bank is None:
            if name is not None:
                raise KeyError(f"runtime has no adapter bank; cannot serve "
                               f"adapter {name!r} — attach one with "
                               "ModelRuntime.attach")
            return
        self.bank.validate(name)

    def acquire_adapter(self, name: Optional[str]) -> Optional[int]:
        """Admission-time slot claim (pins; may page in on a store-backed
        bank). None = admission stall: every slot of the adapter's method
        is pinned by in-flight requests — keep decoding and retry."""
        if self.bank is None:
            self.validate_adapter(name)
            return 0
        return self.bank.acquire(name)

    def release_adapter(self, name: Optional[str]) -> None:
        """Request-finished unpin (no-op on eager/bankless runtimes)."""
        if self.bank is not None:
            self.bank.release(name)

    def attach(self, source, peft_cfg: Optional["peft_lib.PEFTConfigs"] = None,
               *, hbm_budget: Optional[int] = None) -> "ModelRuntime":
        """New runtime over the same params serving per-request adapters
        (universal slot 0 stays the identity/base model). THE one adapter
        attachment surface; ``source`` may be:

          * an ``repro.store.AdapterStore`` — host-offloaded adapters,
            LRU-paged into a slot-compacted HBM bank sized by
            ``hbm_budget`` (default: everything resident, still compact);
          * a pre-built eager ``AdapterBank``;
          * ``{name: adapter_tree}`` + ``peft_cfg`` (a single PEFTConfig
            or a {name: PEFTConfig} mapping for mixed-method serving) —
            eager bank, unless ``hbm_budget`` is given (then store-paged);
          * a checkpoint directory (str) — opened as a DISK-backED store:
            only the index loads up front, adapters page in on admission;
          * a list of ``"name=ckpt_dir"`` / ``"ckpt_dir"`` entries (the
            launcher's ``--adapters`` form).
        """
        from repro import store as store_lib
        if self._merged:
            raise ValueError(
                "this runtime's params already contain a merged adapter; "
                "banking on top would rotate already-rotated activations — "
                "attach to the unmerged base runtime")
        if isinstance(source, (list, tuple)):
            if peft_cfg is not None:
                raise ValueError("checkpoint entries carry their own "
                                 "PEFTConfigs — do not pass peft_cfg")
            source, peft_cfg = store_lib.load_adapter_checkpoints(source)
        if isinstance(source, str):
            if peft_cfg is not None:
                raise ValueError("a checkpoint directory carries its own "
                                 "PEFTConfigs — do not pass peft_cfg")
            source = store_lib.AdapterStore.open(source)
        if isinstance(source, peft_lib.AdapterBank):
            if peft_cfg is not None or hbm_budget is not None:
                raise ValueError("a pre-built AdapterBank is attached "
                                 "as-is — peft_cfg/hbm_budget do not apply")
            bank = source
        elif isinstance(source, store_lib.AdapterStore):
            if peft_cfg is not None:
                raise ValueError("an AdapterStore carries its own "
                                 "PEFTConfigs — do not pass peft_cfg")
            bank = store_lib.PagedAdapterBank(source, self.params,
                                              hbm_budget=hbm_budget)
        elif isinstance(source, Mapping):
            if peft_cfg is None:
                raise ValueError(
                    "attach({name: adapters}) needs peft_cfg — a single "
                    "PEFTConfig or a {name: PEFTConfig} mapping")
            if hbm_budget is not None:
                bank = store_lib.PagedAdapterBank(
                    store_lib.AdapterStore.from_adapters(source, peft_cfg),
                    self.params, hbm_budget=hbm_budget)
            else:
                bank = peft_lib.build_adapter_bank(peft_cfg, self.params,
                                                   source)
        else:
            raise TypeError(f"cannot attach {type(source).__name__}: expected "
                            "AdapterStore, AdapterBank, {name: adapters}, a "
                            "checkpoint dir, or checkpoint entries")
        if self.is_quantized:
            _check_bank_quant_compatible(bank)
        if self.mesh is not None and isinstance(bank, peft_lib.AdapterBank):
            # eager bank: commit factor stacks onto the serve mesh
            # (replicated unless the method's bank_shard_axes hook opts a
            # factor axis into the 'model' split). The store-paged bank is
            # left alone — its stacks are rewritten in place on every
            # page-in, so it keeps default placement.
            from repro.sharding import specs as shard_specs
            rules = shard_specs.ShardingRules(self.cfg, self.mesh)
            bank.tree = shard_specs.place(self.mesh, bank.tree,
                                          rules.bank_spec_tree(bank.tree))
        rt = ModelRuntime(self.cfg, self.params, mesh=self.mesh, bank=bank)
        rt.quant_cfg = self.quant_cfg   # quantize-then-bank commutes
        self._adopt_jit(rt)
        return rt

    def detach(self) -> "ModelRuntime":
        """New runtime over the same params with no adapter bank."""
        rt = ModelRuntime(self.cfg, self.params, mesh=self.mesh)
        rt.quant_cfg = self.quant_cfg
        rt._merged = self._merged
        self._adopt_jit(rt)
        return rt

    def _adopt_jit(self, other: "ModelRuntime") -> None:
        """Share the jitted-closure cache with a runtime derived from this
        one. attach/detach keep (cfg, mesh) and the closures take params /
        bank state as ARGUMENTS, so traces transfer; sharing by REFERENCE
        means every replica of an ``EngineCluster`` built via ``attach``
        reuses one compiled program set instead of re-tracing N times."""
        other._jit = self._jit

    def with_bank(self, adapters_by_name: Dict[str, Tree],
                  peft_cfg: "peft_lib.PEFTConfigs") -> "ModelRuntime":
        """Deprecated: use ``attach(adapters_by_name, peft_cfg)``."""
        _warn_once("ModelRuntime.with_bank", "ModelRuntime.attach")
        return self.attach(adapters_by_name, peft_cfg)

    # -- quantized serving ----------------------------------------------------
    @property
    def is_quantized(self) -> bool:
        return self.quant_cfg is not None

    def quantized(self, mode: Optional[str] = None, *,
                  qcfg=None) -> "ModelRuntime":
        """New runtime over the same model with base weights quantized for
        inference (per-output-channel symmetric int8 by default; fp8 stub
        behind a dtype gate). Pass ``mode`` OR a full ``qcfg`` — naming
        both only works when they agree. The adapter bank — when present —
        is carried over UNTOUCHED: GS rotations stay bf16 and apply
        activation-side before the quantized base matmuls (QOFT recipe,
        DESIGN.md)."""
        from repro import quant
        if self.is_quantized:
            raise ValueError("runtime is already quantized "
                             f"(mode={self.quant_cfg.mode!r})")
        if qcfg is None:
            qcfg = quant.QuantConfig(mode=mode or "int8",
                                     use_pallas=self.cfg.use_pallas)
        elif mode is not None and qcfg.mode != mode:
            raise ValueError(
                f"quantized(mode={mode!r}) conflicts with qcfg.mode="
                f"{qcfg.mode!r} — pass one or the other")
        if self.bank is not None:
            _check_bank_quant_compatible(self.bank)
        rt = ModelRuntime(self.cfg, quant.quantize_params(self.params, qcfg),
                          mesh=self.mesh, bank=self.bank)
        rt._merged = self._merged
        rt.quant_cfg = qcfg
        self._adopt_jit(rt)     # same traces cache; new avals re-specialize
        return rt

    @classmethod
    def load_quantized(cls, directory: str, cfg: ModelConfig, *,
                       qcfg=None, mesh=None, step: Optional[int] = None
                       ) -> "ModelRuntime":
        """Runtime from a checkpoint, served quantized.

        A quantized checkpoint (``CheckpointManager.save_quantized``)
        restores codes+scales directly with its saved QuantConfig (the
        kernel path follows ``cfg.use_pallas``/``qcfg`` — execution
        strategy is chosen at load time, not baked into the checkpoint);
        a plain float checkpoint is quantized ON LOAD with ``qcfg``
        (default int8) — the upgrade path for existing bf16 checkpoints."""
        from repro.checkpoint.manager import CheckpointManager
        qparams, used_cfg = CheckpointManager(directory).restore_quantized(
            api.abstract_params(cfg), qcfg=qcfg, step=step,
            use_pallas=cfg.use_pallas)
        rt = cls(cfg, qparams, mesh=mesh)
        rt.quant_cfg = used_cfg
        return rt

    # -- checkpoint integration (deprecated shims over repro.store) -----------
    @staticmethod
    def save_bank(directory: str, adapters_by_name: Dict[str, Tree],
                  peft_cfg: "peft_lib.PEFTConfigs", step: int = 0) -> None:
        """Deprecated: use ``repro.store.AdapterStore.from_adapters(...)
        .save(directory)`` (same on-disk format)."""
        _warn_once("ModelRuntime.save_bank",
                   "repro.store.AdapterStore.from_adapters(...).save(dir)")
        from repro.store import AdapterStore
        AdapterStore.from_adapters(adapters_by_name,
                                   peft_cfg).save(directory, step)

    @staticmethod
    def load_named_adapters(entries: List[str]
                            ) -> Tuple[Dict[str, Tree],
                                       "peft_lib.PEFTConfigs"]:
        """Deprecated: ``ModelRuntime.attach`` takes the entry list
        directly (or use ``repro.store.load_adapter_checkpoints``)."""
        _warn_once("ModelRuntime.load_named_adapters",
                   "ModelRuntime.attach(entries) / "
                   "repro.store.load_adapter_checkpoints")
        from repro.store import load_adapter_checkpoints
        return load_adapter_checkpoints(entries)

    # -- family ops / state ---------------------------------------------------
    @property
    def stateless(self) -> bool:
        """True for families with no token-level decode state (they serve
        whole inputs through ``infer_fn`` — e.g. ``image``)."""
        return self._ops.stateless

    def init_decode_state(self, batch: int, max_len: int, enc_len: int = 0):
        if self._ops.init_decode_state is None:
            raise ValueError(
                f"family {self.cfg.family!r} is stateless — it has no "
                "decode state; serve it through infer_fn / ImageServeEngine")
        return self._ops.init_decode_state(self.cfg, batch, max_len, enc_len)

    def decode_state(self, batch: int, max_len: int, enc_len: int = 0):
        """Contiguous decode state (one max_len KV region per slot). THE
        engine/bench-facing constructor — a grep guard keeps raw
        ``init_decode_state(`` calls confined to this module so every
        contiguous allocation is auditable against the paged path. On a
        meshed runtime the KV caches commit with kv-heads over 'model'."""
        state = self.init_decode_state(batch, max_len, enc_len)
        if self.mesh is not None:
            from repro.sharding import specs as shard_specs
            rules = shard_specs.ShardingRules(self.cfg, self.mesh)
            state = shard_specs.place(
                self.mesh, state, rules.decode_state_spec(state, batch))
        return state

    def paged_state(self, batch: int, num_pages: int, page_size: int,
                    max_pages: int):
        """Paged decode state: per-layer (num_pages, page_size, K, D) pools
        shared by all slots + a (batch, max_pages + 1) int32 page table per
        slot (sentinel garbage column last). Raises for families without a
        paged serve path. On a meshed runtime the page pools commit with
        kv-heads over 'model'; the table stays replicated (host-side page
        allocation never sees the mesh)."""
        if self._ops.init_paged_state is None:
            raise ValueError(f"family {self.cfg.family!r} has no paged "
                             "KV serve path")
        state = self._ops.init_paged_state(self.cfg, batch, num_pages,
                                           page_size, max_pages)
        if self.mesh is not None:
            from repro.sharding import specs as shard_specs
            rules = shard_specs.ShardingRules(self.cfg, self.mesh)
            state = shard_specs.place(self.mesh, state,
                                      rules.paged_state_spec(state))
        return state

    def active_param_count(self) -> int:
        return self._ops.active_param_count(self.cfg)

    # -- unjitted step builders (dry-run lowering with custom shardings) ------
    def build_prefill(self, batch_divisible: bool = True):
        from repro.train.steps import build_prefill_step
        return build_prefill_step(self.cfg, self.mesh, batch_divisible)

    def build_decode(self, batch_divisible: bool = True):
        from repro.train.steps import build_decode_step
        return build_decode_step(self.cfg, self.mesh, batch_divisible)

    # -- jitted closures (lazy, cached on the runtime) ------------------------
    def prefill_fn(self):
        """jitted (params, PrefillRequest, state) -> (logits, state)."""
        if self._jit.get("prefill") is None:
            self._jit["prefill"] = jax.jit(self.build_prefill())
        return self._jit["prefill"]

    def decode_fn(self):
        """jitted (params, ctx, tokens, state, pos) ->
        (next_tok, logits, state); ``state`` is donated."""
        if self._jit.get("decode") is None:
            self._jit["decode"] = jax.jit(self.build_decode(),
                                          donate_argnums=(3,))
        return self._jit["decode"]

    def paged_decode_fn(self):
        """jitted (params, ctx, tokens, state, pos) ->
        (next_tok, logits, state) through page tables; state donated."""
        if self._jit.get("paged_decode") is None:
            from repro.train.steps import build_paged_decode_step
            self._jit["paged_decode"] = jax.jit(
                build_paged_decode_step(self.cfg, self.mesh),
                donate_argnums=(3,))
        return self._jit["paged_decode"]

    def chunk_prefill_fn(self):
        """jitted (params, req, state, slot, start) -> (first, state);
        state donated. One trace per chunk width (req token shape)."""
        if self._jit.get("chunk_prefill") is None:
            from repro.train.steps import build_chunk_prefill_step
            self._jit["chunk_prefill"] = jax.jit(
                build_chunk_prefill_step(self.cfg, self.mesh),
                donate_argnums=(2,))
        return self._jit["chunk_prefill"]

    def slot_prefill_fn(self, max_len: int, enc_len: int = 0):
        """jitted (params, PrefillRequest, state, slot) -> (first, state);
        ``state`` is donated. Cached per (max_len, enc_len) geometry."""
        key = (max_len, enc_len)
        cache = self._jit["slot_prefill"]
        if key not in cache:
            from repro.train.steps import build_slot_prefill_step
            cache[key] = jax.jit(
                build_slot_prefill_step(self.cfg, self.mesh, max_len=max_len,
                                        enc_len=enc_len),
                donate_argnums=(2,))
        return cache[key]

    def infer_fn(self):
        """jitted (params, ctx, inputs) -> logits — the STATELESS serving
        entry point (``FamilyOps.infer``): one whole-input batched forward,
        no KV. ``ctx`` is the same AdapterContext the decode path takes, so
        per-request banked adapters work identically."""
        if self._jit.get("infer") is None:
            if self._ops.infer is None:
                raise ValueError(
                    f"family {self.cfg.family!r} has no stateless infer "
                    "entry point — serve it through prefill/decode")
            cfg, shard = self.cfg, self._shard()
            fam = self._ops
            self._jit["infer"] = jax.jit(
                lambda params, ctx, inputs: fam.infer(cfg, params, inputs,
                                                      shard, ctx=ctx))
        return self._jit["infer"]

    def infer(self, inputs,
              ctx: Optional[peft_lib.AdapterContext] = None):
        return self.infer_fn()(self.params, ctx, inputs)

    def loss_fn(self):
        """jitted (params, batch) -> (loss, metrics)."""
        if self._jit.get("loss") is None:
            cfg, shard = self.cfg, self._shard()
            fam = self._ops
            self._jit["loss"] = jax.jit(
                lambda params, batch: fam.loss(cfg, params, batch, shard))
        return self._jit["loss"]

    def loss(self, batch):
        return self.loss_fn()(self.params, batch)

    def prefill(self, req: peft_lib.PrefillRequest, state):
        return self.prefill_fn()(self.params, req, state)

    def decode(self, tokens, state, pos,
               ctx: Optional[peft_lib.AdapterContext] = None):
        return self.decode_fn()(self.params, ctx, tokens, state, pos)

    def _shard(self):
        if self.mesh is None:
            from repro.models.layers import no_shard
            return no_shard
        from repro.sharding.specs import ShardingRules
        return ShardingRules(self.cfg, self.mesh).make_sharder()
