"""ModelRuntime — the single serving/eval entry point.

Binds ``ModelConfig + params + mesh (shard rules) + optional AdapterBank``
into one object that owns its jitted ``prefill`` / ``decode`` / ``loss``
closures, so engines, launchers, examples and benchmarks stop re-plumbing
``(cfg, params, mesh, bank, peft_cfg, adapter_ids, ...)`` through every
call. Per-request adapter state flows exclusively through
``AdapterContext`` pytrees built by ``runtime.context(slot_ids)``.

Adapter banks round-trip through the checkpoint manager via
``runtime.save_bank`` / ``ModelRuntime.load_named_adapters`` +
``runtime.with_bank`` — the serving side never touches raw checkpoint
layout.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.models import api

Tree = Any


def _check_bank_quant_compatible(bank: peft_lib.AdapterBank) -> None:
    """Registry-driven capability gate: every method in the bank must be
    flagged ``quant_compatible`` (its rotations apply activation-side in
    bf16 BEFORE the int8 base matmul) to serve over quantized weights."""
    from repro.core import methods as methods_lib
    bad = [m for m in bank.bank_methods
           if not methods_lib.get(m).quant_compatible]
    if bad:
        raise ValueError(
            f"bank methods {bad} are not quantization-compatible — they "
            "cannot serve over quantized base weights (see the "
            "quant_compatible flag on their core.methods records)")


class ModelRuntime:
    """``ModelRuntime(cfg)`` initializes params; pass ``params=`` to reuse
    a tree. ``adapters``+``peft_cfg`` merge ONE adapter into the weights
    offline (the paper's zero-overhead static serving mode, §6.1); a
    ``bank`` serves per-request adapters activation-side. The two are
    mutually exclusive — merging and then rotating would apply adapters
    twice."""

    def __init__(self, cfg: ModelConfig, params: Optional[Tree] = None, *,
                 key: Optional[jax.Array] = None, mesh=None,
                 bank: Optional[peft_lib.AdapterBank] = None,
                 adapters: Optional[Tree] = None,
                 peft_cfg: Optional[peft_lib.PEFTConfig] = None,
                 abstract: bool = False):
        self.cfg = cfg
        self._ops = api.family_ops(cfg)      # fails fast on unknown family
        if params is None:
            params = (api.abstract_params(cfg) if abstract else
                      api.init_params(cfg, key if key is not None
                                      else jax.random.PRNGKey(0)))
        if adapters is not None:
            from repro import quant
            if quant.is_quantized_tree(params):
                raise ValueError(
                    "cannot merge adapters into already-quantized weights — "
                    "merge first, then call runtime.quantized() (quantizing "
                    "the merged tree keeps the rotation at full precision)")
        if (adapters is None) != (peft_cfg is None):
            raise ValueError(
                "offline merge needs BOTH adapters and peft_cfg — passing "
                "only one would silently serve the un-adapted base model")
        if adapters is not None and not adapters:
            raise ValueError(
                "empty adapter tree (target_patterns matched no weights?) — "
                "refusing a no-op merge that would silently serve the "
                "un-adapted base model")
        self._merged = adapters is not None
        if self._merged:
            if bank is not None:
                raise ValueError(
                    "pass EITHER merged adapters (adapters + peft_cfg) OR a "
                    "per-request bank — merging and then rotating per "
                    "request would apply adapters twice")
            params = peft_lib.materialize_tree(peft_cfg, params, adapters,
                                               merged=True)
        self.params = params
        self.mesh = mesh
        self.bank = bank
        self.quant_cfg = None        # set by .quantized() / load_quantized
        self._decode = None
        self._prefill = None
        self._loss = None
        self._slot_prefill: Dict[Tuple[int, int], Any] = {}

    @classmethod
    def abstract(cls, cfg: ModelConfig, mesh=None) -> "ModelRuntime":
        """Runtime over ShapeDtypeStruct params (dry-run lowering)."""
        return cls(cfg, mesh=mesh, abstract=True)

    # -- adapter bank ---------------------------------------------------------
    @property
    def banked(self) -> bool:
        return self.bank is not None

    def slot(self, name: Optional[str]) -> int:
        """Bank slot id for an adapter name (0 = identity). Naming an
        adapter on a bankless runtime raises — silently serving the base
        model instead of the requested fine-tune is the failure mode this
        API exists to prevent."""
        if self.bank is None:
            if name is not None:
                raise KeyError(f"runtime has no adapter bank; cannot serve "
                               f"adapter {name!r} — build one with "
                               "ModelRuntime.with_bank")
            return 0
        return self.bank.slot(name)

    def context(self, slot_ids) -> Optional[peft_lib.AdapterContext]:
        """AdapterContext binding the bank to a batch of slot ids
        (None when this runtime serves the bare/merged model)."""
        if self.bank is None:
            return None
        return self.bank.context(slot_ids)

    def with_bank(self, adapters_by_name: Dict[str, Tree],
                  peft_cfg: "peft_lib.PEFTConfigs") -> "ModelRuntime":
        """New runtime over the same params serving these named adapters
        per-request (slot 0 stays the identity/base model).

        ``peft_cfg`` is a single PEFTConfig (every adapter uses it) or a
        {name: PEFTConfig} mapping — a MIXED-method bank where each named
        adapter declares its own registered method (gsoft / oft / boft /
        householder today)."""
        if self._merged:
            raise ValueError(
                "this runtime's params already contain a merged adapter; "
                "banking on top would rotate already-rotated activations — "
                "build the bank from the unmerged base runtime")
        bank = peft_lib.build_adapter_bank(peft_cfg, self.params,
                                           adapters_by_name)
        if self.is_quantized:
            _check_bank_quant_compatible(bank)
        rt = ModelRuntime(self.cfg, self.params, mesh=self.mesh, bank=bank)
        rt.quant_cfg = self.quant_cfg   # quantize-then-bank commutes
        return rt

    # -- quantized serving ----------------------------------------------------
    @property
    def is_quantized(self) -> bool:
        return self.quant_cfg is not None

    def quantized(self, mode: Optional[str] = None, *,
                  qcfg=None) -> "ModelRuntime":
        """New runtime over the same model with base weights quantized for
        inference (per-output-channel symmetric int8 by default; fp8 stub
        behind a dtype gate). Pass ``mode`` OR a full ``qcfg`` — naming
        both only works when they agree. The adapter bank — when present —
        is carried over UNTOUCHED: GS rotations stay bf16 and apply
        activation-side before the quantized base matmuls (QOFT recipe,
        DESIGN.md)."""
        from repro import quant
        if self.is_quantized:
            raise ValueError("runtime is already quantized "
                             f"(mode={self.quant_cfg.mode!r})")
        if qcfg is None:
            qcfg = quant.QuantConfig(mode=mode or "int8",
                                     use_pallas=self.cfg.use_pallas)
        elif mode is not None and qcfg.mode != mode:
            raise ValueError(
                f"quantized(mode={mode!r}) conflicts with qcfg.mode="
                f"{qcfg.mode!r} — pass one or the other")
        if self.bank is not None:
            _check_bank_quant_compatible(self.bank)
        rt = ModelRuntime(self.cfg, quant.quantize_params(self.params, qcfg),
                          mesh=self.mesh, bank=self.bank)
        rt._merged = self._merged
        rt.quant_cfg = qcfg
        return rt

    @classmethod
    def load_quantized(cls, directory: str, cfg: ModelConfig, *,
                       qcfg=None, mesh=None, step: Optional[int] = None
                       ) -> "ModelRuntime":
        """Runtime from a checkpoint, served quantized.

        A quantized checkpoint (``CheckpointManager.save_quantized``)
        restores codes+scales directly with its saved QuantConfig (the
        kernel path follows ``cfg.use_pallas``/``qcfg`` — execution
        strategy is chosen at load time, not baked into the checkpoint);
        a plain float checkpoint is quantized ON LOAD with ``qcfg``
        (default int8) — the upgrade path for existing bf16 checkpoints."""
        from repro.checkpoint.manager import CheckpointManager
        qparams, used_cfg = CheckpointManager(directory).restore_quantized(
            api.abstract_params(cfg), qcfg=qcfg, step=step,
            use_pallas=cfg.use_pallas)
        rt = cls(cfg, qparams, mesh=mesh)
        rt.quant_cfg = used_cfg
        return rt

    # -- checkpoint integration ----------------------------------------------
    @staticmethod
    def save_bank(directory: str, adapters_by_name: Dict[str, Tree],
                  peft_cfg: "peft_lib.PEFTConfigs", step: int = 0) -> None:
        """Persist named RAW adapter trees + their PEFTConfig(s) as an
        adapter-bank checkpoint (the format ``load_named_adapters`` reads
        back; mixed-method banks record one method + spec per adapter name
        in the index). Static: a built ``AdapterBank`` holds pre-processed
        stacks, so the original adapter trees must be supplied, not a
        runtime's bank."""
        from repro.checkpoint.manager import CheckpointManager
        CheckpointManager(directory).save_adapters(step, adapters_by_name,
                                                   peft_cfg)

    @staticmethod
    def load_named_adapters(entries: List[str]
                            ) -> Tuple[Dict[str, Tree],
                                       "peft_lib.PEFTConfigs"]:
        """``entries``: ["name=ckpt_dir" | "ckpt_dir"] -> (adapters_by_name,
        cfg) where ``cfg`` is a single PEFTConfig (homogeneous bank) or a
        {name: PEFTConfig} mapping (mixed-method bank) — exactly what
        ``with_bank`` accepts. A bare dir loads every adapter in that bank;
        ``name=dir`` picks one. An entry that IS an existing directory is
        always treated as bare, so checkpoint paths containing ``=`` are
        not misparsed."""
        import os

        from repro.checkpoint.manager import CheckpointManager
        adapters_by_name: Dict[str, Tree] = {}
        cfg_by_name: Dict[str, peft_lib.PEFTConfig] = {}
        for entry in entries:
            if os.path.isdir(entry) or "=" not in entry:
                name, path = "", entry
            else:
                # split at the FIRST '=': adapter names never contain '=',
                # checkpoint paths may
                name, _, path = entry.partition("=")
            loaded, cfgs = CheckpointManager(path).restore_adapters()
            if name:      # name=dir form: pick one adapter out of the bank
                if name not in loaded:
                    raise KeyError(f"{path} has adapters {list(loaded)}, "
                                   f"not {name!r}")
                loaded = {name: loaded[name]}
            for n in loaded:
                prev = cfg_by_name.get(n)
                if prev is not None and prev != cfgs[n]:
                    raise ValueError(f"adapter {n!r} ({entry}): PEFTConfig "
                                     f"mismatch ({cfgs[n]} != {prev})")
                cfg_by_name[n] = cfgs[n]
            adapters_by_name.update(loaded)
        if not cfg_by_name:
            raise ValueError("no adapter checkpoints given")
        if len(set(cfg_by_name.values())) == 1:   # frozen -> hashable
            return adapters_by_name, next(iter(cfg_by_name.values()))
        return adapters_by_name, cfg_by_name

    # -- family ops / state ---------------------------------------------------
    def init_decode_state(self, batch: int, max_len: int, enc_len: int = 0):
        return self._ops.init_decode_state(self.cfg, batch, max_len, enc_len)

    def active_param_count(self) -> int:
        return self._ops.active_param_count(self.cfg)

    # -- unjitted step builders (dry-run lowering with custom shardings) ------
    def build_prefill(self, batch_divisible: bool = True):
        from repro.train.steps import build_prefill_step
        return build_prefill_step(self.cfg, self.mesh, batch_divisible)

    def build_decode(self, batch_divisible: bool = True):
        from repro.train.steps import build_decode_step
        return build_decode_step(self.cfg, self.mesh, batch_divisible)

    # -- jitted closures (lazy, cached on the runtime) ------------------------
    def prefill_fn(self):
        """jitted (params, PrefillRequest, state) -> (logits, state)."""
        if self._prefill is None:
            self._prefill = jax.jit(self.build_prefill())
        return self._prefill

    def decode_fn(self):
        """jitted (params, ctx, tokens, state, pos) ->
        (next_tok, logits, state); ``state`` is donated."""
        if self._decode is None:
            self._decode = jax.jit(self.build_decode(), donate_argnums=(3,))
        return self._decode

    def slot_prefill_fn(self, max_len: int, enc_len: int = 0):
        """jitted (params, PrefillRequest, state, slot) -> (first, state);
        ``state`` is donated. Cached per (max_len, enc_len) geometry."""
        key = (max_len, enc_len)
        if key not in self._slot_prefill:
            from repro.train.steps import build_slot_prefill_step
            self._slot_prefill[key] = jax.jit(
                build_slot_prefill_step(self.cfg, self.mesh, max_len=max_len,
                                        enc_len=enc_len),
                donate_argnums=(2,))
        return self._slot_prefill[key]

    def loss_fn(self):
        """jitted (params, batch) -> (loss, metrics)."""
        if self._loss is None:
            cfg, shard = self.cfg, self._shard()
            fam = self._ops
            self._loss = jax.jit(
                lambda params, batch: fam.loss(cfg, params, batch, shard))
        return self._loss

    def loss(self, batch):
        return self.loss_fn()(self.params, batch)

    def prefill(self, req: peft_lib.PrefillRequest, state):
        return self.prefill_fn()(self.params, req, state)

    def decode(self, tokens, state, pos,
               ctx: Optional[peft_lib.AdapterContext] = None):
        return self.decode_fn()(self.params, ctx, tokens, state, pos)

    def _shard(self):
        if self.mesh is None:
            from repro.models.layers import no_shard
            return no_shard
        from repro.sharding.specs import ShardingRules
        return ShardingRules(self.cfg, self.mesh).make_sharder()
