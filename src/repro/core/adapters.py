"""PEFT adapters: GSOFT / Double GSOFT (the paper), plus the baselines it
compares against — OFT (block-diagonal), BOFT (block butterfly), LoRA.

All adapters are *functional*: an ``AdapterSpec`` (static dataclass) plus a
params pytree.  The framework applies them **weight-side**:

    W_eff = materialize(spec, params, W_frozen)

inside the jitted step — for orthogonal methods W_eff = Q @ W (Q acts on the
input dim, preserving the frozen weight's output geometry), for Double GSOFT
W_eff = Q_U @ W @ Q_V, for LoRA W_eff = W + (alpha/r) A B.  Identity init
guarantees W_eff == W at step 0.  ``merge`` bakes the adapter into the weight
for inference (zero overhead — paper §6.1).

Weights with leading batch dims (e.g. stacked MoE experts (E, d_in, d_out))
get independent adapters per batch element, vmapped.

Weight convention: W has shape (d_in, d_out), used as y = x @ W.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops

from . import gs
from .gs import BlockDiagSpec, GSLayout, block_diag_matmul, gsoft_layout, pick_block_size
from .orthogonal import cayley, skew
from .permutations import PermSpec, apply_perm

Array = jnp.ndarray
Params = Dict[str, Array]


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    """Static description of one adapter attached to one weight."""
    method: str                    # gsoft | double_gsoft | oft | boft | lora
    d_in: int
    d_out: int
    block_size: int = 32           # orthogonal methods (input side)
    block_size_out: int = 0        # double_gsoft output side (0 -> same rule)
    rank: int = 8                  # lora
    alpha: float = 16.0            # lora scaling
    boft_factors: int = 2          # BOFT m
    neumann_order: Optional[int] = None   # approximate Cayley (perf option)
    use_scale: bool = False        # learnable per-output magnitude
    use_pallas: bool = False       # GS rotations via the Pallas kernel path
    # leading batch dims of the weight (scan-stacked layers, MoE experts, ...)
    batch: Tuple[int, ...] = ()

    def resolved_block(self, d: int, b: int) -> int:
        return b if d % b == 0 and (d // b) <= b else pick_block_size(d, b)


# ---------------------------------------------------------------------------
# BOFT butterfly permutations
# ---------------------------------------------------------------------------

def butterfly_sigma(d: int, b: int, level: int) -> np.ndarray:
    """Gather order for BOFT butterfly level (1-indexed).

    Half-blocks of size b/2 are paired at half-block stride 2^(level-1):
    level 1 groups contiguous blocks; deeper levels pair at doubling
    distance, reaching density at m = 1 + log2(d/b) (BOFT's bound).
    """
    if b % 2 and level > 1:
        raise ValueError("BOFT butterfly needs even block size")
    h = b // 2 if b > 1 else 1
    nh = d // h
    s = 2 ** (level - 1)
    if nh % (2 * s):
        raise ValueError(f"butterfly level {level} invalid for d={d}, b={b}: "
                         f"{nh} half-blocks not divisible by {2 * s}")
    order = []
    for base in range(0, nh, 2 * s):
        for off in range(s):
            p1, p2 = base + off, base + off + s
            order.extend(range(p1 * h, (p1 + 1) * h))
            order.extend(range(p2 * h, (p2 + 1) * h))
    return np.asarray(order)


def max_butterfly_levels(d: int, b: int) -> int:
    """Deepest valid level: level l tiles the d/(b/2) half-blocks into
    groups of 2^l, so it needs 2^l | num_half_blocks (hypothesis-found edge:
    r not a power of two caps the depth)."""
    nh = d // max(b // 2, 1)
    lvl = 0
    while nh % (2 ** (lvl + 1)) == 0 and 2 ** (lvl + 1) <= nh:
        lvl += 1
    return max(1, lvl)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _maybe_batch(shape: Tuple[int, ...], batch: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(batch) + shape


def init_adapter(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    """Initialize adapter params. Orthogonal methods start at Q = I (K = 0);
    LoRA starts at A ~ N, B = 0. Either way W_eff(init) == W."""
    p: Params = {}
    if spec.method in ("gsoft", "double_gsoft"):
        b_in = spec.resolved_block(spec.d_in, spec.block_size)
        lay = gsoft_layout(spec.d_in, b_in)
        p["L"] = jnp.zeros(_maybe_batch(lay.lspec.param_shape, spec.batch), dtype)
        p["R"] = jnp.zeros(_maybe_batch(lay.rspec.param_shape, spec.batch), dtype)
        if spec.method == "double_gsoft":
            b_out = spec.resolved_block(spec.d_out,
                                        spec.block_size_out or spec.block_size)
            lay_v = gsoft_layout(spec.d_out, b_out)
            p["L_v"] = jnp.zeros(_maybe_batch(lay_v.lspec.param_shape, spec.batch), dtype)
            p["R_v"] = jnp.zeros(_maybe_batch(lay_v.rspec.param_shape, spec.batch), dtype)
    elif spec.method == "oft":
        b = spec.resolved_block(spec.d_in, spec.block_size)
        r = spec.d_in // b
        p["K"] = jnp.zeros(_maybe_batch((r, b, b), spec.batch), dtype)
    elif spec.method == "boft":
        b = spec.resolved_block(spec.d_in, spec.block_size)
        m = min(spec.boft_factors, max_butterfly_levels(spec.d_in, b))
        r = spec.d_in // b
        p["K"] = jnp.zeros(_maybe_batch((m, r, b, b), spec.batch), dtype)
    elif spec.method == "lora":
        ka, _ = jax.random.split(key)
        a = jax.random.normal(ka, _maybe_batch((spec.d_in, spec.rank), spec.batch),
                              dtype) * (1.0 / math.sqrt(spec.d_in))
        p["A"] = a
        p["B"] = jnp.zeros(_maybe_batch((spec.rank, spec.d_out), spec.batch), dtype)
    else:
        raise ValueError(f"unknown adapter method {spec.method}")
    if spec.use_scale:
        p["scale"] = jnp.ones(_maybe_batch((spec.d_out,), spec.batch), dtype)
    return p


def num_adapter_params(spec: AdapterSpec) -> int:
    p = init_adapter(spec, jax.random.PRNGKey(0))
    return sum(int(np.prod(v.shape)) for v in p.values())


# ---------------------------------------------------------------------------
# materialization (weight-side application)
# ---------------------------------------------------------------------------

def _gs_rotate(d: int, b: int, L_k: Array, R_k: Array, W: Array,
               neumann: Optional[int], transpose_side: bool,
               use_pallas: bool = False) -> Array:
    """Apply Q = P^T L P R (orthogonal GS) to W.

    transpose_side=False:  Q @ W    (Q on rows / input dim)
    transpose_side=True:   W @ Q    (Q on columns / output dim)

    use_pallas routes the rotation through the fused GS kernels (forward
    AND backward via their custom-VJP rules); the columns/rows of W play
    the token role on the kernel's lane axis.

    Perf (§Perf iteration A): the Cayley solve stays fp32 but the rotated
    blocks are cast to W's dtype before the block matmuls — bf16 weights
    rotate in bf16, halving the weight-sized HBM traffic of the
    materialization. Orthogonality error at bf16 is ~1e-2 relative
    (benchmarks/micro_gs.py) on blocks whose product preserves norms.
    """
    lay = gsoft_layout(d, b)
    L = cayley(skew(L_k), neumann_order=neumann).astype(W.dtype)
    R = cayley(skew(R_k), neumann_order=neumann).astype(W.dtype)
    if transpose_side:
        if use_pallas:
            return kernel_ops.gs_transform_T(L, R, W, use_pallas=True)
        return gs.gs_apply_T(lay, L, R, W)       # rows w -> w^T Q, i.e. W @ Q
    if use_pallas:
        WT = jnp.swapaxes(W, -1, -2)             # columns of W as "tokens"
        return jnp.swapaxes(kernel_ops.gs_transform(L, R, WT,
                                                    use_pallas=True), -1, -2)
    return gs.gs_matmul(lay, L, R, W)            # Q @ W


def _oft_rotate(K: Array, W: Array, neumann: Optional[int]) -> Array:
    """Block-diagonal orthogonal Q @ W (OFT)."""
    Q = cayley(skew(K), neumann_order=neumann)
    WT = jnp.swapaxes(W, -1, -2)                 # (d_out, d_in)
    return jnp.swapaxes(block_diag_matmul(Q, WT), -1, -2)


def _boft_rotate(K: Array, d: int, b: int, W: Array,
                 neumann: Optional[int]) -> Array:
    """Q = B_m .. B_1 with butterfly factors; returns Q @ W."""
    m = K.shape[0]
    Q = cayley(skew(K), neumann_order=neumann)   # (m, r, b, b)
    WT = jnp.swapaxes(W, -1, -2)                 # columns of W as vectors
    y = WT
    for lvl in range(m):
        sig = butterfly_sigma(d, b, lvl + 1)
        spec_p = PermSpec.from_sigma(sig)
        y = apply_perm(y, spec_p)                # group
        y = block_diag_matmul(Q[lvl], y)         # rotate
        y = apply_perm(y, spec_p.inverse())      # scatter back
    return jnp.swapaxes(y, -1, -2)


def materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """W_eff from frozen W + adapter params. Differentiable w.r.t. params."""
    if spec.batch:
        inner = dataclasses.replace(spec, batch=tuple(spec.batch[1:]))
        fn = lambda p, w: materialize(inner, p, w)
        return jax.vmap(fn)(params, W)

    dtype = W.dtype
    Wf = W
    if spec.method == "gsoft":
        b = spec.resolved_block(spec.d_in, spec.block_size)
        Wf = _gs_rotate(spec.d_in, b, params["L"], params["R"], Wf,
                        spec.neumann_order, transpose_side=False,
                        use_pallas=spec.use_pallas)
    elif spec.method == "double_gsoft":
        b_in = spec.resolved_block(spec.d_in, spec.block_size)
        Wf = _gs_rotate(spec.d_in, b_in, params["L"], params["R"], Wf,
                        spec.neumann_order, transpose_side=False,
                        use_pallas=spec.use_pallas)
        b_out = spec.resolved_block(spec.d_out,
                                    spec.block_size_out or spec.block_size)
        Wf = _gs_rotate(spec.d_out, b_out, params["L_v"], params["R_v"], Wf,
                        spec.neumann_order, transpose_side=True,
                        use_pallas=spec.use_pallas)
    elif spec.method == "oft":
        Wf = _oft_rotate(params["K"], Wf, spec.neumann_order)
    elif spec.method == "boft":
        b = spec.resolved_block(spec.d_in, spec.block_size)
        Wf = _boft_rotate(params["K"], spec.d_in, b, Wf, spec.neumann_order)
    elif spec.method == "lora":
        scale = spec.alpha / spec.rank
        Wf = Wf + scale * (params["A"] @ params["B"]).astype(dtype)
    else:
        raise ValueError(spec.method)
    if spec.use_scale:
        Wf = Wf * params["scale"][None, :].astype(dtype)
    return Wf.astype(dtype)


def merge(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """Bake the adapter into the weight (inference; no runtime overhead)."""
    return materialize(spec, params, W)


# ---------------------------------------------------------------------------
# activation-side application (config option; wins when tokens << d_out)
# ---------------------------------------------------------------------------

def apply_activation_side(spec: AdapterSpec, params: Params, x: Array) -> Array:
    """For input-rotation methods, y = x @ (Q W) == (x Q) @ W: rotate the
    activations instead of the weight. Only valid for gsoft/oft/boft."""
    if spec.method == "gsoft":
        b = spec.resolved_block(spec.d_in, spec.block_size)
        lay = gsoft_layout(spec.d_in, b)
        L = cayley(skew(params["L"]), neumann_order=spec.neumann_order)
        R = cayley(skew(params["R"]), neumann_order=spec.neumann_order)
        # x Q = (Q^T x^T)^T -> per-vector transpose application
        if spec.use_pallas:
            return kernel_ops.gs_transform_T(L, R, x, use_pallas=True)
        return gs.gs_apply_T(lay, L, R, x)
    if spec.method == "oft":
        Q = cayley(skew(params["K"]), neumann_order=spec.neumann_order)
        return block_diag_matmul(jnp.swapaxes(Q, -1, -2), x)
    raise ValueError(f"activation-side not defined for {spec.method}")


def gs_rotate_banked(L_rot: Array, R_rot: Array, ids: Array, x: Array,
                     use_pallas: bool = False) -> Array:
    """Per-row-indexed activation-side GSOFT: row i of x gets x_i Q_{ids[i]}.

    L_rot, R_rot: (A, r, b, b) PRE-ORTHOGONALIZED blocks (the Cayley map is
    applied once at bank-build time — adapters are frozen when serving),
    stacked over A bank slots; slot 0 is the identity. Any scan-stacked
    layer dims have already been sliced off by the model's layer scan.
    ids: (B,) int32 slot per batch row; x: (B, T, d).

    Cost is O(B*T*b*d) — the same per-token scaling argument that makes GS
    rotations serviceable per-request where a dense OFT rotation (O(d^2))
    would not be.
    """
    L = jnp.take(L_rot, ids, axis=0).astype(x.dtype)      # (B, r, b, b)
    R = jnp.take(R_rot, ids, axis=0).astype(x.dtype)
    return kernel_ops.gs_banked_transform_T(L, R, x, use_pallas=use_pallas)
