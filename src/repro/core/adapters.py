"""PEFT adapters: GSOFT / Double GSOFT (the paper), plus the classes it
unifies or compares against — OFT (block-diagonal), BOFT (block butterfly),
Householder products (HOFT), and LoRA.

All adapters are *functional*: an ``AdapterSpec`` (static dataclass) plus a
params pytree.  The framework applies them **weight-side**:

    W_eff = materialize(spec, params, W_frozen)

inside the jitted step — for orthogonal methods W_eff = Q @ W (Q acts on the
input dim, preserving the frozen weight's output geometry), for Double GSOFT
W_eff = Q_U @ W @ Q_V, for LoRA W_eff = W + (alpha/r) A B.  Identity init
guarantees W_eff == W at step 0.  ``merge`` bakes the adapter into the weight
for inference (zero overhead — paper §6.1).

Per-method behavior is defined by the implementation functions in this
module, *wired* by the ``MethodOps`` records in ``core.methods`` — the
public entry points below (``init_adapter`` / ``materialize`` / ``merge`` /
``apply_activation_side`` / ``num_adapter_params``) dispatch exclusively
through that registry; an unknown method raises a ``KeyError`` naming what
is registered.

Weights with leading batch dims (e.g. stacked MoE experts (E, d_in, d_out))
get independent adapters per batch element, vmapped.

Weight convention: W has shape (d_in, d_out), used as y = x @ W.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kernel_ops

from . import gs
from .gs import gsoft_layout, pick_block_size
from .orthogonal import cayley, skew
from .permutations import PermSpec, apply_perm

Array = jnp.ndarray
Params = Dict[str, Array]


# ---------------------------------------------------------------------------
# spec
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AdapterSpec:
    """Static description of one adapter attached to one weight."""
    method: str                    # any name registered in core.methods
    d_in: int
    d_out: int
    block_size: int = 32           # orthogonal methods (input side)
    block_size_out: int = 0        # double_gsoft output side (0 -> same rule)
    rank: int = 8                  # lora
    alpha: float = 16.0            # lora scaling
    boft_factors: int = 2          # BOFT m
    reflections: int = 4           # householder factor count (even)
    givens_rounds: int = 4         # givens brick-wall round count
    neumann_order: Optional[int] = None   # approximate Cayley (perf option)
    use_scale: bool = False        # learnable per-output magnitude
    use_pallas: bool = False       # GS rotations via the Pallas kernel path
    # leading batch dims of the weight (scan-stacked layers, MoE experts, ...)
    batch: Tuple[int, ...] = ()

    def resolved_block(self, d: int, b: int) -> int:
        return b if d % b == 0 and (d // b) <= b else pick_block_size(d, b)


# ---------------------------------------------------------------------------
# BOFT butterfly permutations
# ---------------------------------------------------------------------------

def butterfly_sigma(d: int, b: int, level: int) -> np.ndarray:
    """Gather order for BOFT butterfly level (1-indexed).

    Half-blocks of size b/2 are paired at half-block stride 2^(level-1):
    level 1 groups contiguous blocks; deeper levels pair at doubling
    distance, reaching density at m = 1 + log2(d/b) (BOFT's bound).
    """
    if b % 2 and level > 1:
        raise ValueError("BOFT butterfly needs even block size")
    h = b // 2 if b > 1 else 1
    nh = d // h
    s = 2 ** (level - 1)
    if nh % (2 * s):
        raise ValueError(f"butterfly level {level} invalid for d={d}, b={b}: "
                         f"{nh} half-blocks not divisible by {2 * s}")
    order = []
    for base in range(0, nh, 2 * s):
        for off in range(s):
            p1, p2 = base + off, base + off + s
            order.extend(range(p1 * h, (p1 + 1) * h))
            order.extend(range(p2 * h, (p2 + 1) * h))
    return np.asarray(order)


def max_butterfly_levels(d: int, b: int) -> int:
    """Deepest valid level: level l tiles the d/(b/2) half-blocks into
    groups of 2^l, so it needs 2^l | num_half_blocks (hypothesis-found edge:
    r not a power of two caps the depth)."""
    nh = d // max(b // 2, 1)
    lvl = 0
    while nh % (2 ** (lvl + 1)) == 0 and 2 ** (lvl + 1) <= nh:
        lvl += 1
    return max(1, lvl)


def _boft_depth(spec: AdapterSpec, b: int) -> int:
    return min(spec.boft_factors, max_butterfly_levels(spec.d_in, b))


# ---------------------------------------------------------------------------
# shared helpers
# ---------------------------------------------------------------------------

def _maybe_batch(shape: Tuple[int, ...], batch: Tuple[int, ...]) -> Tuple[int, ...]:
    return tuple(batch) + shape


def _stack_slots(spec: AdapterSpec, identity: Params, processed) -> Params:
    """Stack [per-slot factors] along a new A axis placed after any
    scan-stacked weight batch dims (so the model's layer scan slices the
    bank alongside the weights). ``processed``: list of Params-or-None
    (None -> this method's identity, i.e. the slot belongs to the base
    model or to an adapter of a different method)."""
    axis = len(spec.batch)
    out: Params = {}
    for key, ident in identity.items():
        out[key] = jnp.stack([ident if p is None else p[key]
                              for p in processed], axis=axis)
    return out


# ---------------------------------------------------------------------------
# GSOFT  (Q = P^T L P R — the paper's two-factor GS rotation)
# ---------------------------------------------------------------------------

def _gs_rotate(d: int, b: int, L_k: Array, R_k: Array, W: Array,
               neumann: Optional[int], transpose_side: bool,
               use_pallas: bool = False) -> Array:
    """Apply Q = P^T L P R (orthogonal GS) to W.

    transpose_side=False:  Q @ W    (Q on rows / input dim)
    transpose_side=True:   W @ Q    (Q on columns / output dim)

    use_pallas routes the rotation through the fused GS kernels (forward
    AND backward via their custom-VJP rules); the columns/rows of W play
    the token role on the kernel's lane axis.

    Perf (§Perf iteration A): the Cayley solve stays fp32 but the rotated
    blocks are cast to W's dtype before the block matmuls — bf16 weights
    rotate in bf16, halving the weight-sized HBM traffic of the
    materialization. Orthogonality error at bf16 is ~1e-2 relative
    (benchmarks/micro_gs.py) on blocks whose product preserves norms.
    """
    lay = gsoft_layout(d, b)
    L = cayley(skew(L_k), neumann_order=neumann).astype(W.dtype)
    R = cayley(skew(R_k), neumann_order=neumann).astype(W.dtype)
    if transpose_side:
        if use_pallas:
            return kernel_ops.gs_transform_T(L, R, W, use_pallas=True)
        return gs.gs_apply_T(lay, L, R, W)       # rows w -> w^T Q, i.e. W @ Q
    if use_pallas:
        WT = jnp.swapaxes(W, -1, -2)             # columns of W as "tokens"
        return jnp.swapaxes(kernel_ops.gs_transform(L, R, WT,
                                                    use_pallas=True), -1, -2)
    return gs.gs_matmul(lay, L, R, W)            # Q @ W


def gsoft_init(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    del key  # orthogonal methods start at Q = I (K = 0)
    b_in = spec.resolved_block(spec.d_in, spec.block_size)
    lay = gsoft_layout(spec.d_in, b_in)
    return {"L": jnp.zeros(_maybe_batch(lay.lspec.param_shape, spec.batch), dtype),
            "R": jnp.zeros(_maybe_batch(lay.rspec.param_shape, spec.batch), dtype)}


def gsoft_materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    b = spec.resolved_block(spec.d_in, spec.block_size)
    return _gs_rotate(spec.d_in, b, params["L"], params["R"], W,
                      spec.neumann_order, transpose_side=False,
                      use_pallas=spec.use_pallas)


def gsoft_apply_T(spec: AdapterSpec, params: Params, x: Array) -> Array:
    """x -> x Q = (Q^T x^T)^T: rotate the activations instead of the weight."""
    b = spec.resolved_block(spec.d_in, spec.block_size)
    lay = gsoft_layout(spec.d_in, b)
    L = cayley(skew(params["L"]), neumann_order=spec.neumann_order)
    R = cayley(skew(params["R"]), neumann_order=spec.neumann_order)
    if spec.use_pallas:
        return kernel_ops.gs_transform_T(L, R, x, use_pallas=True)
    return gs.gs_apply_T(lay, L, R, x)


def gsoft_param_count(spec: AdapterSpec) -> int:
    b = spec.resolved_block(spec.d_in, spec.block_size)
    return 2 * (spec.d_in // b) * b * b


def gsoft_bank_build(spec: AdapterSpec, params_by_slot) -> Params:
    """{"L": (..., A, r, b, b), "R": ...} of PRE-ORTHOGONALIZED blocks (the
    Cayley map runs once at build time — adapters are frozen when serving)."""
    b = spec.resolved_block(spec.d_in, spec.block_size)
    lay = gsoft_layout(spec.d_in, b)
    eye = jnp.broadcast_to(jnp.eye(b, dtype=jnp.float32),
                           _maybe_batch(lay.lspec.param_shape, spec.batch))
    processed = [None if p is None else
                 {k: cayley(skew(p[k].astype(jnp.float32)),
                            neumann_order=spec.neumann_order)
                  for k in ("L", "R")}
                 for p in params_by_slot]
    return _stack_slots(spec, {"L": eye, "R": eye}, processed)


def gsoft_bank_shard_axes(factor: str, shape) -> "int | None":
    """Serve-time TP hook (``MethodOps.bank_shard_axes``): a GSOFT bank
    stack {"L"/"R": (..., A, r, b, b)} may split its BLOCK axis r over the
    mesh 'model' axis — the per-row gather (``jnp.take`` over A) and the
    blockwise transform are both elementwise in r, so the split needs no
    collectives until the (already TP-sharded) base matmul. Only worth it
    for banks that outgrow replication (thousands of resident slots)."""
    if factor in ("L", "R") and len(shape) >= 4:
        return len(shape) - 3            # ...the r (block) axis
    return None


def gs_rotate_banked(entry: Params, ids: Array, x: Array,
                     use_pallas: bool = False) -> Array:
    """Per-row-indexed activation-side GSOFT: row i of x gets x_i Q_{ids[i]}.

    ``entry``: a ``gsoft_bank_build`` stack — {"L": (A, r, b, b), "R": ...}
    pre-orthogonalized blocks over A bank slots; slot 0 is the identity.
    Any scan-stacked layer dims have already been sliced off by the model's
    layer scan. ids: (B,) int32 slot per batch row; x: (B, T, d).

    Cost is O(B*T*b*d) — the same per-token scaling argument that makes GS
    rotations serviceable per-request where a dense OFT rotation (O(d^2))
    would not be.
    """
    L = jnp.take(entry["L"], ids, axis=0).astype(x.dtype)      # (B, r, b, b)
    R = jnp.take(entry["R"], ids, axis=0).astype(x.dtype)
    return kernel_ops.gs_banked_transform_T(L, R, x, use_pallas=use_pallas)


def gsoft_quant_fuse(entry: Params, ids: Array, dtype) -> Tuple[Array, Array]:
    """Per-row (L, R) blocks in ``dtype`` for the fused rotate + quantized
    matmul kernel (``ops.gs_q_matmul_banked`` — rotations stay bf16 over
    int8 base weights, QOFT rationale in DESIGN.md)."""
    L = jnp.take(entry["L"], ids, axis=0).astype(dtype)
    R = jnp.take(entry["R"], ids, axis=0).astype(dtype)
    return L, R


# ---------------------------------------------------------------------------
# Double GSOFT  (W_eff = Q_U W Q_V)
# ---------------------------------------------------------------------------

def double_gsoft_init(spec: AdapterSpec, key: jax.Array,
                      dtype=jnp.float32) -> Params:
    p = gsoft_init(spec, key, dtype)
    b_out = spec.resolved_block(spec.d_out,
                                spec.block_size_out or spec.block_size)
    lay_v = gsoft_layout(spec.d_out, b_out)
    p["L_v"] = jnp.zeros(_maybe_batch(lay_v.lspec.param_shape, spec.batch), dtype)
    p["R_v"] = jnp.zeros(_maybe_batch(lay_v.rspec.param_shape, spec.batch), dtype)
    return p


def double_gsoft_materialize(spec: AdapterSpec, params: Params,
                             W: Array) -> Array:
    b_in = spec.resolved_block(spec.d_in, spec.block_size)
    Wf = _gs_rotate(spec.d_in, b_in, params["L"], params["R"], W,
                    spec.neumann_order, transpose_side=False,
                    use_pallas=spec.use_pallas)
    b_out = spec.resolved_block(spec.d_out,
                                spec.block_size_out or spec.block_size)
    return _gs_rotate(spec.d_out, b_out, params["L_v"], params["R_v"], Wf,
                      spec.neumann_order, transpose_side=True,
                      use_pallas=spec.use_pallas)


def double_gsoft_param_count(spec: AdapterSpec) -> int:
    b_out = spec.resolved_block(spec.d_out,
                                spec.block_size_out or spec.block_size)
    return gsoft_param_count(spec) + 2 * (spec.d_out // b_out) * b_out * b_out


# ---------------------------------------------------------------------------
# OFT  (block-diagonal Q)
# ---------------------------------------------------------------------------

def oft_init(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    del key
    b = spec.resolved_block(spec.d_in, spec.block_size)
    r = spec.d_in // b
    return {"K": jnp.zeros(_maybe_batch((r, b, b), spec.batch), dtype)}


def oft_materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """Block-diagonal orthogonal Q @ W (OFT)."""
    Q = cayley(skew(params["K"]), neumann_order=spec.neumann_order)
    WT = jnp.swapaxes(W, -1, -2)                 # (d_out, d_in)
    return jnp.swapaxes(gs.block_diag_matmul(Q, WT), -1, -2)


def oft_apply_T(spec: AdapterSpec, params: Params, x: Array) -> Array:
    Q = cayley(skew(params["K"]), neumann_order=spec.neumann_order)
    return gs.block_diag_matmul(jnp.swapaxes(Q, -1, -2), x)


def oft_param_count(spec: AdapterSpec) -> int:
    b = spec.resolved_block(spec.d_in, spec.block_size)
    return (spec.d_in // b) * b * b


def oft_bank_build(spec: AdapterSpec, params_by_slot) -> Params:
    b = spec.resolved_block(spec.d_in, spec.block_size)
    r = spec.d_in // b
    eye = jnp.broadcast_to(jnp.eye(b, dtype=jnp.float32),
                           _maybe_batch((r, b, b), spec.batch))
    processed = [None if p is None else
                 {"Q": cayley(skew(p["K"].astype(jnp.float32)),
                              neumann_order=spec.neumann_order)}
                 for p in params_by_slot]
    return _stack_slots(spec, {"Q": eye}, processed)


def oft_rotate_banked(entry: Params, ids: Array, x: Array,
                      use_pallas: bool = False) -> Array:
    """Per-row x_i Q_{ids[i]} for block-diagonal Q: a banked bdmm with the
    per-row blocks transposed (row-vector application). Pallas path =
    the vmapped bdmm kernel (``dispatch.bdmm_key``)."""
    Q = jnp.take(entry["Q"], ids, axis=0).astype(x.dtype)      # (B, r, b, b)
    return kernel_ops.bdmm_banked(jnp.swapaxes(Q, -1, -2), x,
                                  use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# BOFT  (butterfly product Q = B_m .. B_1)
# ---------------------------------------------------------------------------

def boft_init(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    del key
    b = spec.resolved_block(spec.d_in, spec.block_size)
    m = _boft_depth(spec, b)
    r = spec.d_in // b
    return {"K": jnp.zeros(_maybe_batch((m, r, b, b), spec.batch), dtype)}


def boft_materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """Q = B_m .. B_1 with butterfly factors; returns Q @ W."""
    b = spec.resolved_block(spec.d_in, spec.block_size)
    d = spec.d_in
    m = params["K"].shape[0]
    Q = cayley(skew(params["K"]), neumann_order=spec.neumann_order)
    WT = jnp.swapaxes(W, -1, -2)                 # columns of W as vectors
    y = WT
    for lvl in range(m):
        sig = butterfly_sigma(d, b, lvl + 1)
        spec_p = PermSpec.from_sigma(sig)
        y = apply_perm(y, spec_p)                # group
        y = gs.block_diag_matmul(Q[lvl], y)      # rotate
        y = apply_perm(y, spec_p.inverse())      # scatter back
    return jnp.swapaxes(y, -1, -2)


def boft_apply_T(spec: AdapterSpec, params: Params, x: Array) -> Array:
    """x -> x Q = (Q^T x^T)^T: levels in reverse order, blocks transposed."""
    b = spec.resolved_block(spec.d_in, spec.block_size)
    m = params["K"].shape[0]
    Q = cayley(skew(params["K"]), neumann_order=spec.neumann_order)
    y = x
    for lvl in reversed(range(m)):
        sig = butterfly_sigma(spec.d_in, b, lvl + 1)
        spec_p = PermSpec.from_sigma(sig)
        y = apply_perm(y, spec_p)
        y = gs.block_diag_matmul(jnp.swapaxes(Q[lvl], -1, -2), y)
        y = apply_perm(y, spec_p.inverse())
    return y


def boft_param_count(spec: AdapterSpec) -> int:
    b = spec.resolved_block(spec.d_in, spec.block_size)
    return _boft_depth(spec, b) * (spec.d_in // b) * b * b


def boft_bank_build(spec: AdapterSpec, params_by_slot) -> Params:
    b = spec.resolved_block(spec.d_in, spec.block_size)
    m = _boft_depth(spec, b)
    r = spec.d_in // b
    eye = jnp.broadcast_to(jnp.eye(b, dtype=jnp.float32),
                           _maybe_batch((m, r, b, b), spec.batch))
    processed = [None if p is None else
                 {"Q": cayley(skew(p["K"].astype(jnp.float32)),
                              neumann_order=spec.neumann_order)}
                 for p in params_by_slot]
    return _stack_slots(spec, {"Q": eye}, processed)


def boft_rotate_banked(entry: Params, ids: Array, x: Array,
                       use_pallas: bool = False) -> Array:
    """Per-row x_i Q_{ids[i]} for butterfly Q: per level, a static butterfly
    permutation sandwiching a banked bdmm (levels reversed, blocks
    transposed — the row-vector application). The block matmuls ride the
    vmapped bdmm Pallas kernel; the permutations are free gathers."""
    Q = jnp.take(entry["Q"], ids, axis=0).astype(x.dtype)  # (B, m, r, b, b)
    m, b = Q.shape[1], Q.shape[-1]
    d = x.shape[-1]
    y = x
    for lvl in reversed(range(m)):
        sig = butterfly_sigma(d, b, lvl + 1)
        spec_p = PermSpec.from_sigma(sig)
        y = apply_perm(y, spec_p)
        y = kernel_ops.bdmm_banked(jnp.swapaxes(Q[:, lvl], -1, -2), y,
                                   use_pallas=use_pallas)
        y = apply_perm(y, spec_p.inverse())
    return y


# ---------------------------------------------------------------------------
# Householder products  (HOFT: Q = H_1 .. H_k,  H_i = I - 2 v_i v_i^T)
# ---------------------------------------------------------------------------

def _hh_reflections(spec: AdapterSpec) -> int:
    k = spec.reflections
    if k <= 0 or k % 2:
        raise ValueError(
            f"householder needs a positive EVEN reflection count (identity "
            f"init is a product of paired reflections); got {k}")
    return k


def _hh_identity(spec: AdapterSpec, k: int) -> Array:
    """Reflection vectors whose product is exactly I: k (even) copies of
    e_1 — H(e_1)^2 = I with no rounding (each application negates one
    coordinate slab exactly)."""
    v = jnp.zeros(_maybe_batch((k, spec.d_in), spec.batch), jnp.float32)
    return v.at[..., 0].set(1.0)


def _hh_unit(v: Array) -> Array:
    """Safe fp32 unit vectors over the last axis. A (near-)zero vector
    falls back to e_1 so H stays EXACTLY orthogonal for every parameter
    value — the method never leaves the orthogonal group."""
    v32 = v.astype(jnp.float32)
    n2 = jnp.sum(v32 * v32, axis=-1, keepdims=True)
    e0 = jnp.zeros_like(v32).at[..., :1].set(1.0)
    v32 = jnp.where(n2 > 1e-12, v32, e0)
    return v32 * jax.lax.rsqrt(jnp.sum(v32 * v32, axis=-1, keepdims=True))


def householder_init(spec: AdapterSpec, key: jax.Array,
                     dtype=jnp.float32) -> Params:
    del key
    k = _hh_reflections(spec)
    return {"V": _hh_identity(spec, k).astype(dtype)}


def householder_materialize(spec: AdapterSpec, params: Params,
                            W: Array) -> Array:
    """Q @ W applied reflection by reflection: H W = W - 2 v (v^T W), no
    dense Q ever materializes — O(k d n) total, and d_in needs NO block
    divisibility (Householder's selling point over blocked classes)."""
    k = _hh_reflections(spec)
    Vu = _hh_unit(params["V"]).astype(W.dtype)           # (k, d)
    Wf = W
    for i in reversed(range(k)):                         # Q W = H_1(..H_k W)
        v = Vu[i]
        Wf = Wf - 2.0 * jnp.outer(v, v @ Wf)
    return Wf


def householder_apply_T(spec: AdapterSpec, params: Params, x: Array) -> Array:
    """x -> x Q = ((x H_1) H_2).. H_k;  x H = x - 2 (x.v) v."""
    k = _hh_reflections(spec)
    Vu = _hh_unit(params["V"])
    y = x
    for i in range(k):
        v = Vu[i].astype(x.dtype)
        y = y - 2.0 * (y @ v)[..., None] * v
    return y


def householder_param_count(spec: AdapterSpec) -> int:
    return _hh_reflections(spec) * spec.d_in


def householder_bank_build(spec: AdapterSpec, params_by_slot) -> Params:
    """{"V": (..., A, k, d)} PRE-NORMALIZED unit reflection vectors; the
    identity slot holds k copies of e_1 (product = I exactly)."""
    k = _hh_reflections(spec)
    ident = _hh_identity(spec, k)
    processed = [None if p is None else {"V": _hh_unit(p["V"])}
                 for p in params_by_slot]
    return _stack_slots(spec, {"V": ident}, processed)


def householder_rotate_banked(entry: Params, ids: Array, x: Array,
                              use_pallas: bool = False) -> Array:
    """Per-row x_i Q_{ids[i]} for Householder products. No dedicated Pallas
    kernel exists (the op is O(k d) per token, bandwidth-trivial next to
    the projection matmul) — ``ops.householder_banked`` is the reference
    einsum fallback on every backend."""
    V = jnp.take(entry["V"], ids, axis=0).astype(x.dtype)  # (B, k, d)
    return kernel_ops.householder_banked(V, x, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# Givens rounds  (GOFT: Q = G_m .. G_1, each G_l one brick-wall round of
# disjoint 2x2 plane rotations — quasi-Givens orthogonal fine-tuning,
# arXiv 2404.04316)
# ---------------------------------------------------------------------------

def _givens_num_rounds(spec: AdapterSpec) -> int:
    m = spec.givens_rounds
    if m <= 0:
        raise ValueError(f"givens needs a positive round count; got {m}")
    return m


def _givens_pairs(d: int, level: int) -> np.ndarray:
    """Left indices of round ``level``'s disjoint neighbor pairs (i, i+1).

    Brick-wall layout: even rounds pair (0,1),(2,3),..; odd rounds shift by
    one — (1,2),(3,4),.. — so two consecutive rounds couple every coordinate
    with both neighbors (odd-even transposition network). Boundary elements
    with no partner stay fixed, which also handles odd d."""
    off = level % 2
    return off + 2 * np.arange((d - off) // 2)


def _givens_apply(theta: Array, y: Array, transpose: bool) -> Array:
    """Apply Q = G_{m-1}..G_0 (or Q^T) to vectors on the last axis of y.

    theta: (m, d//2) angles — round l consumes its first ``len(pairs(l))``
    columns (odd rounds have one fewer pair; the tail is ignored and stays
    zero from init). Q^T = reversed rounds with negated angles. Rotations
    run in fp32 (angles are tiny; the cos/sin and pair updates are exact
    enough that Q stays orthogonal to fp32 roundoff for ANY theta — like
    Householder, the method never leaves the orthogonal group)."""
    m = theta.shape[0]
    d = y.shape[-1]
    t32 = theta.astype(jnp.float32)
    c_all, s_all = jnp.cos(t32), jnp.sin(t32)
    y32 = y.astype(jnp.float32)
    for lvl in (reversed(range(m)) if transpose else range(m)):
        ii = _givens_pairs(d, lvl)
        if ii.size == 0:
            continue
        c = c_all[lvl, :ii.size]
        s = -s_all[lvl, :ii.size] if transpose else s_all[lvl, :ii.size]
        a, b = y32[..., ii], y32[..., ii + 1]
        y32 = y32.at[..., ii].set(c * a - s * b)
        y32 = y32.at[..., ii + 1].set(s * a + c * b)
    return y32.astype(y.dtype)


def givens_init(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    del key  # theta = 0 -> every round is I -> Q = I
    m = _givens_num_rounds(spec)
    return {"theta": jnp.zeros(
        _maybe_batch((m, spec.d_in // 2), spec.batch), dtype)}


def givens_materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """Q @ W round by round on the columns of W — O(m d n) total, no dense
    Q, and (like Householder) no block-divisibility constraint on d_in."""
    del spec
    WT = jnp.swapaxes(W, -1, -2)
    WT = _givens_apply(params["theta"], WT, transpose=False)
    return jnp.swapaxes(WT, -1, -2)


def givens_apply_T(spec: AdapterSpec, params: Params, x: Array) -> Array:
    """x -> x Q = (Q^T x^T)^T: rounds reversed, angles negated."""
    del spec
    return _givens_apply(params["theta"], x, transpose=True)


def givens_param_count(spec: AdapterSpec) -> int:
    return _givens_num_rounds(spec) * (spec.d_in // 2)


def givens_bank_build(spec: AdapterSpec, params_by_slot) -> Params:
    """{"c"/"s": (..., A, m, d//2)} PRE-EVALUATED cos/sin stacks (the
    trig runs once at build time); the identity slot is c = 1, s = 0."""
    m = _givens_num_rounds(spec)
    p = spec.d_in // 2
    ident = {"c": jnp.ones(_maybe_batch((m, p), spec.batch), jnp.float32),
             "s": jnp.zeros(_maybe_batch((m, p), spec.batch), jnp.float32)}
    processed = [None if pr is None else
                 {"c": jnp.cos(pr["theta"].astype(jnp.float32)),
                  "s": jnp.sin(pr["theta"].astype(jnp.float32))}
                 for pr in params_by_slot]
    return _stack_slots(spec, ident, processed)


def givens_rotate_banked(entry: Params, ids: Array, x: Array,
                         use_pallas: bool = False) -> Array:
    """Per-row x_i Q_{ids[i]} for Givens rounds. Like Householder, the op
    is O(m d) per token — bandwidth-trivial — so ``ops.givens_banked`` is
    the reference implementation on every backend."""
    C = jnp.take(entry["c"], ids, axis=0)               # (B, m, p)
    S = jnp.take(entry["s"], ids, axis=0)
    return kernel_ops.givens_banked(C, S, x, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# LoRA  (low-rank residual — the non-orthogonal baseline)
# ---------------------------------------------------------------------------

def lora_init(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    import math
    ka, _ = jax.random.split(key)
    a = jax.random.normal(ka, _maybe_batch((spec.d_in, spec.rank), spec.batch),
                          dtype) * (1.0 / math.sqrt(spec.d_in))
    return {"A": a,
            "B": jnp.zeros(_maybe_batch((spec.rank, spec.d_out), spec.batch),
                           dtype)}


def lora_materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    scale = spec.alpha / spec.rank
    return W + scale * (params["A"] @ params["B"]).astype(W.dtype)


def lora_param_count(spec: AdapterSpec) -> int:
    return spec.rank * (spec.d_in + spec.d_out)


# ---------------------------------------------------------------------------
# public entry points — registry dispatch only (no method conditionals)
# ---------------------------------------------------------------------------

def init_adapter(spec: AdapterSpec, key: jax.Array, dtype=jnp.float32) -> Params:
    """Initialize adapter params. Orthogonal methods start at Q = I;
    LoRA starts at A ~ N, B = 0. Either way W_eff(init) == W."""
    from . import methods
    p = methods.get(spec.method).init_params(spec, key, dtype)
    if spec.use_scale:
        p["scale"] = jnp.ones(_maybe_batch((spec.d_out,), spec.batch), dtype)
    return p


def num_adapter_params(spec: AdapterSpec) -> int:
    from . import methods
    n = methods.get(spec.method).param_count(spec)
    if spec.use_scale:
        n += spec.d_out
    return n * int(np.prod(spec.batch)) if spec.batch else n


def materialize(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """W_eff from frozen W + adapter params. Differentiable w.r.t. params."""
    from . import methods
    if spec.batch:
        inner = dataclasses.replace(spec, batch=tuple(spec.batch[1:]))
        fn = lambda p, w: materialize(inner, p, w)
        return jax.vmap(fn)(params, W)
    dtype = W.dtype
    Wf = methods.get(spec.method).materialize(spec, params, W)
    if spec.use_scale:
        Wf = Wf * params["scale"][None, :].astype(dtype)
    return Wf.astype(dtype)


def merge(spec: AdapterSpec, params: Params, W: Array) -> Array:
    """Bake the adapter into the weight (inference; no runtime overhead)."""
    return materialize(spec, params, W)


def apply_activation_side(spec: AdapterSpec, params: Params, x: Array) -> Array:
    """For input-rotation methods, y = x @ (Q W) == (x Q) @ W: rotate the
    activations instead of the weight (wins when tokens << d_out)."""
    from . import methods
    ops = methods.get(spec.method)
    if ops.apply_activation_side is None:
        raise ValueError(f"activation-side not defined for {spec.method}")
    return ops.apply_activation_side(spec, params, x)
