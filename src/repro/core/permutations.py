"""Permutations used by Group-and-Shuffle (GS) matrices.

Conventions
-----------
A permutation is described by an index map ``sigma`` with the *gather*
semantics of the paper (Definition 5.2):

    y = P x   with   y[i] = x[sigma(i)],        P[i, sigma(i)] = 1.

The canonical GS shuffle ``P_(k, n)`` uses

    sigma(i) = (i mod k) * (n // k) + i // k,

which is exactly ``reshape(k, n/k) -> transpose -> reshape(n)`` applied to the
vector — on TPU this lowers to a relayout, never a gather, which is why GS
matrices are hardware-friendly.  The inverse of ``P_(k, n)`` is ``P_(n/k, n)``.

The "paired" variant (paper Appendix F) moves *pairs* of adjacent channels
together so that MaxMinPermuted activations and ChShuffle cooperate:

    sigma_paired(i) = (floor(i/2) mod k) * (n/k) + 2*floor(i/(2k)) + (i mod 2).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Sequence, Union

import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# sigma construction (static / numpy — these become compile-time constants)
# ---------------------------------------------------------------------------

def gs_sigma(k: int, n: int) -> np.ndarray:
    """Index map of ``P_(k, n)`` from Definition 5.2 (gather semantics)."""
    if n % k != 0:
        raise ValueError(f"P_(k,n) requires k | n, got k={k}, n={n}")
    i = np.arange(n)
    return (i % k) * (n // k) + i // k


def paired_sigma(k: int, n: int) -> np.ndarray:
    """Paired variant of ``P_(k, n)`` (paper App. F): shuffles channel *pairs*."""
    if n % (2 * k) != 0:
        raise ValueError(f"paired perm requires 2k | n, got k={k}, n={n}")
    i = np.arange(n)
    return ((i // 2) % k) * (n // k) + 2 * (i // (2 * k)) + (i % 2)


def inverse_sigma(sigma: np.ndarray) -> np.ndarray:
    """sigma^{-1}: if y = x[sigma] then x = y[inverse_sigma(sigma)]."""
    inv = np.empty_like(sigma)
    inv[sigma] = np.arange(sigma.shape[0])
    return inv


def compose_sigma(s1: np.ndarray, s2: np.ndarray) -> np.ndarray:
    """sigma of the matrix product ``P_{s1} @ P_{s2}``  (apply s2 first)."""
    return s2[s1]


def perm_matrix(sigma: np.ndarray, dtype=np.float32) -> np.ndarray:
    """Dense matrix P with P[i, sigma[i]] = 1 (for tests / materialization)."""
    return np.eye(sigma.shape[0], dtype=dtype)[sigma]


def is_permutation(sigma: np.ndarray) -> bool:
    return bool(np.all(np.sort(sigma) == np.arange(sigma.shape[0])))


# ---------------------------------------------------------------------------
# PermSpec — a jit-friendly symbolic description of a permutation
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PermSpec:
    """Symbolic permutation.

    kind:
      - "identity":  no-op
      - "gs":        P_(k, n)       (reshape/transpose fast path)
      - "gs_inv":    P_(k, n)^{-1}  = P_(n/k, n)
      - "paired":    paired GS shuffle (gather path; used in conv nets)
      - "paired_inv"
      - "index":     arbitrary sigma (gather path); ``table`` holds the array
    """
    kind: str
    k: int = 0
    table: Optional[tuple] = None  # hashable storage for "index" kind

    # -- constructors ------------------------------------------------------
    @staticmethod
    def identity() -> "PermSpec":
        return PermSpec("identity")

    @staticmethod
    def gs(k: int) -> "PermSpec":
        return PermSpec("gs", k=k)

    @staticmethod
    def gs_inv(k: int) -> "PermSpec":
        return PermSpec("gs_inv", k=k)

    @staticmethod
    def paired(k: int) -> "PermSpec":
        return PermSpec("paired", k=k)

    @staticmethod
    def from_sigma(sigma: np.ndarray) -> "PermSpec":
        return PermSpec("index", table=tuple(int(v) for v in sigma))

    # -- conversions -------------------------------------------------------
    def sigma(self, n: int) -> np.ndarray:
        """Materialize the index map for size-n vectors."""
        if self.kind == "identity":
            return np.arange(n)
        if self.kind == "gs":
            return gs_sigma(self.k, n)
        if self.kind == "gs_inv":
            return inverse_sigma(gs_sigma(self.k, n))
        if self.kind == "paired":
            return paired_sigma(self.k, n)
        if self.kind == "paired_inv":
            return inverse_sigma(paired_sigma(self.k, n))
        if self.kind == "index":
            assert self.table is not None and len(self.table) == n
            return np.asarray(self.table, dtype=np.int64)
        raise ValueError(f"unknown perm kind {self.kind}")

    def inverse(self) -> "PermSpec":
        if self.kind == "identity":
            return self
        if self.kind == "gs":
            return PermSpec("gs_inv", k=self.k)
        if self.kind == "gs_inv":
            return PermSpec("gs", k=self.k)
        if self.kind == "paired":
            return PermSpec("paired_inv", k=self.k)
        if self.kind == "paired_inv":
            return PermSpec("paired", k=self.k)
        if self.kind == "index":
            return PermSpec.from_sigma(inverse_sigma(np.asarray(self.table)))
        raise ValueError(self.kind)

    def matrix(self, n: int, dtype=np.float32) -> np.ndarray:
        return perm_matrix(self.sigma(n), dtype=dtype)


# ---------------------------------------------------------------------------
# application to arrays (jit-traceable)
# ---------------------------------------------------------------------------

def _move_last(x: Array, axis: int):
    axis = axis % x.ndim
    if axis == x.ndim - 1:
        return x, None
    return jnp.moveaxis(x, axis, -1), axis


def apply_perm(x: Array, spec: PermSpec, axis: int = -1) -> Array:
    """Compute ``P x`` along ``axis`` (gather semantics y[i] = x[sigma(i)]).

    The "gs"/"gs_inv" kinds use the reshape/transpose fast path: zero FLOPs,
    relayout-only on TPU.  Other kinds gather with a static index table.
    """
    if spec.kind == "identity":
        return x
    x, orig_axis = _move_last(x, axis)
    n = x.shape[-1]
    if spec.kind == "gs":
        m = n // spec.k
        y = x.reshape(x.shape[:-1] + (spec.k, m))
        y = jnp.swapaxes(y, -1, -2)
        y = y.reshape(x.shape[:-1] + (n,))
    elif spec.kind == "gs_inv":
        # inverse of reshape(k, m).T is reshape(m, k).T
        m = n // spec.k
        y = x.reshape(x.shape[:-1] + (m, spec.k))
        y = jnp.swapaxes(y, -1, -2)
        y = y.reshape(x.shape[:-1] + (n,))
    else:
        sig = jnp.asarray(spec.sigma(n))
        y = jnp.take(x, sig, axis=-1)
    if orig_axis is not None:
        y = jnp.moveaxis(y, -1, orig_axis)
    return y


def apply_perm_T(x: Array, spec: PermSpec, axis: int = -1) -> Array:
    """Compute ``P^T x`` (= P^{-1} x for permutations)."""
    return apply_perm(x, spec.inverse(), axis=axis)
