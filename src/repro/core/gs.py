"""Group-and-Shuffle (GS) matrices — the paper's core structured class.

A (two-factor) GS matrix is

    A = P_L (L P R) P_R                                         (paper eq. 1)

with L = diag(L_1..L_{k_L}), R = diag(R_1..R_{k_R}) block-diagonal and
P_L, P, P_R permutations.  The class generalizes Monarch matrices (App. C:
Monarch adds the coupling k_L = b_R, k_R = b_L) and — with the right
permutations — block-butterfly matrices (Remark 2).

Higher-order GS (Definition 5.1):

    A = P_{m+1} * prod_{i=m..1} (B_i P_i)

Everything here is functional: parameters are plain arrays (stacked block
tensors), layouts are hashable dataclasses that become jit-static arguments.

Key results implemented / verified in tests:
  * Proposition 1  — block-low-rank interpretation of GS(I, P, I)
  * Theorem 2      — m = 1 + ceil(log_b r) factors form a dense matrix with
                     P_(k, n) shuffles; fewer factors cannot
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from .permutations import PermSpec, apply_perm, inverse_sigma

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# layouts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockDiagSpec:
    """diag(B_1..B_k) with every block of shape (rows, cols)."""
    num_blocks: int
    rows: int
    cols: int

    @property
    def in_dim(self) -> int:
        return self.num_blocks * self.cols

    @property
    def out_dim(self) -> int:
        return self.num_blocks * self.rows

    @property
    def param_shape(self) -> Tuple[int, int, int]:
        return (self.num_blocks, self.rows, self.cols)

    @property
    def num_params(self) -> int:
        return self.num_blocks * self.rows * self.cols


@dataclasses.dataclass(frozen=True)
class GSLayout:
    """Two-factor layout  A = P_L (L P R) P_R  (sizes per Definition 3.1)."""
    lspec: BlockDiagSpec
    rspec: BlockDiagSpec
    perm_left: PermSpec
    perm_mid: PermSpec
    perm_right: PermSpec

    def __post_init__(self):
        if self.lspec.in_dim != self.rspec.out_dim:
            raise ValueError(
                f"inner dims disagree: L takes {self.lspec.in_dim}, "
                f"R produces {self.rspec.out_dim}")

    @property
    def in_dim(self) -> int:
        return self.rspec.in_dim

    @property
    def out_dim(self) -> int:
        return self.lspec.out_dim

    @property
    def inner_dim(self) -> int:
        return self.rspec.out_dim

    @property
    def num_params(self) -> int:
        return self.lspec.num_params + self.rspec.num_params


def gsoft_layout(d: int, block_size: int) -> GSLayout:
    """The layout used by GSOFT:  Q = P^T L P R  (square, equal b x b blocks).

    P = P_(r, d) with r = d / b.  Dense iff r <= b (Theorem 2 with m = 2).
    """
    if d % block_size != 0:
        raise ValueError(f"block size {block_size} must divide d={d}")
    r = d // block_size
    spec = BlockDiagSpec(r, block_size, block_size)
    return GSLayout(
        lspec=spec, rspec=spec,
        perm_left=PermSpec.gs_inv(r),   # P^T = P^{-1}
        perm_mid=PermSpec.gs(r),
        perm_right=PermSpec.identity(),
    )


def pick_block_size(d: int, target_b: int) -> int:
    """Largest divisor b of d with b <= target_b and d/b <= b when possible.

    Guarantees the m=2 GSOFT density condition (r <= b) whenever any divisor
    satisfies it; otherwise returns the largest divisor <= target_b (caller
    may switch to higher-order GS).
    """
    divs = [b for b in range(1, d + 1) if d % b == 0]
    ok = [b for b in divs if b <= target_b and d // b <= b]
    if ok:
        return max(ok)
    le = [b for b in divs if b <= target_b]
    return max(le) if le else min(divs)


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------

def init_blocks(spec: BlockDiagSpec, rng: Optional[np.random.Generator] = None,
                scale: float = 0.02, identity: bool = False,
                dtype=jnp.float32) -> Array:
    """Stacked block tensor of shape (k, rows, cols)."""
    if identity:
        if spec.rows != spec.cols:
            raise ValueError("identity init needs square blocks")
        eye = np.eye(spec.rows)
        return jnp.asarray(np.broadcast_to(eye, spec.param_shape).copy(), dtype)
    rng = rng or np.random.default_rng(0)
    w = rng.normal(0.0, scale, size=spec.param_shape)
    return jnp.asarray(w, dtype)


# ---------------------------------------------------------------------------
# application (the hot path — also the contract for the Pallas kernels)
# ---------------------------------------------------------------------------

def block_diag_matmul(blocks: Array, x: Array) -> Array:
    """y = diag(B_1..B_k) x  along the last axis of x.

    blocks: (k, rows, cols); x: (..., k*cols) -> (..., k*rows).
    Lowered as a batched dot_general — this is the op the `bdmm` Pallas
    kernel implements for TPU (tokens on the 128-lane axis).
    """
    k, rows, cols = blocks.shape
    lead = x.shape[:-1]
    xg = x.reshape(lead + (k, cols))
    yg = jnp.einsum("gij,...gj->...gi", blocks, xg,
                    preferred_element_type=x.dtype)
    return yg.reshape(lead + (k * rows,))


def gs_apply(layout: GSLayout, L: Array, R: Array, x: Array) -> Array:
    """y = A x with A = P_L (L P R) P_R, x: (..., in_dim)."""
    y = apply_perm(x, layout.perm_right)
    y = block_diag_matmul(R, y)
    y = apply_perm(y, layout.perm_mid)
    y = block_diag_matmul(L, y)
    y = apply_perm(y, layout.perm_left)
    return y


def gs_apply_T(layout: GSLayout, L: Array, R: Array, x: Array) -> Array:
    """y = A^T x  (transpose application; used for activation-side adapters)."""
    y = apply_perm(x, layout.perm_left.inverse())
    y = block_diag_matmul(jnp.swapaxes(L, -1, -2), y)
    y = apply_perm(y, layout.perm_mid.inverse())
    y = block_diag_matmul(jnp.swapaxes(R, -1, -2), y)
    y = apply_perm(y, layout.perm_right.inverse())
    return y


def gs_matmul(layout: GSLayout, L: Array, R: Array, W: Array) -> Array:
    """A @ W for a matrix W of shape (in_dim, n) — weight-side application.

    Equivalent to applying A to every column of W; we transpose so the
    block-diagonal matmuls run with n on the lane axis.
    """
    return jnp.swapaxes(gs_apply(layout, L, R, jnp.swapaxes(W, -1, -2)), -1, -2)


# ---------------------------------------------------------------------------
# materialization & structure (tests / analysis — small sizes only)
# ---------------------------------------------------------------------------

def materialize_block_diag(blocks: np.ndarray) -> np.ndarray:
    k, r, c = blocks.shape
    out = np.zeros((k * r, k * c), dtype=blocks.dtype)
    for i in range(k):
        out[i * r:(i + 1) * r, i * c:(i + 1) * c] = blocks[i]
    return out


def gs_materialize(layout: GSLayout, L, R) -> np.ndarray:
    Lm = materialize_block_diag(np.asarray(L))
    Rm = materialize_block_diag(np.asarray(R))
    P_L = layout.perm_left.matrix(layout.out_dim)
    P = layout.perm_mid.matrix(layout.inner_dim)
    P_R = layout.perm_right.matrix(layout.in_dim)
    return P_L @ Lm @ P @ Rm @ P_R


# ---------------------------------------------------------------------------
# Proposition 1: block-low-rank interpretation of GS(I, P, I)
# ---------------------------------------------------------------------------

def block_ranks(layout: GSLayout) -> np.ndarray:
    """rank bound r_{k1,k2} of block (k1,k2) of P_L^T A P_R^T, from P alone.

    With our gather convention (Px)[j] = x[sigma(j)], the L column j pairs
    with the R row sigma(j); the paper states u_{sigma(i)} v_i^T under the
    scatter convention (their sigma is our sigma^{-1} — same statement).
    The paper's division by k_L/k_R is a typo for the block sizes; App. B
    uses row/column block membership, which is what this computes.
    """
    bL, bR = layout.lspec.cols, layout.rspec.rows
    kL, kR = layout.lspec.num_blocks, layout.rspec.num_blocks
    sigma = layout.perm_mid.sigma(layout.inner_dim)
    ranks = np.zeros((kL, kR), dtype=np.int64)
    for j in range(layout.inner_dim):
        ranks[j // bL, sigma[j] // bR] += 1
    return ranks


def lowrank_blocks(layout: GSLayout, L, R) -> np.ndarray:
    """Materialize the middle factor L P R via the Prop. 1 sum-of-outer-products.

    Returns the dense (out_dim, inner... in_dim) matrix built block by block —
    used in tests to confirm the proposition against gs_materialize.
    """
    L = np.asarray(L)
    R = np.asarray(R)
    kL, bL1, bL2 = L.shape
    kR, bR1, bR2 = R.shape
    sigma = layout.perm_mid.sigma(layout.inner_dim)
    # u_j: columns of L blocks in consecutive order; v_i: rows of R blocks.
    # Gather convention: (P R)[j, :] = R[sigma(j), :], so u_j pairs v_{sigma(j)}.
    out = np.zeros((kL * bL1, kR * bR2), dtype=np.result_type(L, R))
    for j in range(layout.inner_dim):
        i = sigma[j]
        k1, k2 = j // bL2, i // bR1
        col = L[k1][:, j % bL2]                  # u_j
        row = R[k2][i % bR1, :]                  # v_{sigma(j)}^T
        out[k1 * bL1:(k1 + 1) * bL1, k2 * bR2:(k2 + 1) * bR2] += np.outer(col, row)
    return out


# ---------------------------------------------------------------------------
# higher-order GS  (Definition 5.1)  + Theorem 2 density tools
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GSFactors:
    """A = P_{m+1} * prod_{i=m..1} (B_i P_i).

    specs[i] / perms[i] correspond to (B_{i+1}, P_{i+1}) in paper indexing,
    i.e. factors are stored in application order (P_1 first).
    """
    specs: Tuple[BlockDiagSpec, ...]
    perms: Tuple[PermSpec, ...]        # len = m + 1 (last = P_{m+1})

    def __post_init__(self):
        if len(self.perms) != len(self.specs) + 1:
            raise ValueError("need m block specs and m+1 permutations")
        for a, b in zip(self.specs[:-1], self.specs[1:]):
            if a.out_dim != b.in_dim:
                raise ValueError("factor dims must chain")

    @property
    def in_dim(self) -> int:
        return self.specs[0].in_dim

    @property
    def out_dim(self) -> int:
        return self.specs[-1].out_dim

    @property
    def num_params(self) -> int:
        return sum(s.num_params for s in self.specs)


def gs_order_layout(d: int, block_size: int, m: int) -> GSFactors:
    """m-factor square GS layout with P_(r, d) shuffles between factors."""
    if d % block_size:
        raise ValueError("block must divide d")
    r = d // block_size
    spec = BlockDiagSpec(r, block_size, block_size)
    perms = [PermSpec.identity()]                      # P_1
    for _ in range(m - 1):
        perms.append(PermSpec.gs(r))                   # P_2..P_m
    perms.append(PermSpec.identity())                  # P_{m+1}
    return GSFactors(specs=(spec,) * m, perms=tuple(perms))


def gs_factors_apply(factors: GSFactors, blocks: Sequence[Array], x: Array) -> Array:
    y = x
    for i, spec in enumerate(factors.specs):
        y = apply_perm(y, factors.perms[i])
        y = block_diag_matmul(blocks[i], y)
    return apply_perm(y, factors.perms[-1])


def gs_factors_materialize(factors: GSFactors, blocks) -> np.ndarray:
    out = factors.perms[0].matrix(factors.in_dim)
    for i in range(len(factors.specs)):
        out = materialize_block_diag(np.asarray(blocks[i])) @ out
        out = factors.perms[i + 1].matrix(out.shape[0]) @ out
    return out


def min_factors_dense(block_size: int, num_blocks: int) -> int:
    """Theorem 2:  m = 1 + ceil(log_b r)  (vs 1 + ceil(log2 r) for butterfly)."""
    if num_blocks <= 1:
        return 1
    if block_size <= 1:
        raise ValueError("b = 1 can never densify")
    return 1 + math.ceil(math.log(num_blocks, block_size) - 1e-12)


def support_pattern(factors: GSFactors) -> np.ndarray:
    """Boolean reachability pattern of the class (1 where entries CAN be nonzero)."""
    ones = [np.ones(s.param_shape, dtype=np.float64) for s in factors.specs]
    pat = gs_factors_materialize(factors, ones)
    return pat > 0


def is_dense_class(factors: GSFactors) -> bool:
    return bool(np.all(support_pattern(factors)))
