"""MethodOps registry — every orthogonal parametrization (and LoRA) is one
explicit record, mirroring ``models/registry.py``.

The paper's core claim is that GS matrices *unify* prior structured
orthogonal classes — block-diagonal OFT, butterfly BOFT (Liu et al. 2023),
Householder products (HOFT, Moreno Arcas et al. 2025). This module makes
that unification an API: ``repro.core.adapters`` / ``repro.core.peft``
dispatch exclusively through ``methods.get(name)``, so a new
parametrization is ONE registry entry (init + materialize + optional
activation-side / bank hooks), never a cross-codebase surgery.

This is also THE one module allowed to compare method strings — a CI grep
guard rejects ``method ==`` dispatch anywhere else under ``src/repro``
(mirrored by ``tests/test_methods.py``).

Per-record capability surface:

* ``init_params(spec, key, dtype)``      — identity-init adapter params
* ``materialize(spec, params, W)``       — W_eff (weight-side, unbatched)
* ``merge``                              — alias of materialize by default
                                           (inference: bake into weights)
* ``apply_activation_side(spec, params, x)`` — x -> x Q, or None when the
  method has no input-rotation form (LoRA, Double GSOFT's output factor)
* ``param_count(spec)``                  — analytic count (unbatched,
                                           without the ``use_scale`` vector)
* ``bank_build(spec, params_by_slot)``   — stack per-request factors for
  the serving ``AdapterBank`` (``None`` slot -> that method's identity), or
  None when the method cannot be banked (``bank_unsupported`` says why)
* ``bank_rotator(entry, slots, x, use_pallas)`` — per-row activation-side
  application of a built bank entry (geometry derived from factor shapes;
  Pallas path where a kernel exists, reference einsum fallback otherwise)
* ``banked_kernel``                      — which ``kernels.dispatch``
  key family the banked transform rides ("gs" / "bdmm"; "" = einsum-only)
* ``quant_fuse(entry, slots, dtype)``    — per-row factors for the fused
  rotate+quantized-matmul kernel (only GSOFT has one today)
* ``bank_shard_axes(factor, shape)``     — serve-time tensor parallelism:
  which axis of a built bank-factor stack may split over the mesh 'model'
  axis (None/absent -> replicate; ``sharding.specs.bank_spec_tree`` is the
  only consumer — methods never touch jax.sharding themselves)
* ``orthogonal`` / ``quant_compatible``  — capability flags (README table)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from . import adapters as _ad

# methods that mean "no adapters at all" — they select a training regime,
# not a parametrization, and are never registered
NON_ADAPTER_METHODS = ("full", "none")


@dataclasses.dataclass(frozen=True)
class MethodOps:
    """The per-method call surface (see module docstring)."""
    method: str
    structure: str                    # one-liner for docs/benchmarks
    orthogonal: bool
    init_params: Callable
    materialize: Callable
    param_count: Callable
    merge: Optional[Callable] = None              # default: materialize
    apply_activation_side: Optional[Callable] = None
    bank_build: Optional[Callable] = None
    bank_rotator: Optional[Callable] = None
    quant_fuse: Optional[Callable] = None
    bank_shard_axes: Optional[Callable] = None
    quant_compatible: bool = False
    bank_unsupported: str = ""        # why bank_build is None (error text)
    banked_kernel: str = ""           # kernels.dispatch.BANKED_KEYS family

    def __post_init__(self):
        if self.merge is None:
            object.__setattr__(self, "merge", self.materialize)


_METHODS: Dict[str, MethodOps] = {}


def register(ops: MethodOps) -> MethodOps:
    _METHODS[ops.method] = ops
    return ops


def get(method: str) -> MethodOps:
    if method not in _METHODS:
        raise KeyError(f"unknown adapter method {method!r}; registered "
                       f"methods: {sorted(_METHODS)}")
    return _METHODS[method]


def registered() -> List[str]:
    return sorted(_METHODS)


def is_adapter_method(method: str) -> bool:
    """True when ``method`` names an adapter parametrization (as opposed to
    the ``full``/``none`` training regimes)."""
    return method not in NON_ADAPTER_METHODS


def trainable_split(method: str, params: Any, adapters: Any):
    """(trainable, frozen) for the optimizer — the ONE place the
    ``full``/``none`` pseudo-methods are interpreted."""
    if method == "full":
        return params, adapters      # adapters empty; everything trains
    if method == "none":
        return {}, params
    get(method)                      # fail fast on unknown methods
    return adapters, params


# ---------------------------------------------------------------------------
# records — implementations live in core.adapters; this module only wires
# ---------------------------------------------------------------------------

register(MethodOps(
    method="gsoft",
    structure="Q = P^T L P R (two-factor GS, paper eq. 1)",
    orthogonal=True,
    init_params=_ad.gsoft_init,
    materialize=_ad.gsoft_materialize,
    param_count=_ad.gsoft_param_count,
    apply_activation_side=_ad.gsoft_apply_T,
    bank_build=_ad.gsoft_bank_build,
    bank_rotator=_ad.gs_rotate_banked,
    quant_fuse=_ad.gsoft_quant_fuse,
    bank_shard_axes=_ad.gsoft_bank_shard_axes,
    quant_compatible=True,
    banked_kernel="gs",
))

register(MethodOps(
    method="double_gsoft",
    structure="W_eff = Q_U W Q_V (two-sided GS, paper §4)",
    orthogonal=True,
    init_params=_ad.double_gsoft_init,
    materialize=_ad.double_gsoft_materialize,
    param_count=_ad.double_gsoft_param_count,
    bank_unsupported=("its output-side factor Q_V rotates AFTER the base "
                      "matmul, which the per-request serving hook does not "
                      "carry yet — merge it offline instead"),
))

register(MethodOps(
    method="oft",
    structure="Q = diag(Q_1..Q_r) (block-diagonal, OFT)",
    orthogonal=True,
    init_params=_ad.oft_init,
    materialize=_ad.oft_materialize,
    param_count=_ad.oft_param_count,
    apply_activation_side=_ad.oft_apply_T,
    bank_build=_ad.oft_bank_build,
    bank_rotator=_ad.oft_rotate_banked,
    quant_compatible=True,
    banked_kernel="bdmm",
))

register(MethodOps(
    method="boft",
    structure="Q = B_m..B_1 (block butterfly, BOFT)",
    orthogonal=True,
    init_params=_ad.boft_init,
    materialize=_ad.boft_materialize,
    param_count=_ad.boft_param_count,
    apply_activation_side=_ad.boft_apply_T,
    bank_build=_ad.boft_bank_build,
    bank_rotator=_ad.boft_rotate_banked,
    quant_compatible=True,
    banked_kernel="bdmm",
))

register(MethodOps(
    method="householder",
    structure="Q = H_1..H_k, H_i = I - 2 v_i v_i^T (HOFT)",
    orthogonal=True,
    init_params=_ad.householder_init,
    materialize=_ad.householder_materialize,
    param_count=_ad.householder_param_count,
    apply_activation_side=_ad.householder_apply_T,
    bank_build=_ad.householder_bank_build,
    bank_rotator=_ad.householder_rotate_banked,
    quant_compatible=True,
))

register(MethodOps(
    method="givens",
    structure="Q = G_m..G_1 (brick-wall Givens rounds, GOFT)",
    orthogonal=True,
    init_params=_ad.givens_init,
    materialize=_ad.givens_materialize,
    param_count=_ad.givens_param_count,
    apply_activation_side=_ad.givens_apply_T,
    bank_build=_ad.givens_bank_build,
    bank_rotator=_ad.givens_rotate_banked,
    quant_compatible=True,
))

register(MethodOps(
    method="lora",
    structure="W + (alpha/r) A B (low-rank residual)",
    orthogonal=False,
    init_params=_ad.lora_init,
    materialize=_ad.lora_materialize,
    param_count=_ad.lora_param_count,
    bank_unsupported=("it is weight-side only — the low-rank residual "
                      "W + (alpha/r) A B is not an orthogonal rotation of "
                      "the inputs, so there is no activation-side form to "
                      "bank; merge it offline instead"),
))
