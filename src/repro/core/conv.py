"""GS orthogonal convolutions (paper §6.3, App. F) — TPU-native JAX.

Building blocks
---------------
* ``skew_kernel``       — L = M - ConvTranspose(M): makes the induced conv
                          matrix (eq. 2) skew-symmetric, so its exponential is
                          orthogonal (SOC, Singla & Feizi 2021).
* ``conv_exponential``  — truncated Taylor series of the convolution
                          exponential L *_e X (Definition 6.1), grouped via
                          ``feature_group_count`` (TPU-native grouped conv —
                          no im2col, adapts the paper's GPU grouped conv).
* ``ChShuffle``         — channel permutation; the *paired* variant
                          (App. F) keeps MaxMin pairs together.
* ``MaxMin / MaxMinPermuted`` — gradient-norm-preserving activations.
* ``gs_soc_layer``      — Y = GrExpConv2(ChShuffle2(GrExpConv1(ChShuffle1 X))),
                          the GS-SOC layer of eq. (3); second conv is 1x1
                          (paper finding: keeps quality, restores speed).

Layout: NHWC activations, HWIO kernels (TPU conventions).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .permutations import PermSpec, apply_perm

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# skew-symmetric convolution kernels
# ---------------------------------------------------------------------------

def skew_kernel(m: Array, groups: int = 1) -> Array:
    """L = M - ConvTranspose(M), per group.

    m: (H, W, c//g, c) HWIO grouped kernel with c_out == c_in == c.
    ConvTranspose(M)[h, w, i, o] = M[H-1-h, W-1-w, o, i]  (within each group).
    """
    H, W, cg, c = m.shape
    if c % groups or cg != c // groups:
        raise ValueError(f"bad grouped kernel shape {m.shape} for groups={groups}")
    mg = m.reshape(H, W, cg, groups, cg)              # split O -> (g, o_local)
    mt = jnp.flip(mg, axis=(0, 1))                    # spatial flip
    mt = jnp.swapaxes(mt, 2, 4)                       # (i <-> o_local)
    return (mg - mt).reshape(H, W, cg, c)


def conv2d(x: Array, kernel: Array, groups: int = 1) -> Array:
    """SAME-padded NHWC grouped convolution."""
    return jax.lax.conv_general_dilated(
        x, kernel, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=x.dtype)


def conv_exponential(x: Array, kernel: Array, groups: int = 1,
                     terms: int = 6) -> Array:
    """L *_e X = X + LX/1! + L^2 X/2! + ...  truncated at ``terms``.

    With a skew kernel the Jacobian is orthogonal up to truncation error.
    """
    acc = x
    term = x
    for t in range(1, terms + 1):
        term = conv2d(term, kernel, groups) / t
        acc = acc + term
    return acc


# ---------------------------------------------------------------------------
# activations (App. F)
# ---------------------------------------------------------------------------

def maxmin(x: Array) -> Array:
    """Original MaxMin: pairs channel i with channel i + c/2 (Def. F.1)."""
    c = x.shape[-1]
    a, b = x[..., : c // 2], x[..., c // 2:]
    return jnp.concatenate([jnp.maximum(a, b), jnp.minimum(a, b)], axis=-1)


def maxmin_permuted(x: Array) -> Array:
    """MaxMinPermuted (Def. F.2): pairs *neighboring* channels (2i, 2i+1), so
    activations never leak information across ChShuffle groups."""
    a, b = x[..., 0::2], x[..., 1::2]
    mx, mn = jnp.maximum(a, b), jnp.minimum(a, b)
    out = jnp.stack([mx, mn], axis=-1)
    return out.reshape(x.shape)


ACTIVATIONS = {"maxmin": maxmin, "maxmin_permuted": maxmin_permuted,
               "none": lambda x: x}


# ---------------------------------------------------------------------------
# channel shuffle
# ---------------------------------------------------------------------------

def ch_shuffle_spec(channels: int, k: int, paired: bool = True) -> PermSpec:
    """ChShuffle before a k-grouped conv. ``paired`` (App. F) moves channel
    pairs jointly — optimal information transition AND keeps MaxMinPermuted
    pairs intact (Table 4 ablation: paired >> not paired)."""
    if paired and channels % (2 * k) == 0 and channels >= 2 * k:
        return PermSpec.paired(k)
    return PermSpec.gs(k)


def ch_shuffle(x: Array, spec: PermSpec) -> Array:
    return apply_perm(x, spec, axis=-1)


# ---------------------------------------------------------------------------
# GS-SOC layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GSSOCSpec:
    """One GS-SOC orthogonal convolution layer (paper Table 3 rows).

    groups = (a, b): first grouped exp-conv has ``a`` groups, kernel k1 x k1;
    second has ``b`` groups with kernel 1x1. b = 0 -> single conv (row "(4,-)").
    a == b == 1 with no shuffle reduces to plain SOC.
    """
    channels: int
    groups1: int = 4
    groups2: int = 0
    k1: int = 3
    k2: int = 1
    terms: int = 6
    paired: bool = True

    def param_shapes(self):
        c, g1 = self.channels, self.groups1
        shapes = {"m1": (self.k1, self.k1, c // g1, c)}
        if self.groups2:
            shapes["m2"] = (self.k2, self.k2, c // self.groups2, c)
        return shapes

    @property
    def num_params(self) -> int:
        return sum(int(np.prod(s)) for s in self.param_shapes().values())


def init_gs_soc(spec: GSSOCSpec, key: jax.Array, dtype=jnp.float32):
    shapes = spec.param_shapes()
    params = {}
    for i, (name, shp) in enumerate(sorted(shapes.items())):
        scale = 1.0 / np.sqrt(np.prod(shp[:3]))
        params[name] = jax.random.normal(jax.random.fold_in(key, i), shp,
                                         dtype) * scale
    return params


def gs_soc_layer(spec: GSSOCSpec, params, x: Array) -> Array:
    """Eq. (3): GrExpConv2(ChShuffle2(GrExpConv1(ChShuffle1(X)))).

    Orthogonal Jacobian (up to Taylor truncation): permutations are
    orthogonal, grouped conv exponentials of skew kernels are orthogonal,
    and compositions of orthogonal maps are orthogonal.
    """
    c = spec.channels
    if spec.groups1 > 1:
        x = ch_shuffle(x, ch_shuffle_spec(c, spec.groups1, spec.paired))
    k1 = skew_kernel(params["m1"], spec.groups1)
    x = conv_exponential(x, k1, spec.groups1, spec.terms)
    if spec.groups2:
        if spec.groups2 > 1:
            x = ch_shuffle(x, ch_shuffle_spec(c, spec.groups2, spec.paired))
        k2 = skew_kernel(params["m2"], spec.groups2)
        x = conv_exponential(x, k2, spec.groups2, spec.terms)
    return x


def soc_layer_spec(channels: int, terms: int = 6) -> GSSOCSpec:
    """Plain SOC baseline = one ungrouped exp conv, no shuffle."""
    return GSSOCSpec(channels=channels, groups1=1, groups2=0, terms=terms,
                     paired=False)


# ---------------------------------------------------------------------------
# utilities for Lipschitz nets
# ---------------------------------------------------------------------------

def space_to_depth(x: Array, factor: int = 2) -> Array:
    """Invertible (orthogonal) downsampling: (H, W, C) -> (H/2, W/2, 4C)."""
    n, h, w, c = x.shape
    x = x.reshape(n, h // factor, factor, w // factor, factor, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(n, h // factor, w // factor, factor * factor * c)


def power_iteration_sn(w: Array, iters: int = 20) -> Array:
    """Spectral norm estimate of a 2D matrix (for 1-Lipschitz dense heads)."""
    v = jnp.ones((w.shape[1],), w.dtype) / np.sqrt(w.shape[1])
    for _ in range(iters):
        u = w @ v
        u = u / (jnp.linalg.norm(u) + 1e-12)
        v = w.T @ u
        v = v / (jnp.linalg.norm(v) + 1e-12)
    return jnp.einsum("i,ij,j->", u, w, v)


def certified_radius(logits: Array) -> Array:
    """SOC certificate: margin / sqrt(2) for 1-Lipschitz nets."""
    top2 = jax.lax.top_k(logits, 2)[0]
    return (top2[..., 0] - top2[..., 1]) / np.sqrt(2.0)
