"""PEFT engine — attaches adapters to arbitrary parameter trees.

The framework keeps *frozen* base params and *trainable* adapter params as
separate pytrees; the jitted train step calls ``materialize_tree`` to build
effective weights (differentiable w.r.t. adapters only), so:

  * optimizer state exists only for adapters (tiny),
  * base weights can live in bf16 with no master copies,
  * under tensor parallelism the GSOFT rotation adds **zero collectives**
    (Q acts on the unsharded input dim of each Megatron-sharded weight).

Adapted-weight selection is by path regex; weights with leading batch dims
(scan-stacked layers ``(L, d_in, d_out)``, MoE experts ``(L, E, d_in, d_out)``)
receive independent per-slice adapters via vmap.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .adapters import (AdapterSpec, gs_rotate_banked, init_adapter,
                       materialize, num_adapter_params)
from .gs import gsoft_layout
from .orthogonal import cayley, skew

Array = jnp.ndarray
Tree = Any

# weights the paper adapts: attention projections + MLP matrices (and the
# SSM in/out projections for the state-space architectures — see DESIGN §5)
DEFAULT_TARGETS: Tuple[str, ...] = (
    r".*/(wq|wk|wv|wo|wi|wg)$",       # attention + MLP/MoE projections
    r".*/(wz|wx)$",                   # mamba in-projections (z / x branches)
    r".*/(in_proj|out_proj)$",
)


@dataclasses.dataclass(frozen=True)
class PEFTConfig:
    method: str = "gsoft"          # gsoft|double_gsoft|oft|boft|lora|full|none
    block_size: int = 32
    block_size_out: int = 0
    rank: int = 8
    alpha: float = 16.0
    boft_factors: int = 2
    neumann_order: Optional[int] = None
    use_scale: bool = False
    use_pallas: bool = False       # GS rotations via the Pallas kernel path
    target_patterns: Tuple[str, ...] = DEFAULT_TARGETS

    @property
    def is_peft(self) -> bool:
        return self.method not in ("full", "none")


# ---------------------------------------------------------------------------
# path utilities
# ---------------------------------------------------------------------------

def _key_name(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def path_str(path) -> str:
    return "/".join(_key_name(p) for p in path)


def flatten_paths(tree: Tree, is_leaf=None) -> Dict[str, Array]:
    leaves = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    return {path_str(p): v for p, v in leaves}


def matches_patterns(patterns, path: str) -> bool:
    """fullmatch only: an unanchored target like ``.*/wq`` must not also
    match a decoy weight named ``.../wq_extra`` (the old ``re.search``
    fallback ignored the end anchor). THE one implementation of
    target-pattern semantics — PEFT adapter selection and
    ``quant.weights`` both use it."""
    return any(re.fullmatch(pat, path) for pat in patterns)


def _matches(cfg: PEFTConfig, path: str) -> bool:
    return matches_patterns(cfg.target_patterns, path)


# ---------------------------------------------------------------------------
# spec inference + init
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def spec_for(cfg: PEFTConfig, shape: Tuple[int, ...]) -> AdapterSpec:
    """Derive the AdapterSpec for a weight shape. Cached: ``materialize_tree``
    runs inside jit every step and would otherwise re-derive the spec for
    every adapted leaf on every call (cfg and shape are both hashable)."""
    if len(shape) < 2:
        raise ValueError(f"cannot adapt weight of shape {shape}")
    return AdapterSpec(
        method=cfg.method,
        d_in=int(shape[-2]),
        d_out=int(shape[-1]),
        block_size=cfg.block_size,
        block_size_out=cfg.block_size_out,
        rank=cfg.rank,
        alpha=cfg.alpha,
        boft_factors=cfg.boft_factors,
        neumann_order=cfg.neumann_order,
        use_scale=cfg.use_scale,
        use_pallas=cfg.use_pallas,
        batch=tuple(int(s) for s in shape[:-2]),
    )


def adapted_paths(cfg: PEFTConfig, params: Tree) -> Dict[str, AdapterSpec]:
    """Which weights get adapters, and with what spec.

    Quantized trees work too: a ``QuantTensor`` stays ONE leaf here (its
    ``shape``/``ndim`` mirror the logical weight), so an adapter bank can
    be built over an already-quantized runtime — the adapters themselves
    are always full-precision, applied activation-side.
    """
    if not cfg.is_peft:
        return {}
    from repro.quant.core import is_quant_tensor
    out = {}
    for path, leaf in flatten_paths(params, is_leaf=is_quant_tensor).items():
        if leaf.ndim >= 2 and _matches(cfg, path):
            out[path] = spec_for(cfg, tuple(leaf.shape))
    return out


def init_peft(cfg: PEFTConfig, params: Tree, key: jax.Array,
              dtype=jnp.float32) -> Dict[str, Dict[str, Array]]:
    """Adapter tree: {weight_path: adapter_params}. Empty for full/none."""
    specs = adapted_paths(cfg, params)
    adapters: Dict[str, Dict[str, Array]] = {}
    for i, (path, spec) in enumerate(sorted(specs.items())):
        adapters[path] = init_adapter(spec, jax.random.fold_in(key, i), dtype)
    return adapters


# ---------------------------------------------------------------------------
# materialization / merge
# ---------------------------------------------------------------------------

def materialize_tree(cfg: PEFTConfig, params: Tree,
                     adapters: Dict[str, Dict[str, Array]],
                     merged: bool = False) -> Tree:
    """Effective parameter tree with adapters applied (weight-side).

    Runs inside jit each step; cost is O(2 b d n) per adapted weight —
    a ~b/T fraction of the corresponding GEMM for T tokens (DESIGN §3).

    ``merged=True`` documents the offline single-merge call sites (serving:
    adapters folded into the weights once, zero per-token overhead — paper
    §6.1). The math is identical; the flag only marks intent where the old
    ``merge_tree`` alias used to.
    """
    del merged  # intent marker only — same math either way
    if not adapters:
        return params

    def visit(path, leaf):
        p = path_str(path)
        if p in adapters:
            return materialize(spec_for(cfg, leaf.shape), adapters[p], leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# adapter bank: N named GSOFT adapters + identity slot, per-request serving
# ---------------------------------------------------------------------------

BASE_ADAPTER = "__base__"


@dataclasses.dataclass
class AdapterBank:
    """Stacked per-request GSOFT rotations for multi-adapter serving.

    ``tree`` mirrors the params nesting: each adapted weight path maps to
    ``{"L": (..., A, r, b, b), "R": ...}`` of PRE-ORTHOGONALIZED blocks
    (the Cayley map runs once at build time — adapters are frozen when
    serving). Slot 0 is the identity (serves the unmodified base model);
    slots 1..N are the named adapters in ``names`` order. Scan-stacked
    layer dims stay LEADING (before the A axis) so the model's layer scan
    slices the bank alongside the weights.

    The serving engine applies the bank activation-side — row i of a decode
    batch computes x_i Q_{ids[i]} before each adapted matmul, costing
    O(b*d) per token per weight versus O(d^2) to re-merge a dense rotation;
    that asymmetry is what makes per-request orthogonal adapters viable at
    continuous-batching granularity.
    """
    cfg: PEFTConfig
    names: Tuple[str, ...]           # names[0] == BASE_ADAPTER
    tree: Dict[str, Any]

    @property
    def num_slots(self) -> int:
        return len(self.names)

    def slot(self, name: Optional[str]) -> int:
        """Bank slot for an adapter name (None / BASE_ADAPTER -> identity)."""
        if name is None:
            return 0
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(f"unknown adapter '{name}'; bank has "
                           f"{list(self.names)}") from None

    def context(self, slot_ids) -> "AdapterContext":
        """Bind this bank to a batch of slot ids -> the per-request
        AdapterContext that flows through prefill/decode as ONE pytree."""
        return AdapterContext(bank=self.tree,
                              slots=jnp.asarray(slot_ids, jnp.int32),
                              peft=self.cfg)


def _nest_insert(root: Dict[str, Any], path: str, value: Any) -> None:
    parts = path.split("/")
    node = root
    for seg in parts[:-1]:
        node = node.setdefault(seg, {})
    node[parts[-1]] = value


def build_adapter_bank(cfg: PEFTConfig, params: Tree,
                       adapters_by_name: Dict[str, Dict[str, Dict[str, Array]]]
                       ) -> AdapterBank:
    """Build an AdapterBank from named adapter trees (as from ``init_peft``).

    Orthogonalizes every block up front and stacks [identity] + adapters
    along a new A axis placed after any scan-stacked weight batch dims.
    """
    if cfg.method != "gsoft":
        raise ValueError("adapter bank supports method='gsoft' only "
                         f"(got {cfg.method!r}); double_gsoft needs an "
                         "output-side hook and LoRA is not orthogonal")
    if cfg.use_scale:
        raise ValueError("adapter bank does not support use_scale "
                         "(the per-output magnitude acts on the weight "
                         "output, not the rotated input)")
    specs = adapted_paths(cfg, params)
    names = (BASE_ADAPTER,) + tuple(adapters_by_name)
    tree: Dict[str, Any] = {}
    for path, spec in sorted(specs.items()):
        if len(spec.batch) > 1:
            raise ValueError(
                f"adapter bank cannot serve {path}: weights with batch dims "
                f"{spec.batch} (MoE experts / hybrid blocks) need "
                "routing-aware rotation")
        b = spec.resolved_block(spec.d_in, spec.block_size)
        lay = gsoft_layout(spec.d_in, b)
        eye = jnp.broadcast_to(
            jnp.eye(b, dtype=jnp.float32),
            tuple(spec.batch) + lay.lspec.param_shape)
        stacks: Dict[str, list] = {"L": [eye], "R": [eye]}
        for name, adapters in adapters_by_name.items():
            if path not in adapters:
                raise KeyError(f"adapter '{name}' has no params for {path}")
            for pkey in ("L", "R"):
                k = adapters[path][pkey].astype(jnp.float32)
                stacks[pkey].append(
                    cayley(skew(k), neumann_order=cfg.neumann_order))
        entry = {k: jnp.stack(v, axis=len(spec.batch))
                 for k, v in stacks.items()}
        _nest_insert(tree, path, entry)
    return AdapterBank(cfg=cfg, names=names, tree=tree)


# ---------------------------------------------------------------------------
# adapter context: the ONE pytree that carries per-request adapter state
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AdapterContext:
    """Per-request adapter state as a single frozen pytree.

    Bundles the stacked bank subtree (``AdapterBank.tree``), the (B,) slot
    ids of the current batch, and the bank's PEFTConfig — replacing the old
    loose ``bank``/``adapter_ids``/``bank_cfg`` kwarg triple. ``bank`` and
    ``slots`` are pytree children (they trace through jit/scan); ``peft`` is
    static aux data (hashable frozen dataclass, part of the jit cache key).
    """
    bank: Tree                       # nested {path: {"L": ..., "R": ...}}
    slots: Array                     # (B,) int32 bank-slot ids
    peft: Optional[PEFTConfig] = None

    def tree_flatten(self):
        return (self.bank, self.slots), self.peft

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(bank=children[0], slots=children[1], peft=aux)

    def group(self, *names) -> Optional[Dict]:
        """Bank subtree under ``names`` (e.g. ``"layers"``), or None.

        The returned raw tree is what model code feeds to ``jax.lax.scan``
        alongside the stacked layer weights — scan-slicing and the rotation
        hook below live in one place."""
        node: Any = self.bank
        for n in names:
            node = node.get(n) if isinstance(node, dict) else None
            if node is None:
                return None
        return node or None

    def rotator(self, group: Optional[Dict]) -> Optional["BankRotator"]:
        """Rotation hook over one (scan-sliced) module subtree, e.g.
        ``{"wq": {"L": (A, r, b, b), "R": ...}, ...}``. Returns None when
        there is nothing to rotate, so model code can pass it straight
        through to attention_block/apply_mlp."""
        if group is None or self.slots is None:
            return None
        return BankRotator(group, self.slots, self.peft)


class BankRotator:
    """Per-request GS rotation hook: ``rot(name, x)`` rotates row i of x
    with its own adapter (slot 0 = identity) before projection ``name``.

    Besides being callable, it exposes ``banked_factors`` — the per-row
    pre-orthogonalized (L, R) stacks — so the ``qlinear`` hook can fuse
    rotation + quantized base matmul into one ``gs_q_matmul_banked`` call
    instead of round-tripping the rotated slab through HBM. The factors
    are gathered/cast to the ACTIVATION dtype: rotations stay bf16 even
    when the base weights are int8 (QOFT rationale, DESIGN.md)."""

    __slots__ = ("_group", "slots", "_peft")

    def __init__(self, group: Dict, slots: Array,
                 peft: Optional[PEFTConfig]):
        self._group = group
        self.slots = slots
        self._peft = peft

    @property
    def use_pallas(self) -> bool:
        return self._peft.use_pallas if self._peft else False

    def __call__(self, name: str, x: Array) -> Array:
        entry = self._group.get(name)
        if entry is None:
            return x
        return gs_rotate_banked(entry["L"], entry["R"], self.slots, x,
                                use_pallas=self.use_pallas)

    def banked_factors(self, name: str, dtype
                       ) -> Optional[Tuple[Array, Array]]:
        """Per-row (L, R) blocks for projection ``name`` in ``dtype``
        ((B, r, b, b) each), or None when ``name`` has no bank entry."""
        entry = self._group.get(name)
        if entry is None:
            return None
        L = jnp.take(entry["L"], self.slots, axis=0).astype(dtype)
        R = jnp.take(entry["R"], self.slots, axis=0).astype(dtype)
        return L, R


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PrefillRequest:
    """Everything one prefill call needs beyond params/state, as a pytree:
    the input batch, the per-row ``last_idx`` (index of each row's last
    valid prompt position — the ragged-prompt fix), and the optional
    AdapterContext. Folds the old ``last_idx`` special-case kwarg and the
    adapter triple into one argument."""
    batch: Dict[str, Array]
    last_idx: Optional[Array] = None
    ctx: Optional[AdapterContext] = None

    def tree_flatten(self):
        return (self.batch, self.last_idx, self.ctx), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(batch=children[0], last_idx=children[1], ctx=children[2])


def count_params(tree: Tree) -> int:
    return sum(int(v.size) for v in jax.tree_util.tree_leaves(tree))


def trainable_and_frozen(cfg: PEFTConfig, params: Tree, adapters: Tree):
    """(trainable, frozen) split for the optimizer/train step."""
    if cfg.method == "full":
        return params, adapters  # adapters empty; everything trains
    if cfg.method == "none":
        return {}, params
    return adapters, params
