"""PEFT engine — attaches adapters to arbitrary parameter trees.

The framework keeps *frozen* base params and *trainable* adapter params as
separate pytrees; the jitted train step calls ``materialize_tree`` to build
effective weights (differentiable w.r.t. adapters only), so:

  * optimizer state exists only for adapters (tiny),
  * base weights can live in bf16 with no master copies,
  * under tensor parallelism the GSOFT rotation adds **zero collectives**
    (Q acts on the unsharded input dim of each Megatron-sharded weight).

Adapted-weight selection is by path regex; weights with leading batch dims
(scan-stacked layers ``(L, d_in, d_out)``, MoE experts ``(L, E, d_in, d_out)``)
receive independent per-slice adapters via vmap.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .adapters import AdapterSpec, init_adapter, materialize, num_adapter_params

Array = jnp.ndarray
Tree = Any

# weights the paper adapts: attention projections + MLP matrices (and the
# SSM in/out projections for the state-space architectures — see DESIGN §5)
DEFAULT_TARGETS: Tuple[str, ...] = (
    r".*/(wq|wk|wv|wo|wi|wg)$",       # attention + MLP/MoE projections
    r".*/(wz|wx)$",                   # mamba in-projections (z / x branches)
    r".*/(in_proj|out_proj)$",
)


@dataclasses.dataclass(frozen=True)
class PEFTConfig:
    method: str = "gsoft"          # gsoft|double_gsoft|oft|boft|lora|full|none
    block_size: int = 32
    block_size_out: int = 0
    rank: int = 8
    alpha: float = 16.0
    boft_factors: int = 2
    neumann_order: Optional[int] = None
    use_scale: bool = False
    use_pallas: bool = False       # GS rotations via the Pallas kernel path
    target_patterns: Tuple[str, ...] = DEFAULT_TARGETS

    @property
    def is_peft(self) -> bool:
        return self.method not in ("full", "none")


# ---------------------------------------------------------------------------
# path utilities
# ---------------------------------------------------------------------------

def _key_name(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def path_str(path) -> str:
    return "/".join(_key_name(p) for p in path)


def flatten_paths(tree: Tree) -> Dict[str, Array]:
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {path_str(p): v for p, v in leaves}


def _matches(cfg: PEFTConfig, path: str) -> bool:
    return any(re.match(pat + r"\Z", path) or re.search(pat, path)
               for pat in cfg.target_patterns)


# ---------------------------------------------------------------------------
# spec inference + init
# ---------------------------------------------------------------------------

def spec_for(cfg: PEFTConfig, shape: Tuple[int, ...]) -> AdapterSpec:
    if len(shape) < 2:
        raise ValueError(f"cannot adapt weight of shape {shape}")
    return AdapterSpec(
        method=cfg.method,
        d_in=int(shape[-2]),
        d_out=int(shape[-1]),
        block_size=cfg.block_size,
        block_size_out=cfg.block_size_out,
        rank=cfg.rank,
        alpha=cfg.alpha,
        boft_factors=cfg.boft_factors,
        neumann_order=cfg.neumann_order,
        use_scale=cfg.use_scale,
        use_pallas=cfg.use_pallas,
        batch=tuple(int(s) for s in shape[:-2]),
    )


def adapted_paths(cfg: PEFTConfig, params: Tree) -> Dict[str, AdapterSpec]:
    """Which weights get adapters, and with what spec."""
    if not cfg.is_peft:
        return {}
    out = {}
    for path, leaf in flatten_paths(params).items():
        if leaf.ndim >= 2 and _matches(cfg, path):
            out[path] = spec_for(cfg, leaf.shape)
    return out


def init_peft(cfg: PEFTConfig, params: Tree, key: jax.Array,
              dtype=jnp.float32) -> Dict[str, Dict[str, Array]]:
    """Adapter tree: {weight_path: adapter_params}. Empty for full/none."""
    specs = adapted_paths(cfg, params)
    adapters: Dict[str, Dict[str, Array]] = {}
    for i, (path, spec) in enumerate(sorted(specs.items())):
        adapters[path] = init_adapter(spec, jax.random.fold_in(key, i), dtype)
    return adapters


# ---------------------------------------------------------------------------
# materialization / merge
# ---------------------------------------------------------------------------

def materialize_tree(cfg: PEFTConfig, params: Tree,
                     adapters: Dict[str, Dict[str, Array]]) -> Tree:
    """Effective parameter tree with adapters applied (weight-side).

    Runs inside jit each step; cost is O(2 b d n) per adapted weight —
    a ~b/T fraction of the corresponding GEMM for T tokens (DESIGN §3).
    """
    if not adapters:
        return params

    def visit(path, leaf):
        p = path_str(path)
        if p in adapters:
            return materialize(spec_for(cfg, leaf.shape), adapters[p], leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


def merge_tree(cfg: PEFTConfig, params: Tree,
               adapters: Dict[str, Dict[str, Array]]) -> Tree:
    """Offline merge for serving — identical math, applied once."""
    return materialize_tree(cfg, params, adapters)


def count_params(tree: Tree) -> int:
    return sum(int(v.size) for v in jax.tree_util.tree_leaves(tree))


def trainable_and_frozen(cfg: PEFTConfig, params: Tree, adapters: Tree):
    """(trainable, frozen) split for the optimizer/train step."""
    if cfg.method == "full":
        return params, adapters  # adapters empty; everything trains
    if cfg.method == "none":
        return {}, params
    return adapters, params
