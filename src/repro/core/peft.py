"""PEFT engine — attaches adapters to arbitrary parameter trees.

The framework keeps *frozen* base params and *trainable* adapter params as
separate pytrees; the jitted train step calls ``materialize_tree`` to build
effective weights (differentiable w.r.t. adapters only), so:

  * optimizer state exists only for adapters (tiny),
  * base weights can live in bf16 with no master copies,
  * under tensor parallelism the GSOFT rotation adds **zero collectives**
    (Q acts on the unsharded input dim of each Megatron-sharded weight).

Adapted-weight selection is by path regex; weights with leading batch dims
(scan-stacked layers ``(L, d_in, d_out)``, MoE experts ``(L, E, d_in, d_out)``)
receive independent per-slice adapters via vmap.
"""
from __future__ import annotations

import dataclasses
import functools
import re
from typing import Any, Dict, List, Mapping, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from . import methods as methods_lib
from .adapters import AdapterSpec, init_adapter, materialize

Array = jnp.ndarray
Tree = Any

# weights the paper adapts: attention projections + MLP matrices (and the
# SSM in/out projections for the state-space architectures — see DESIGN §5)
DEFAULT_TARGETS: Tuple[str, ...] = (
    r".*/(wq|wk|wv|wo|wi|wg)$",       # attention + MLP/MoE projections
    r".*/(wz|wx)$",                   # mamba in-projections (z / x branches)
    r".*/(in_proj|out_proj)$",
    r".*/wc$",                        # image-family conv channel mixers
)


@dataclasses.dataclass(frozen=True)
class PEFTConfig:
    method: str = "gsoft"          # any core.methods entry, or full|none
    block_size: int = 32
    block_size_out: int = 0
    rank: int = 8
    alpha: float = 16.0
    boft_factors: int = 2
    reflections: int = 4           # householder factor count (even)
    givens_rounds: int = 4         # givens brick-wall round count
    neumann_order: Optional[int] = None
    use_scale: bool = False
    use_pallas: bool = False       # GS rotations via the Pallas kernel path
    target_patterns: Tuple[str, ...] = DEFAULT_TARGETS

    @property
    def is_peft(self) -> bool:
        return methods_lib.is_adapter_method(self.method)


# ---------------------------------------------------------------------------
# path utilities
# ---------------------------------------------------------------------------

def _key_name(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    if hasattr(entry, "name"):
        return str(entry.name)
    return str(entry)


def path_str(path) -> str:
    return "/".join(_key_name(p) for p in path)


def flatten_paths(tree: Tree, is_leaf=None) -> Dict[str, Array]:
    leaves = jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_leaf)[0]
    return {path_str(p): v for p, v in leaves}


def matches_patterns(patterns, path: str) -> bool:
    """fullmatch only: an unanchored target like ``.*/wq`` must not also
    match a decoy weight named ``.../wq_extra`` (the old ``re.search``
    fallback ignored the end anchor). THE one implementation of
    target-pattern semantics — PEFT adapter selection and
    ``quant.weights`` both use it."""
    return any(re.fullmatch(pat, path) for pat in patterns)


def _matches(cfg: PEFTConfig, path: str) -> bool:
    return matches_patterns(cfg.target_patterns, path)


# ---------------------------------------------------------------------------
# spec inference + init
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=4096)
def spec_for(cfg: PEFTConfig, shape: Tuple[int, ...]) -> AdapterSpec:
    """Derive the AdapterSpec for a weight shape. Cached: ``materialize_tree``
    runs inside jit every step and would otherwise re-derive the spec for
    every adapted leaf on every call (cfg and shape are both hashable)."""
    if len(shape) < 2:
        raise ValueError(f"cannot adapt weight of shape {shape}")
    return AdapterSpec(
        method=cfg.method,
        d_in=int(shape[-2]),
        d_out=int(shape[-1]),
        block_size=cfg.block_size,
        block_size_out=cfg.block_size_out,
        rank=cfg.rank,
        alpha=cfg.alpha,
        boft_factors=cfg.boft_factors,
        reflections=cfg.reflections,
        givens_rounds=cfg.givens_rounds,
        neumann_order=cfg.neumann_order,
        use_scale=cfg.use_scale,
        use_pallas=cfg.use_pallas,
        batch=tuple(int(s) for s in shape[:-2]),
    )


def adapted_paths(cfg: PEFTConfig, params: Tree) -> Dict[str, AdapterSpec]:
    """Which weights get adapters, and with what spec.

    Quantized trees work too: a ``QuantTensor`` stays ONE leaf here (its
    ``shape``/``ndim`` mirror the logical weight), so an adapter bank can
    be built over an already-quantized runtime — the adapters themselves
    are always full-precision, applied activation-side.
    """
    if not cfg.is_peft:
        return {}
    from repro.quant.core import is_quant_tensor
    out = {}
    for path, leaf in flatten_paths(params, is_leaf=is_quant_tensor).items():
        if leaf.ndim >= 2 and _matches(cfg, path):
            out[path] = spec_for(cfg, tuple(leaf.shape))
    return out


def init_peft(cfg: PEFTConfig, params: Tree, key: jax.Array,
              dtype=jnp.float32) -> Dict[str, Dict[str, Array]]:
    """Adapter tree: {weight_path: adapter_params}. Empty for full/none."""
    specs = adapted_paths(cfg, params)
    adapters: Dict[str, Dict[str, Array]] = {}
    for i, (path, spec) in enumerate(sorted(specs.items())):
        adapters[path] = init_adapter(spec, jax.random.fold_in(key, i), dtype)
    return adapters


# ---------------------------------------------------------------------------
# materialization / merge
# ---------------------------------------------------------------------------

def materialize_tree(cfg: PEFTConfig, params: Tree,
                     adapters: Dict[str, Dict[str, Array]],
                     merged: bool = False) -> Tree:
    """Effective parameter tree with adapters applied (weight-side).

    Runs inside jit each step; cost is O(2 b d n) per adapted weight —
    a ~b/T fraction of the corresponding GEMM for T tokens (DESIGN §3).

    ``merged=True`` documents the offline single-merge call sites (serving:
    adapters folded into the weights once, zero per-token overhead — paper
    §6.1). The math is identical; the flag only marks intent where the old
    ``merge_tree`` alias used to.
    """
    del merged  # intent marker only — same math either way
    if not adapters:
        return params

    def visit(path, leaf):
        p = path_str(path)
        if p in adapters:
            return materialize(spec_for(cfg, leaf.shape), adapters[p], leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(visit, params)


# ---------------------------------------------------------------------------
# adapter bank: N named adapters + identity slot, per-request serving.
# Heterogeneous: each named adapter declares its own (registered, bankable)
# method; the identity slot stays universal.
# ---------------------------------------------------------------------------

BASE_ADAPTER = "__base__"

PEFTConfigs = Union[PEFTConfig, Mapping[str, PEFTConfig]]


@dataclasses.dataclass
class AdapterBank:
    """Stacked per-request orthogonal rotations for multi-adapter serving.

    ``tree`` mirrors the params nesting: each adapted weight path maps to
    ``{method: factors}`` where ``factors`` are that method's PRE-PROCESSED
    per-slot stacks (Cayley-orthogonalized GS/OFT/BOFT blocks, normalized
    Householder vectors — ``MethodOps.bank_build``; adapters are frozen
    when serving). Slot 0 is the identity (serves the unmodified base
    model); slots 1..N are the named adapters in ``names`` order. In a
    MIXED-method bank every method stack spans all A slots, holding that
    method's identity wherever the slot's adapter uses a different method —
    so slot ids stay universal and the per-row composition of all method
    stacks equals exactly the one non-identity rotation. Scan-stacked
    layer dims stay LEADING (before the A axis) so the model's layer scan
    slices the bank alongside the weights.

    The serving engine applies the bank activation-side — row i of a decode
    batch computes x_i Q_{ids[i]} before each adapted matmul, costing
    O(b*d) per token per weight versus O(d^2) to re-merge a dense rotation;
    that asymmetry is what makes per-request orthogonal adapters viable at
    continuous-batching granularity.
    """
    cfg: PEFTConfig                  # primary/default config (bank knobs)
    names: Tuple[str, ...]           # names[0] == BASE_ADAPTER
    tree: Dict[str, Any]
    # per-adapter configs (adapter names only; absent names use ``cfg``)
    cfgs: Dict[str, PEFTConfig] = dataclasses.field(default_factory=dict)

    @property
    def num_slots(self) -> int:
        return len(self.names)

    @property
    def bank_methods(self) -> Tuple[str, ...]:
        """Methods actually present in this bank (sorted)."""
        return tuple(sorted({c.method for c in self.cfgs.values()}))

    def cfg_for(self, name: str) -> PEFTConfig:
        """The PEFTConfig a named adapter was built with."""
        return self.cfgs.get(name, self.cfg)

    def slot(self, name: Optional[str]) -> int:
        """Bank slot for an adapter name (None / BASE_ADAPTER -> identity)."""
        if name is None:
            return 0
        try:
            return self.names.index(name)
        except ValueError:
            raise KeyError(f"unknown adapter '{name}'; bank has "
                           f"{list(self.names)}") from None

    def context(self, slot_ids) -> "AdapterContext":
        """Bind this bank to a batch of slot ids -> the per-request
        AdapterContext that flows through prefill/decode as ONE pytree."""
        return AdapterContext(bank=self.tree,
                              slots=jnp.asarray(slot_ids, jnp.int32),
                              peft=self.cfg)

    # -- residency surface (trivial here; real on the paged store bank) ------
    def validate(self, name: Optional[str]) -> None:
        """Raise KeyError on an unknown adapter name (None = identity)."""
        self.slot(name)

    def acquire(self, name: Optional[str]) -> Optional[int]:
        """Admission-time slot claim. An eager bank is always fully
        resident, so this is just the slot lookup; the paged store bank
        overrides it with page-in + pinning (may return None = stall)."""
        return self.slot(name)

    def release(self, name: Optional[str]) -> None:
        """Request-finished unpin (no-op for a fully-resident bank)."""


def _nest_insert(root: Dict[str, Any], path: str, value: Any) -> None:
    parts = path.split("/")
    node = root
    for seg in parts[:-1]:
        node = node.setdefault(seg, {})
    node[parts[-1]] = value


def normalize_bank_cfgs(adapters_by_name: Mapping[str, Any],
                        peft_cfg: PEFTConfigs
                        ) -> Tuple[PEFTConfig, Dict[str, PEFTConfig]]:
    """(primary, {name: cfg}) from either a single PEFTConfig (homogeneous
    bank) or a {name: PEFTConfig} mapping (mixed-method bank)."""
    if isinstance(peft_cfg, PEFTConfig):
        return peft_cfg, {name: peft_cfg for name in adapters_by_name}
    cfgs = dict(peft_cfg)
    missing = sorted(set(adapters_by_name) - set(cfgs))
    if missing:
        raise ValueError(f"no PEFTConfig for adapters {missing} — a mixed-"
                         "method bank needs one config per adapter name")
    if not cfgs:
        raise ValueError("empty PEFTConfig mapping — pass a single "
                         "PEFTConfig for an adapterless (identity-only) "
                         "bank")
    primary = next(iter(cfgs.values()))
    return primary, {name: cfgs[name] for name in adapters_by_name}


def bank_capability_check(name: Optional[str], cfg: PEFTConfig) -> None:
    """Registry-driven: the method must be registered AND provide
    ``bank_build`` (``MethodOps.bank_unsupported`` explains why not).
    Shared by eager bank builds AND ``AdapterStore.add`` — a host store
    fails at INSERT time, never at first admission mid-traffic."""
    ops = methods_lib.get(cfg.method)   # KeyError lists registered methods
    if ops.bank_build is None:
        who = f"adapter '{name}'" if name else "the bank config"
        raise ValueError(f"adapter bank cannot serve {who}: method "
                         f"{cfg.method!r} has no bank path — "
                         f"{ops.bank_unsupported}")
    if cfg.use_scale:
        raise ValueError("adapter bank does not support use_scale "
                         "(the per-output magnitude acts on the weight "
                         "output, not the rotated input)")


def check_bank_member(name: str, cfg: PEFTConfig, primary: PEFTConfig,
                      cfg_of_method: Dict[str, PEFTConfig]) -> None:
    """One adapter's admissibility against a bank/store under ``primary``:
    bankable method, bank-wide knobs, one config per method.

    Mutates ``cfg_of_method`` (method -> canonical config). THE shared
    membership rule — ``build_adapter_bank`` applies it when stacking
    up-front, ``repro.store.AdapterStore.add`` applies it at host-insert
    time so a bad adapter is rejected before it can break an admission."""
    bank_capability_check(name, cfg)
    if cfg.target_patterns != primary.target_patterns:
        raise ValueError(
            f"adapter '{name}': target_patterns differ from the bank's "
            "— all adapters in one bank must adapt the same weights")
    if cfg.use_pallas != primary.use_pallas:
        raise ValueError(
            f"adapter '{name}': use_pallas differs from the bank's — "
            "the kernel path is a bank-wide choice")
    prev = cfg_of_method.setdefault(cfg.method, cfg)
    if prev != cfg:
        raise ValueError(
            f"adapter '{name}' shares method {cfg.method!r} with other "
            "adapters but differs in config — one bank holds one stack "
            "(hence one config) per method")


def bank_specs(cfg: PEFTConfig, params: Tree) -> Dict[str, AdapterSpec]:
    """Adapted-path specs a serving bank can actually hold (rejects MoE /
    hybrid multi-batch-dim weights) — shared by the eager ``AdapterBank``
    build and the paged ``repro.store`` bank, so both fail identically."""
    specs = adapted_paths(cfg, params)
    for path, spec in specs.items():
        if len(spec.batch) > 1:
            raise ValueError(
                f"adapter bank cannot serve {path}: weights with batch dims "
                f"{spec.batch} (MoE experts / hybrid blocks) need "
                "routing-aware rotation")
    return specs


def build_adapter_bank(cfg: PEFTConfigs, params: Tree,
                       adapters_by_name: Dict[str, Dict[str, Dict[str, Array]]]
                       ) -> AdapterBank:
    """Build an AdapterBank from named adapter trees (as from ``init_peft``).

    ``cfg`` is a single PEFTConfig (every adapter uses it) or a
    {name: PEFTConfig} mapping for MIXED-method banks. Capability checks
    come from the ``core.methods`` registry: any method providing
    ``bank_build`` can be banked; per path, each method's factors are
    pre-processed up front and stacked over [identity] + adapters along a
    new A axis placed after any scan-stacked weight batch dims (slots of a
    different method hold that method's identity).

    Constraints: all configs must share ``target_patterns`` / ``use_pallas``
    (they define the bank-wide adapted set and kernel path), and adapters
    sharing a method must share its full config (one stack per method).
    """
    primary, cfg_by_name = normalize_bank_cfgs(adapters_by_name, cfg)
    bank_capability_check(None, primary)
    # one stack per method -> same-method adapters must share their config
    cfg_of_method: Dict[str, PEFTConfig] = {}
    names_of_method: Dict[str, set] = {}
    for name, c in cfg_by_name.items():
        check_bank_member(name, c, primary, cfg_of_method)
        names_of_method.setdefault(c.method, set()).add(name)

    specs = bank_specs(primary, params)
    names = (BASE_ADAPTER,) + tuple(adapters_by_name)
    tree: Dict[str, Any] = {}
    for path, spec in sorted(specs.items()):
        shape = tuple(spec.batch) + (spec.d_in, spec.d_out)
        entry: Dict[str, Any] = {}
        for m in sorted(cfg_of_method):
            mcfg = cfg_of_method[m]
            mspec = spec_for(mcfg, shape)
            members = names_of_method[m]
            params_by_slot: List[Optional[Dict[str, Array]]] = [None]
            for name in names[1:]:
                if name not in members:
                    params_by_slot.append(None)     # other method: identity
                    continue
                if path not in adapters_by_name[name]:
                    raise KeyError(
                        f"adapter '{name}' has no params for {path}")
                params_by_slot.append(adapters_by_name[name][path])
            entry[m] = methods_lib.get(m).bank_build(mspec, params_by_slot)
        _nest_insert(tree, path, entry)
    return AdapterBank(cfg=primary, names=names, tree=tree,
                       cfgs=cfg_by_name)


# ---------------------------------------------------------------------------
# adapter context: the ONE pytree that carries per-request adapter state
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class AdapterContext:
    """Per-request adapter state as a single frozen pytree.

    Bundles the stacked bank subtree (``AdapterBank.tree``), the (B,) slot
    ids of the current batch, and the bank's PEFTConfig — replacing the old
    loose ``bank``/``adapter_ids``/``bank_cfg`` kwarg triple. ``bank`` and
    ``slots`` are pytree children (they trace through jit/scan); ``peft`` is
    static aux data (hashable frozen dataclass, part of the jit cache key).

    ``slots`` is either ONE (B,) int32 array indexing every method stack
    (the eager padded ``AdapterBank``, where slot ids are universal because
    each stack holds identities at other methods' slots) or a
    ``{method: (B,) int32}`` dict of per-method COMPACT ids (the paged
    ``repro.store`` bank, whose stacks hold no identity padding — the
    host-side indirection table resolves universal slot -> compact slot
    per method before the context is built, so the device graph is
    identical either way: one gather per method stack).
    """
    bank: Tree                       # nested {path: {"L": ..., "R": ...}}
    slots: Array                     # (B,) int32 ids, or {method: (B,) ids}
    peft: Optional[PEFTConfig] = None

    def tree_flatten(self):
        return (self.bank, self.slots), self.peft

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(bank=children[0], slots=children[1], peft=aux)

    def group(self, *names) -> Optional[Dict]:
        """Bank subtree under ``names`` (e.g. ``"layers"``), or None.

        The returned raw tree is what model code feeds to ``jax.lax.scan``
        alongside the stacked layer weights — scan-slicing and the rotation
        hook below live in one place."""
        node: Any = self.bank
        for n in names:
            node = node.get(n) if isinstance(node, dict) else None
            if node is None:
                return None
        return node or None

    def rotator(self, group: Optional[Dict]) -> Optional["BankRotator"]:
        """Rotation hook over one (scan-sliced) module subtree, e.g.
        ``{"wq": {"L": (A, r, b, b), "R": ...}, ...}``. Returns None when
        there is nothing to rotate, so model code can pass it straight
        through to attention_block/apply_mlp."""
        if group is None or self.slots is None:
            return None
        return BankRotator(group, self.slots, self.peft)


class BankRotator:
    """Per-request rotation hook: ``rot(name, x)`` rotates row i of x with
    its own adapter (slot 0 = identity) before projection ``name``.

    Method-generic: a bank entry is ``{method: factors}`` and each method's
    ``MethodOps.bank_rotator`` applies its stack in turn. In a mixed bank
    at most one stack is non-identity for any given row, so the composition
    order is immaterial — it is fixed (sorted) only for trace stability.

    Besides being callable, it exposes ``quant_rotation`` so the
    ``qlinear`` hook can fuse the GS rotation + quantized base matmul into
    one ``gs_q_matmul_banked`` call instead of round-tripping the rotated
    slab through HBM. All factors are gathered/cast to the ACTIVATION
    dtype: rotations stay bf16 for EVERY method even when the base weights
    are int8 (QOFT rationale, DESIGN.md)."""

    __slots__ = ("_group", "slots", "_peft")

    def __init__(self, group: Dict, slots: Array,
                 peft: Optional[PEFTConfig]):
        self._group = group
        self.slots = slots
        self._peft = peft

    @property
    def use_pallas(self) -> bool:
        return self._peft.use_pallas if self._peft else False

    def _ids(self, method: str) -> Array:
        """Per-row ids into ``method``'s stack: universal slot ids index
        every stack of a padded bank; a slot-compacted store bank carries
        per-method compact ids (``AdapterContext.slots`` as a dict)."""
        if isinstance(self.slots, dict):
            return self.slots[method]
        return self.slots

    def __call__(self, name: str, x: Array) -> Array:
        entry = self._group.get(name)
        if entry is None:
            return x
        for m in sorted(entry):
            x = methods_lib.get(m).bank_rotator(entry[m], self._ids(m), x,
                                                self.use_pallas)
        return x

    def quant_rotation(self, name: str, x: Array, dtype
                       ) -> Tuple[Array, Optional[Tuple[Array, ...]]]:
        """Split the rotation for a QUANTIZED base matmul: apply every
        method stack that cannot fuse with the quantized kernel, and
        return the per-row factors of the (at most one) method that can
        (``MethodOps.quant_fuse`` — GSOFT's (L, R) today).

        -> (x with unfusible rotations applied, fusible factors or None).
        """
        entry = self._group.get(name)
        if entry is None:
            return x, None
        fused = None
        for m in sorted(entry):
            ops = methods_lib.get(m)
            if fused is None and ops.quant_fuse is not None:
                fused = ops.quant_fuse(entry[m], self._ids(m), dtype)
            else:
                x = ops.bank_rotator(entry[m], self._ids(m), x,
                                     self.use_pallas)
        return x, fused


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PrefillRequest:
    """Everything one prefill call needs beyond params/state, as a pytree:
    the input batch, the per-row ``last_idx`` (index of each row's last
    valid prompt position — the ragged-prompt fix), and the optional
    AdapterContext. Folds the old ``last_idx`` special-case kwarg and the
    adapter triple into one argument."""
    batch: Dict[str, Array]
    last_idx: Optional[Array] = None
    ctx: Optional[AdapterContext] = None

    def tree_flatten(self):
        return (self.batch, self.last_idx, self.ctx), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        del aux
        return cls(batch=children[0], last_idx=children[1], ctx=children[2])


def count_params(tree: Tree) -> int:
    return sum(int(v.size) for v in jax.tree_util.tree_leaves(tree))


def trainable_and_frozen(cfg: PEFTConfig, params: Tree, adapters: Tree):
    """(trainable, frozen) split for the optimizer/train step (the
    ``full``/``none`` pseudo-methods are interpreted by the registry
    module — the one place method strings are compared)."""
    return methods_lib.trainable_split(cfg.method, params, adapters)
