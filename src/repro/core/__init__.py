"""repro.core — the paper's contribution: GS matrices, orthogonal
parametrization, projection, PEFT adapters, and GS orthogonal convolutions."""
from .permutations import (PermSpec, apply_perm, apply_perm_T, gs_sigma,
                           paired_sigma, inverse_sigma, compose_sigma,
                           perm_matrix, is_permutation)
from .gs import (BlockDiagSpec, GSLayout, GSFactors, gsoft_layout,
                 pick_block_size, init_blocks, block_diag_matmul, gs_apply,
                 gs_apply_T, gs_matmul, gs_materialize, materialize_block_diag,
                 block_ranks, lowrank_blocks, gs_order_layout,
                 gs_factors_apply, gs_factors_materialize, min_factors_dense,
                 support_pattern, is_dense_class)
from .orthogonal import (skew, cayley, cayley_inverse, orthogonal_blocks,
                         orthogonality_error, project_orthogonal,
                         random_orthogonal_blocks)
from .projection import project_to_gs, gs_reconstruction_error
from .adapters import (AdapterSpec, init_adapter, materialize, merge,
                       num_adapter_params, butterfly_sigma,
                       apply_activation_side, gs_rotate_banked)
from .methods import MethodOps
from . import methods
from .peft import (PEFTConfig, init_peft, materialize_tree,
                   adapted_paths, count_params, flatten_paths,
                   trainable_and_frozen, DEFAULT_TARGETS, AdapterBank,
                   build_adapter_bank, AdapterContext, PrefillRequest,
                   BASE_ADAPTER)
