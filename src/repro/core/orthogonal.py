"""Orthogonality machinery: Cayley parametrization of GS blocks.

OFT / BOFT / GSOFT all enforce orthogonality per block via the Cayley map

    Q = (I + K)(I - K)^{-1},      K = A - A^T  (skew-symmetric)

K = 0  =>  Q = I, which gives the identity initialization all orthogonal
fine-tuning methods rely on.  Theorem 1 of the paper guarantees block-wise
Cayley loses no orthogonal GS matrix (up to the measure-zero Cayley domain).

Two evaluation paths:
  * exact  — batched LU solve in fp32 (default; blocks are tiny, b <= 128)
  * neumann — truncated series (I-K)^{-1} ~ sum K^t, as in BOFT's codebase;
    cheaper on MXU (matmuls only, no solve), used in §Perf experiments.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def skew(a: Array) -> Array:
    """K = A - A^T over the last two dims (batched)."""
    return a - jnp.swapaxes(a, -1, -2)


def cayley(k_skew: Array, *, neumann_order: Optional[int] = None) -> Array:
    """Batched Cayley map Q = (I + K)(I - K)^{-1} over the last two dims.

    ``k_skew`` must already be skew-symmetric (use ``skew``).  Solve runs in
    fp32 regardless of input dtype; the result is cast back.
    """
    dtype = k_skew.dtype
    k32 = k_skew.astype(jnp.float32)
    eye = jnp.eye(k32.shape[-1], dtype=jnp.float32)
    if neumann_order is not None:
        # (I - K)^{-1} ~ I + K + K^2 + ... + K^order  (Horner)
        inv = eye
        for _ in range(neumann_order):
            inv = eye + k32 @ inv
        q = (eye + k32) @ inv
    else:
        # solve(I + K, I - K)^T = (I + K)(I - K)^{-1}   since (I-K)^T = I+K
        q = jnp.swapaxes(jnp.linalg.solve(eye + k32, eye - k32), -1, -2)
    return q.astype(dtype)


def cayley_inverse(q: Array) -> Array:
    """K with cayley(K) = Q (for Q without -1 eigenvalue): K = (Q-I)(Q+I)^{-1}.

    Computed as solve((Q+I)^T, (Q-I)^T)^T so it stays a single batched LU.
    """
    q32 = q.astype(jnp.float32)
    eye = jnp.eye(q32.shape[-1], dtype=jnp.float32)
    k = jnp.linalg.solve(jnp.swapaxes(q32 + eye, -1, -2),
                         jnp.swapaxes(q32 - eye, -1, -2))
    return jnp.swapaxes(k, -1, -2).astype(q.dtype)


def orthogonal_blocks(params: Array, *, neumann_order: Optional[int] = None) -> Array:
    """Map free parameters (k, b, b) -> orthogonal blocks via skew + Cayley."""
    return cayley(skew(params), neumann_order=neumann_order)


def orthogonality_error(q: Array) -> Array:
    """max |Q^T Q - I| over a batch of blocks (diagnostic / tests)."""
    eye = jnp.eye(q.shape[-1], dtype=q.dtype)
    gram = jnp.swapaxes(q, -1, -2) @ q
    return jnp.max(jnp.abs(gram - eye))


def project_orthogonal(a: Array) -> Array:
    """Nearest orthogonal matrix (polar factor) per block, via SVD."""
    u, _, vt = jnp.linalg.svd(a.astype(jnp.float32), full_matrices=False)
    return (u @ vt).astype(a.dtype)


def random_orthogonal_blocks(rng: np.random.Generator, k: int, b: int,
                             dtype=jnp.float32) -> Array:
    """Haar-ish random orthogonal blocks (QR of Gaussian), for tests."""
    g = rng.normal(size=(k, b, b))
    qs = []
    for i in range(k):
        q, r = np.linalg.qr(g[i])
        q = q * np.sign(np.diag(r))[None, :]
        qs.append(q)
    return jnp.asarray(np.stack(qs), dtype=dtype)
