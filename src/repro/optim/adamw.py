"""AdamW + SGD-momentum, built from scratch (no optax in this container).

Functional: ``init`` returns a state pytree, ``update`` maps
(grads, state, params) -> (new_params, new_state).  Weight decay is masked
off 1-D params (norm scales, biases).  Global-norm clipping included.
The PEFT split means these states exist only for adapter params in
fine-tuning runs — a few MB even for the 123B config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray
Tree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    kind: str = "adamw"              # adamw | sgd
    learning_rate: float = 1e-3      # peak LR (schedules scale it)
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    momentum: float = 0.9            # sgd


def _decay_mask(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.asarray(1.0 if p.ndim >= 2 else 0.0,
                                              jnp.float32), params)


def global_norm(tree: Tree) -> Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves) + 1e-30)


def clip_by_global_norm(grads: Tree, max_norm: float) -> Tuple[Tree, Array]:
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def init(cfg: OptimizerConfig, params: Tree) -> Tree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    if cfg.kind == "adamw":
        return {"mu": jax.tree.map(zeros, params),
                "nu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}
    if cfg.kind == "sgd":
        return {"mu": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.kind)


def update(cfg: OptimizerConfig, grads: Tree, state: Tree, params: Tree,
           lr_scale: Array = 1.0) -> Tuple[Tree, Tree, dict]:
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cfg.learning_rate * lr_scale
    mask = _decay_mask(params)

    if cfg.kind == "adamw":
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                          state["nu"], grads)
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, m, v, dm):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            delta = delta + cfg.weight_decay * dm * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu, mask)
        return new_params, {"mu": mu, "nu": nu, "step": step}, {"grad_norm": gn}

    if cfg.kind == "sgd":
        mu = jax.tree.map(lambda m, g: cfg.momentum * m + g,
                          state["mu"], grads)

        def upd(p, m, dm):
            delta = m + cfg.weight_decay * dm * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, mask)
        return new_params, {"mu": mu, "step": step}, {"grad_norm": gn}
    raise ValueError(cfg.kind)
