"""Optimizers, schedules, gradient compression (built from scratch — the
container has no optax)."""
from .adamw import (OptimizerConfig, init, update, clip_by_global_norm,
                    global_norm)
from .schedules import constant, warmup_cosine, warmup_linear
from .compression import (quantize_int8, dequantize_int8, ef_compress,
                          init_error_buffer, compressed_psum_mean)
