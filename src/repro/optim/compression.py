"""int8 error-feedback gradient compression for the DP all-reduce.

At 1000+ node scale the data-parallel gradient all-reduce over DCI (the
"pod" axis) is the slowest collective; int8 quantization cuts its bytes 4x
(vs fp32) / 2x (vs bf16).  Error feedback keeps the *accumulated* quantizer
error in an fp32 buffer added back before the next quantization — the
standard fix that restores convergence for biased compressors.

``compressed_psum_mean`` is built on shard_map: quantize locally ->
all_gather int8 (+ fp32 scales) -> dequantize-mean locally.  The dry-run
lowers it to measure the collective-byte reduction (§Perf).

The int8 codec itself lives in ``repro.quant.core`` (ONE implementation
shared with serving-side weight quantization); ``quantize_int8`` /
``dequantize_int8`` are re-exported here for the error-feedback call
sites. New code should import them from ``repro.quant``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.quant.core import (dequantize_int8,  # noqa: F401  (re-export)
                              quantize_int8)

Tree = Any


def ef_compress(grads: Tree, err: Tree) -> Tuple[Tree, Tree, Tree]:
    """Error-feedback quantization: returns (q_tree, scale_tree, new_err)."""
    def one(g, e):
        corrected = g.astype(jnp.float32) + e
        q, s = quantize_int8(corrected)
        new_e = corrected - dequantize_int8(q, s)
        return (q, s), new_e
    qs = jax.tree.map(one, grads, err)
    q_tree = jax.tree.map(lambda t: t[0][0], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    s_tree = jax.tree.map(lambda t: t[0][1], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    e_tree = jax.tree.map(lambda t: t[1], qs,
                          is_leaf=lambda t: isinstance(t, tuple))
    return q_tree, s_tree, e_tree


def init_error_buffer(grads_like: Tree) -> Tree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def compressed_psum_mean(tree: Tree, err: Tree, mesh, axes: Tuple[str, ...]):
    """Mean-reduce ``tree`` over mesh ``axes`` with int8 compression.

    Returns (reduced_tree fp32, new_error_buffer). Each leaf is assumed
    replicated over ``axes`` holding the *local* contribution (the standard
    per-shard gradient before psum).
    """
    from jax.experimental.shard_map import shard_map

    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def inner(t, e):
        q, s, new_e = ef_compress(t, e)

        def reduce_leaf(qq, ss):
            allq, alls = qq, ss
            for a in axes:                               # each gather prepends
                allq = jax.lax.all_gather(allq, a)       # one mesh-axis dim
                alls = jax.lax.all_gather(alls, a)
            lead = len(axes)
            deq = allq.astype(jnp.float32) * alls.reshape(
                alls.shape + (1,) * qq.ndim)
            return jnp.mean(deq, axis=tuple(range(lead)))

        red = jax.tree.map(reduce_leaf, q, s)
        return red, new_e

    spec = jax.tree.map(lambda _: P(), tree)
    fn = shard_map(inner, mesh=mesh,
                   in_specs=(spec, spec), out_specs=(spec, spec),
                   check_rep=False)
    return fn(tree, err)
