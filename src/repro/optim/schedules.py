"""LR schedules as pure step -> scale functions (multiply the peak LR)."""
from __future__ import annotations

import jax.numpy as jnp


def constant():
    return lambda step: jnp.asarray(1.0, jnp.float32)


def warmup_cosine(warmup_steps: int, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        w = jnp.maximum(warmup_steps, 1)
        warm = s / w
        prog = jnp.clip((s - warmup_steps) /
                        jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn


def warmup_linear(warmup_steps: int, total_steps: int):
    def fn(step):
        s = jnp.asarray(step, jnp.float32)
        warm = s / jnp.maximum(warmup_steps, 1)
        decay = jnp.clip(1.0 - (s - warmup_steps) /
                         jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        return jnp.where(s < warmup_steps, warm, decay)
    return fn
