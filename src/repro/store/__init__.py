"""Adapter store: host-offloaded named adapters + LRU-paged HBM banks.

``AdapterStore`` is the host/disk residency tier ("one adapter per
customer"); ``PagedAdapterBank`` is its fixed-budget HBM view with
slot-compacted per-method stacks. ``ModelRuntime.attach`` accepts either
a store (paged) or pre-built ``AdapterBank`` (eager) behind one API.
"""
from .paging import PagedAdapterBank, split_budget
from .store import AdapterStore, load_adapter_checkpoints

__all__ = ["AdapterStore", "PagedAdapterBank", "load_adapter_checkpoints",
           "split_budget"]
