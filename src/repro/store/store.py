"""AdapterStore — host-offloaded residency for thousands of named adapters.

The serving bank used to be the ONLY residency tier: every named adapter
was pre-processed and stacked into HBM up front, and the heterogeneous
representation held identity factors at every other method's slots —
O(N_adapters x N_methods) waste that capped tenant count at whatever one
bank build could afford. The store splits residency into tiers:

  host RAM   — RAW adapter param trees as numpy (this module); cheap,
               effectively unbounded ("one adapter per customer")
  disk       — optional backing via the checkpoint manager's per-name
               method+spec index (``AdapterStore.open``): only the index
               is read up front, each adapter's leaves load on first use
  HBM        — a fixed-budget ``PagedAdapterBank`` (``repro.store.paging``)
               that pages adapters in on admission with LRU eviction

Capability checks run at INSERT time: ``add`` applies the same
``core.peft.check_bank_member`` rule as an eager bank build, so a method
with no bank path (lora, double_gsoft) or a mismatched config is rejected
when the adapter enters the store — naming the method and the reason —
never at first admission mid-traffic.
"""
from __future__ import annotations

from typing import Dict, Iterator, Mapping, Optional, Tuple

import jax
import numpy as np

from repro.core import peft as peft_lib

Tree = Dict[str, Dict[str, np.ndarray]]      # {weight_path: {param: arr}}


def _host_tree(adapters: Tree) -> Tree:
    """Pull one adapter's params to host numpy (frees device memory; the
    store is the off-HBM tier)."""
    return {path: {k: np.asarray(jax.device_get(v)) for k, v in entry.items()}
            for path, entry in adapters.items()}


class AdapterStore:
    """Named adapters living in host RAM (optionally disk-backed), plus
    the config bookkeeping a paged bank needs: one canonical PEFTConfig
    per method, bank-wide target/kernel knobs from the first insert."""

    def __init__(self, cfg: Optional[peft_lib.PEFTConfig] = None):
        # name -> PEFTConfig; insertion-ordered (stable demo/bench traffic)
        self._cfgs: Dict[str, peft_lib.PEFTConfig] = {}
        self._host: Dict[str, Tree] = {}
        self._cfg_of_method: Dict[str, peft_lib.PEFTConfig] = {}
        self._primary = cfg                      # set by first add() if None
        if cfg is not None:
            peft_lib.bank_capability_check(None, cfg)
        self._manager = None                     # checkpoint backing (open)
        self._ckpt_step: Optional[int] = None

    # -- introspection -------------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(self._cfgs)

    @property
    def primary_cfg(self) -> peft_lib.PEFTConfig:
        if self._primary is None:
            raise ValueError(
                "empty AdapterStore has no PEFTConfig — add an adapter, or "
                "construct AdapterStore(cfg=...) for an identity-only store")
        return self._primary

    def __contains__(self, name: str) -> bool:
        return name in self._cfgs

    def __len__(self) -> int:
        return len(self._cfgs)

    def __iter__(self) -> Iterator[str]:
        return iter(self._cfgs)

    def cfg_for(self, name: str) -> peft_lib.PEFTConfig:
        if name not in self._cfgs:
            raise KeyError(f"store has no adapter {name!r}; it holds "
                           f"{sorted(self._cfgs)}")
        return self._cfgs[name]

    def method_of(self, name: str) -> str:
        return self.cfg_for(name).method

    def cfg_of_method(self, method: str) -> peft_lib.PEFTConfig:
        return self._cfg_of_method[method]

    def method_counts(self) -> Dict[str, int]:
        counts: Dict[str, int] = {}
        for c in self._cfgs.values():
            counts[c.method] = counts.get(c.method, 0) + 1
        return counts

    # -- mutation ------------------------------------------------------------
    def add(self, name: str, adapters: Tree,
            peft_cfg: peft_lib.PEFTConfig) -> None:
        """Insert a named RAW adapter tree (as from ``init_peft``).

        All bank-membership rules run HERE: a ``bank_build=None`` method
        (lora / double_gsoft), ``use_scale``, mismatched target patterns /
        kernel path, or a same-method config fork all raise at insert time
        with the method named — the paged bank can then assume every store
        entry is admissible and page under traffic without surprises."""
        if name == peft_lib.BASE_ADAPTER:
            raise ValueError(f"{name!r} is the reserved identity slot")
        if name in self._cfgs:
            raise ValueError(f"store already holds adapter {name!r} — "
                             "remove() it first to replace")
        primary = self._primary if self._primary is not None else peft_cfg
        trial = dict(self._cfg_of_method)
        peft_lib.check_bank_member(name, peft_cfg, primary, trial)
        self._cfg_of_method = trial
        self._primary = primary
        self._cfgs[name] = peft_cfg
        self._host[name] = _host_tree(adapters)

    def remove(self, name: str) -> None:
        self.cfg_for(name)                       # KeyError listing names
        del self._cfgs[name]
        self._host.pop(name, None)
        counts = self.method_counts()
        self._cfg_of_method = {m: c for m, c in self._cfg_of_method.items()
                               if m in counts}

    def adapters_for(self, name: str) -> Tree:
        """The raw host param tree for one adapter; disk-backed entries
        load lazily on first use and stay cached in host RAM."""
        self.cfg_for(name)
        if name not in self._host:               # disk-backed (open())
            self._host[name] = _host_tree(
                self._manager.load_adapter(name, step=self._ckpt_step))
        return self._host[name]

    # -- persistence ---------------------------------------------------------
    def save(self, directory: str, step: int = 0) -> None:
        """Persist the store as an adapter-bank checkpoint (the same
        per-name method+spec index ``save_adapters`` has always written —
        old checkpoints open as stores and vice versa)."""
        from repro.checkpoint.manager import CheckpointManager
        CheckpointManager(directory).save_adapters(
            step, {name: self.adapters_for(name) for name in self._cfgs},
            dict(self._cfgs) if self._cfgs else self.primary_cfg)

    @classmethod
    def open(cls, directory: str,
             step: Optional[int] = None) -> "AdapterStore":
        """Disk-backed store over an adapter-bank checkpoint: reads ONLY
        the index (names + per-name method/spec); adapter leaves load on
        first ``adapters_for``/page-in."""
        from repro.checkpoint.manager import CheckpointManager
        mgr = CheckpointManager(directory)
        names, cfgs, _ = mgr.adapter_index(step=step)
        store = cls()
        store._manager = mgr
        store._ckpt_step = step
        for name in names:
            cfg = cfgs[name]
            primary = store._primary if store._primary is not None else cfg
            peft_lib.check_bank_member(name, cfg, primary,
                                       store._cfg_of_method)
            store._primary = primary
            store._cfgs[name] = cfg
        return store

    @classmethod
    def from_adapters(cls, adapters_by_name: Mapping[str, Tree],
                      peft_cfg: "peft_lib.PEFTConfigs") -> "AdapterStore":
        """Store from in-memory named adapters + a single PEFTConfig or a
        {name: PEFTConfig} mapping (the old ``with_bank`` argument pair)."""
        primary, cfg_by_name = peft_lib.normalize_bank_cfgs(
            adapters_by_name, peft_cfg)
        store = cls(cfg=primary)
        for name, tree in adapters_by_name.items():
            store.add(name, tree, cfg_by_name[name])
        return store


def load_adapter_checkpoints(entries) -> Tuple[Dict[str, Tree],
                                               "peft_lib.PEFTConfigs"]:
    """``entries``: ["name=ckpt_dir" | "ckpt_dir"] -> (adapters_by_name,
    cfg) where ``cfg`` is a single PEFTConfig (homogeneous) or a
    {name: PEFTConfig} mapping — exactly what ``ModelRuntime.attach``
    accepts. A bare dir loads every adapter in that checkpoint;
    ``name=dir`` picks one. An entry that IS an existing directory is
    always treated as bare, so checkpoint paths containing ``=`` are not
    misparsed."""
    import os

    from repro.checkpoint.manager import CheckpointManager
    adapters_by_name: Dict[str, Tree] = {}
    cfg_by_name: Dict[str, peft_lib.PEFTConfig] = {}
    for entry in entries:
        if os.path.isdir(entry) or "=" not in entry:
            name, path = "", entry
        else:
            # split at the FIRST '=': adapter names never contain '=',
            # checkpoint paths may
            name, _, path = entry.partition("=")
        loaded, cfgs = CheckpointManager(path).restore_adapters()
        if name:          # name=dir form: pick one adapter out of the bank
            if name not in loaded:
                raise KeyError(f"{path} has adapters {list(loaded)}, "
                               f"not {name!r}")
            loaded = {name: loaded[name]}
        for n in loaded:
            prev = cfg_by_name.get(n)
            if prev is not None and prev != cfgs[n]:
                raise ValueError(f"adapter {n!r} ({entry}): PEFTConfig "
                                 f"mismatch ({cfgs[n]} != {prev})")
            cfg_by_name[n] = cfgs[n]
        adapters_by_name.update(loaded)
    if not cfg_by_name:
        raise ValueError("no adapter checkpoints given")
    if len(set(cfg_by_name.values())) == 1:       # frozen -> hashable
        return adapters_by_name, next(iter(cfg_by_name.values()))
    return adapters_by_name, cfg_by_name
