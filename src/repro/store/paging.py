"""PagedAdapterBank — fixed-HBM-budget view over AdapterStore pages.

The eager ``AdapterBank`` pre-builds every adapter into HBM and pads each
method's stack with identities at every OTHER method's slots, so resident
bytes scale O(N_adapters x N_methods). This bank fixes both axes:

  slot compaction   Each method's stack holds ONLY its own members:
                    shape ``(batch..., c_m + 1, ...)`` where ``c_m`` is
                    that method's share of the HBM budget and compact
                    slot 0 is the method identity. Universal slot ids
                    (0 = base, 1..capacity) survive unchanged — a host
                    indirection table per method maps universal slot ->
                    compact slot (0 where the slot's adapter uses a
                    different method), and ``context()`` resolves it into
                    the per-method ``{method: (B,) ids}`` dict that
                    ``BankRotator`` gathers with. The device graph is
                    identical to the padded bank: one gather per stack.

  LRU paging        Adapters page in at admission: factors come from the
                    host page cache (an evict->re-admit round trip never
                    re-runs ``bank_build``) or are built on the spot via
                    ``MethodOps.bank_build`` from the store's raw params,
                    then written into the method stack at the claimed
                    compact slot. Victims are the least-recently-admitted
                    UNPINNED members of the same method region; active
                    requests pin their adapter, so ``acquire`` returns
                    None (admission stall) rather than evicting a page a
                    resident slot is still decoding with — a full bank
                    never blocks decode of resident slots.

Stack shapes are fixed at construction (jit traces once; page-in swaps
array CONTENTS at unchanged shapes, so no retrace ever happens under
traffic). That is also why the per-method capacities ``c_m`` are static —
a hot method cannot borrow slots from a cold one mid-flight, because
borrowing would resize a stack and retrace every jitted step.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import methods as methods_lib
from repro.core import peft as peft_lib
from repro.obs.metrics import REGISTRY

from .store import AdapterStore

Tree = Any

#: reservoir size for the page-in latency histogram. The pre-obs bank kept
#: an append-forever ``page_in_ms`` LIST, which grew one float per miss for
#: the life of the process — a real leak under thousand-tenant churn. A
#: bounded reservoir keeps the p50/p95 queries and constant memory.
PAGE_IN_HIST_CAP = 1024


def split_budget(budget: int, counts: Dict[str, int]) -> Dict[str, int]:
    """Per-method compact capacities: proportional to store population,
    at least 1 each, never more than the method has members. Deterministic
    (ties break on method name)."""
    methods = sorted(counts)
    if not methods:
        return {}
    if budget < len(methods):
        raise ValueError(
            f"hbm_budget={budget} cannot hold one adapter per method — the "
            f"store mixes {len(methods)} methods ({methods})")
    caps = {m: 1 for m in methods}
    remaining = budget - len(methods)
    while remaining > 0:
        # most under-served method relative to its population, name-tied
        open_m = [m for m in methods if caps[m] < counts[m]]
        if not open_m:
            break
        pick = max(open_m, key=lambda m: (counts[m] / caps[m], m))
        caps[pick] += 1
        remaining -= 1
    return caps


class PagedAdapterBank:
    """LRU-paged, slot-compacted HBM bank over an ``AdapterStore``.

    Duck-types the ``AdapterBank`` serving surface (``context`` /
    ``validate`` / ``acquire`` / ``release`` / ``bank_methods`` / ``cfg``)
    so ``ModelRuntime`` and ``ServeEngine`` drive either interchangeably.
    """

    def __init__(self, store: AdapterStore, params: Tree, *,
                 hbm_budget: Optional[int] = None):
        self.store = store
        counts = store.method_counts()
        if hbm_budget is None:
            hbm_budget = max(len(store), 1)     # everything fits; still compact
        self.caps = split_budget(hbm_budget, counts)
        self.capacity = sum(self.caps.values())     # universal slots 1..cap
        self._methods: Tuple[str, ...] = tuple(sorted(self.caps))
        self.cfg = store.primary_cfg
        self._specs = peft_lib.bank_specs(self.cfg, params)

        # device stacks: {path: {method: {factor: (batch.., c_m+1, ...)}}}
        # _stacks[path][m] is the SAME dict object nested into self.tree,
        # so in-place page writes flow into every context built afterwards.
        self._stacks: Dict[str, Dict[str, Dict[str, jnp.ndarray]]] = {}
        self.tree: Dict[str, Any] = {}
        # per-path A-axis index: the slot axis sits after any scan-stacked
        # weight batch dims, which differ per weight, not per method
        self._axis: Dict[str, int] = {}
        for path, spec in sorted(self._specs.items()):
            shape = tuple(spec.batch) + (spec.d_in, spec.d_out)
            self._axis[path] = len(spec.batch)
            entry: Dict[str, Dict[str, jnp.ndarray]] = {}
            for m in self._methods:
                mspec = peft_lib.spec_for(store.cfg_of_method(m), shape)
                entry[m] = methods_lib.get(m).bank_build(
                    mspec, [None] * (self.caps[m] + 1))   # all-identity
            self._stacks[path] = entry
            peft_lib._nest_insert(self.tree, path, entry)

        # host indirection: universal slot -> compact slot, per method
        self._lut: Dict[str, np.ndarray] = {
            m: np.zeros(self.capacity + 1, np.int32) for m in self._methods}
        # residency: name -> (universal slot, method, compact slot)
        self._resident: Dict[str, Tuple[int, str, int]] = {}
        self._lru: Dict[str, None] = {}             # insertion-ordered
        self._pins: Dict[str, int] = {}
        self._free_universal: List[int] = list(range(self.capacity, 0, -1))
        self._free_compact: Dict[str, List[int]] = {
            m: list(range(self.caps[m], 0, -1)) for m in self._methods}
        # built factor pages on host — evict->re-admit skips bank_build
        self._page_cache: Dict[str, Dict[str, Dict[str, np.ndarray]]] = {}
        # instruments in the process metrics plane; `counters` (property)
        # and `stats()` are views. page_in_ms is a BOUNDED histogram now.
        scope = REGISTRY.scope("bank")
        self._c = scope.counters("hits", "misses", "evictions", "stalls",
                                 "builds", "build_cache_hits")
        self._page_in_ms = scope.histogram("page_in_ms",
                                           cap=PAGE_IN_HIST_CAP)
        self._max_resident = scope.gauge("max_resident")
        # bumped on every residency change (page-in / evict): engines key
        # their per-step AdapterContext cache on (slot ids, version), so a
        # context built over stale stacks can never serve a decode step
        self.version = 0

    # -- AdapterBank surface --------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        """Every servable name (host tier), identity first — residency is
        an implementation detail of the fixed HBM budget."""
        return (peft_lib.BASE_ADAPTER,) + self.store.names

    @property
    def num_slots(self) -> int:
        return self.capacity + 1

    @property
    def bank_methods(self) -> Tuple[str, ...]:
        return self._methods

    @property
    def resident(self) -> Tuple[str, ...]:
        return tuple(self._resident)

    def is_resident(self, name: str) -> bool:
        """Is this adapter's factor set currently paged into HBM? The
        cluster router's affinity probe — warm here means admitting here
        skips the page-in entirely."""
        return name in self._resident

    def cfg_for(self, name: str) -> peft_lib.PEFTConfig:
        return self.store.cfg_for(name)

    def _unknown(self, name: str) -> KeyError:
        return KeyError(
            f"unknown adapter {name!r}; resident: "
            f"{sorted(self._resident)}; host store holds "
            f"{sorted(self.store.names)}")

    def validate(self, name: Optional[str]) -> None:
        """Raise KeyError (listing resident AND host-side names) unless
        ``name`` is servable. Does not touch residency."""
        if name is not None and name not in self.store:
            raise self._unknown(name)

    def slot(self, name: Optional[str]) -> int:
        """Universal slot of a RESIDENT adapter (None -> 0). Unlike the
        eager bank this can miss for a known name — admission goes through
        ``acquire``, which pages in."""
        if name is None:
            return 0
        rec = self._resident.get(name)
        if rec is None:
            if name in self.store:
                raise KeyError(
                    f"adapter {name!r} is in the store but not resident — "
                    "admission must go through acquire(), which pages it in")
            raise self._unknown(name)
        return rec[0]

    def context(self, slot_ids) -> peft_lib.AdapterContext:
        """Per-request context from UNIVERSAL slot ids: the host luts
        resolve them into per-method compact ids; the device graph then
        matches the padded bank exactly (one gather per method stack)."""
        ids = np.asarray(slot_ids, np.int32)
        slots = {m: jnp.asarray(self._lut[m][ids]) for m in self._methods}
        return peft_lib.AdapterContext(bank=self.tree, slots=slots,
                                       peft=self.cfg)

    # -- residency ------------------------------------------------------------
    def acquire(self, name: Optional[str]) -> Optional[int]:
        """Admission: pin ``name`` and return its universal slot, paging
        it in first on a miss. Returns None when every compact slot of the
        adapter's method is pinned by in-flight requests (admission stall
        — the caller keeps decoding resident slots and retries later).
        Balance every non-None acquire with ``release``."""
        if name is None:
            return 0
        if name not in self.store:
            raise self._unknown(name)
        rec = self._resident.get(name)
        if rec is not None:
            self._c["hits"].inc()
            self._lru.pop(name, None)
            self._lru[name] = None                   # move to MRU
            self._pins[name] = self._pins.get(name, 0) + 1
            return rec[0]

        method = self.store.method_of(name)
        if method not in self.caps:
            raise ValueError(
                f"adapter {name!r} uses method {method!r}, added to the "
                "store after this bank was built — re-attach to size a "
                "compact region for it")
        self._c["misses"].inc()
        if not self._free_compact[method]:
            victim = next((n for n in self._lru
                           if self._resident[n][1] == method
                           and not self._pins.get(n)), None)
            if victim is None:
                self._c["stalls"].inc()
                return None
            self._evict(victim)
        cslot = self._free_compact[method].pop()
        # every resident holds one universal + one compact slot, so a free
        # compact slot guarantees a free universal one
        uslot = self._free_universal.pop()

        t0 = time.perf_counter()
        self._page_in(name, method, cslot)
        self._page_in_ms.observe((time.perf_counter() - t0) * 1e3)
        self._lut[method][uslot] = cslot
        self._resident[name] = (uslot, method, cslot)
        self._lru[name] = None
        self._pins[name] = self._pins.get(name, 0) + 1
        self._max_resident.set_max(len(self._resident))
        return uslot

    def release(self, name: Optional[str]) -> None:
        """Request finished: unpin (the page stays resident until LRU
        eviction needs its compact slot)."""
        if name is None or name not in self._pins:
            return
        self._pins[name] -= 1
        if self._pins[name] <= 0:
            del self._pins[name]

    def _evict(self, name: str) -> None:
        self.version += 1
        uslot, method, cslot = self._resident.pop(name)
        self._lru.pop(name, None)
        self._lut[method][uslot] = 0                 # universal id -> identity
        self._free_universal.append(uslot)
        self._free_compact[method].append(cslot)
        self._c["evictions"].inc()
        # the stale page stays in the stack: nothing maps to its compact
        # slot until a new admission overwrites it

    # -- page materialization -------------------------------------------------
    def _pages_for(self, name: str,
                   method: str) -> Dict[str, Dict[str, np.ndarray]]:
        """Built (pre-processed) factor pages for one adapter, one per
        adapted path — from the host page cache, else ``bank_build`` over
        the store's raw params (pulled lazily from disk if backed)."""
        cached = self._page_cache.get(name)
        if cached is not None:
            self._c["build_cache_hits"].inc()
            return cached
        self._c["builds"].inc()
        mcfg = self.store.cfg_of_method(method)
        ops = methods_lib.get(method)
        raw = self.store.adapters_for(name)
        pages: Dict[str, Dict[str, np.ndarray]] = {}
        for path, spec in self._specs.items():
            if path not in raw:
                raise KeyError(f"adapter {name!r} has no params for {path}")
            shape = tuple(spec.batch) + (spec.d_in, spec.d_out)
            mspec = peft_lib.spec_for(mcfg, shape)
            built = ops.bank_build(mspec, [raw[path]])     # A=1 stack
            axis = len(mspec.batch)
            pages[path] = {k: np.asarray(jax.device_get(
                jnp.take(v, 0, axis=axis))) for k, v in built.items()}
        self._page_cache[name] = pages
        return pages

    def _page_in(self, name: str, method: str, cslot: int) -> None:
        self.version += 1
        pages = self._pages_for(name, method)
        for path, page in pages.items():
            idx = (slice(None),) * self._axis[path] + (cslot,)
            entry = self._stacks[path][method]
            for k in entry:
                entry[k] = entry[k].at[idx].set(
                    jnp.asarray(page[k], entry[k].dtype))
        jax.block_until_ready(
            [self._stacks[p][method][k] for p, pg in pages.items()
             for k in pg])

    # -- accounting -----------------------------------------------------------
    def resident_bytes(self) -> int:
        """HBM held by the compact stacks (identity slots included)."""
        return sum(int(arr.size * arr.dtype.itemsize)
                   for entry in self._stacks.values()
                   for factors in entry.values()
                   for arr in factors.values())

    def padded_bytes(self) -> int:
        """What the SAME universal capacity would cost in the eager padded
        representation: every method stack spanning all capacity+1 slots
        (identities at other methods' slots) instead of its c_m+1."""
        total = 0
        for entry in self._stacks.values():
            for m, factors in entry.items():
                per_slot = sum(int(a.size * a.dtype.itemsize)
                               for a in factors.values()) // (self.caps[m] + 1)
                total += per_slot * (self.capacity + 1)
        return total

    @property
    def counters(self) -> Dict[str, Any]:
        """Read-only value view of the bank's registry instruments, keyed
        by the pre-obs short names (tests and tools read these)."""
        return {k: c.value for k, c in self._c.items()}

    def stats(self) -> Dict[str, Any]:
        """Thin view over the bank's registry instruments — same keys the
        pre-obs dict exposed; page-in percentiles now come from the
        bounded histogram."""
        c = self.counters
        resident = self.resident_bytes()
        padded = self.padded_bytes()
        seen = c["hits"] + c["misses"]
        return {
            "store_adapters": len(self.store),
            "methods": dict(self.caps),
            "capacity": self.capacity,
            "resident": len(self._resident),
            "max_resident": self._max_resident.value,
            "hits": c["hits"],
            "misses": c["misses"],
            "hit_rate": c["hits"] / seen if seen else 0.0,
            "evictions": c["evictions"],
            "admission_stalls": c["stalls"],
            "builds": c["builds"],
            "build_cache_hits": c["build_cache_hits"],
            "page_in_ms_p50": self._page_in_ms.percentile(50),
            "page_in_ms_p95": self._page_in_ms.percentile(95),
            "resident_bank_bytes": resident,
            "padded_bank_bytes": padded,
            "compaction_ratio": padded / resident if resident else 0.0,
        }
