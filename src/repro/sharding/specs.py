"""Partition rules: DP / TP (Megatron) / EP on the production mesh.

Mesh axes: ("data", "model") single-pod, ("pod", "data", "model") multi-pod.
DP = pod x data (gradient all-reduce only crosses pods); TP = model.

All rules are divisibility-guarded: a dim that doesn't divide its mesh axis
falls back to replication (e.g. GQA kv-heads < |model| replicate; the
mamba2-130m 24-head SSD replicates over 'model' — DESIGN §5).  That makes
every (arch x shape x mesh) cell lowerable by construction; the roofline
report then shows the cost of whatever replication was forced.

Serve-time placement (ISSUE 8) lives here too: ``serve_params_tree``
(quantization-aware — QuantTensor codes shard like their logical weight,
scales ride along where their keepdims shape divides), ``paged_state_spec``
(KV page pools split over the kv-head axis, page tables replicated) and
``bank_spec_tree`` (adapter-bank factor stacks replicated by default, with
a per-method ``MethodOps.bank_shard_axes`` hook so large GSOFT (L, R)
stacks can shard over their block axis). ``ModelRuntime`` applies these
when built with a mesh; a CI grep guard keeps ``NamedSharding``/
``shard_map`` construction confined to ``sharding/`` and ``distrib/`` so
placement policy has one home.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.config import ModelConfig

Tree = Any


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


def dp_size(mesh: Mesh) -> int:
    n = 1
    for a in dp_axes(mesh):
        n *= mesh.shape[a]
    return n


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def _ax(axes):
    """Normalize an axis entry: () -> None (replicated)."""
    if axes is None or (isinstance(axes, tuple) and len(axes) == 0):
        return None
    return axes


class ShardingRules:
    """Derives parameter / activation / state PartitionSpecs for one arch."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh):
        self.cfg = cfg
        self.mesh = mesh
        self.tp = tp_size(mesh)
        self.dp = dp_axes(mesh)
        c = cfg
        self.attn_heads_shardable = _div(c.num_heads, self.tp)
        self.kv_heads_shardable = _div(c.num_kv_heads, self.tp)
        self.ff_shardable = _div(c.d_ff, self.tp) if c.d_ff else False
        self.expert_ff_shardable = _div(c.expert_d_ff, self.tp) if c.is_moe else False
        self.experts_shardable = c.is_moe and _div(c.moe_experts, self.tp)
        self.vocab_shardable = _div(c.padded_vocab(), self.tp)
        self.mamba_shardable = (c.ssm_state > 0 and _div(c.ssm_heads, self.tp)
                                and _div(c.d_inner, self.tp))

    # -- parameters ---------------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        tp = "model"
        none_lead = (None,) * max(len(shape) - 2, 0)

        def guarded(axis_idx_from_end: int, ok: bool) -> P:
            if not ok:
                return P()
            spec = [None] * len(shape)
            spec[len(shape) - axis_idx_from_end] = tp
            return P(*spec)

        if re.search(r"embed/table$", path):
            return P(tp, None) if self.vocab_shardable else P()
        if re.search(r"lm_head/w$", path):
            return P(None, tp) if self.vocab_shardable else P()
        if re.search(r"moe/router$", path):
            return P()
        if re.search(r"moe/(wi|wg|wo)$", path):
            # (L, E, din, dout): EP if possible, else shard the ff dim
            if self.experts_shardable:
                return P(None, tp, None, None)
            if self.expert_ff_shardable:
                return (P(None, None, None, tp) if path.endswith(("wi", "wg"))
                        else P(None, None, tp, None))
            return P()
        if re.search(r"attn/(wq)$", path) or re.search(r"cross/(wq)$", path):
            return guarded(1, self.attn_heads_shardable)
        if re.search(r"(attn|cross)/(wk|wv)$", path):
            return guarded(1, self.kv_heads_shardable)
        if re.search(r"(attn|cross)/(bq)$", path):
            return guarded(1, self.attn_heads_shardable)
        if re.search(r"(attn|cross)/(bk|bv)$", path):
            return guarded(1, self.kv_heads_shardable)
        if re.search(r"(attn|cross)/wo$", path):
            return guarded(2, self.attn_heads_shardable)
        if re.search(r"(mlp|shared_attn)/wi$", path) or re.search(r"mlp/(wi|wg)$", path) \
                or re.search(r"/wg$", path):
            return guarded(1, self.ff_shardable)
        if re.search(r"mlp/wo$", path):
            return guarded(2, self.ff_shardable)
        if re.search(r"patch_proj/wi$", path):
            return P()
        # mamba
        if re.search(r"/(wz|wx)$", path):
            return guarded(1, self.mamba_shardable)
        if re.search(r"/wdt$", path):
            return guarded(1, self.mamba_shardable and
                           _div(self.cfg.ssm_heads, self.tp))
        if re.search(r"/(wb|wc)$", path):
            return P()
        if re.search(r"/(A_log|D|dt_bias)$", path):
            return guarded(1, self.mamba_shardable)
        if re.search(r"/gate_norm$", path):
            return guarded(1, self.mamba_shardable)
        if re.search(r"out_proj/wo$", path):
            return guarded(2, self.mamba_shardable)
        if re.search(r"/(conv_w|conv_b)$", path):
            return P()
        return P()  # norms, scalars, anything unmatched: replicate

    def params_tree(self, abstract: Tree) -> Tree:
        from repro.core.peft import path_str
        import jax.tree_util as jtu
        return jtu.tree_map_with_path(
            lambda p, l: self.param_spec(path_str(p), l.shape), abstract)

    # -- adapters (PEFT) ------------------------------------------------------
    def adapter_spec(self, weight_path: str, shape: Tuple[int, ...]) -> P:
        # per-expert adapters follow their expert's EP sharding
        if "/moe/" in weight_path and self.experts_shardable and len(shape) >= 2 \
                and shape[1] == self.cfg.moe_experts:
            return P(None, "model", *([None] * (len(shape) - 2)))
        return P()  # adapters are tiny: replicate

    def adapters_tree(self, adapters_abstract: Tree) -> Tree:
        out = {}
        for wpath, tree in adapters_abstract.items():
            out[wpath] = jax.tree.map(
                lambda l: self.adapter_spec(wpath, l.shape), tree)
        return out

    # -- activations ----------------------------------------------------------
    def act_spec(self, name: str) -> Optional[P]:
        dp, tp = _ax(self.dp), "model"
        # Megatron sequence parallelism (§Perf iteration E): the residual
        # stream shards its SEQUENCE dim over 'model' between blocks, turning
        # each TP all-reduce into a reduce-scatter + all-gather pair (half
        # the bytes); norms/elementwise run on 1/tp of the tokens.
        sp = "model" if self.cfg.seq_parallel else None
        table = {
            "act_btd": P(dp, sp, None),
            "act_d": P(dp, sp, None),
            "act_ff": P(dp, None, tp) if self.ff_shardable else P(dp, None, None),
            "act_heads": (P(dp, None, tp, None) if self.attn_heads_shardable
                          else P(dp, None, None, None)),
            "act_kv_heads": (P(dp, None, tp, None) if self.kv_heads_shardable
                             else P(dp, None, None, None)),
            "act_inner": (P(dp, None, tp) if self.mamba_shardable
                          else P(dp, None, None)),
            "logits": (P(dp, None, tp) if self.vocab_shardable
                       else P(dp, None, None)),
            "moe_expert_in": (P(tp, dp, None, None) if self.experts_shardable
                              else P(None, dp, None, None)),
            "moe_expert_out": (P(tp, dp, None, None) if self.experts_shardable
                               else P(None, dp, None, None)),
        }
        return table.get(name)

    def make_sharder(self, batch_divisible: bool = True):
        """Activation-constraint callback passed into the models."""
        mesh = self.mesh

        def shard(x, name):
            spec = self.act_spec(name)
            if spec is None:
                return x
            if not batch_divisible and len(spec) > 0 and spec[0] == _ax(self.dp):
                spec = P(None, *spec[1:])
            # guard: dims must divide their assigned axes
            sizes = dict(mesh.shape)
            ok = True
            for dim, ax in zip(x.shape, tuple(spec) + (None,) * x.ndim):
                if ax is None:
                    continue
                n = int(np.prod([sizes[a] for a in
                                 ((ax,) if isinstance(ax, str) else ax)]))
                if dim % n:
                    ok = False
            if not ok:
                return x
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(mesh, spec))
        return shard

    # -- serve-time placement (ISSUE 8) ---------------------------------------
    def _fit(self, spec: P, shape: Tuple[int, ...]) -> P:
        """Divisibility guard at leaf granularity: any spec axis whose dim
        does not divide its mesh axes drops to None (replicated). This is
        what lets ONE rule cover a weight and its keepdims quantization
        scales (a size-1 dim can never shard)."""
        sizes = dict(self.mesh.shape)
        out = []
        for dim, ax in zip(shape, tuple(spec) + (None,) * len(shape)):
            if ax is None:
                out.append(None)
                continue
            n = int(np.prod([sizes[a] for a in
                             ((ax,) if isinstance(ax, str) else ax)]))
            out.append(ax if dim % n == 0 else None)
        return P(*out)

    def serve_params_tree(self, params: Tree) -> Tree:
        """Param placement specs for a SERVING runtime. Unlike
        ``params_tree`` this understands quantized trees: a ``QuantTensor``
        leaf expands to per-child specs — the int8/fp8 codes shard exactly
        like the logical weight (same shape), and the fp32 scales reuse the
        same spec wherever their keepdims shape divides (a per-channel
        scale keeps its out-channel split; the size-1 reduced dims
        replicate via the ``_fit`` guard)."""
        from repro.core.peft import path_str
        from repro.quant.core import QuantTensor, is_quant_tensor
        import jax.tree_util as jtu

        def one(p, leaf):
            spec = self.param_spec(path_str(p), tuple(leaf.shape))
            if is_quant_tensor(leaf):
                return QuantTensor(q=self._fit(spec, leaf.q.shape),
                                   scale=self._fit(spec, leaf.scale.shape),
                                   meta=leaf.meta)
            return self._fit(spec, leaf.shape)

        return jtu.tree_map_with_path(one, params, is_leaf=is_quant_tensor)

    def paged_state_spec(self, abstract: Tree) -> Tree:
        """Paged-KV serve state: the per-layer (P, page, K, hd) page pools
        (layer-stacked: (L, P, page, K, hd)) shard over the KV-HEAD axis on
        'model' — every device holds its heads' slice of EVERY page, so the
        host-side page table stays replicated int32 and allocation policy
        never sees the mesh. Tables/scalars replicate."""
        kv = "model" if self.kv_heads_shardable else None

        from repro.core.peft import path_str
        import jax.tree_util as jtu

        def one(p, l):
            path = path_str(p)
            if "pages/" in path or path.endswith(("/k", "/v")):
                # (L, P, page, K, hd) or (P, page, K, hd): K is axis -2
                spec = [None] * l.ndim
                if l.ndim >= 2:
                    spec[l.ndim - 2] = kv
                return self._fit(P(*spec), l.shape)
            return P()        # page table, scalars: replicated

        return jtu.tree_map_with_path(one, abstract)

    def bank_spec_tree(self, bank_tree: Tree) -> Tree:
        """Adapter-bank factor placement: REPLICATED by default (bank
        factors are tiny next to the base weights, and every row of a
        decode batch may gather any slot), with a per-method opt-out — a
        ``MethodOps.bank_shard_axes`` hook names the factor axis that may
        split over 'model' (GSOFT's block axis: thousands of resident
        (L, R) stacks are the one bank that outgrows replication)."""
        from repro.core import methods as methods_lib
        from repro.core.peft import path_str
        import jax.tree_util as jtu

        registered = set(methods_lib.registered())

        def one(p, leaf):
            parts = path_str(p).split("/")
            method = next((s for s in parts if s in registered), None)
            if method is None:
                return P()
            hook = methods_lib.get(method).bank_shard_axes
            if hook is None:
                return P()
            ax = hook(parts[-1], tuple(leaf.shape))
            if ax is None:
                return P()
            spec = [None] * leaf.ndim
            spec[ax % leaf.ndim] = "model"
            return self._fit(P(*spec), leaf.shape)

        return jtu.tree_map_with_path(one, bank_tree)

    # -- batches / states ------------------------------------------------------
    def batch_spec(self, abstract: Tree, batch_size: int) -> Tree:
        ok = _div(batch_size, dp_size(self.mesh))
        lead = _ax(self.dp if ok else ())

        def one(l):
            return P(lead, *([None] * (l.ndim - 1))) if l.ndim else P()
        return jax.tree.map(one, abstract)

    def decode_state_spec(self, abstract: Tree, batch_size: int) -> Tree:
        """KV caches (L, B, S, K, hd) / mamba states: batch on dp, kv-heads /
        ssd-heads on model when divisible."""
        ok_b = _div(batch_size, dp_size(self.mesh))
        dp = _ax(self.dp if ok_b else ())
        kv = "model" if self.kv_heads_shardable else None
        ssm_h = "model" if self.mamba_shardable else None

        from repro.core.peft import path_str
        import jax.tree_util as jtu

        def one(p, l):
            path = path_str(p)
            if "kv/" in path or path.endswith(("/k", "/v")):
                # (L, B, S, K, hd) or (B, S, K, hd)
                if l.ndim == 5:
                    return P(None, dp, None, kv, None)
                if l.ndim == 4:
                    return P(dp, None, kv, None)
            if "mamba/ssm" in path:
                # (..., B, H, N, P)
                lead = (None,) * (l.ndim - 4)
                return P(*lead, dp, ssm_h, None, None)
            if "mamba/conv" in path:
                lead = (None,) * (l.ndim - 3)
                return P(*lead, dp, None, None)
            if "enc_out" in path:
                return P(dp, None, None)
            return P(*([dp] + [None] * (l.ndim - 1))) if l.ndim else P()

        return jtu.tree_map_with_path(one, abstract)


def named(mesh: Mesh, spec_tree: Tree) -> Tree:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def place(mesh: Mesh, tree: Tree, spec_tree: Tree) -> Tree:
    """``device_put`` a (possibly quantized) tree onto the mesh per its
    spec tree. The ONE entry point non-sharding code uses to commit serve
    state — ``NamedSharding`` construction stays inside this module (CI
    grep guard)."""
    return jax.device_put(tree, named(mesh, spec_tree))
