"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map.

Alternative layout for the multi-pod mesh: map the 'pod' (or a dedicated
'pipe') axis to pipeline stages — each device group holds one stage's layer
slice, activations flow stage-to-stage with jax.lax.ppermute, microbatches
fill the pipeline (bubble fraction = (S-1)/(M+S-1)).

This complements the GSPMD DP/TP path (sharding/specs.py): PP is the
explicit-collective style (shard_map), exercised on virtual devices by
tests/pipeline_runner.py, and composes with inner-TP by nesting meshes.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

Array = jnp.ndarray


def gpipe_forward(stage_fn: Callable, stage_params: Any, x_mb: Array,
                  mesh: Mesh, axis: str = "pipe") -> Array:
    """Run M microbatches through S pipeline stages.

    stage_fn:      (params_slice, activations (mb, ...)) -> activations
    stage_params:  pytree with leading stage dim (S, ...) on every leaf
    x_mb:          (M, mb, ...) microbatched input
    Returns (M, mb, ...) outputs (replicated across the axis).
    """
    nstage = mesh.shape[axis]
    nmb = x_mb.shape[0]

    def per_device(params_local, x_local):
        # params_local leaves: (1, ...) stage slice; x_local: (M, mb, ...)
        p = jax.tree.map(lambda v: v[0], params_local)
        idx = jax.lax.axis_index(axis)
        is_first = idx == 0
        is_last = idx == nstage - 1
        buf = jnp.zeros_like(x_local[0])
        outs = jnp.zeros_like(x_local)
        fwd_perm = [(i, i + 1) for i in range(nstage - 1)]

        for t in range(nmb + nstage - 1):
            mb = t - idx                                # this stage's µb id
            active = jnp.logical_and(mb >= 0, mb < nmb)
            feed = jnp.where(is_first,
                             x_local[jnp.clip(jnp.asarray(t), 0, nmb - 1)],
                             buf)
            y = stage_fn(p, feed)
            y = jnp.where(active, y, jnp.zeros_like(y))
            # drain: last stage records its finished microbatch
            slot = jnp.clip(jnp.asarray(mb), 0, nmb - 1)
            cur = jax.lax.dynamic_index_in_dim(outs, slot, keepdims=False)
            val = jnp.where(jnp.logical_and(is_last, active), y, cur)
            outs = jax.lax.dynamic_update_index_in_dim(outs, val, slot, 0)
            # advance: send activations to the next stage
            buf = jax.lax.ppermute(y, axis, fwd_perm)

        # broadcast the last stage's outputs to every stage
        return jax.lax.psum(jnp.where(is_last, outs, jnp.zeros_like(outs)),
                            axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    fn = shard_map(per_device, mesh=mesh,
                   in_specs=(pspec, P()), out_specs=P(),
                   check_rep=False)
    return fn(stage_params, x_mb)


def pipeline_bubble_fraction(num_stages: int, num_microbatches: int) -> float:
    """GPipe bubble overhead: (S-1) / (M + S-1)."""
    return (num_stages - 1) / (num_microbatches + num_stages - 1)
