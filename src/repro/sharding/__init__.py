from .specs import ShardingRules, named, dp_axes, dp_size, tp_size
from .pipeline import gpipe_forward, pipeline_bubble_fraction
