"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train \
        --arch qwen2-72b --smoke --peft gsoft --steps 200 \
        --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real pod: run under launch/scripts/run_with_restart.sh with
--mesh data,model sized to the slice (jax.distributed.initialize is called
when JAX_COORDINATOR is set).  In this container it runs single-process.
"""
from __future__ import annotations

import argparse
import os

import jax

from repro import optim
from repro.config import get_config, get_smoke_config, parse_overrides
from repro.core import methods as methods_lib
from repro.core import peft as peft_lib
from repro.data import DataConfig
from repro.launch.mesh import make_mesh
from repro.optim import schedules
from repro.train.loop import LoopConfig, train
from repro.train.steps import TrainStepConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--peft", default="gsoft",
                    choices=methods_lib.registered() + ["full"])
    ap.add_argument("--block-size", type=int, default=32)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--mesh", default=None, help="e.g. 4,2 for (data, model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--corpus", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    if os.environ.get("JAX_COORDINATOR"):
        jax.distributed.initialize()  # multi-host pods

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_overrides(**parse_overrides(args.set))

    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh(d, m)

    tcfg = TrainStepConfig(
        peft=peft_lib.PEFTConfig(method=args.peft, block_size=args.block_size,
                                 use_pallas=cfg.use_pallas),
        opt=optim.OptimizerConfig(learning_rate=args.lr),
        num_microbatches=args.microbatches,
        schedule=schedules.warmup_cosine(args.warmup, args.steps),
    )
    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      seed=args.seed, corpus_path=args.corpus,
                      vocab_size=min(cfg.vocab_size, 256))
    loop = LoopConfig(steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir,
                      heartbeat_path=(os.path.join(args.ckpt_dir, "heartbeat")
                                      if args.ckpt_dir else None))
    out = train(cfg, tcfg, dcfg, loop, mesh=mesh, resume=not args.no_resume)
    hist = out["history"]
    if hist:
        print(f"final loss {hist[-1]['loss']:.4f} "
              f"(from {hist[0]['loss']:.4f} @ step {hist[0]['step']})")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
