"""Serving launcher: batched engine over a (smoke or full) config.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --requests 16 --prompt-len 12 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import get_config, get_smoke_config, parse_overrides
from repro.core import peft as peft_lib
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.serve.engine import ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--peft-demo", action="store_true",
                    help="attach + merge GSOFT adapters before serving "
                         "(paper: zero inference overhead)")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_overrides(**parse_overrides(args.set))
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh(d, m)

    params = api.init_params(cfg, jax.random.PRNGKey(0))
    adapters = peft_cfg = None
    if args.peft_demo:
        peft_cfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
        adapters = peft_lib.init_peft(peft_cfg, params, jax.random.PRNGKey(1))

    eng = ServeEngine(cfg, params, max_batch=args.max_batch,
                      max_len=args.prompt_len + args.max_new + 8,
                      mesh=mesh, adapters=adapters, peft_cfg=peft_cfg)
    rng = np.random.default_rng(0)
    for _ in range(args.requests):
        prompt = rng.integers(1, min(cfg.vocab_size, 255),
                              size=args.prompt_len).tolist()
        eng.add_request(prompt, max_new_tokens=args.max_new)
    t0 = time.perf_counter()
    results = eng.run()
    dt = time.perf_counter() - t0
    toks = eng.stats["tokens_generated"]
    print(f"served {len(results)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / max(dt, 1e-9):.1f} tok/s, "
          f"{eng.stats['decode_steps']} decode steps)")
    sample = results[min(results)]
    print("sample output tokens:", sample[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
