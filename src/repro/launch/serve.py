"""Serving launcher: continuous-batching (default) or static engine over a
(smoke or full) config, with streaming Poisson arrivals and a per-request
adapter bank.

    # continuous batching, mixed-length synthetic traffic
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --requests 16 --prompt-len 12 --max-new 8 --mixed-lengths

    # streaming arrivals at 4 req/s
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --requests 16 --arrival-rate 4

    # multi-adapter serving from saved checkpoints (ModelRuntime.attach);
    # requests round-robin over the loaded adapters
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --adapters alice=/ckpts/alice bob=/ckpts/bob

    # fabricate a demo bank, save it, and round-trip through the loader
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --demo-adapters 3 --save-adapters /tmp/bank

    # thousand-tenant mode: serve a whole adapter checkpoint as a DISK-
    # backed store, paged into HBM under a fixed budget (LRU eviction)
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --store-dir /ckpts/tenants --hbm-adapter-budget 64

    # image lane: batched stateless serving of the 1-Lipschitz convnet
    # with per-request conv adapters (ImageServeEngine; same bank/store/
    # quantize/replica flags as the token lanes)
    PYTHONPATH=src python -m repro.launch.serve --arch lipconvnet-15 \
        --smoke --family image --requests 16 --demo-adapters 3

    # observability: per-request trace spans (TTFT/TPOT/stall attribution)
    # exported for chrome://tracing, periodic SLO report, JSON tick log
    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-72b --smoke \
        --requests 16 --arrival-rate 8 --trace --trace-out /tmp/trace.json \
        --report-interval 1 --log-json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.config import get_config, get_smoke_config, parse_overrides
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.distrib import EngineCluster, format_cluster_report, serve_mesh
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.obs import SLOMonitor, TraceRecorder
from repro.serve.engine import (PagedServeEngine, ServeEngine,
                                StaticServeEngine, latency_percentiles)
from repro.serve.image import ImageServeEngine


def make_demo_adapters(names, params, peft_cfg, seed=1, scale=0.1):
    """Random (non-identity) adapters, one per name; an int n means names
    a0..a{n-1}. ``peft_cfg`` is a single PEFTConfig or a {name: PEFTConfig}
    mapping (mixed-method demo banks). Stands in for real fine-tunes in
    demos/benchmarks."""
    if isinstance(names, int):
        names = [f"a{i}" for i in range(names)]
    out = {}
    for i, name in enumerate(names):
        cfg = peft_cfg[name] if isinstance(peft_cfg, dict) else peft_cfg
        key = jax.random.fold_in(jax.random.PRNGKey(seed), i)
        ad = peft_lib.init_peft(cfg, params, key)
        out[name] = jax.tree.map(
            lambda a, k=key: a + scale * jax.random.normal(
                jax.random.fold_in(k, 7), a.shape), ad)
    return out


def drive_streaming(eng, requests, arrivals, tick_hook=None):
    """Admit requests as they 'arrive' (Poisson sim) while stepping the
    continuous scheduler; returns results once traffic drains. ``eng`` is
    anything engine-shaped — a single engine or an ``EngineCluster``.
    ``tick_hook`` (optional) runs after every scheduler tick — the
    launcher's periodic SLO report / --log-json emitter. An SLO-breached
    cluster (``eng.accepting`` False) HOLDS arrivals until the monitor
    clears — admission backpressure, not drops."""
    t0 = time.perf_counter()
    i = 0
    while i < len(requests) or not eng.idle:
        now = time.perf_counter() - t0
        while (i < len(requests) and arrivals[i] <= now
               and getattr(eng, "accepting", True)):
            eng.add_request(**requests[i])
            i += 1
        if eng.idle:                     # nothing in flight: wait for traffic
            time.sleep(min(0.005, max(arrivals[i] - now, 0.0)))
            continue
        eng.step()
        if tick_hook is not None:
            tick_hook()
    eng.add_wall(time.perf_counter() - t0)
    return {r.rid: r.output for r in eng.finished}


def make_tick_observer(eng, slo, interval, log_json):
    """Per-tick callback: every ``interval`` seconds (every tick when 0)
    emit either the human SLO report or one ``--log-json`` record — the
    machine-readable mirror of the same numbers."""
    state = {"t0": time.perf_counter(), "last": time.perf_counter()}

    def observe():
        now = time.perf_counter()
        if interval > 0 and now - state["last"] < interval:
            return
        state["last"] = now
        if log_json:
            rec = {"event": "tick", "t_s": round(now - state["t0"], 6),
                   "queue_depth": eng.queue_depth,
                   "active": eng.num_active,
                   "requests": eng.stats["requests"],
                   "tokens_generated": eng.stats["tokens_generated"],
                   "decode_steps": eng.stats["decode_steps"],
                   "prefills": eng.stats["prefills"],
                   "admission_stalls": eng.stats["admission_stalls"]}
            if slo is not None:
                rec["slo"] = slo.report()
            print(json.dumps(rec))
        elif slo is not None:
            print(SLOMonitor.format_report(slo.report()))

    return observe


def describe(eng, results, engine_name, dt):
    toks = eng.stats["tokens_generated"]
    lat = latency_percentiles(eng.finished)
    print(f"[{engine_name}] served {len(results)} requests, {toks} tokens "
          f"in {dt:.2f}s ({toks / max(dt, 1e-9):.1f} tok/s, "
          f"{eng.stats['decode_steps']} decode steps, "
          f"{eng.stats['prefills']} prefills)")
    print(f"latency p50={lat[50] * 1e3:.0f}ms p95={lat[95] * 1e3:.0f}ms")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--family", default=None,
                    help="assert the arch's registered family (lane "
                         "selector in scripts: --family image routes "
                         "through the batched stateless ImageServeEngine)")
    ap.add_argument("--engine", choices=("continuous", "static", "paged"),
                    default="continuous",
                    help="'paged': fixed-size KV pages + per-slot page "
                         "tables, chunked prefill, shared-prefix caching")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="prompt lens U[4, prompt_len], budgets U[2, max_new]"
                         " — the ragged workload continuous batching wins on")
    ap.add_argument("--arrival-rate", type=float, default=0.0,
                    help="Poisson arrivals (req/s); 0 = all queued up front")
    ap.add_argument("--mesh", default=None,
                    help="'data,model' mesh shape for tensor-parallel "
                         "serving (params/KV/bank commit per "
                         "sharding.specs)")
    ap.add_argument("--tp", type=int, default=0,
                    help="shorthand for --mesh 1,N: split the model over N "
                         "devices at serve time")
    ap.add_argument("--replicas", type=int, default=1,
                    help="run N engine replicas behind an EngineCluster "
                         "with adapter-affinity routing (continuous/paged "
                         "engines)")
    ap.add_argument("--adapters", nargs="*", default=[],
                    metavar="NAME=CKPT_DIR",
                    help="load named adapters into a per-request bank "
                         "(continuous engine only)")
    ap.add_argument("--store-dir", default=None,
                    help="serve an adapter checkpoint dir as a DISK-backed "
                         "AdapterStore: only the index loads up front; "
                         "adapters page into HBM on admission")
    ap.add_argument("--hbm-adapter-budget", type=int, default=0,
                    help="max adapters resident in HBM at once (slot-"
                         "compacted, LRU-paged); 0 = everything resident")
    ap.add_argument("--demo-adapters", type=int, default=0,
                    help="fabricate N random adapters as a demo bank")
    ap.add_argument("--demo-methods", default="gsoft",
                    help="comma-list of registered methods assigned round-"
                         "robin to the demo adapters (mixed-method bank), "
                         "e.g. gsoft,boft,householder")
    ap.add_argument("--save-adapters", default=None,
                    help="save the (demo) bank to this checkpoint dir and "
                         "reload it through the round-trip path")
    ap.add_argument("--peft-demo", action="store_true",
                    help="attach + merge one GSOFT adapter into the weights "
                         "before serving (paper §6.1: zero overhead)")
    ap.add_argument("--quantize", choices=("none", "int8", "fp8"),
                    default="none",
                    help="serve with quantized base weights (per-channel "
                         "int8 / fp8 stub); GS adapter rotations stay bf16")
    ap.add_argument("--page-size", type=int, default=8,
                    help="KV page size in tokens (paged engine)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="prompt tokens fed per scheduler tick (paged "
                         "engine): decode latency is bounded by one chunk, "
                         "not one prompt")
    ap.add_argument("--hbm-kv-budget", type=int, default=0,
                    help="KV pool HBM budget in BYTES (paged engine); the "
                         "page count is static — exhaustion stalls "
                         "admission. 0 = stall-free worst-case pool")
    ap.add_argument("--trace", action="store_true",
                    help="record per-request lifecycle spans (submit/"
                         "stall/prefill/tokens/finish) with TTFT/TPOT; "
                         "all lanes including --family image")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="export finished traces: .jsonl = one event per "
                         "line, anything else = Chrome trace_event JSON "
                         "(chrome://tracing / Perfetto); implies --trace")
    ap.add_argument("--report-interval", type=float, default=0.0,
                    help="print the sliding-window SLO report (p50/p95/p99 "
                         "TTFT+TPOT, tok/s, stall rates) every N seconds "
                         "while serving; implies --trace")
    ap.add_argument("--log-json", action="store_true",
                    help="emit structured per-tick JSON records to stdout "
                         "— the machine-readable mirror of the human "
                         "report (throttled by --report-interval)")
    ap.add_argument("--set", nargs="*", default=[])
    args = ap.parse_args()

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    cfg = cfg.with_overrides(**parse_overrides(args.set))
    if args.family and not registry.is_family(cfg, args.family):
        raise SystemExit(f"--family {args.family} but arch {args.arch!r} "
                         f"registers family {cfg.family!r}")
    stateless = registry.get(cfg.family).stateless
    if stateless and args.engine != "continuous":
        raise SystemExit(f"family {cfg.family!r} is stateless (no KV) — "
                         "it serves through the batched image engine "
                         "(--engine continuous, the default)")
    mesh = None
    if args.tp:
        if args.mesh:
            raise SystemExit("--tp is shorthand for --mesh 1,N — pass one "
                             "or the other")
        mesh = serve_mesh(args.tp)
    elif args.mesh:
        d, m = (int(x) for x in args.mesh.split(","))
        mesh = make_mesh(d, m)
    if args.replicas < 1:
        raise SystemExit("--replicas must be >= 1")
    if args.replicas > 1 and args.engine == "static":
        raise SystemExit("--replicas needs a steppable engine "
                         "(continuous/paged) — the static engine drains "
                         "one batch at a time")

    base_rt = ModelRuntime(cfg, key=jax.random.PRNGKey(0), mesh=mesh)
    rt = base_rt
    max_len = cfg.frontend_tokens + args.prompt_len + args.max_new + 8

    # ---- adapter bank / store ----------------------------------------------
    from repro.store import AdapterStore, load_adapter_checkpoints
    budget = args.hbm_adapter_budget or None
    adapter_names = []
    if sum(map(bool, (args.adapters, args.demo_adapters,
                      args.store_dir))) > 1:
        raise SystemExit("--adapters / --demo-adapters / --store-dir are "
                         "exclusive: load a saved bank, fabricate one, OR "
                         "serve a checkpoint dir as a paged store")
    if args.save_adapters and not (args.adapters or args.demo_adapters):
        raise SystemExit("--save-adapters needs a bank to save: pass "
                         "--demo-adapters N or --adapters name=dir")
    if args.peft_demo and (args.adapters or args.demo_adapters or
                           args.store_dir):
        raise SystemExit("--peft-demo merges an adapter INTO the weights; "
                         "combining it with a per-request bank would rotate "
                         "already-rotated activations — pick one")
    if args.store_dir:
        store = AdapterStore.open(args.store_dir)
        rt = rt.attach(store, hbm_budget=budget)
        adapter_names = list(store.names)
        print(f"adapter store: {len(store)} adapters on disk/host, "
              f"HBM capacity {rt.bank.capacity} "
              f"(per-method {rt.bank.caps})")
    elif args.adapters or args.demo_adapters:
        if args.demo_adapters:
            # mixed-method demo bank: methods round-robin over the names
            meths = [m.strip() for m in args.demo_methods.split(",")
                     if m.strip()]
            if not meths:
                raise SystemExit("--demo-methods needs at least one "
                                 "registered method (e.g. "
                                 "gsoft,boft,householder)")
            names = [f"a{i}" for i in range(args.demo_adapters)]
            bank_peft = {name: peft_lib.PEFTConfig(
                             method=meths[i % len(meths)], block_size=8,
                             use_pallas=cfg.use_pallas)
                         for i, name in enumerate(names)}
            adapters_by_name = make_demo_adapters(names, rt.params,
                                                  bank_peft)
        else:
            adapters_by_name, bank_peft = load_adapter_checkpoints(
                args.adapters)
        if args.save_adapters:
            AdapterStore.from_adapters(adapters_by_name,
                                       bank_peft).save(args.save_adapters)
            adapters_by_name, bank_peft = load_adapter_checkpoints(
                [args.save_adapters])
            print(f"round-tripped {list(adapters_by_name)} through "
                  f"{args.save_adapters}")
        rt = rt.attach(adapters_by_name, bank_peft, hbm_budget=budget)
        adapter_names = list(adapters_by_name)
        print(f"adapter bank: {rt.bank.num_slots} slots "
              f"{list(rt.bank.names)}, methods {list(rt.bank.bank_methods)}")

    # ---- merged single-adapter demo (static story) -------------------------
    if args.peft_demo:
        peft_cfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
        adapters = peft_lib.init_peft(peft_cfg, rt.params,
                                      jax.random.PRNGKey(1))
        rt = ModelRuntime(cfg, rt.params, mesh=mesh, adapters=adapters,
                          peft_cfg=peft_cfg)

    # ---- weight quantization (after any merge/bank: rotations stay bf16) ---
    if args.quantize != "none":
        from repro.quant import tree_bytes
        before = tree_bytes(rt.params)
        rt = rt.quantized(args.quantize)
        after = tree_bytes(rt.params)
        print(f"quantized base weights ({args.quantize}): params "
              f"{before / 1e6:.2f} MB -> {after / 1e6:.2f} MB "
              f"({before / max(after, 1):.2f}x smaller)")

    def replica_runtimes(n: int):
        """Runtimes for N engine replicas. Stateless runtimes (bankless,
        eager bank, merged, quantized) are SHARED — engines keep their own
        KV state, and jitted closures/weights exist once. Only a store-
        paged bank forces a fresh runtime per replica: paging state
        (residency, pins, LRU order) must be per-replica for the
        cluster's adapter-affinity routing to mean anything."""
        from repro.store import PagedAdapterBank
        if n == 1 or not isinstance(rt.bank, PagedAdapterBank):
            return [rt] * n
        out = [rt]
        for _ in range(n - 1):
            r = base_rt.attach(rt.bank.store, hbm_budget=budget)
            if args.quantize != "none":
                r = r.quantized(args.quantize)
            out.append(r)
        return out

    # ---- observability: one tracer + SLO monitor across every lane ---------
    want_trace = (args.trace or args.trace_out is not None
                  or args.report_interval > 0)
    slo = SLOMonitor(window=256) if want_trace else None
    tracer = TraceRecorder(slo=slo) if want_trace else None

    if args.engine == "static":
        if rt.banked:
            raise SystemExit("--adapters needs --engine continuous "
                             "(static serving merges ONE adapter offline)")
        eng = StaticServeEngine(rt, max_batch=args.max_batch,
                                max_len=max_len, tracer=tracer)
    elif stateless:
        engines = [ImageServeEngine(r, max_batch=args.max_batch,
                                    tracer=tracer)
                   for r in replica_runtimes(args.replicas)]
        eng = EngineCluster(engines, slo=slo)
    else:
        rts = replica_runtimes(args.replicas)
        if args.engine == "paged":
            engines = [PagedServeEngine(r, max_batch=args.max_batch,
                                        max_len=max_len,
                                        page_size=args.page_size,
                                        prefill_chunk=args.prefill_chunk,
                                        hbm_kv_budget=args.hbm_kv_budget
                                        or None, tracer=tracer)
                       for r in rts]
        else:
            engines = [ServeEngine(r, max_batch=args.max_batch,
                                   max_len=max_len, tracer=tracer)
                       for r in rts]
        # N=1 rides the same cluster path: the launcher report below IS
        # cluster_stats(), single-replica being its degenerate case
        eng = EngineCluster(engines, slo=slo)

    # ---- synthetic traffic -------------------------------------------------
    rng = np.random.default_rng(0)
    names = adapter_names if rt.banked and adapter_names else [None]
    requests = []
    for i in range(args.requests):
        if stateless:       # one image in, one class out — the prompt IS
            req = {"prompt": rng.normal(size=(       # the (H, W, C) array
                       cfg.image_size, cfg.image_size,
                       cfg.in_channels)).astype(np.float32),
                   "max_new_tokens": 1}
        else:
            plen = (int(rng.integers(4, args.prompt_len + 1))
                    if args.mixed_lengths else args.prompt_len)
            mnew = (int(rng.integers(2, args.max_new + 1))
                    if args.mixed_lengths else args.max_new)
            req = {"prompt": rng.integers(1, min(cfg.vocab_size, 255),
                                          size=plen).tolist(),
                   "max_new_tokens": mnew}
        if rt.banked:
            req["adapter"] = names[i % len(names)]
        requests.append(req)

    tick_hook = None
    if args.log_json or (args.report_interval > 0 and slo is not None):
        tick_hook = make_tick_observer(eng, slo, args.report_interval,
                                       args.log_json)

    t0 = time.perf_counter()
    if args.arrival_rate > 0 and args.engine == "continuous":
        arrivals = np.cumsum(rng.exponential(1.0 / args.arrival_rate,
                                             size=args.requests))
        results = drive_streaming(eng, requests, arrivals, tick_hook)
    else:
        if args.arrival_rate > 0:
            print("note: static engine ignores arrival times "
                  "(drain-queue batching)")
        for req in requests:
            eng.add_request(**req)
        if tick_hook is not None and hasattr(eng, "step"):
            t0r = time.perf_counter()
            while eng.step():
                tick_hook()
            eng.add_wall(time.perf_counter() - t0r)
            results = {r.rid: r.output for r in eng.finished}
        else:
            results = eng.run()
    dt = time.perf_counter() - t0

    describe(eng, results, args.engine, dt)
    if isinstance(eng, EngineCluster):
        # the ONE residency/routing report — replica rows carry the bank
        # and KV-pool residency that used to be printed ad hoc here
        # (and the SLO block when tracing is on)
        print(format_cluster_report(eng.cluster_stats()))
    elif slo is not None:
        print(SLOMonitor.format_report(slo.report()))
    if args.log_json:
        print(json.dumps({
            "event": "summary", "engine": args.engine,
            "replicas": args.replicas, "requests": len(results),
            "tokens_generated": eng.stats["tokens_generated"],
            "decode_steps": eng.stats["decode_steps"],
            "prefills": eng.stats["prefills"],
            "admission_stalls": eng.stats["admission_stalls"],
            "wall_s": round(dt, 6),
            "slo": slo.report() if slo is not None else None}))
    if tracer is not None and args.trace_out:
        if args.trace_out.endswith(".jsonl"):
            n = tracer.export_jsonl(args.trace_out)
        else:
            n = tracer.export_chrome(args.trace_out)
        print(f"trace: {len(tracer.finished)} requests, {n} events "
              f"-> {args.trace_out}")
    sample = results[min(results)]
    print("sample output tokens:", sample[:16])
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
