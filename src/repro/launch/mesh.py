"""Mesh factories. Functions, not module-level constants — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def make_axes_mesh(shape, axes):
    # jax.sharding.AxisType landed after 0.4.x; meshes default to Auto axes
    # there, so omitting the argument is equivalent on older versions.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 = 256 chips per pod;
    multi-pod adds a leading pod axis (2 x 16 x 16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_axes_mesh(shape, axes)


def make_mesh(data: int, model: int, pods: int = 1):
    """Elastic variant: any (pods x data x model) that fits the device count
    (used by tests and by elastic-restart re-sharding)."""
    if pods > 1:
        return make_axes_mesh((pods, data, model), ("pod", "data", "model"))
    return make_axes_mesh((data, model), ("data", "model"))
