"""Mesh factories. Functions, not module-level constants — importing this
module never touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    """The assignment's production mesh: 16x16 = 256 chips per pod;
    multi-pod adds a leading pod axis (2 x 16 x 16 = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh(data: int, model: int, pods: int = 1):
    """Elastic variant: any (pods x data x model) that fits the device count
    (used by tests and by elastic-restart re-sharding)."""
    if pods > 1:
        return jax.make_mesh((pods, data, model), ("pod", "data", "model"),
                             axis_types=_auto(3))
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
