"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell —
weak-type-correct, shardable, zero allocation.  The dry-run lowers
train_step / serve_step against exactly these."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig, ShapeConfig
from repro.models import api, registry

SDS = jax.ShapeDtypeStruct


def _i32(shape):
    return SDS(shape, jnp.int32)


def _f(shape, cfg: ModelConfig):
    return SDS(shape, cfg.act_dtype)


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    t = registry.get(cfg.family)
    if t.has_patches:
        p = cfg.frontend_tokens
        st = s - p
        return {"tokens": _i32((b, st)), "labels": _i32((b, st)),
                "mask": SDS((b, st), jnp.float32),
                "patches": _f((b, p, cfg.frontend_dim), cfg)}
    if t.has_encoder:
        return {"frames": _f((b, s // 4, cfg.d_model), cfg),
                "tokens": _i32((b, s)), "labels": _i32((b, s)),
                "mask": SDS((b, s), jnp.float32)}
    return {"tokens": _i32((b, s)), "labels": _i32((b, s)),
            "mask": SDS((b, s), jnp.float32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    b, s = shape.global_batch, shape.seq_len
    t = registry.get(cfg.family)
    batch: Dict[str, Any] = {"tokens": _i32((b, s))}
    if t.has_patches:
        batch = {"tokens": _i32((b, s - cfg.frontend_tokens)),
                 "patches": _f((b, cfg.frontend_tokens, cfg.frontend_dim), cfg)}
    if t.has_encoder:
        batch["frames"] = _f((b, s // 4, cfg.d_model), cfg)
    state = api.abstract_decode_state(cfg, b, s, enc_len=s // 4)
    return batch, state


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """One new token against a KV cache / SSM state of seq_len."""
    b, s = shape.global_batch, shape.seq_len
    tokens = _i32((b, 1))
    state = api.abstract_decode_state(cfg, b, s, enc_len=max(s // 4, 8))
    pos = SDS((), jnp.int32)
    return tokens, state, pos


def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    if shape.kind == "train":
        return {"batch": train_input_specs(cfg, shape)}
    if shape.kind == "prefill":
        batch, state = prefill_input_specs(cfg, shape)
        return {"batch": batch, "state": state}
    tokens, state, pos = decode_input_specs(cfg, shape)
    return {"tokens": tokens, "state": state, "pos": pos}
