import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh) cell
against ShapeDtypeStruct inputs — no allocation — and record
memory_analysis / cost_analysis / collective traffic for the roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun \
        --arch qwen2-72b --shape train_4k --mesh single --peft gsoft

Pallas kernels are disabled here (TPU kernels cannot lower on the CPU
backend); the pure-JAX path is semantically identical (tests prove it).
"""
import argparse
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import optim
from repro.analysis.hlo import collective_stats
from repro.analysis.roofline import Roofline, advice, model_flops
from repro.config import (SHAPES, get_config, list_archs, parse_overrides,
                          shape_applicable)
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (decode_input_specs, prefill_input_specs,
                                train_input_specs)
from repro.sharding.specs import ShardingRules, dp_size, named
from repro.train.steps import TrainStepConfig, build_train_step

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "../../../results/dryrun")


def _mem_dict(ma) -> Dict[str, float]:
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes")
    return {k: float(getattr(ma, k, 0) or 0) for k in keys}


def _microbatches(shape, mesh) -> int:
    local = shape.global_batch // max(dp_size(mesh), 1)
    if shape.global_batch % dp_size(mesh):
        return 1
    # keep per-device microbatch small enough for remat'd activations
    for n in (8, 4, 2, 1):
        if shape.global_batch % n == 0 and (shape.global_batch // n) % dp_size(mesh) == 0:
            return n
    return 1


def run_cell(arch: str, shape_name: str, mesh_kind: str, peft: str = "gsoft",
             overrides: Optional[dict] = None, save_hlo: bool = False,
             microbatches: int = 0) -> Dict[str, Any]:
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "peft": peft, "ok": False}
    ok, why = shape_applicable(arch, shape_name)
    if not ok:
        rec.update({"skipped": True, "reason": why, "ok": True})
        return rec
    t0 = time.time()
    try:
        cfg = get_config(arch).with_overrides(use_pallas=False,
                                              **(overrides or {}))
        shape = SHAPES[shape_name]
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        chips = int(len(mesh.devices.ravel()))
        rules = ShardingRules(cfg, mesh)
        rt = ModelRuntime.abstract(cfg, mesh=mesh)
        params_abs = rt.params
        params_sh = named(mesh, rules.params_tree(params_abs))
        bdiv = shape.global_batch % dp_size(mesh) == 0

        if shape.kind == "train":
            peft_cfg = peft_lib.PEFTConfig(method=peft)
            adapters_abs = jax.eval_shape(
                lambda: peft_lib.init_peft(peft_cfg, params_abs,
                                           jax.random.PRNGKey(0)))
            ocfg = optim.OptimizerConfig()
            opt_abs = jax.eval_shape(functools.partial(optim.init, ocfg),
                                     adapters_abs)
            batch_abs = train_input_specs(cfg, shape)
            n_micro = microbatches or _microbatches(shape, mesh)
            tcfg = TrainStepConfig(peft=peft_cfg, opt=ocfg,
                                   num_microbatches=n_micro)
            step = build_train_step(cfg, tcfg, mesh, batch_divisible=bdiv)
            ad_sh = named(mesh, rules.adapters_tree(adapters_abs))
            opt_sh = {"mu": ad_sh, "nu": ad_sh,
                      "step": named(mesh, jax.sharding.PartitionSpec())}
            b_sh = named(mesh, rules.batch_spec(batch_abs, shape.global_batch))
            lowered = jax.jit(
                step, in_shardings=(params_sh, ad_sh, opt_sh, b_sh),
                out_shardings=(ad_sh, opt_sh, None),
            ).lower(params_abs, adapters_abs, opt_abs, batch_abs)
            tokens_per_step = shape.global_batch * shape.seq_len
            rec["microbatches"] = n_micro
        elif shape.kind == "prefill":
            batch_abs, state_abs = prefill_input_specs(cfg, shape)
            step = rt.build_prefill(batch_divisible=bdiv)

            def prefill_cell(params, batch, state):
                return step(params, peft_lib.PrefillRequest(batch=batch),
                            state)
            st_sh = named(mesh, rules.decode_state_spec(state_abs,
                                                        shape.global_batch))
            b_sh = named(mesh, rules.batch_spec(batch_abs, shape.global_batch))
            lowered = jax.jit(prefill_cell,
                              in_shardings=(params_sh, b_sh, st_sh),
                              donate_argnums=(2,)).lower(
                params_abs, batch_abs, state_abs)
            tokens_per_step = shape.global_batch * shape.seq_len
        else:  # decode
            tokens_abs, state_abs, pos_abs = decode_input_specs(cfg, shape)
            step = rt.build_decode(batch_divisible=bdiv)

            def decode_cell(params, tokens, state, pos):
                return step(params, None, tokens, state, pos)
            st_sh = named(mesh, rules.decode_state_spec(state_abs,
                                                        shape.global_batch))
            tok_sh = named(mesh, rules.batch_spec(tokens_abs,
                                                  shape.global_batch))
            pos_sh = named(mesh, jax.sharding.PartitionSpec())
            lowered = jax.jit(decode_cell,
                              in_shardings=(params_sh, tok_sh, st_sh, pos_sh),
                              donate_argnums=(2,)).lower(
                params_abs, tokens_abs, state_abs, pos_abs)
            tokens_per_step = shape.global_batch  # one token per sequence
        t_lower = time.time() - t0

        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        from repro.analysis.hlo_cost import module_cost, normalize_cost_analysis
        cost = normalize_cost_analysis(compiled.cost_analysis())
        mem = _mem_dict(compiled.memory_analysis())
        hlo = compiled.as_text()
        # trip-count-aware accounting (XLA's cost_analysis counts while
        # bodies once — see analysis/hlo_cost.py); raw numbers kept alongside
        walk = module_cost(hlo)
        if save_hlo:
            os.makedirs(RESULTS_DIR, exist_ok=True)
            with open(os.path.join(
                    RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}.hlo"),
                    "w") as f:
                f.write(hlo)
        del hlo

        n_active = rt.active_param_count()
        mf = model_flops(n_active, tokens_per_step,
                         "train" if shape.is_train else "serve")
        rl = Roofline(
            arch=arch, shape=shape_name, mesh=mesh_kind, chips=chips,
            flops_per_device=walk.flops,
            bytes_per_device=walk.bytes,
            coll_bytes_per_device=walk.coll_bytes,
            model_flops=mf,
            peak_memory_per_device=mem["argument_size_in_bytes"]
            + mem["temp_size_in_bytes"] + mem["output_size_in_bytes"]
            - mem["alias_size_in_bytes"],
        )
        rec.update({
            "ok": True,
            "chips": chips,
            "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
            "cost_raw": {k: cost.get(k) for k in ("flops", "bytes accessed")},
            "memory": mem,
            "collectives": {k: dict(v) for k, v in walk.coll.items()},
            "roofline": rl.row(),
            "advice": advice(rl),
            "active_params": n_active,
        })
    except Exception as e:  # record the failure; the sweep continues
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--peft", default="gsoft")
    ap.add_argument("--out", default=None)
    ap.add_argument("--set", nargs="*", default=[],
                    help="ModelConfig overrides key=value")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--all", action="store_true")
    args = ap.parse_args()

    archs = list_archs() if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    overrides = parse_overrides(args.set)

    out_dir = args.out or os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "..", "..",
                     "results", "dryrun"))
    os.makedirs(out_dir, exist_ok=True)

    results = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                name = f"{arch}__{shape}__{mesh_kind}"
                path = os.path.join(out_dir, name + ".json")
                print(f"=== {name} ===", flush=True)
                rec = run_cell(arch, shape, mesh_kind, peft=args.peft,
                               overrides=overrides, save_hlo=args.save_hlo,
                               microbatches=args.microbatches)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                status = ("SKIP " + rec.get("reason", "") if rec.get("skipped")
                          else "OK" if rec["ok"] else
                          "FAIL " + rec.get("error", ""))
                if rec.get("ok") and not rec.get("skipped"):
                    r = rec["roofline"]
                    print(f"  {status}  dominant={r['dominant']} "
                          f"compute={r['compute_s']:.4f}s "
                          f"mem={r['memory_s']:.4f}s "
                          f"coll={r['collective_s']:.4f}s "
                          f"roofline={r['roofline_frac']:.2%} "
                          f"mem/dev={r['peak_mem_gib']:.2f}GiB "
                          f"(compile {rec['compile_s']}s)", flush=True)
                else:
                    print("  " + status, flush=True)
                results.append(rec)

    with open(os.path.join(out_dir, "summary.json"), "w") as f:
        json.dump(results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} cells OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
