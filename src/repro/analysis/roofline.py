"""Three-term roofline model from the compiled dry-run artifact.

Per (arch x shape x mesh) cell:
    compute_s    = HLO_FLOPs_total    / (chips * PEAK_FLOPS)
    memory_s     = HLO_bytes_total    / (chips * HBM_BW)
    collective_s = coll_bytes_total   / (chips * ICI_BW)

Hardware constants (assignment): TPU v5e — 197 bf16 TFLOP/s, 819 GB/s HBM,
~50 GB/s/link ICI, 16 GiB HBM per chip.

``cost_analysis()`` on a GSPMD-partitioned executable reports the per-device
program; we scale by chip count for the fabric totals (the probe in
tests/test_roofline.py pins this interpretation down empirically).
MODEL_FLOPS uses 6*N*D (train) / 2*N*D (inference), N = active params.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip
HBM_CAP = 16 * 2 ** 30       # v5e HBM per chip


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    model_flops: float                # 6*N*D or 2*N*D (global, per step)
    peak_memory_per_device: float = 0.0

    @property
    def compute_s(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Optimistic overlap model: step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_fraction(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat/causal-waste/redundancy."""
        hlo_total = self.flops_per_device * self.chips
        return self.model_flops / hlo_total if hlo_total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful model FLOPs vs what the chips could do in the modeled step
        time — the headline '% of roofline' number."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return self.model_flops / (self.chips * PEAK_FLOPS * t)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_flops_frac": self.useful_flops_fraction,
            "roofline_frac": self.roofline_fraction,
            "peak_mem_gib": self.peak_memory_per_device / 2 ** 30,
        }


def model_flops(active_params: int, tokens: int, kind: str) -> float:
    """6ND for training (fwd+bwd), 2ND for inference forward."""
    factor = 6.0 if kind == "train" else 2.0
    return factor * active_params * tokens


def advice(r: Roofline) -> str:
    if r.dominant == "compute":
        if r.useful_flops_fraction < 0.4:
            return ("compute-bound with low useful-FLOP fraction: cut remat "
                    "recompute / causal-mask waste (prefix_loop attention), "
                    "or reduce microbatch recompute")
        return "compute-bound near useful peak: only kernel-level wins left"
    if r.dominant == "memory":
        return ("HBM-bound: increase arithmetic intensity — larger fused "
                "blocks (gs_fused kernel), bf16 activations, fewer "
                "materialized intermediates / layouts")
    return ("collective-bound: reshard to cut cross-device traffic (kv-head "
            "replication, EP capacity, gradient compression on the DP axes, "
            "overlap collectives with compute)")
