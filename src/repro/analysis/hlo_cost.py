"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so any
scan-over-layers / microbatch-accumulation program is undercounted by the
trip count (verified empirically — see tests/test_roofline.py).  This walker
parses the post-SPMD HLO text and computes, per device:

  * FLOPs       — 2 * out_elems * contraction for every dot (batch dims
                  included in out_elems); elementwise ops ~ out_elems
  * HBM bytes   — operand + output bytes at fusion boundaries and top-level
                  ops (instructions inside a fusion body touch registers,
                  not HBM, so only their FLOPs count)
  * collectives — operand bytes per kind (all-gather / all-reduce /
                  reduce-scatter / all-to-all / collective-permute)

multiplying every while body/cond by its ``known_trip_count`` (recursive;
nested scans compose).  This is the basis of the §Roofline terms.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """``compiled.cost_analysis()`` returns a dict on recent jax and a
    one-element list of dicts on older versions; normalize to a dict."""
    if isinstance(ca, (list, tuple)):
        return ca[0] if ca else {}
    return ca or {}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*")
_OPCODE_RE = re.compile(r"([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s+\(.*\)\s*->.*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")


def _dims(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for m in _SHAPE_RE.finditer(type_str):
        dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
        out.append((m.group(1), dims))
    return out


def _bytes_of(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(type_str: str) -> int:
    total = 0
    for dt, dims in _dims(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"count": 0.0,
                                                     "operand_bytes": 0.0}))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll.items():
            self.coll[k]["count"] += v["count"] * mult
            self.coll[k]["operand_bytes"] += v["operand_bytes"] * mult


@dataclasses.dataclass
class _Instr:
    name: str
    out_type: str
    opcode: str
    rest: str


def _operands(rest: str) -> List[str]:
    """Split the operand region of an instruction (``rest`` starts right
    after the opcode's opening paren) into operand tokens.

    Types may be printed inline (``f32[64,32]{1,0} %name``) and contain
    commas/braces/parens of their own, so this is a balanced scan, not a
    ``split(",")``: commas only separate operands at paren depth 1
    outside [] and {}.
    """
    depth_p, depth_b, depth_c = 1, 0, 0
    out: List[str] = []
    cur: List[str] = []
    for ch in rest:
        if ch == "(":
            depth_p += 1
        elif ch == ")":
            depth_p -= 1
            if depth_p == 0:
                break
        elif ch == "[":
            depth_b += 1
        elif ch == "]":
            depth_b -= 1
        elif ch == "{":
            depth_c += 1
        elif ch == "}":
            depth_c -= 1
        if ch == "," and depth_p == 1 and depth_b == 0 and depth_c == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    tail = "".join(cur).strip()
    if tail:
        out.append(tail)
    return [o.strip() for o in out if o.strip()]


def _split_tok(tok: str) -> Tuple[Optional[str], str]:
    """Operand token -> (inline type or None, instruction name).

    Depending on XLA version, operands print as ``%name`` or with the type
    inline: ``f32[64,32]{1,0} %name``.
    """
    tok = tok.strip()
    if " %" in tok:
        typ, name = tok.rsplit(" %", 1)
        return typ, name.split(" ")[0]
    return None, tok.lstrip("%").split(" ")[0]


def _operand_name(tok: str) -> str:
    return _split_tok(tok)[1]


def _operand_type(tok: str, syms: Dict[str, str]) -> Optional[str]:
    typ, name = _split_tok(tok)
    return typ if typ is not None else syms.get(name)


def _split_instr(ln: str) -> Optional[_Instr]:
    """Hand parser: tuple types may contain '(', '=', '/*index=N*/' comments,
    so the type is extracted by balanced-paren scan, not regex."""
    m = _NAME_RE.match(ln)
    if not m:
        return None
    rest = ln[m.end():]
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        out_type, rest2 = rest[:end + 1], rest[end + 1:].lstrip()
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        out_type, rest2 = rest[:sp], rest[sp + 1:].lstrip()
    mo = _OPCODE_RE.match(rest2)
    if not mo:
        return None
    return _Instr(m.group(1), out_type, mo.group(1), rest2[mo.end():])


class HloModuleCost:
    def __init__(self, hlo_text: str):
        self.comps: Dict[str, List[_Instr]] = {}
        self.entry: Optional[str] = None
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Cost] = {}

    # -- parsing --------------------------------------------------------------
    def _parse(self, text: str):
        current = None
        for ln in text.splitlines():
            mc = _COMP_RE.match(ln)
            if mc:
                current = mc.group(1)
                self.comps[current] = []
                if ln.lstrip().startswith("ENTRY"):
                    self.entry = current
                continue
            if ln.strip() == "}":
                current = None
                continue
            if current is None:
                continue
            ins = _split_instr(ln)
            if ins:
                self.comps[current].append(ins)

    # -- cost -----------------------------------------------------------------
    def _symbols(self, comp: str) -> Dict[str, str]:
        return {i.name: i.out_type for i in self.comps.get(comp, [])}

    def comp_cost(self, name: str, count_bytes: bool = True) -> Cost:
        key = (name, count_bytes)
        if key in self._memo:
            return self._memo[key]
        cost = Cost()
        self._memo[key] = cost  # break cycles defensively
        syms = self._symbols(name)
        for ins in self.comps.get(name, []):
            cost.add(self._instr_cost(ins, syms, count_bytes))
        return cost

    def _param_slice_info(self, comp: str):
        """Per fusion-body parameter index: how is it actually touched?

        Returns {param_idx: ("slice", bytes) | ("dus", bytes)} for params
        consumed by dynamic-slice (read one slice per call — the scan-over-
        layers pattern: stacked weights / saved activations) or updated by
        dynamic-update-slice (in-place accumulator — RMW of the region).
        Params absent from the map are read fully.  Memoized.
        """
        cache = getattr(self, "_psi_cache", None)
        if cache is None:
            cache = self._psi_cache = {}
        if comp in cache:
            return cache[comp]
        syms = self._symbols(comp)
        param_of = {}
        for ins in self.comps.get(comp, []):
            if ins.opcode == "parameter":
                m = re.match(r"\s*(\d+)", ins.rest)
                if m:
                    param_of[ins.name] = int(m.group(1))
        # follow simple pass-through chains (convert/bitcast/copy/reshape)
        # back to parameters so dus(convert(param)) still resolves
        passthrough = {}
        for ins in self.comps.get(comp, []):
            if ins.opcode in ("convert", "bitcast", "copy", "reshape",
                              "transpose"):
                srcs = _operands(ins.rest)
                if srcs:
                    passthrough[ins.name] = _operand_name(srcs[0])

        def resolve(name):
            seen = 0
            while name in passthrough and seen < 8:
                name = passthrough[name]
                seen += 1
            return name

        info = {}
        for ins in self.comps.get(comp, []):
            ops = [resolve(_operand_name(o)) for o in _operands(ins.rest)]
            if ins.opcode == "dynamic-slice" and ops and ops[0] in param_of:
                idx = param_of[ops[0]]
                prev = info.get(idx, ("slice", 0))[1]
                info[idx] = ("slice", prev + _bytes_of(ins.out_type))
            if ins.opcode == "dynamic-update-slice" and ops:
                upd = _bytes_of(syms.get(ops[1], "")) if len(ops) > 1 else \
                    _bytes_of(ins.out_type) // 8
                if ops[0] in param_of:
                    idx = param_of[ops[0]]
                    prev = info.get(idx, ("dus", 0))[1]
                    info[idx] = ("dus", prev + 2 * upd)
                else:
                    info.setdefault("_dus_orphan", ("dus_orphan", 0))
                    info["_dus_orphan"] = (
                        "dus_orphan",
                        info["_dus_orphan"][1] + 2 * upd)
        cache[comp] = info
        return info

    def _fusion_boundary_bytes(self, ins: _Instr, syms: Dict[str, str],
                               callee: Optional[str]) -> float:
        info = self._param_slice_info(callee) if callee else {}
        orphan = info.get("_dus_orphan", (None, 0))[1]
        op_bytes = []
        total = 0.0
        aliased_out = False
        for pos, o in enumerate(_operands(ins.rest)):
            if pos in info:
                kind, b = info[pos]
                total += b
                if kind == "dus":
                    aliased_out = True     # accumulator aliased in->out
            else:
                ot = _operand_type(o, syms)
                if ot is not None:
                    op_bytes.append(_bytes_of(ot))
        if orphan and not aliased_out and op_bytes:
            # DUS on an unresolved chain: assume the largest operand is the
            # aliased accumulator
            op_bytes.remove(max(op_bytes))
            total += orphan
            aliased_out = True
        total += sum(op_bytes)
        if not aliased_out:
            total += _bytes_of(ins.out_type)
        return total

    def _operand_bytes(self, ins: _Instr, syms: Dict[str, str]) -> int:
        total = 0
        for op in _operands(ins.rest):
            ot = _operand_type(op, syms)
            if ot is not None:
                total += _bytes_of(ot)
        return total

    def _instr_cost(self, ins: _Instr, syms: Dict[str, str],
                    count_bytes: bool) -> Cost:
        c = Cost()
        op = ins.opcode
        base = op.replace("-start", "").replace("-done", "")

        if base in COLLECTIVES:
            if op.endswith("-done"):
                return c
            ob = self._operand_bytes(ins, syms) or _bytes_of(ins.out_type)
            c.coll_bytes += ob
            c.coll[base]["count"] += 1
            c.coll[base]["operand_bytes"] += ob
            if count_bytes:
                c.bytes += ob + _bytes_of(ins.out_type)
            return c

        if op == "while":
            mt = _TRIP_RE.search(ins.rest)
            trips = int(mt.group(1)) if mt else 1
            mb = _BODY_RE.search(ins.rest)
            mc2 = _COND_RE.search(ins.rest)
            if mb:
                c.add(self.comp_cost(mb.group(1), count_bytes), trips)
            if mc2:
                c.add(self.comp_cost(mc2.group(1), False), trips)
            return c

        if op in ("fusion", "call", "async-start"):
            mcal = _CALLS_RE.search(ins.rest) or \
                re.search(r"(?:to_apply|called_computation)=%?([\w\.\-]+)",
                          ins.rest)
            callee = mcal.group(1) if mcal else None
            if callee:
                inner = self.comp_cost(callee, count_bytes=False)
                c.add(Cost(flops=inner.flops, coll_bytes=inner.coll_bytes,
                           coll=inner.coll))
            if count_bytes:
                c.bytes += self._fusion_boundary_bytes(ins, syms, callee)
            return c

        if op == "conditional":
            branches = re.findall(
                r"(?:true_computation|false_computation|branch_computations=\{)"
                r"[^\}]*", ins.rest)
            names = re.findall(r"%([\w\.\-]+)", ",".join(branches))
            if names:
                worst = Cost()
                for n in set(names):
                    bc = self.comp_cost(n, count_bytes=False)
                    if bc.flops >= worst.flops:
                        worst = bc
                c.add(worst)
            if count_bytes:
                c.bytes += self._operand_bytes(ins, syms) + \
                    _bytes_of(ins.out_type)
            return c

        if op == "dot":
            out_elems = _elems_of(ins.out_type)
            contract = 1
            mcon = _CONTRACT_RE.search(ins.rest)
            dot_ops = _operands(ins.rest)
            lhs_type = _operand_type(dot_ops[0], syms) if dot_ops else None
            if mcon and lhs_type is not None:
                ldims = _dims(lhs_type)
                if ldims:
                    dims = ldims[0][1]
                    for idx in (int(x) for x in mcon.group(1).split(",")
                                if x != ""):
                        if idx < len(dims):
                            contract *= dims[idx]
            c.flops += 2.0 * out_elems * contract
            if count_bytes:
                c.bytes += self._operand_bytes(ins, syms) + \
                    _bytes_of(ins.out_type)
            return c

        if op == "convolution":
            # flops ~ 2 * out_elems * (kernel elems / out_features)
            out_elems = _elems_of(ins.out_type)
            ops = _operands(ins.rest)
            kelems = 0
            ktype = _operand_type(ops[1], syms) if len(ops) > 1 else None
            if ktype is not None:
                kd = _dims(ktype)
                if kd:
                    n = 1
                    for d in kd[0][1]:
                        n *= d
                    kelems = n
                    ofeat = kd[0][1][-1] if kd[0][1] else 1
                    kelems = n // max(ofeat, 1)
            c.flops += 2.0 * out_elems * max(kelems, 1)
            if count_bytes:
                c.bytes += self._operand_bytes(ins, syms) + \
                    _bytes_of(ins.out_type)
            return c

        if op in ("parameter", "constant", "get-tuple-element", "tuple",
                  "bitcast", "after-all", "partition-id", "replica-id"):
            return c

        if op == "dynamic-update-slice":
            # RMW of the update region only (in-place on TPU): 2x update bytes
            ops = _operands(ins.rest)
            utype = _operand_type(ops[1], syms) if len(ops) > 1 else None
            if count_bytes:
                c.bytes += 2 * _bytes_of(utype or "")
            return c

        if op == "dynamic-slice":
            if count_bytes:
                c.bytes += 2 * _bytes_of(ins.out_type)  # read slice + write
            return c

        # everything else: ~1 flop per output element; bytes at top level
        c.flops += _elems_of(ins.out_type)
        if count_bytes and op not in ("broadcast", "iota", "reshape", "copy"):
            c.bytes += self._operand_bytes(ins, syms) + _bytes_of(ins.out_type)
        elif count_bytes:
            c.bytes += _bytes_of(ins.out_type)
        return c

    def total(self) -> Cost:
        if not self.entry:
            return Cost()
        return self.comp_cost(self.entry, count_bytes=True)


def module_cost(hlo_text: str) -> Cost:
    return HloModuleCost(hlo_text).total()
