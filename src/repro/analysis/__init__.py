from .roofline import Roofline, model_flops, advice, PEAK_FLOPS, HBM_BW, ICI_BW
from .hlo import collective_stats, total_collective_bytes
from .hlo_cost import module_cost, HloModuleCost
