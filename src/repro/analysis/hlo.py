"""HLO text analysis: collective-traffic accounting for the roofline.

``collective_stats`` parses a post-SPMD-partitioning HLO module
(compiled.as_text()) and sums *operand* bytes of every communication op:
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(+ their -start async forms).  A symbol table of instruction output shapes
resolves operand sizes; unresolvable operands fall back to the op's own
output size.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.+)$")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of a (possibly tuple) HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """-> {kind: {count, operand_bytes}} over the per-device module."""
    # pass 1: symbol table name -> output bytes
    table: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for ln in lines:
        m = _DEF_RE.match(ln)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        # type is everything up to the opcode name; take the leading type expr
        table[name] = _shape_bytes(rhs.split(" ")[0] if not
                                   rhs.startswith("(") else
                                   rhs[:rhs.index(")") + 1])

    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "operand_bytes": 0.0})
    op_re = re.compile(
        r"=\s*(\([^)]*\)|\S+)\s+(" + "|".join(COLLECTIVES) +
        r")(?:-start|-done)?\s*\(([^)]*)\)")
    for ln in lines:
        m = op_re.search(ln)
        if not m:
            continue
        out_type, kind, operands = m.group(1), m.group(2), m.group(3)
        if "-done" in ln.split(kind)[1][:8]:
            continue  # count start, skip done (same transfer)
        ob = 0
        for op in operands.split(","):
            op = op.strip().lstrip("%")
            op = op.split(" ")[0]
            ob += table.get(op, 0)
        if ob == 0:
            ob = _shape_bytes(out_type)
        stats[kind]["count"] += 1
        stats[kind]["operand_bytes"] += float(ob)
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["operand_bytes"] for v in collective_stats(hlo_text).values())
