from .steps import (TrainStepConfig, build_train_step, build_eval_step,
                    build_decode_step, build_prefill_step,
                    build_slot_prefill_step)
from .loop import LoopConfig, train
