"""Jitted train / serve step builders (the units the dry-run lowers).

train_step (PEFT mode — the paper's setting):
    inputs : frozen base params (bf16, no grads), adapter params (fp32,
             trainable), optimizer state (adapters only), batch
    body   : scan over microbatches -> mean adapter grads -> AdamW update
    GSOFT adapters are materialized weight-side inside the step
    (core.peft.materialize_tree) — zero extra collectives under TP.

serve_step: decode_step over a sharded KV cache / SSM state (cache donated);
``pos`` may be per-slot (continuous batching) and an optional
``AdapterContext`` pytree adds per-request GS rotations.
``build_slot_prefill_step`` is the continuous engine's admission unit:
batch-1 prefill scattered into a decode slot. ``ModelRuntime`` owns the
jitted closures built here — engines and launchers go through it.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro import optim
from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.kernels import dispatch as kernel_dispatch
from repro.models import api
from repro.models.layers import no_shard
from repro.sharding.specs import ShardingRules

Tree = Any


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    peft: peft_lib.PEFTConfig = peft_lib.PEFTConfig()
    opt: optim.OptimizerConfig = optim.OptimizerConfig()
    num_microbatches: int = 1
    schedule: Optional[Callable] = None


def _split_microbatches(batch: Tree, n: int) -> Tree:
    return jax.tree.map(
        lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), batch)


def _resolve_peft(cfg: ModelConfig, tcfg: TrainStepConfig) -> peft_lib.PEFTConfig:
    """Sync the kernel path into the PEFT config and install any launch-
    geometry overrides the model config carries: a model run with
    ``use_pallas=True`` fine-tunes through the differentiable Pallas kernels
    end-to-end (adapter materialization included)."""
    kernel_dispatch.install_tunings(cfg.kernel_tunings)
    peft_cfg = tcfg.peft
    if cfg.use_pallas and peft_cfg.is_peft and not peft_cfg.use_pallas:
        peft_cfg = dataclasses.replace(peft_cfg, use_pallas=True)
    return peft_cfg


def build_train_step(cfg: ModelConfig, tcfg: TrainStepConfig,
                     mesh: Optional[Mesh] = None,
                     batch_divisible: bool = True):
    """Returns train_step(frozen, trainable, opt_state, batch) ->
    (trainable, opt_state, metrics). PEFT: trainable = adapters; full FT:
    trainable = params and frozen is an empty dict."""
    shard = (ShardingRules(cfg, mesh).make_sharder(batch_divisible)
             if mesh is not None else no_shard)
    peft_cfg = _resolve_peft(cfg, tcfg)
    is_peft = peft_cfg.is_peft
    n_micro = tcfg.num_microbatches
    schedule = tcfg.schedule or (lambda s: jnp.asarray(1.0, jnp.float32))

    def loss_fn(trainable, frozen, mb):
        if is_peft:
            params = peft_lib.materialize_tree(peft_cfg, frozen, trainable)
        else:
            params = trainable
        loss, metrics = api.loss_fn(cfg, params, mb, shard)
        return loss, metrics

    def train_step(frozen: Tree, trainable: Tree, opt_state: Tree,
                   batch: Tree):
        grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

        if n_micro > 1:
            mbs = _split_microbatches(batch, n_micro)

            def acc_step(carry, mb):
                gacc, lacc = carry
                (loss, metrics), g = grad_fn(trainable, frozen, mb)
                gacc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro, gacc, g)
                return (gacc, lacc + loss / n_micro), metrics

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), trainable)
            (grads, loss), metrics_all = jax.lax.scan(
                acc_step, (zeros, jnp.zeros((), jnp.float32)), mbs)
            metrics = jax.tree.map(lambda m: m[-1], metrics_all)
            metrics["loss"] = loss
        else:
            (loss, metrics), grads = grad_fn(trainable, frozen, batch)

        lr_scale = schedule(opt_state["step"])
        new_trainable, new_opt, om = optim.update(
            tcfg.opt, grads, opt_state, trainable, lr_scale)
        metrics = dict(metrics)
        metrics.update(om)
        return new_trainable, new_opt, metrics

    return train_step


def build_eval_step(cfg: ModelConfig, tcfg: TrainStepConfig,
                    mesh: Optional[Mesh] = None):
    shard = (ShardingRules(cfg, mesh).make_sharder() if mesh is not None
             else no_shard)
    peft_cfg = _resolve_peft(cfg, tcfg)

    def eval_step(frozen, trainable, batch):
        params = (peft_lib.materialize_tree(peft_cfg, frozen, trainable)
                  if peft_cfg.is_peft else trainable)
        _, metrics = api.loss_fn(cfg, params, batch, shard)
        return metrics
    return eval_step


def build_decode_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                      batch_divisible: bool = True):
    """One decode token for the whole batch. ``pos`` may be a scalar
    (lockstep) or an int32 (B,) array of per-slot write positions
    (continuous batching).

    ``ctx`` is an optional ``AdapterContext`` (None when serving the bare
    model): each row's activations rotate with its own GS adapter, slot 0
    being the identity. Structure of ctx is part of the jit cache key."""
    shard = (ShardingRules(cfg, mesh).make_sharder(batch_divisible)
             if mesh is not None else no_shard)
    fam = api.family_ops(cfg)

    def serve_step(params, ctx, tokens, state, pos):
        logits, new_state = fam.decode_step(cfg, params, tokens, state, pos,
                                            shard, ctx=ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_state

    return serve_step


def build_paged_decode_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                            batch_divisible: bool = True):
    """One decode token for the whole batch through per-slot PAGE TABLES
    (ISSUE 7). Same call shape as ``build_decode_step`` — params, ctx,
    tokens (B, 1), state {"pages", "table"}, pos (B,) — so the paged engine
    drops in next to the contiguous one. Parked rows (pos at the sentinel
    position) write into the garbage page; their sampled token is ignored
    by the engine."""
    shard = (ShardingRules(cfg, mesh).make_sharder(batch_divisible)
             if mesh is not None else no_shard)
    fam = api.family_ops(cfg)
    if fam.paged_decode_step is None:
        raise ValueError(f"family {cfg.family!r} has no paged decode path")

    def serve_step(params, ctx, tokens, state, pos):
        logits, new_state = fam.paged_decode_step(cfg, params, tokens, state,
                                                  pos, shard, ctx=ctx)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], logits, new_state

    return serve_step


def build_chunk_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                             batch_divisible: bool = True):
    """Chunked-prefill admission unit: ONE fixed-width prompt chunk for ONE
    slot, written through that slot's page table. Returns
    step(params, req, state, slot, start) -> (first_token scalar, state);
    the chunk width is static (one trace per width), slot/start are traced
    scalars, and the returned first_token is only meaningful on the final
    chunk (req.last_idx marks the prompt's last valid token there)."""
    shard = (ShardingRules(cfg, mesh).make_sharder(batch_divisible)
             if mesh is not None else no_shard)
    fam = api.family_ops(cfg)
    if fam.paged_chunk_prefill is None:
        raise ValueError(f"family {cfg.family!r} has no chunked-prefill path")

    def chunk_step(params, req: peft_lib.PrefillRequest, state, slot, start):
        logits, new_state = fam.paged_chunk_prefill(cfg, params, req, state,
                                                    slot, start, shard)
        first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[0]
        return first, new_state

    return chunk_step


def build_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                       batch_divisible: bool = True):
    """Full-prompt prefill. The single ``PrefillRequest`` argument carries
    the input batch, the per-row ``last_idx`` (ragged-prompt fix) and the
    optional AdapterContext — there are no mode flags or loose kwargs."""
    shard = (ShardingRules(cfg, mesh).make_sharder(batch_divisible)
             if mesh is not None else no_shard)
    fam = api.family_ops(cfg)

    def prefill_step(params, req: peft_lib.PrefillRequest, state):
        logits, new_state = fam.prefill(cfg, params, req, state, shard)
        return logits, new_state

    return prefill_step


def _decode_state_batch_axes(cfg: ModelConfig, max_len: int, enc_len: int):
    """Per-leaf batch-axis tree for the decode state, found by diffing the
    abstract state shapes at two batch sizes (leaves place the batch dim at
    different axes: kv (L, B, S, K, D) vs encdec enc_out (B, F, D) vs hybrid
    mamba (nsuper, per, B, ...))."""
    s1 = api.abstract_decode_state(cfg, 1, max_len, enc_len)
    s2 = api.abstract_decode_state(cfg, 2, max_len, enc_len)

    def axis(a, b):
        for i, (x, y) in enumerate(zip(a.shape, b.shape)):
            if x != y:
                return i
        raise ValueError(f"no batch axis found in decode-state leaf {a.shape}")

    return jax.tree.map(axis, s1, s2)


def build_slot_prefill_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                            *, max_len: int, enc_len: int = 0,
                            batch_divisible: bool = True):
    """Continuous-batching admission: prefill ONE request (batch 1) and
    scatter its fresh decode state into row ``slot`` of the engine's
    persistent slot-array state.

    Returns step(params, req, state, slot) -> (first_token scalar, updated
    state). ``req`` is a batch-1 ``PrefillRequest`` carrying the bucketed
    prompt feed, its ``last_idx`` (the request's last valid position in the
    processed stream — ragged fix) and, when serving a bank, an
    AdapterContext with the (1,) slot id. Donate ``state`` when jitting.
    """
    shard = (ShardingRules(cfg, mesh).make_sharder(batch_divisible)
             if mesh is not None else no_shard)
    fam = api.family_ops(cfg)
    axes = _decode_state_batch_axes(cfg, max_len, enc_len)

    def scatter(dst, src, ax, slot):
        start = [0] * dst.ndim
        start[ax] = slot
        return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                            tuple(start))

    def slot_prefill(params, req, state, slot):
        sub = fam.init_decode_state(cfg, 1, max_len, enc_len)
        logits, sub = fam.prefill(cfg, params, req, sub, shard)
        first = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[0]
        state = jax.tree.map(
            lambda dst, src, ax: scatter(dst, src, ax, slot),
            state, sub, axes)
        return first, state

    return slot_prefill
