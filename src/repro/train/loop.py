"""The training loop: checkpointed, heartbeat-monitored, resumable.

Wiring (per DESIGN §4): deterministic data by (seed, step) — resume replays
exactly; checkpoints carry adapters + optimizer + data cursor; heartbeat +
step-time straggler detection feed the restart wrapper
(launch/scripts/run_with_restart.sh).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.config import ModelConfig
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.data import DataConfig, LMDataSource
from repro.runtime import Heartbeat, StepTimer
from repro.train.steps import TrainStepConfig, build_train_step


@dataclasses.dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    heartbeat_path: Optional[str] = None
    async_ckpt: bool = True


def train(cfg: ModelConfig, tcfg: TrainStepConfig, dcfg: DataConfig,
          loop: LoopConfig, mesh=None, resume: bool = True,
          log_fn: Callable[[str], None] = print) -> Dict[str, Any]:
    key = jax.random.PRNGKey(dcfg.seed)
    params = ModelRuntime(cfg, key=key, mesh=mesh).params
    adapters = peft_lib.init_peft(tcfg.peft, params, key)
    trainable, frozen = peft_lib.trainable_and_frozen(tcfg.peft, params,
                                                      adapters)
    if not tcfg.peft.is_peft:
        trainable, frozen = params, {}
    opt_state = optim.init(tcfg.opt, trainable)

    step_fn = build_train_step(cfg, tcfg, mesh)
    if mesh is not None:
        from repro.sharding.specs import ShardingRules, named
        rules = ShardingRules(cfg, mesh)
        p_sh = named(mesh, rules.params_tree(frozen if tcfg.peft.is_peft
                                             else trainable))
        if tcfg.peft.is_peft:
            t_sh = named(mesh, rules.adapters_tree(trainable))
            frozen = jax.device_put(frozen, p_sh)
        else:
            t_sh = p_sh
        o_sh = jax.tree.map(lambda _: named(
            mesh, jax.sharding.PartitionSpec()), opt_state)
        o_sh = {"mu": t_sh, "nu": t_sh,
                "step": named(mesh, jax.sharding.PartitionSpec())} \
            if tcfg.opt.kind == "adamw" else o_sh
        trainable = jax.device_put(trainable, t_sh)
        opt_state = jax.device_put(opt_state, o_sh)
        step_fn = jax.jit(step_fn, out_shardings=(t_sh, o_sh, None))
    else:
        step_fn = jax.jit(step_fn)

    data = LMDataSource(dcfg)
    start_step = 0
    mgr = None
    if loop.ckpt_dir:
        mgr = CheckpointManager(loop.ckpt_dir)
        if resume and mgr.latest_step() is not None:
            state = mgr.restore({"trainable": jax.device_get(trainable),
                                 "opt": jax.device_get(opt_state)})
            trainable = jax.tree.map(jnp.asarray, state["trainable"])
            opt_state = jax.tree.map(jnp.asarray, state["opt"])
            start_step = mgr.extra().get("data_step", mgr.latest_step())
            log_fn(f"resumed from step {start_step}")

    hb = Heartbeat(loop.heartbeat_path) if loop.heartbeat_path else None
    timer = StepTimer()
    history = []
    for step in range(start_step, loop.steps):
        batch = jax.tree.map(jnp.asarray, data.batch_at(step))
        timer.start()
        trainable, opt_state, metrics = step_fn(frozen, trainable,
                                                opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        t = timer.stop()
        if hb:
            hb.beat(step)
        if step % loop.log_every == 0 or step == loop.steps - 1:
            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss,
                            "accuracy": float(metrics["accuracy"]),
                            "step_time_s": t["step_time_s"],
                            "straggler": t["straggler"]})
            log_fn(f"step {step:5d} loss {loss:.4f} "
                   f"acc {float(metrics['accuracy']):.3f} "
                   f"({t['step_time_s']:.2f}s)")
        if mgr and ((step + 1) % loop.ckpt_every == 0 or
                    step == loop.steps - 1):
            mgr.save(step + 1,
                     {"trainable": trainable, "opt": opt_state},
                     blocking=not loop.async_ckpt,
                     extra={"data_step": step + 1})
    if mgr:
        mgr.wait()
    # serving runtime over the TRAINED weights: adapters merged into the
    # frozen base (PEFT) or the trained tree itself (full FT) — returning
    # the init-time runtime here would silently serve untrained params
    final_params = (peft_lib.materialize_tree(tcfg.peft, frozen, trainable,
                                              merged=True)
                    if tcfg.peft.is_peft else trainable)
    return {"trainable": trainable, "opt_state": opt_state, "frozen": frozen,
            "history": history,
            "runtime": ModelRuntime(cfg, final_params, mesh=mesh)}
