"""Pallas TPU kernels for the framework's compute hot-spots.

  bdmm     — block-diagonal (grouped) matmul: the GS "group" primitive
  gs_fused — fused GSOFT rotation P^T L P R x (one HBM round-trip)
  ssd      — Mamba2 state-space-dual chunked scan (mamba2/zamba2 archs)

Each kernel has a pure-jnp oracle in ref.py; ops.py is the jit-friendly
dispatch used by the model code (use_pallas flag; interpret mode on CPU).
"""
from .ops import bdmm, gs_transform, ssd
from . import ref
