"""Pallas TPU kernels for the framework's compute hot-spots.

  bdmm     — block-diagonal (grouped) matmul: the GS "group" primitive
  gs_fused — fused GSOFT rotation P^T L P R x (one HBM round-trip), its
             transpose rotation Q^T x, and a fused backward producing
             (dx, dL, dR) in a single pass
  ssd      — Mamba2 state-space-dual chunked scan (mamba2/zamba2 archs)

Each kernel has a pure-jnp oracle in ref.py; ops.py is the jit-friendly
dispatch used by the model code.

``use_pallas`` semantics
------------------------
``False`` (default) runs the reference path — identical math via XLA, used
on backends where Mosaic cannot lower (launch/dryrun.py pins it off).
``True`` runs ``pl.pallas_call``; on a non-TPU backend the call transparently
drops to interpret mode so tests/examples exercise the kernel bodies on CPU.
Both settings are fully differentiable: the Pallas path installs the
``jax.custom_vjp`` rules from dispatch.py, whose backward passes are Pallas
kernels too (transposed-blocks bdmm + token-contraction for bdmm; the
transpose rotation R^T P^T L^T P plus fused per-factor gradients for
gs_fused).

Autotuner overrides
-------------------
Launch geometry (token/group tiles) resolves per (shape, dtype, backend)
in dispatch.py: explicit ``tuning=`` argument and config overrides
(``ModelConfig.kernel_tunings``, installed via ``dispatch.install_tunings``)
take precedence, then cached ``dispatch.autotune_*`` search results, then
shape heuristics. Autotuning is eager (times real launches) — trigger it
from warmup/benchmark code, never inside jit.
"""
from .ops import bdmm, flash_mha, gs_transform, gs_transform_T, ssd
from . import dispatch, ref
