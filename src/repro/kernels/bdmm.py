"""Pallas TPU kernel: block-diagonal matmul (the GS "group" primitive).

y[t, g*bo:(g+1)*bo] = blocks[g] @ x[t, g*bi:(g+1)*bi]

TPU mapping (DESIGN §3): paper-scale GS blocks (b in 8..128) are smaller than
the 128x128 MXU, so putting the *block* dim on the systolic array wastes it.
Instead we put tokens on the lane axis (token_tile rows per grid step) and
process ``group_tile`` consecutive blocks per grid step, issuing one
(token_tile x bi) @ (bi x bo) dot per block — contraction dim bi stays on
sublanes, tokens saturate lanes.  One HBM read of x / one write of y total.

VMEM per grid step:
    token_tile * group_tile * (bi + bo) * dtype  +  group_tile * bo * bi * 4
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

Array = jnp.ndarray


def _bdmm_kernel(x_ref, w_ref, o_ref, *, group_tile: int, bi: int, bo: int):
    x = x_ref[...]                       # (tt, group_tile * bi)
    for g in range(group_tile):          # static unroll
        xg = x[:, g * bi:(g + 1) * bi]
        w = w_ref[g]                     # (bo, bi)
        yg = jax.lax.dot_general(
            xg, w, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        o_ref[:, g * bo:(g + 1) * bo] = yg.astype(o_ref.dtype)


def default_group_tile(r: int, bi: int) -> int:
    """Heuristic: >= 128 lanes of weight columns per grid step, capped at r,
    rounded down to a divisor of r."""
    group_tile = max(1, min(r, 128 // max(bi, 1) or 1))
    while r % group_tile:
        group_tile -= 1
    return group_tile


def bdmm_pallas(blocks: Array, x: Array, *, token_tile: int = 128,
                group_tile: int = 0, interpret: bool = False) -> Array:
    """blocks: (r, bo, bi); x: (T, r*bi) -> (T, r*bo)."""
    r, bo, bi = blocks.shape
    t, d = x.shape
    assert d == r * bi, (blocks.shape, x.shape)
    if group_tile <= 0:
        group_tile = default_group_tile(r, bi)
    while r % group_tile:
        group_tile -= 1
    tt = min(token_tile, t)
    pad = (-t) % tt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    tp = x.shape[0]

    grid = (tp // tt, r // group_tile)
    out = pl.pallas_call(
        functools.partial(_bdmm_kernel, group_tile=group_tile, bi=bi, bo=bo),
        out_shape=jax.ShapeDtypeStruct((tp, r * bo), x.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, group_tile * bi), lambda ti, gi: (ti, gi)),
            pl.BlockSpec((group_tile, bo, bi), lambda ti, gi: (gi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tt, group_tile * bo), lambda ti, gi: (ti, gi)),
        interpret=interpret,
    )(x, blocks)
    return out[:t] if pad else out


def _bdmm_dw_kernel(dy_ref, x_ref, dw_ref, *, group_tile: int,
                    bo: int, bi: int):
    ti = pl.program_id(1)
    dy = dy_ref[...]                     # (tt, group_tile * bo)
    x = x_ref[...]                       # (tt, group_tile * bi)
    for g in range(group_tile):          # static unroll
        dyg = dy[:, g * bo:(g + 1) * bo]
        xg = x[:, g * bi:(g + 1) * bi]
        dw = jax.lax.dot_general(         # (bo, bi): contract over tokens
            dyg, xg, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

        @pl.when(ti == 0)
        def _init():
            dw_ref[g] = dw

        @pl.when(ti != 0)
        def _acc():
            dw_ref[g] += dw


def bdmm_dblocks_pallas(dy: Array, x: Array, *, bo: int, bi: int,
                        token_tile: int = 128, group_tile: int = 0,
                        interpret: bool = False) -> Array:
    """Token-contraction backward of bdmm: the gradient w.r.t. the blocks.

    dy: (T, r*bo); x: (T, r*bi)  ->  dblocks (r, bo, bi) in fp32:
        dblocks[g, i, j] = sum_t dy[t, g*bo + i] * x[t, g*bi + j]

    Grid is (group steps, token steps) with tokens innermost, so each
    (group_tile, bo, bi) output block is revisited across consecutive token
    steps and accumulated in place (fp32) — one HBM read of dy and x total.
    """
    t, dyd = dy.shape
    assert dyd % bo == 0 and x.shape[-1] % bi == 0
    r = dyd // bo
    assert x.shape == (t, r * bi), (dy.shape, x.shape, bo, bi)
    if group_tile <= 0:
        group_tile = default_group_tile(r, max(bi, bo))
    while r % group_tile:
        group_tile -= 1
    tt = min(token_tile, t)
    pad = (-t) % tt
    if pad:                               # zero rows contribute zero gradient
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
        x = jnp.pad(x, ((0, pad), (0, 0)))
    tp = dy.shape[0]

    grid = (r // group_tile, tp // tt)
    return pl.pallas_call(
        functools.partial(_bdmm_dw_kernel, group_tile=group_tile, bo=bo,
                          bi=bi),
        out_shape=jax.ShapeDtypeStruct((r, bo, bi), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tt, group_tile * bo), lambda gi, ti: (ti, gi)),
            pl.BlockSpec((tt, group_tile * bi), lambda gi, ti: (ti, gi)),
        ],
        out_specs=pl.BlockSpec((group_tile, bo, bi), lambda gi, ti: (gi, 0, 0)),
        interpret=interpret,
    )(dy, x)
