"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function is the semantic definition; kernels/*.py must match these to
numerical tolerance across the shape/dtype sweeps in tests/test_kernels_*.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

Array = jnp.ndarray


def bdmm_ref(blocks: Array, x: Array) -> Array:
    """Block-diagonal matmul.

    blocks: (r, b_out, b_in);  x: (T, r * b_in)  ->  (T, r * b_out)
    y[t, g*b_out : (g+1)*b_out] = blocks[g] @ x[t, g*b_in : (g+1)*b_in]
    """
    r, b_out, b_in = blocks.shape
    t = x.shape[0]
    xg = x.reshape(t, r, b_in)
    yg = jnp.einsum("gij,tgj->tgi", blocks.astype(jnp.float32),
                    xg.astype(jnp.float32))
    return yg.reshape(t, r * b_out).astype(x.dtype)


def bdmm_banked_ref(blocks: Array, x: Array) -> Array:
    """Per-row block-diagonal matmul (multi-adapter serving).

    blocks: (B, r, b_out, b_in);  x: (B, T, r * b_in)  ->  (B, T, r * b_out)
    Row i of the batch uses its own block set blocks[i] — the reference for
    the "gather adapter blocks -> batched bdmm" serving path.
    """
    bsz, r, b_out, b_in = blocks.shape
    t = x.shape[1]
    xg = x.reshape(bsz, t, r, b_in)
    yg = jnp.einsum("zgij,ztgj->ztgi", blocks.astype(jnp.float32),
                    xg.astype(jnp.float32))
    return yg.reshape(bsz, t, r * b_out).astype(x.dtype)


def gs_banked_T_ref(L: Array, R: Array, x: Array) -> Array:
    """Per-row transpose GSOFT rotation  y[i] = R_i^T P^T L_i^T P x[i].

    L, R: (B, r, b, b); x: (B, T, d) with d = r*b. Row i applies Q_i^T with
    Q_i = P^T L_i P R_i — the activation-side form x Q_i used when each
    request in a decode batch carries a different GS adapter.
    """
    bsz, r, b, _ = L.shape
    t, d = x.shape[1], x.shape[2]
    y = x.reshape(bsz, t, r, b).swapaxes(2, 3).reshape(bsz, t, d)   # P
    y = bdmm_banked_ref(jnp.swapaxes(L, -1, -2), y)                 # L^T .
    y = y.reshape(bsz, t, b, r).swapaxes(2, 3).reshape(bsz, t, d)   # P^T
    y = bdmm_banked_ref(jnp.swapaxes(R, -1, -2), y)                 # R^T .
    return y


def gs_fused_ref(L: Array, R: Array, x: Array) -> Array:
    """Fused GSOFT transform  y = P^T L P R x  with P = P_(r, d).

    L, R: (r, b, b); x: (T, d) with d = r*b. Matches
    core.gs.gs_apply(gsoft_layout(d, b), L, R, x).
    """
    r, b, _ = L.shape
    t, d = x.shape
    y = bdmm_ref(R, x)                               # R x
    y = y.reshape(t, r, b).swapaxes(1, 2).reshape(t, d)   # P   (gather k=r)
    y = bdmm_ref(L, y)                               # L .
    y = y.reshape(t, b, r).swapaxes(1, 2).reshape(t, d)   # P^T (gather k=b)
    return y


def gs_fused_T_ref(L: Array, R: Array, x: Array) -> Array:
    """Transpose GSOFT rotation  y = Q^T x = R^T P^T L^T P x.

    The VJP of gs_fused_ref w.r.t. x; matches
    core.gs.gs_apply_T(gsoft_layout(d, b), L, R, x).
    """
    r, b, _ = L.shape
    t, d = x.shape
    y = x.reshape(t, r, b).swapaxes(1, 2).reshape(t, d)   # P   (gather k=r)
    y = bdmm_ref(jnp.swapaxes(L, -1, -2), y)              # L^T .
    y = y.reshape(t, b, r).swapaxes(1, 2).reshape(t, d)   # P^T (gather k=b)
    y = bdmm_ref(jnp.swapaxes(R, -1, -2), y)              # R^T .
    return y


def householder_banked_ref(V: Array, x: Array) -> Array:
    """Per-row Householder product rotation y[i] = x[i] Q_{i} with
    Q_i = H(v_{i,1}) .. H(v_{i,k}),  H(v) = I - 2 v v^T.

    V: (B, k, d) PRE-NORMALIZED unit reflection vectors (rows of all-e_1
    with k even encode the identity slot exactly); x: (B, T, d).
    Applied reflection by reflection in fp32 — x H = x - 2 (x.v) v — so no
    dense Q ever materializes; O(B*T*k*d) total.
    """
    k = V.shape[1]
    y = x.astype(jnp.float32)
    v32 = V.astype(jnp.float32)
    for i in range(k):
        v = v32[:, i]                                   # (B, d)
        coef = jnp.einsum("btd,bd->bt", y, v)
        y = y - 2.0 * coef[..., None] * v[:, None, :]
    return y.astype(x.dtype)


def givens_banked_ref(C: Array, S: Array, x: Array) -> Array:
    """Per-row Givens-round rotation y[i] = x[i] Q_{i} with
    Q_i = G_m .. G_1 brick-wall rounds of disjoint 2x2 rotations (GOFT).

    C, S: (B, m, d//2) PRE-EVALUATED cos/sin stacks (identity slot is
    c = 1, s = 0); x: (B, T, d). Round l pairs neighbors at offset l % 2
    — (off, off+1), (off+2, off+3), .. — boundary elements stay fixed.
    Row-vector application: rounds reversed, angles negated (x Q =
    (Q^T x^T)^T). fp32 accumulate; O(B*T*m*d) total.
    """
    m = C.shape[1]
    d = x.shape[-1]
    y = x.astype(jnp.float32)
    c32, s32 = C.astype(jnp.float32), S.astype(jnp.float32)
    for lvl in reversed(range(m)):
        off = lvl % 2
        p = (d - off) // 2
        if p == 0:
            continue
        ii = off + 2 * np.arange(p)
        c = c32[:, lvl, :p][:, None, :]                 # (B, 1, p)
        s = -s32[:, lvl, :p][:, None, :]                # transpose side
        a, b = y[..., ii], y[..., ii + 1]
        y = y.at[..., ii].set(c * a - s * b)
        y = y.at[..., ii + 1].set(s * a + c * b)
    return y.astype(x.dtype)


def q_matmul_ref(x: Array, q: Array, scale: Array) -> Array:
    """Quantized-weight matmul oracle.

    x: (T, K) float; q: (K, N) int8/fp8 codes; scale: fp32 broadcastable
    against (K, N) (per-output-channel (1, N) or scalar).
    y = (x @ q) * out_scale in fp32, cast back to x.dtype — the dequant
    runs in the epilogue (scales fold into the N columns), never as a
    materialized (K, N) float weight.
    """
    y = jnp.einsum("tk,kn->tn", x.astype(jnp.float32),
                   q.astype(jnp.float32))
    # per-channel scale is keepdims over the reduced axis -> (1, N); a
    # scalar broadcasts trivially. Either way it multiplies the output.
    return (y * jnp.asarray(scale, jnp.float32).reshape(
        (1, -1) if jnp.ndim(scale) else ())).astype(x.dtype)


def gs_q_matmul_ref(L: Array, R: Array, x: Array, q: Array,
                    scale: Array) -> Array:
    """Fused oracle: activation-side GS rotation then quantized matmul.

    y = (x Q_gs) @ W_q  with  x Q_gs = (R^T P^T L^T P x^T)^T applied in
    the activation dtype (bf16 rotations — the QOFT recipe) and the int8
    base matmul dequantized in the epilogue.
    """
    return q_matmul_ref(gs_fused_T_ref(L, R, x), q, scale)


def flash_ref(q: Array, k: Array, v: Array, causal: bool = True,
              scale: float = 0.0) -> Array:
    """Plain softmax attention oracle. q: (H, Sq, D); k, v: (H, Sk, D)."""
    h, sq, d = q.shape
    sk = k.shape[1]
    scale = scale or 1.0 / (d ** 0.5)
    s = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: Array, loga: Array, B: Array, C: Array,
            initial_state: Array | None = None,
            return_state: bool = False):
    """Mamba2 SSD (state-space dual) — sequential-scan oracle.

    x:    (T, H, P)   inputs (already multiplied by dt)
    loga: (T, H)      log decay per step (dt * A, A < 0)
    B:    (T, H, N)   input projections (already multiplied by dt where
                      applicable; per-head — groups broadcast upstream)
    C:    (T, H, N)   output projections
    state: (H, N, P)

    y_t = C_t^T S_t,   S_t = exp(loga_t) S_{t-1} + B_t x_t^T
    """
    T, H, P = x.shape
    N = B.shape[-1]
    f32 = jnp.float32
    if initial_state is None:
        initial_state = jnp.zeros((H, N, P), f32)

    def step(S, inp):
        xt, lat, Bt, Ct = inp
        S = jnp.exp(lat)[:, None, None] * S + Bt[:, :, None] * xt[:, None, :]
        yt = jnp.einsum("hn,hnp->hp", Ct, S)
        return S, yt

    S, y = jax.lax.scan(step, initial_state.astype(f32),
                        (x.astype(f32), loga.astype(f32),
                         B.astype(f32), C.astype(f32)))
    y = y.astype(x.dtype)
    if return_state:
        return y, S
    return y


def ssd_chunked_ref(x: Array, loga: Array, B: Array, C: Array,
                    chunk: int = 16) -> Array:
    """Chunk-parallel SSD formulation (the algorithm the kernel implements).

    Equivalent to ssd_ref; exists to make the chunking math independently
    testable. All in fp32.
    """
    T, H, P = x.shape
    N = B.shape[-1]
    assert T % chunk == 0
    nc = T // chunk
    f32 = jnp.float32
    xc = x.astype(f32).reshape(nc, chunk, H, P)
    lac = loga.astype(f32).reshape(nc, chunk, H)
    Bc = B.astype(f32).reshape(nc, chunk, H, N)
    Cc = C.astype(f32).reshape(nc, chunk, H, N)

    def per_chunk(S, inp):
        xq, laq, Bq, Cq = inp          # (Q,H,*)
        cum = jnp.cumsum(laq, axis=0)  # (Q,H) inclusive
        total = cum[-1]                # (H,)
        # intra-chunk: causal decay-weighted attention  (Q,Q,H)
        rel = cum[:, None, :] - cum[None, :, :]
        mask = jnp.tril(jnp.ones((xq.shape[0], xq.shape[0]), bool))
        gamma = jnp.where(mask[:, :, None], jnp.exp(rel), 0.0)
        scores = jnp.einsum("thn,shn->tsh", Cq, Bq) * gamma
        y_intra = jnp.einsum("tsh,shp->thp", scores, xq)
        # inter-chunk: contribution of carried state
        y_inter = jnp.einsum("thn,hnp->thp", Cq * jnp.exp(cum)[..., None], S)
        # state update
        w = jnp.exp(total[None, :] - cum)              # (Q,H)
        S_new = jnp.exp(total)[:, None, None] * S + \
            jnp.einsum("qhn,qhp->hnp", Bq * w[..., None], xq)
        return S_new, y_intra + y_inter

    S0 = jnp.zeros((H, N, P), f32)
    _, y = jax.lax.scan(per_chunk, S0, (xc, lac, Bc, Cc))
    return y.reshape(T, H, P).astype(x.dtype)


def paged_attn_ref(q: Array, k_pages: Array, v_pages: Array, table: Array,
                   kv_len: Array, scale: float = 0.0) -> Array:
    """Paged decode-attention oracle: one query token per row attends over
    the KV pages its page table maps to.

    q: (B, H, D); k_pages, v_pages: (P, page, K, D) — the shared page pool;
    table: (B, W) int32 page table (stream page j of row b lives in physical
    page table[b, j]); kv_len: (B,) valid-key counts. GQA: H = K * G.
    Returns (B, H, D).
    """
    b, h, d = q.shape
    _, page, kh, _ = k_pages.shape
    g = h // kh
    scale = scale or 1.0 / (d ** 0.5)
    # gather stream-ordered KV: (B, W*page, K, D)
    k = k_pages[table].reshape(b, -1, kh, d)
    v = v_pages[table].reshape(b, -1, kh, d)
    qg = (q.astype(jnp.float32) * scale).reshape(b, kh, g, d)
    s = jnp.einsum("bkgd,bckd->bkgc", qg, k.astype(jnp.float32))
    kpos = jnp.arange(k.shape[1])
    s = jnp.where(kpos[None, None, None, :] < kv_len[:, None, None, None],
                  s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgc,bckd->bkgd", p, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)
