"""Pallas TPU kernel: flash attention (online-softmax, causal-block skip).

The roofline analysis (EXPERIMENTS.md §Roofline) shows every attention cell
is memory-dominant on the XLA fallback path because the (Sq, Sk) score/prob
tensors are materialized in HBM per KV chunk.  This kernel keeps the
(blk_q, blk_k) score tile, the running (m, l) statistics and the output
accumulator in VMEM scratch across the KV grid dimension — HBM traffic drops
to one read of Q/K/V and one write of O (the flash-attention bound).

Causality is exploited structurally: KV blocks strictly above the diagonal
are skipped with pl.when (predicated out on TPU), halving causal FLOPs —
the same win the prefix_loop schedule gets on the XLA path (§Perf iter 3a).

Grid: (heads, Sq/blk_q, Sk/blk_k), KV innermost so scratch carries the
running statistics for one (head, q-block) row. GQA: callers map/bcast KV
heads (ops.flash_mha handles (B, S, H, D) + group broadcast).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  blk_q: int, blk_k: int, scale: float, causal: bool,
                  nk: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * blk_q
    k_lo = ki * blk_k
    live = (q_lo + blk_q - 1 >= k_lo) if causal else True

    @pl.when(live)
    def _():
        q = q_ref[0]                                    # (blk_q, D)
        k = k_ref[0]                                    # (blk_k, D)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 0)
            cols = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    scale: float = 0.0, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False) -> Array:
    """q: (H, Sq, D); k, v: (H, Sk, D) -> (H, Sq, D).

    Sq/Sk are padded to block multiples internally; padded KV rows are
    masked by construction (padded K rows produce pad-query interactions
    only in the pad region which is sliced off; for non-causal use callers
    must pass exact lengths or pre-mask — ops.flash_mha handles this).
    """
    h, sq, d = q.shape
    sk = k.shape[1]
    scale = scale or 1.0 / math.sqrt(d)
    bq, bk = min(blk_q, sq), min(blk_k, sk)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # pad keys with a large-negative sentinel via masking: pad rows of K
        # are zeros; mask them through an additive bias on the scores is not
        # expressible per-block here, so require causal or exact multiples.
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
        if not causal:
            raise ValueError("non-causal flash needs Sk % blk_k == 0")
    sqp, skp = q.shape[1], k.shape[1]
    nk = skp // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, blk_q=bq, blk_k=bk, scale=scale,
                          causal=causal, nk=nk),
        out_shape=jax.ShapeDtypeStruct((h, sqp, d), q.dtype),
        grid=(h, sqp // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hi, qi, ki: (hi, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda hi, qi, ki: (hi, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda hi, qi, ki: (hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hi, qi, ki: (hi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq] if pq else out
