"""Pallas TPU kernel: flash attention (online-softmax, causal-block skip).

The roofline analysis (EXPERIMENTS.md §Roofline) shows every attention cell
is memory-dominant on the XLA fallback path because the (Sq, Sk) score/prob
tensors are materialized in HBM per KV chunk.  This kernel keeps the
(blk_q, blk_k) score tile, the running (m, l) statistics and the output
accumulator in VMEM scratch across the KV grid dimension — HBM traffic drops
to one read of Q/K/V and one write of O (the flash-attention bound).

Causality is exploited structurally: KV blocks strictly above the diagonal
are skipped with pl.when (predicated out on TPU), halving causal FLOPs —
the same win the prefix_loop schedule gets on the XLA path (§Perf iter 3a).

Grid: (heads, Sq/blk_q, Sk/blk_k), KV innermost so scratch carries the
running statistics for one (head, q-block) row. GQA: callers map/bcast KV
heads (ops.flash_mha handles (B, S, H, D) + group broadcast).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  blk_q: int, blk_k: int, scale: float, causal: bool,
                  nk: int):
    qi, ki = pl.program_id(1), pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = qi * blk_q
    k_lo = ki * blk_k
    live = (q_lo + blk_q - 1 >= k_lo) if causal else True

    @pl.when(live)
    def _():
        q = q_ref[0]                                    # (blk_q, D)
        k = k_ref[0]                                    # (blk_k, D)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            rows = q_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 0)
            cols = k_lo + jax.lax.broadcasted_iota(jnp.int32,
                                                   (blk_q, blk_k), 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    scale: float = 0.0, blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = False) -> Array:
    """q: (H, Sq, D); k, v: (H, Sk, D) -> (H, Sq, D).

    Sq/Sk are padded to block multiples internally; padded KV rows are
    masked by construction (padded K rows produce pad-query interactions
    only in the pad region which is sliced off; for non-causal use callers
    must pass exact lengths or pre-mask — ops.flash_mha handles this).
    """
    h, sq, d = q.shape
    sk = k.shape[1]
    scale = scale or 1.0 / math.sqrt(d)
    bq, bk = min(blk_q, sq), min(blk_k, sk)
    pq, pk = (-sq) % bq, (-sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pk:
        # pad keys with a large-negative sentinel via masking: pad rows of K
        # are zeros; mask them through an additive bias on the scores is not
        # expressible per-block here, so require causal or exact multiples.
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0)))
        if not causal:
            raise ValueError("non-causal flash needs Sk % blk_k == 0")
    sqp, skp = q.shape[1], k.shape[1]
    nk = skp // bk

    out = pl.pallas_call(
        functools.partial(_flash_kernel, blk_q=bq, blk_k=bk, scale=scale,
                          causal=causal, nk=nk),
        out_shape=jax.ShapeDtypeStruct((h, sqp, d), q.dtype),
        grid=(h, sqp // bq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda hi, qi, ki: (hi, qi, 0)),
            pl.BlockSpec((1, bk, d), lambda hi, qi, ki: (hi, ki, 0)),
            pl.BlockSpec((1, bk, d), lambda hi, qi, ki: (hi, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda hi, qi, ki: (hi, qi, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq] if pq else out


# ---------------------------------------------------------------------------
# paged decode attention (PagedAttention-style KV page pool + page tables)
# ---------------------------------------------------------------------------

def _paged_decode_kernel(tbl_ref, kvl_ref, q_ref, k_ref, v_ref, o_ref,
                         m_scr, l_scr, acc_scr, *, page: int, kh: int,
                         g: int, scale: float, nw: int):
    """Grid (B, W): one query token per batch row, KV pages innermost.

    The page table rides the scalar-prefetch channel so each (b, j) step's
    K/V BlockSpec index_map gathers physical page ``tbl[b, j]`` straight
    from the pool — the kernel body never sees an indirection. Running
    (m, l, acc) stats live in VMEM scratch across the page dimension, so a
    row's whole history is one pass over its resident pages."""
    b, j = pl.program_id(0), pl.program_id(1)
    del tbl_ref  # consumed by the BlockSpec index_maps

    @pl.when(j == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kvlen = kvl_ref[b]

    @pl.when(j * page < kvlen)           # skip pages past the filled prefix
    def _():
        d = q_ref.shape[-1]
        q = (q_ref[0] * scale).reshape(kh, g, d)         # (K, G, D)
        k = jnp.swapaxes(k_ref[0], 0, 1)                 # (K, page, D)
        v = jnp.swapaxes(v_ref[0], 0, 1)
        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        kpos = j * page + jax.lax.broadcasted_iota(jnp.int32,
                                                   (kh, g, page), 2)
        s = jnp.where(kpos < kvlen, s, NEG_INF)          # (K, G, page)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=-1)
        acc_scr[...] = acc_scr[...] * corr[..., None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(j == nw - 1)
    def _():
        d = q_ref.shape[-1]
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / l[..., None]).reshape(kh * g, d).astype(
            o_ref.dtype)


def paged_flash_decode(q: Array, k_pages: Array, v_pages: Array,
                       table: Array, kv_len: Array, *, scale: float = 0.0,
                       interpret: bool = False) -> Array:
    """q: (B, H, D); k_pages/v_pages: (P, page, K, D); table: (B, W) int32;
    kv_len: (B,) -> (B, H, D). Semantics: kernels/ref.py::paged_attn_ref."""
    b, h, d = q.shape
    _, page, kh, _ = k_pages.shape
    g = h // kh
    w = table.shape[1]
    scale = scale or 1.0 / math.sqrt(d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                    # table + kv_len
        grid=(b, w),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda bi, j, tbl, kvl: (bi, 0, 0)),
            pl.BlockSpec((1, page, kh, d),
                         lambda bi, j, tbl, kvl: (tbl[bi, j], 0, 0, 0)),
            pl.BlockSpec((1, page, kh, d),
                         lambda bi, j, tbl, kvl: (tbl[bi, j], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda bi, j, tbl, kvl: (bi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kh, g), jnp.float32),
            pltpu.VMEM((kh, g), jnp.float32),
            pltpu.VMEM((kh, g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_decode_kernel, page=page, kh=kh, g=g,
                          scale=scale, nw=w),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=interpret,
    )(table.astype(jnp.int32), kv_len.astype(jnp.int32), q, k_pages, v_pages)
