"""Pallas TPU kernel: fused GSOFT transform  y = P^T L P R x.

The unfused path costs 4 HBM round-trips of the activation (R-matmul, shuffle,
L-matmul, unshuffle — XLA usually fuses some but keeps the transpose copies).
This kernel keeps a (token_tile, d) slab resident in VMEM and performs
group -> shuffle -> group -> unshuffle entirely on-chip: exactly one HBM read
of x and one write of y.  The P_(r,d) shuffle is a reshape/swap on VMEM data
(a Mosaic relayout, no HBM traffic) — the TPU-native realization of the
paper's "shuffle is free" property.

Constraint: token_tile * d * (2 dtypes) + 2*d*b*4 bytes must fit VMEM
(~16 MB); ops.py falls back to two bdmm calls for oversized d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

Array = jnp.ndarray


def _gs_fused_kernel(x_ref, l_ref, r_ref, o_ref, *, r: int, b: int):
    t = x_ref.shape[0]
    d = r * b
    x = x_ref[...]                                   # (t, d)
    f32 = jnp.float32

    # R x  — grouped right-multiplication (tokens on lanes)
    xg = x.reshape(t, r, b)
    R = r_ref[...]                                   # (r, b, b)
    y = jax.lax.dot_general(xg, R, (((2,), (2,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)

    # P (k = r): flat feature index g*b+i  ->  i*r+g. y is (r, t, b); laying it
    # out as (t, i, g) IS the shuffled order, so one transpose + regroup does P.
    y = y.transpose(1, 2, 0)                         # (t, b, r): [t, i, g]
    L = l_ref[...]                                   # (r, b, b) blocks of L
    y = y.reshape(t, r, b)                           # regroup for L's blocks
    z = jax.lax.dot_general(y, L, (((2,), (2,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)
    # P^T: inverse shuffle (k = b): (r_groups, b) -> interleave back
    z = z.transpose(1, 0, 2)                         # (t, r, b)
    z = z.reshape(t, d).reshape(t, b, r).transpose(0, 2, 1).reshape(t, d)
    o_ref[...] = z.astype(o_ref.dtype)


def gs_fused_pallas(L: Array, R: Array, x: Array, *, token_tile: int = 128,
                    interpret: bool = False) -> Array:
    """L, R: (r, b, b); x: (T, d=r*b) -> (T, d). y = P^T L P R x."""
    r, b, _ = L.shape
    t, d = x.shape
    assert d == r * b
    tt = min(token_tile, t)
    pad = (-t) % tt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    tp = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_gs_fused_kernel, r=r, b=b),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        grid=(tp // tt,),
        in_specs=[
            pl.BlockSpec((tt, d), lambda ti: (ti, 0)),
            pl.BlockSpec((r, b, b), lambda ti: (0, 0, 0)),
            pl.BlockSpec((r, b, b), lambda ti: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tt, d), lambda ti: (ti, 0)),
        interpret=interpret,
    )(x, L, R)
    return out[:t] if pad else out
