"""Pallas TPU kernel: fused GSOFT transform  y = P^T L P R x.

The unfused path costs 4 HBM round-trips of the activation (R-matmul, shuffle,
L-matmul, unshuffle — XLA usually fuses some but keeps the transpose copies).
This kernel keeps a (token_tile, d) slab resident in VMEM and performs
group -> shuffle -> group -> unshuffle entirely on-chip: exactly one HBM read
of x and one write of y.  The P_(r,d) shuffle is a reshape/swap on VMEM data
(a Mosaic relayout, no HBM traffic) — the TPU-native realization of the
paper's "shuffle is free" property.

Constraint: token_tile * d * (2 dtypes) + 2*d*b*4 bytes must fit VMEM
(~16 MB); ops.py falls back to two bdmm calls for oversized d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

Array = jnp.ndarray


def _gs_fused_kernel(x_ref, l_ref, r_ref, o_ref, *, r: int, b: int):
    t = x_ref.shape[0]
    d = r * b
    x = x_ref[...]                                   # (t, d)
    f32 = jnp.float32

    # R x  — grouped right-multiplication (tokens on lanes)
    xg = x.reshape(t, r, b)
    R = r_ref[...]                                   # (r, b, b)
    y = jax.lax.dot_general(xg, R, (((2,), (2,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)

    # P (k = r): flat feature index g*b+i  ->  i*r+g. y is (r, t, b); laying it
    # out as (t, i, g) IS the shuffled order, so one transpose + regroup does P.
    y = y.transpose(1, 2, 0)                         # (t, b, r): [t, i, g]
    L = l_ref[...]                                   # (r, b, b) blocks of L
    y = y.reshape(t, r, b)                           # regroup for L's blocks
    z = jax.lax.dot_general(y, L, (((2,), (2,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)
    # P^T: inverse shuffle (k = b): (r_groups, b) -> interleave back
    z = z.transpose(1, 0, 2)                         # (t, r, b)
    z = z.reshape(t, d).reshape(t, b, r).transpose(0, 2, 1).reshape(t, d)
    o_ref[...] = z.astype(o_ref.dtype)


def _gs_fused_T_kernel(x_ref, l_ref, r_ref, o_ref, *, r: int, b: int):
    t = x_ref.shape[0]
    d = r * b
    x = x_ref[...]                                   # (t, d)
    f32 = jnp.float32

    # P x (k = r): shuffle, then regroup for L's blocks
    s = x.reshape(t, r, b).transpose(0, 2, 1).reshape(t, r, b)
    L = l_ref[...]                                   # (r, b, b)
    # L^T .  — q[g,t,j] = sum_i L[g,i,j] s[t,g,i]
    q = jax.lax.dot_general(s, L, (((2,), (1,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)
    # P^T (k = b): inverse shuffle, regroup for R's blocks
    m = q.transpose(1, 0, 2).reshape(t, d)
    m = m.reshape(t, b, r).transpose(0, 2, 1).reshape(t, r, b)
    R = r_ref[...]
    # R^T .
    z = jax.lax.dot_general(m, R, (((2,), (1,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)
    o_ref[...] = z.transpose(1, 0, 2).reshape(t, d).astype(o_ref.dtype)


def _gs_fused_bwd_kernel(dy_ref, x_ref, l_ref, r_ref, *out_refs,
                         r: int, b: int, with_dx: bool):
    """Fused backward: one read of (x, dy), all intermediates in VMEM.

    Recomputes the cheap forward intermediates (2*d*b flops/token) instead
    of saving them — residuals are just (x, L, R), so the bwd HBM traffic is
    one slab read of x and dy plus the block factors.  with_dx=False skips
    the dx rotation and its slab write entirely (the gs_T VJP needs only
    the factor grads from this kernel).
    """
    if with_dx:
        dx_ref, dl_ref, dr_ref = out_refs
    else:
        dl_ref, dr_ref = out_refs
    ti = pl.program_id(0)
    t = dy_ref.shape[0]
    d = r * b
    f32 = jnp.float32
    dy = dy_ref[...]
    x = x_ref[...]
    L = l_ref[...]
    R = r_ref[...]

    xg = x.reshape(t, r, b)
    # forward intermediates:  u = R x  (grouped),  v = P u  (shuffled groups)
    u = jax.lax.dot_general(xg, R, (((2,), (2,)), ((1,), (0,))),
                            preferred_element_type=f32)   # (r, t, b)
    v = u.transpose(1, 2, 0).reshape(t, r, b)
    # dw = P dy  (y = P^T w  =>  w-cotangent is the shuffled dy)
    dw = dy.reshape(t, r, b).transpose(0, 2, 1).reshape(t, r, b)
    # dL[g, i, j] = sum_t dw[t, g, i] v[t, g, j]
    dL = jax.lax.dot_general(dw, v, (((0,), (0,)), ((1,), (1,))),
                             preferred_element_type=f32)  # (r, b, b)
    # dv = L^T dw
    dv = jax.lax.dot_general(dw, L, (((2,), (1,)), ((1,), (0,))),
                             preferred_element_type=f32)  # (r, t, b)
    # du = P^T dv  (back to original grouping)
    du = dv.transpose(1, 0, 2).reshape(t, d)
    du = du.reshape(t, b, r).transpose(0, 2, 1).reshape(t, r, b)
    # dR[g, i, j] = sum_t du[t, g, i] x[t, g, j]
    dR = jax.lax.dot_general(du, xg.astype(f32),
                             (((0,), (0,)), ((1,), (1,))),
                             preferred_element_type=f32)  # (r, b, b)
    if with_dx:
        # dx = R^T du
        dx = jax.lax.dot_general(du, R, (((2,), (1,)), ((1,), (0,))),
                                 preferred_element_type=f32)  # (r, t, b)
        dx_ref[...] = dx.transpose(1, 0, 2).reshape(t, d).astype(dx_ref.dtype)

    @pl.when(ti == 0)
    def _init():
        dl_ref[...] = dL
        dr_ref[...] = dR

    @pl.when(ti != 0)
    def _acc():
        dl_ref[...] += dL
        dr_ref[...] += dR


def _call_gs_kernel(kernel, L: Array, R: Array, x: Array,
                    token_tile: int, interpret: bool) -> Array:
    r, b, _ = L.shape
    t, d = x.shape
    assert d == r * b
    tt = min(token_tile, t)
    pad = (-t) % tt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    tp = x.shape[0]
    out = pl.pallas_call(
        functools.partial(kernel, r=r, b=b),
        out_shape=jax.ShapeDtypeStruct((tp, d), x.dtype),
        grid=(tp // tt,),
        in_specs=[
            pl.BlockSpec((tt, d), lambda ti: (ti, 0)),
            pl.BlockSpec((r, b, b), lambda ti: (0, 0, 0)),
            pl.BlockSpec((r, b, b), lambda ti: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tt, d), lambda ti: (ti, 0)),
        interpret=interpret,
    )(x, L, R)
    return out[:t] if pad else out


def gs_fused_pallas(L: Array, R: Array, x: Array, *, token_tile: int = 128,
                    interpret: bool = False) -> Array:
    """L, R: (r, b, b); x: (T, d=r*b) -> (T, d). y = P^T L P R x."""
    return _call_gs_kernel(_gs_fused_kernel, L, R, x, token_tile, interpret)


def gs_fused_T_pallas(L: Array, R: Array, x: Array, *, token_tile: int = 128,
                      interpret: bool = False) -> Array:
    """Transpose rotation  y = R^T P^T L^T P x  (= Q^T x), same VMEM budget.

    This is both the VJP of gs_fused_pallas w.r.t. x and the activation-side
    adapter application (x Q = (Q^T x^T)^T).
    """
    return _call_gs_kernel(_gs_fused_T_kernel, L, R, x, token_tile, interpret)


def _call_gs_bwd(L: Array, R: Array, x: Array, dy: Array, *,
                 token_tile: int, interpret: bool, with_dx: bool):
    r, b, _ = L.shape
    t, d = x.shape
    assert d == r * b and dy.shape == x.shape
    tt = min(token_tile, t)
    pad = (-t) % tt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        dy = jnp.pad(dy, ((0, pad), (0, 0)))
    tp = x.shape[0]
    grad_shape = jax.ShapeDtypeStruct((r, b, b), jnp.float32)
    grad_spec = pl.BlockSpec((r, b, b), lambda ti: (0, 0, 0))
    slab_spec = pl.BlockSpec((tt, d), lambda ti: (ti, 0))
    out_shape = (grad_shape, grad_shape)
    out_specs = (grad_spec, grad_spec)
    if with_dx:
        out_shape = (jax.ShapeDtypeStruct((tp, d), x.dtype),) + out_shape
        out_specs = (slab_spec,) + out_specs
    outs = pl.pallas_call(
        functools.partial(_gs_fused_bwd_kernel, r=r, b=b, with_dx=with_dx),
        out_shape=out_shape,
        grid=(tp // tt,),
        in_specs=[slab_spec, slab_spec, grad_spec, grad_spec],
        out_specs=out_specs,
        interpret=interpret,
    )(dy, x, L, R)
    if with_dx:
        dx, dL, dR = outs
        return (dx[:t] if pad else dx), dL, dR
    return outs


def gs_fused_bwd_pallas(L: Array, R: Array, x: Array, dy: Array, *,
                        token_tile: int = 128, interpret: bool = False):
    """Fused backward of  y = P^T L P R x.

    Returns (dx, dL, dR) with dx in x.dtype and dL, dR accumulated in fp32:
        dx = Q^T dy,   dL[g] = sum_t (P dy)_g (P R x)_g^T,
        dR[g] = sum_t (P^T L^T P dy)_g x_g^T.

    One grid pass over token tiles; dL/dR output blocks are revisited every
    step and accumulated in place, the activation slab never leaves VMEM.
    """
    return _call_gs_bwd(L, R, x, dy, token_tile=token_tile,
                        interpret=interpret, with_dx=True)


def gs_fused_grads_pallas(L: Array, R: Array, x: Array, dy: Array, *,
                          token_tile: int = 128, interpret: bool = False):
    """Factor gradients only: (dL, dR) of <dy, P^T L P R x> — no dx slab
    is computed or written (used by the gs_T VJP, which gets its dx from
    the forward rotation of dy instead)."""
    return _call_gs_bwd(L, R, x, dy, token_tile=token_tile,
                        interpret=interpret, with_dx=False)
