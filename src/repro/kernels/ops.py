"""Dispatch layer for the Pallas kernels.

``use_pallas`` semantics (plumbed from model configs):
  * False  — pure-jnp reference path (XLA). Always used by launch/dryrun.py:
             TPU kernels cannot lower on the CPU dry-run backend, and the
             reference path is semantically identical (tests prove it).
  * True   — pl.pallas_call; on a non-TPU backend this transparently runs in
             interpret mode so examples/tests exercise the kernel body on CPU.

Both paths are differentiable: the reference path via XLA autodiff, the
Pallas path via the custom-VJP rules in dispatch.py (backward passes are
Pallas kernels too, so ``jax.grad`` of a GSOFT loss never round-trips the
activation slab through HBM more than once per direction).

Launch geometry (token/group tiles) is resolved per (shape, dtype, backend)
by ``dispatch.get_tuning`` — config overrides > autotuned > heuristic; pass
``tuning=`` to pin a call site explicitly.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from . import dispatch, ref
from .dispatch import Tuning
from .flash_attention import flash_attention, paged_flash_decode
from .ssd import ssd_pallas

Array = jnp.ndarray


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def bdmm(blocks: Array, x: Array, use_pallas: bool = False,
         tuning: Optional[Tuning] = None) -> Array:
    """Block-diagonal matmul; supports leading batch dims on x."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_pallas:
        r, bo, bi = blocks.shape
        tun = tuning or dispatch.get_tuning(dispatch.bdmm_key(r, bo, bi,
                                                              x.dtype))
        y = dispatch.bdmm_diff(tun, _interpret(), blocks, x2)
    else:
        y = ref.bdmm_ref(blocks, x2)
    return y.reshape(lead + (y.shape[-1],))


def _gs_2d(L: Array, x: Array):
    r, b, _ = L.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    tun = dispatch.get_tuning(dispatch.gs_key(r, b, x.dtype))
    return lead, x2, tun


def gs_transform(L: Array, R: Array, x: Array, use_pallas: bool = False,
                 tuning: Optional[Tuning] = None) -> Array:
    """y = P^T L P R x (GSOFT rotation) over the last dim of x."""
    lead, x2, tun = _gs_2d(L, x)
    if use_pallas:
        y = dispatch.gs_diff(tuning or tun, _interpret(), L, R, x2)
    else:
        y = ref.gs_fused_ref(L, R, x2)
    return y.reshape(lead + (x.shape[-1],))


def gs_transform_T(L: Array, R: Array, x: Array, use_pallas: bool = False,
                   tuning: Optional[Tuning] = None) -> Array:
    """y = R^T P^T L^T P x (transpose rotation Q^T x) over the last dim.

    Used for activation-side adapters (x Q) and the output-side factor of
    Double GSOFT (W Q).
    """
    lead, x2, tun = _gs_2d(L, x)
    if use_pallas:
        y = dispatch.gs_T_diff(tuning or tun, _interpret(), L, R, x2)
    else:
        y = ref.gs_fused_T_ref(L, R, x2)
    return y.reshape(lead + (x.shape[-1],))


def bdmm_banked(blocks: Array, x: Array, use_pallas: bool = False,
                tuning: Optional[Tuning] = None) -> Array:
    """Per-row block-diagonal matmul: blocks (B, r, bo, bi), x (B, T, r*bi).

    Row i uses its own block set — the serving-side primitive behind
    per-request adapter rotations. The Pallas path vmaps the bdmm kernel
    over the row axis (one grid dim per row)."""
    if use_pallas:
        _, r, bo, bi = blocks.shape
        tun = tuning or dispatch.get_tuning(dispatch.bdmm_key(r, bo, bi,
                                                              x.dtype))
        interp = _interpret()
        return jax.vmap(
            lambda bb, xx: dispatch.bdmm_diff(tun, interp, bb, xx))(blocks, x)
    return ref.bdmm_banked_ref(blocks, x)


def gs_banked_transform_T(L: Array, R: Array, x: Array,
                          use_pallas: bool = False,
                          tuning: Optional[Tuning] = None) -> Array:
    """Per-row transpose GSOFT rotation y[i] = Q_i^T x[i] (= x[i] Q_i as a
    row vector), Q_i = P^T L_i P R_i.

    L, R: (B, r, b, b) pre-gathered per-row orthogonal blocks; x: (B, T, d).
    This is the continuous-batching engine's multi-adapter hot path: each
    decode slot rotates its activations with its own adapter at O(b*d) per
    token instead of re-merging an O(d^2) weight set per request."""
    if use_pallas:
        _, r, b, _bb = L.shape
        tun = tuning or dispatch.get_tuning(dispatch.gs_key(r, b, x.dtype))
        interp = _interpret()
        return jax.vmap(
            lambda l, rr, xx: dispatch.gs_T_diff(tun, interp, l, rr, xx))(
                L, R, x)
    return ref.gs_banked_T_ref(L, R, x)


def householder_banked(V: Array, x: Array, use_pallas: bool = False) -> Array:
    """Per-row Householder-product rotation y[i] = x[i] Q_{i} (HOFT bank).

    V: (B, k, d) pre-normalized unit reflection vectors; x: (B, T, d).
    There is NO dedicated Pallas kernel for this transform: it is O(k*d)
    per token — bandwidth-trivial next to the projection matmul it
    precedes — so the reference einsum is the implementation on every
    backend (``use_pallas`` is accepted for hook uniformity and ignored;
    the method registers ``banked_kernel=""`` — see
    ``dispatch.BANKED_KEYS``)."""
    del use_pallas
    return ref.householder_banked_ref(V, x)


def givens_banked(C: Array, S: Array, x: Array,
                  use_pallas: bool = False) -> Array:
    """Per-row Givens-round rotation y[i] = x[i] Q_{i} (GOFT bank).

    C, S: (B, m, d//2) pre-evaluated cos/sin round stacks; x: (B, T, d).
    Like the Householder bank, the transform is O(m*d) per token —
    bandwidth-trivial next to the projection matmul — so the reference
    gather/rotate is the implementation on every backend (``use_pallas``
    accepted for hook uniformity and ignored; ``banked_kernel=""``)."""
    del use_pallas
    return ref.givens_banked_ref(C, S, x)


def q_matmul(x: Array, q: Array, scale: Array, use_pallas: bool = False,
             tuning: Optional[Tuning] = None) -> Array:
    """Quantized-weight matmul y = x @ dequant(q, scale) with the dequant
    in the epilogue. x: (..., K); q: (K, N) int8/fp8; scale broadcastable
    (1, N) / scalar. The serving hot path of ``ModelRuntime.quantized``."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_pallas and q.dtype == jnp.int8:
        k, n = q.shape
        tun = tuning or dispatch.get_tuning(dispatch.qmm_key(k, n, x.dtype))
        y = dispatch.q_matmul_pallas(x2, q, scale,
                                     token_tile=tun.token_tile,
                                     n_tile=tun.group_tile,
                                     interpret=_interpret())
    else:
        # fp8 codes (and the no-kernel path) run the reference einsum
        y = ref.q_matmul_ref(x2, q, scale)
    return y.reshape(lead + (y.shape[-1],))


def gs_q_matmul(L: Array, R: Array, x: Array, q: Array, scale: Array,
                use_pallas: bool = False,
                tuning: Optional[Tuning] = None) -> Array:
    """Fused activation-side GS rotation + quantized matmul:
    y = (x Q_gs) @ dequant(q, scale). L, R: (r, b, b); x: (..., d=r*b)."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if use_pallas and q.dtype == jnp.int8:
        r, b, _ = L.shape
        n = q.shape[-1]
        tun = tuning or dispatch.get_tuning(
            dispatch.gs_qmm_key(r, b, n, x.dtype))
        y = dispatch.gs_q_matmul_pallas(L, R, x2, q, scale,
                                        token_tile=tun.token_tile,
                                        n_tile=tun.group_tile,
                                        interpret=_interpret())
    else:
        y = ref.gs_q_matmul_ref(L, R, x2, q, scale)
    return y.reshape(lead + (y.shape[-1],))


def gs_q_matmul_banked(L: Array, R: Array, x: Array, q: Array, scale: Array,
                       use_pallas: bool = False,
                       tuning: Optional[Tuning] = None) -> Array:
    """Per-row fused rotate+quantized-matmul (multi-adapter quantized
    serving): L, R (B, r, b, b) pre-gathered per-row GS blocks, x (B, T, d),
    ONE shared quantized base weight q (d, N). Row i computes
    (x_i Q_i) @ W_q — bf16 rotation, int8 base matmul, one kernel."""
    if use_pallas and q.dtype == jnp.int8:
        _, r, b, _bb = L.shape
        n = q.shape[-1]
        tun = tuning or dispatch.get_tuning(
            dispatch.gs_qmm_key(r, b, n, x.dtype))
        interp = _interpret()
        return jax.vmap(
            lambda l, rr, xx: dispatch.gs_q_matmul_pallas(
                l, rr, xx, q, scale, token_tile=tun.token_tile,
                n_tile=tun.group_tile, interpret=interp))(L, R, x)
    xr = ref.gs_banked_T_ref(L, R, x)
    bsz, t, d = xr.shape
    y = ref.q_matmul_ref(xr.reshape(bsz * t, d), q, scale)
    return y.reshape(bsz, t, y.shape[-1])


def paged_attention(q: Array, k_pages: Array, v_pages: Array, table: Array,
                    kv_len: Array, *, scale: float = 0.0,
                    use_pallas: bool = False) -> Array:
    """Single-token decode attention through a KV page table.

    q: (B, H, D) one query per row; k_pages / v_pages: (P, page, K, D)
    shared page pools; table: (B, W) int32 page ids (unused entries point
    at the garbage page 0); kv_len: (B,) valid prefix length per row.
    The serving engine's paged decode hot path (ISSUE 7 / vLLM-style)."""
    if use_pallas:
        b, h, d = q.shape
        _, page, kh, _ = k_pages.shape
        # fixed launch geometry today, but resolve through the registry so
        # the persisted tuning cache covers this call site too
        dispatch.get_tuning(dispatch.paged_attn_key(h, kh, d, page, q.dtype))
        return paged_flash_decode(q, k_pages, v_pages, table, kv_len,
                                  scale=scale, interpret=_interpret())
    return ref.paged_attn_ref(q, k_pages, v_pages, table, kv_len, scale=scale)


def ssd(x: Array, loga: Array, B: Array, C: Array, chunk: int = 64,
        use_pallas: bool = False) -> Array:
    """Mamba2 SSD scan. Accepts (T,H,P) or batched (N,T,H,P) inputs."""
    if x.ndim == 4:
        fn = partial(ssd, chunk=chunk, use_pallas=use_pallas)
        return jax.vmap(fn)(x, loga, B, C)
    if use_pallas:
        t = x.shape[0]
        q = chunk
        while t % q:
            q //= 2
        return ssd_pallas(x, loga, B, C, chunk=max(q, 1),
                          interpret=_interpret())
    return ref.ssd_chunked_ref(x, loga, B, C,
                               chunk=dispatch.pick_chunk(x.shape[0], chunk))


def flash_mha(q: Array, k: Array, v: Array, *, causal: bool = True,
              use_pallas: bool = False, blk: int = 128) -> Array:
    """Multi-head attention over (B, S, H, D) activations with GQA support
    (kv heads broadcast to query heads). Kernel path keeps scores in VMEM."""
    b, sq, h, d = q.shape
    kh = k.shape[2]
    if kh != h:
        rep = h // kh
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qh = jnp.swapaxes(q, 1, 2)          # (B, H, S, D)
    kkh = jnp.swapaxes(k, 1, 2)
    vvh = jnp.swapaxes(v, 1, 2)
    if use_pallas:
        fn = lambda qq, kk, vv: flash_attention(
            qq, kk, vv, causal=causal, blk_q=blk, blk_k=blk,
            interpret=_interpret())
    else:
        fn = lambda qq, kk, vv: ref.flash_ref(qq, kk, vv, causal=causal)
    out = jax.vmap(fn)(qh, kkh, vvh)
    return jnp.swapaxes(out, 1, 2)
