"""Pallas TPU kernel: Mamba2 SSD (state-space duality) chunked scan.

The SSD recurrence  S_t = exp(loga_t) S_{t-1} + B_t x_t^T,  y_t = C_t^T S_t
is evaluated chunk-parallel (Dao & Gu 2024): within a chunk of Q steps the
output is a causal decay-masked attention (three MXU matmuls); across chunks
a small (N x P) state carries the recurrence.  This turns an elementwise scan
(memory-bound on TPU) into MXU work with O(T/Q) sequential steps.

Grid: (batch*heads, T/Q) — the chunk axis is innermost, so the VMEM scratch
state persists across chunk iterations of one (batch, head) row (TPU grid
execution is sequential).  All state math in fp32.

This is the compute hot-spot of the mamba2/zamba2 architectures at
long_500k; the pure-jnp oracle lives in ref.py (ssd_ref / ssd_chunked_ref).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, state, *, q: int):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _():
        state[...] = jnp.zeros_like(state)

    f32 = jnp.float32
    x = x_ref[0].astype(f32)            # (Q, P)
    la = la_ref[0].astype(f32)          # (Q,)
    B = b_ref[0].astype(f32)            # (Q, N)
    C = c_ref[0].astype(f32)            # (Q, N)

    cum = jnp.cumsum(la)                # inclusive
    total = cum[-1]

    # intra-chunk: causal decay attention
    rel = cum[:, None] - cum[None, :]
    causal = jnp.tril(jnp.ones((q, q), dtype=jnp.bool_))
    gamma = jnp.where(causal, jnp.exp(rel), 0.0)
    scores = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                                 preferred_element_type=f32) * gamma  # (Q,Q)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)               # (Q,P)

    # inter-chunk: carried state contribution
    s_in = state[...]
    y += jax.lax.dot_general(C * jnp.exp(cum)[:, None], s_in,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=f32)

    # state update: S' = exp(total) S + sum_q exp(total - cum_q) B_q x_q^T
    w = jnp.exp(total - cum)[:, None] * B                             # (Q,N)
    state[...] = jnp.exp(total) * s_in + jax.lax.dot_general(
        w, x, (((0,), (0,)), ((), ())), preferred_element_type=f32)   # (N,P)

    y_ref[0] = y.astype(y_ref.dtype)


def ssd_pallas(x: Array, loga: Array, B: Array, C: Array, *, chunk: int = 64,
               interpret: bool = False) -> Array:
    """x: (T, H, P); loga: (T, H); B, C: (T, H, N)  ->  y: (T, H, P).

    Matches kernels.ref.ssd_ref. T must be a multiple of ``chunk`` (callers
    pad; decode paths use the O(1) recurrent update instead).
    """
    t, h, p = x.shape
    n = B.shape[-1]
    q = min(chunk, t)
    assert t % q == 0, (t, q)
    nc = t // q

    # (T, H, *) -> (H, T, *): head-major so the grid rows are contiguous
    xh = jnp.swapaxes(x, 0, 1)
    lah = jnp.swapaxes(loga, 0, 1)
    Bh = jnp.swapaxes(B, 0, 1)
    Ch = jnp.swapaxes(C, 0, 1)

    out = pl.pallas_call(
        functools.partial(_ssd_kernel, q=q),
        out_shape=jax.ShapeDtypeStruct((h, t, p), x.dtype),
        grid=(h, nc),
        in_specs=[
            pl.BlockSpec((1, q, p), lambda hi, ci: (hi, ci, 0)),
            pl.BlockSpec((1, q), lambda hi, ci: (hi, ci)),
            pl.BlockSpec((1, q, n), lambda hi, ci: (hi, ci, 0)),
            pl.BlockSpec((1, q, n), lambda hi, ci: (hi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, q, p), lambda hi, ci: (hi, ci, 0)),
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xh, lah, Bh, Ch)
    return jnp.swapaxes(out, 0, 1)
