"""Pallas TPU kernels: quantized-weight matmul for the serving hot path.

The decode step is memory-bandwidth-bound: every token re-reads the whole
weight tree from HBM. Storing base weights as int8 codes + fp32
per-output-channel scales halves (vs bf16) the bytes the matmul streams;
the kernel widens the int8 tile to the activation dtype IN VMEM (a VPU
cast, no extra HBM traffic), runs the MXU dot, and applies the scales in
the fp32 epilogue before the output cast — the dequantized float weight
never exists in HBM.

``gs_q_matmul`` is the adapter-serving fusion: the activation-side GSOFT
rotation x·Q (transpose rotation, same math as kernels/gs_fused.py) runs
in the activation dtype on the VMEM slab, then feeds the quantized base
matmul directly — one HBM read of x, one of the int8 weight, one write of
y for the whole rotate+project step. Rotations stay bf16 per the
QOFT/OFTv2 rationale (int8 would break Cayley orthogonality; the factors
are O(r·b²) anyway — the memory win lives in the O(d²) base weights).

Grid: (token tiles, out-channel tiles); the contraction dim K stays whole
per step (weights enter VMEM as (K, n_tile) int8 — 2 bytes/param cheaper
than bf16, which is the point).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import jax.experimental.pallas as pl

Array = jnp.ndarray


def default_n_tile(n: int, cap: int = 256) -> int:
    """Largest divisor of n that is <= cap (out-channel tile)."""
    t = min(cap, n)
    while n % t:
        t -= 1
    return max(t, 1)


def _prep(x: Array, scale, n: int, token_tile: int, n_tile: int):
    """Shared launch prologue: broadcast the scale to (1, n), resolve the
    out-channel tile to a divisor of n, pad tokens to the token tile.
    Returns (x_padded, scale, n_tile, token_tile, pad)."""
    t = x.shape[0]
    s = jnp.broadcast_to(jnp.asarray(scale, jnp.float32).reshape(1, -1)
                         if jnp.ndim(scale) else
                         jnp.full((1, n), scale, jnp.float32), (1, n))
    if n_tile <= 0:
        n_tile = default_n_tile(n)
    while n % n_tile:
        n_tile -= 1
    tt = min(token_tile, t)
    pad = (-t) % tt
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, s, n_tile, tt, pad


def _q_matmul_kernel(x_ref, q_ref, s_ref, o_ref):
    x = x_ref[...]                                   # (tt, K) activation dtype
    w = q_ref[...].astype(x.dtype)                   # int8 -> bf16 in VMEM
    y = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[...] = (y * s_ref[...]).astype(o_ref.dtype)   # epilogue dequant


def q_matmul_pallas(x: Array, q: Array, scale: Array, *,
                    token_tile: int = 128, n_tile: int = 0,
                    interpret: bool = False) -> Array:
    """x: (T, K); q: (K, N) int8; scale: (1, N) or scalar fp32 -> (T, N)."""
    t, k = x.shape
    kq, n = q.shape
    assert k == kq, (x.shape, q.shape)
    x, s, n_tile, tt, pad = _prep(x, scale, n, token_tile, n_tile)
    tp = x.shape[0]
    out = pl.pallas_call(
        _q_matmul_kernel,
        out_shape=jax.ShapeDtypeStruct((tp, n), x.dtype),
        grid=(tp // tt, n // n_tile),
        in_specs=[
            pl.BlockSpec((tt, k), lambda ti, ni: (ti, 0)),
            pl.BlockSpec((k, n_tile), lambda ti, ni: (0, ni)),
            pl.BlockSpec((1, n_tile), lambda ti, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((tt, n_tile), lambda ti, ni: (ti, ni)),
        interpret=interpret,
    )(x, q, s)
    return out[:t] if pad else out


def _gs_q_matmul_kernel(x_ref, l_ref, r_ref, q_ref, s_ref, o_ref, *,
                        r: int, b: int):
    t = x_ref.shape[0]
    d = r * b
    x = x_ref[...]                                   # (tt, d)
    f32 = jnp.float32

    # transpose GS rotation x Q = (R^T P^T L^T P x^T)^T, all on the VMEM
    # slab (same math as gs_fused._gs_fused_T_kernel), fp32 accumulation,
    # result dropped back to the activation dtype before the base matmul
    sh = x.reshape(t, r, b).transpose(0, 2, 1).reshape(t, r, b)      # P
    L = l_ref[...]
    u = jax.lax.dot_general(sh, L, (((2,), (1,)), ((1,), (0,))),
                            preferred_element_type=f32)              # L^T .
    m = u.transpose(1, 0, 2).reshape(t, d)                           # P^T
    m = m.reshape(t, b, r).transpose(0, 2, 1).reshape(t, r, b)
    R = r_ref[...]
    z = jax.lax.dot_general(m, R, (((2,), (1,)), ((1,), (0,))),
                            preferred_element_type=f32)              # R^T .
    xr = z.transpose(1, 0, 2).reshape(t, d).astype(x.dtype)

    w = q_ref[...].astype(x.dtype)                   # (d, nt) int8 -> bf16
    y = jax.lax.dot_general(xr, w, (((1,), (0,)), ((), ())),
                            preferred_element_type=f32)
    o_ref[...] = (y * s_ref[...]).astype(o_ref.dtype)


def gs_q_matmul_pallas(L: Array, R: Array, x: Array, q: Array, scale: Array,
                       *, token_tile: int = 128, n_tile: int = 0,
                       interpret: bool = False) -> Array:
    """Fused (x Q_gs) @ W_q. L, R: (r, b, b); x: (T, d=r*b); q: (d, N).

    The rotation recomputes per out-channel tile — O(t·d·b) VPU/MXU work
    against the O(t·d·n_tile) base matmul, a cheap trade for keeping the
    rotated slab out of HBM entirely.
    """
    r, b, _ = L.shape
    t, d = x.shape
    dq, n = q.shape
    assert d == r * b == dq, (L.shape, x.shape, q.shape)
    x, s, n_tile, tt, pad = _prep(x, scale, n, token_tile, n_tile)
    tp = x.shape[0]
    out = pl.pallas_call(
        functools.partial(_gs_q_matmul_kernel, r=r, b=b),
        out_shape=jax.ShapeDtypeStruct((tp, n), x.dtype),
        grid=(tp // tt, n // n_tile),
        in_specs=[
            pl.BlockSpec((tt, d), lambda ti, ni: (ti, 0)),
            pl.BlockSpec((r, b, b), lambda ti, ni: (0, 0, 0)),
            pl.BlockSpec((r, b, b), lambda ti, ni: (0, 0, 0)),
            pl.BlockSpec((d, n_tile), lambda ti, ni: (0, ni)),
            pl.BlockSpec((1, n_tile), lambda ti, ni: (0, ni)),
        ],
        out_specs=pl.BlockSpec((tt, n_tile), lambda ti, ni: (ti, ni)),
        interpret=interpret,
    )(x, L, R, q, s)
    return out[:t] if pad else out
