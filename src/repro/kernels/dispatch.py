"""Autotuned dispatch + custom-VJP rules for the GS kernel suite.

This module is the single place that decides *how* a GS kernel call runs:

  * ``Tuning`` — the (token_tile, group_tile) launch geometry of a call.
  * a three-level resolution order, consulted at trace time on static shapes:
        1. config overrides  (``register_tuning`` / ``install_tunings`` —
           wired from ``ModelConfig.kernel_tunings`` by train/steps.py)
        2. autotuned results (``autotune_bdmm`` / ``autotune_gs`` — a small
           cached timing search over candidate tiles, keyed per
           (shape, dtype, backend))
        3. shape heuristics  (the former ad-hoc rules from ops.py/bdmm.py)
  * ``bdmm_diff`` / ``gs_diff`` / ``gs_T_diff`` — the kernels wrapped in
    ``jax.custom_vjp`` so ``jax.grad`` through ``use_pallas=True`` runs
    Pallas in both directions instead of falling back to XLA autodiff over
    the kernel body:

        bdmm:  dx = bdmm(blocks^T, dy);  dblocks = token-contraction kernel.
        gs:    dx = Q^T dy (the transpose rotation is itself a GS transform —
               the paper's structure makes the VJP closed under the class);
               dL/dR from the fused backward kernel (activations stay in
               VMEM, fp32 accumulation over token tiles).
        gs_T:  dx = Q dy (the forward kernel); dL/dR via the identity
               <g, Q^T x> = <x, Q g>, i.e. the same fused backward kernel
               with (input, cotangent) swapped.

Autotuning is *eager* (it times real kernel launches), so call it from
benchmarks / warmup code, never inside jit; lookups inside jit are pure
Python on static shapes and cost nothing at runtime.
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
from typing import Callable, Dict, Iterable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from .bdmm import bdmm_dblocks_pallas, bdmm_pallas, default_group_tile
from .gs_fused import (gs_fused_T_pallas, gs_fused_bwd_pallas,
                       gs_fused_grads_pallas, gs_fused_pallas)
from .q_matmul import default_n_tile, gs_q_matmul_pallas, q_matmul_pallas

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Tuning:
    """Launch geometry for one kernel call site (hashable, jit-static)."""
    token_tile: int = 128
    group_tile: int = 0          # 0 -> per-shape heuristic (bdmm only)


Key = Tuple  # (op, *shape_sig, dtype_name, backend)

# config-provided overrides (backend/dtype wildcards) beat autotuned results,
# which beat the shape heuristic.
_OVERRIDES: Dict[Key, Tuning] = {}
_TUNED: Dict[Key, Tuning] = {}
# overrides installed from a ModelConfig — replaced wholesale on the next
# install so one config's tunings never leak into another model built in
# the same process (register_tuning entries are sticky by design)
_CONFIG_KEYS: set = set()

DEFAULT_TOKEN_TILES: Tuple[int, ...] = (64, 128, 256)


def _backend() -> str:
    return jax.default_backend()


def _interpret() -> bool:
    return _backend() != "tpu"


# Serve-time tensor parallelism (ISSUE 8): under ``shard_map``/GSPMD each
# device traces the kernel on its LOCAL shard, whose shapes alias a
# different single-device problem (e.g. a tp=2 split of d_ff=256 looks like
# an unsharded d_ff=128 call). Tunings timed for one must not answer for
# the other — the launch that wins for the full array can be illegal for
# the shard — so a meshed ``ModelRuntime`` declares its TP degree here and
# every key's op name picks up an ``@tpN`` tag. tp=1 (the default, and
# every pre-existing caller) leaves op names, wildcard semantics
# (``key[:-2]``) and the persisted REPRO_TUNING_CACHE byte-identical.
_SERVE_TP: int = 1


def set_serve_tp(n: int) -> None:
    """Declare the serve-time TP degree (1 = off). Called by
    ``core.runtime.ModelRuntime`` when built with a mesh."""
    global _SERVE_TP
    _SERVE_TP = max(int(n), 1)


def serve_tp() -> int:
    return _SERVE_TP


def _op(name: str) -> str:
    return name if _SERVE_TP == 1 else f"{name}@tp{_SERVE_TP}"


def bdmm_key(r: int, bo: int, bi: int, dtype,
             backend: Optional[str] = None) -> Key:
    return (_op("bdmm"), r, bo, bi, jnp.dtype(dtype).name,
            backend or _backend())


def gs_key(r: int, b: int, dtype, backend: Optional[str] = None) -> Key:
    return (_op("gs"), r, b, jnp.dtype(dtype).name, backend or _backend())


def qmm_key(k: int, n: int, dtype, backend: Optional[str] = None) -> Key:
    """Quantized matmul (kernels/q_matmul.py): x (T, k) @ W_q (k, n).
    ``dtype`` is the ACTIVATION dtype (codes are int8 by construction);
    ``Tuning.group_tile`` doubles as the out-channel tile here."""
    return (_op("qmm"), k, n, jnp.dtype(dtype).name, backend or _backend())


def gs_qmm_key(r: int, b: int, n: int, dtype,
               backend: Optional[str] = None) -> Key:
    """Fused rotate+quantized-matmul: GS factors (r, b, b), W_q (r*b, n)."""
    return (_op("gs_qmm"), r, b, n, jnp.dtype(dtype).name,
            backend or _backend())


def paged_attn_key(h: int, kh: int, d: int, page: int, dtype,
                   backend: Optional[str] = None) -> Key:
    """Paged decode attention (kernels/flash_attention.py): one query token
    per row gathered through a page table over the shared KV page pool.
    The launch geometry is fixed by (heads, page) — the key exists so the
    serving path resolves through the same registry (and the persisted
    tuning cache) as every other kernel."""
    return (_op("paged_attn"), h, kh, d, page, jnp.dtype(dtype).name,
            backend or _backend())


# Banked (per-request, multi-adapter) activation-side transforms resolve
# their launch geometry through these key families — keyed by KERNEL name
# (this module's vocabulary); WHICH family an adapter method rides is that
# method's ``MethodOps.banked_kernel`` field in core.methods (single
# source of per-method truth). Today: the gsoft bank rides the vmapped
# gs_T kernel ("gs"), oft/boft banks ride the vmapped bdmm kernel ("bdmm",
# one bdmm per butterfly level for boft), and householder declares no
# kernel — its banked transform is an O(k*d)-per-token reference einsum
# (kernels/ref.py), so there is nothing to tune.
BANKED_KEYS: Dict[str, Callable] = {
    "gs": gs_key,
    "bdmm": bdmm_key,
}


def banked_key_fn(kernel: str) -> Optional[Callable]:
    """Key builder for a banked-transform kernel family (""/unknown ->
    einsum-only, nothing to tune — a new method starts on the reference
    fallback until a kernel lands)."""
    return BANKED_KEYS.get(kernel)


def _wildcard(key: Key) -> Key:
    return key[:-2] + ("*", "*")


def register_tuning(key: Key, tuning: Tuning) -> None:
    """Pin the launch geometry for a call-site key (highest precedence)."""
    _OVERRIDES[key] = tuning


def install_tunings(entries: Iterable[Tuple]) -> None:
    """Install config-level overrides (``ModelConfig.kernel_tunings``).

    Each entry is a tuple:
        ("bdmm",   r, bo, bi, token_tile, group_tile)
        ("gs",     r, b,      token_tile)
        ("qmm",    k, n,      token_tile, n_tile)
        ("gs_qmm", r, b, n,   token_tile, n_tile)
    Entries apply to every dtype/backend (wildcard keys). Each call replaces
    the previously installed config set.
    """
    for key in _CONFIG_KEYS:
        _OVERRIDES.pop(key, None)
    _CONFIG_KEYS.clear()
    for e in entries or ():
        op = e[0]
        if op == "bdmm":
            _, r, bo, bi, tt, gt = e
            key = _wildcard(bdmm_key(r, bo, bi, jnp.float32))
            tun = Tuning(token_tile=tt, group_tile=gt)
        elif op == "gs":
            _, r, b, tt = e
            key = _wildcard(gs_key(r, b, jnp.float32))
            tun = Tuning(token_tile=tt)
        elif op == "qmm":
            _, k, n, tt, nt = e
            key = _wildcard(qmm_key(k, n, jnp.float32))
            tun = Tuning(token_tile=tt, group_tile=nt)
        elif op == "gs_qmm":
            _, r, b, n, tt, nt = e
            key = _wildcard(gs_qmm_key(r, b, n, jnp.float32))
            tun = Tuning(token_tile=tt, group_tile=nt)
        else:
            raise ValueError(f"unknown kernel_tunings op {op!r}")
        register_tuning(key, tun)
        _CONFIG_KEYS.add(key)


def get_tuning(key: Key) -> Tuning:
    """Resolve launch geometry: override > wildcard override > autotuned >
    heuristic default."""
    _ensure_cache_loaded()
    if key in _OVERRIDES:
        return _OVERRIDES[key]
    wc = _wildcard(key)
    if wc in _OVERRIDES:
        return _OVERRIDES[wc]
    if key in _TUNED:
        return _TUNED[key]
    op = str(key[0]).split("@", 1)[0]     # strip any serve-TP tag
    if op == "bdmm":
        _, r, bo, bi = key[:4]
        return Tuning(token_tile=128, group_tile=default_group_tile(r, bi))
    if op == "qmm":
        return Tuning(token_tile=128, group_tile=default_n_tile(key[2]))
    if op == "gs_qmm":
        return Tuning(token_tile=128, group_tile=default_n_tile(key[3]))
    return Tuning(token_tile=128)


def clear_tunings() -> None:
    _OVERRIDES.clear()
    _TUNED.clear()
    _CONFIG_KEYS.clear()


# ---------------------------------------------------------------------------
# tuning-cache persistence: autotuned results survive the process
# ---------------------------------------------------------------------------
# Autotuning times real kernel launches, so re-deriving the same geometry
# every process is pure waste. ``save_tuning_cache`` serializes _TUNED to
# JSON keyed exactly like the in-memory registry ((op, *shape_sig, dtype,
# backend) tuples); ``load_tuning_cache`` restores entries WITHOUT clobbering
# results timed in this process, and config overrides still outrank both.
# Set ``REPRO_TUNING_CACHE=/path/cache.json`` to make the round trip
# automatic: lazily loaded on the first resolution, written through after
# every autotune_* call.

TUNING_CACHE_ENV = "REPRO_TUNING_CACHE"
_cache_loaded = False


def save_tuning_cache(path: Optional[str] = None) -> Optional[str]:
    """Write every autotuned entry to ``path`` (default: $REPRO_TUNING_CACHE;
    no-op returning None when neither names a file)."""
    path = path or os.environ.get(TUNING_CACHE_ENV)
    if not path:
        return None
    entries = [{"key": list(k), "token_tile": t.token_tile,
                "group_tile": t.group_tile}
               for k, t in sorted(_TUNED.items(),
                                  key=lambda kv: tuple(map(str, kv[0])))]
    with open(path, "w") as f:
        json.dump({"version": 1, "entries": entries}, f, indent=2)
    return path


def load_tuning_cache(path: Optional[str] = None) -> int:
    """Merge a saved cache into the autotuned tier (results timed in THIS
    process win ties; explicit overrides always outrank). Returns the number
    of entries loaded; missing/unset path -> 0."""
    path = path or os.environ.get(TUNING_CACHE_ENV)
    if not path or not os.path.exists(path):
        return 0
    with open(path) as f:
        data = json.load(f)
    n = 0
    for e in data.get("entries", ()):
        key = tuple(e["key"])
        if key not in _TUNED:
            _TUNED[key] = Tuning(token_tile=int(e["token_tile"]),
                                 group_tile=int(e.get("group_tile", 0)))
            n += 1
    return n


def _ensure_cache_loaded() -> None:
    global _cache_loaded
    if not _cache_loaded:
        _cache_loaded = True
        if os.environ.get(TUNING_CACHE_ENV):
            load_tuning_cache()


def _write_through() -> None:
    if os.environ.get(TUNING_CACHE_ENV):
        save_tuning_cache()


def pick_chunk(t: int, chunk: int) -> int:
    """Largest divisor of t that is <= chunk (SSD scan chunking)."""
    q = min(chunk, t)
    while t % q:
        q -= 1
    return max(q, 1)


# ---------------------------------------------------------------------------
# autotuner (eager; results cached in the registry)
# ---------------------------------------------------------------------------

def _time_us(fn, *args, iters: int = 5, warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def autotune_bdmm(r: int, bo: int, bi: int, t: int, dtype=jnp.float32, *,
                  token_tiles: Sequence[int] = DEFAULT_TOKEN_TILES,
                  group_tiles: Optional[Sequence[int]] = None,
                  iters: int = 5) -> Tuning:
    """Search (token_tile, group_tile) by timing real launches; cache best."""
    key = bdmm_key(r, bo, bi, dtype)
    _ensure_cache_loaded()
    if key in _TUNED:
        return _TUNED[key]
    if group_tiles is None:
        group_tiles = sorted({g for g in (1, 2, 4, 8, default_group_tile(r, bi))
                              if r % g == 0 and g <= r})
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    blocks = jax.random.normal(k1, (r, bo, bi), dtype)
    x = jax.random.normal(k2, (t, r * bi), dtype)
    interp = _interpret()
    best, best_us = None, float("inf")
    for tt in token_tiles:
        for gt in group_tiles:
            fn = jax.jit(functools.partial(
                bdmm_pallas, token_tile=tt, group_tile=gt, interpret=interp))
            us = _time_us(fn, blocks, x, iters=iters)
            if us < best_us:
                best, best_us = Tuning(token_tile=tt, group_tile=gt), us
    _TUNED[key] = best
    _write_through()
    return best


def autotune_gs(r: int, b: int, t: int, dtype=jnp.float32, *,
                token_tiles: Sequence[int] = DEFAULT_TOKEN_TILES,
                iters: int = 5) -> Tuning:
    key = gs_key(r, b, dtype)
    _ensure_cache_loaded()
    if key in _TUNED:
        return _TUNED[key]
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    L = jax.random.normal(ks[0], (r, b, b), dtype)
    R = jax.random.normal(ks[1], (r, b, b), dtype)
    x = jax.random.normal(ks[2], (t, r * b), dtype)
    interp = _interpret()
    best, best_us = None, float("inf")
    for tt in token_tiles:
        fn = jax.jit(functools.partial(
            gs_fused_pallas, token_tile=tt, interpret=interp))
        us = _time_us(fn, L, R, x, iters=iters)
        if us < best_us:
            best, best_us = Tuning(token_tile=tt), us
    _TUNED[key] = best
    _write_through()
    return best


def autotune_qmm(k: int, n: int, t: int, dtype=jnp.bfloat16, *,
                 token_tiles: Sequence[int] = DEFAULT_TOKEN_TILES,
                 n_tiles: Optional[Sequence[int]] = None,
                 iters: int = 5) -> Tuning:
    """Search (token_tile, n_tile) for the quantized matmul; cache best.
    ``dtype`` is the activation dtype — codes are int8."""
    key = qmm_key(k, n, dtype)
    _ensure_cache_loaded()
    if key in _TUNED:
        return _TUNED[key]
    if n_tiles is None:
        n_tiles = sorted({nt for nt in (128, 256, 512, default_n_tile(n))
                          if n % nt == 0 and nt <= n} or {default_n_tile(n)})
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (t, k), dtype)
    q = jax.random.randint(k2, (k, n), -127, 128, jnp.int8)
    scale = jnp.full((1, n), 1e-2, jnp.float32)
    interp = _interpret()
    best, best_us = None, float("inf")
    for tt in token_tiles:
        for nt in n_tiles:
            fn = jax.jit(functools.partial(
                q_matmul_pallas, token_tile=tt, n_tile=nt, interpret=interp))
            us = _time_us(fn, x, q, scale, iters=iters)
            if us < best_us:
                best, best_us = Tuning(token_tile=tt, group_tile=nt), us
    _TUNED[key] = best
    _write_through()
    return best


# ---------------------------------------------------------------------------
# differentiable kernel entry points (2-D token-major inputs)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def bdmm_diff(tuning: Tuning, interpret: bool, blocks: Array,
              x: Array) -> Array:
    """Differentiable bdmm: blocks (r, bo, bi), x (T, r*bi) -> (T, r*bo)."""
    return bdmm_pallas(blocks, x, token_tile=tuning.token_tile,
                       group_tile=tuning.group_tile, interpret=interpret)


def _bdmm_fwd(tuning, interpret, blocks, x):
    y = bdmm_diff(tuning, interpret, blocks, x)
    return y, (blocks, x)


def _bdmm_bwd(tuning, interpret, res, dy):
    blocks, x = res
    r, bo, bi = blocks.shape
    dx = bdmm_pallas(jnp.swapaxes(blocks, -1, -2), dy,
                     token_tile=tuning.token_tile,
                     group_tile=tuning.group_tile, interpret=interpret)
    dblocks = bdmm_dblocks_pallas(dy, x, bo=bo, bi=bi,
                                  token_tile=tuning.token_tile,
                                  group_tile=tuning.group_tile,
                                  interpret=interpret)
    return dblocks.astype(blocks.dtype), dx.astype(x.dtype)


bdmm_diff.defvjp(_bdmm_fwd, _bdmm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def gs_diff(tuning: Tuning, interpret: bool, L: Array, R: Array,
            x: Array) -> Array:
    """Differentiable fused GSOFT rotation  y = P^T L P R x."""
    return gs_fused_pallas(L, R, x, token_tile=tuning.token_tile,
                           interpret=interpret)


def _gs_fwd(tuning, interpret, L, R, x):
    y = gs_diff(tuning, interpret, L, R, x)
    return y, (L, R, x)


def _gs_bwd(tuning, interpret, res, dy):
    L, R, x = res
    dx, dL, dR = gs_fused_bwd_pallas(L, R, x, dy,
                                     token_tile=tuning.token_tile,
                                     interpret=interpret)
    return dL.astype(L.dtype), dR.astype(R.dtype), dx.astype(x.dtype)


gs_diff.defvjp(_gs_fwd, _gs_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1))
def gs_T_diff(tuning: Tuning, interpret: bool, L: Array, R: Array,
              x: Array) -> Array:
    """Differentiable transpose rotation  y = Q^T x = R^T P^T L^T P x."""
    return gs_fused_T_pallas(L, R, x, token_tile=tuning.token_tile,
                             interpret=interpret)


def _gs_T_fwd(tuning, interpret, L, R, x):
    y = gs_T_diff(tuning, interpret, L, R, x)
    return y, (L, R, x)


def _gs_T_bwd(tuning, interpret, res, dy):
    # <dy, Q^T x> = <x, Q dy>:  dx is the forward rotation of dy, and the
    # factor grads come from the grads-only backward kernel with (input,
    # cotangent) swapped.
    L, R, x = res
    dx = gs_fused_pallas(L, R, dy, token_tile=tuning.token_tile,
                         interpret=interpret)
    dL, dR = gs_fused_grads_pallas(L, R, dy, x,
                                   token_tile=tuning.token_tile,
                                   interpret=interpret)
    return dL.astype(L.dtype), dR.astype(R.dtype), dx.astype(x.dtype)


gs_T_diff.defvjp(_gs_T_fwd, _gs_T_bwd)
