"""Fault-tolerance runtime: heartbeats, straggler detection, crash recovery.

On real pods the launcher (launch/scripts/run_with_restart.sh) restarts a
failed worker from the latest committed checkpoint; this module provides the
host-side signals it consumes:

  * Heartbeat      — train loop touches a file every step; an external
                     watchdog (watch_heartbeat) kills/reforms if it goes
                     stale (hung collective, dead host)
  * StepTimer      — EWMA step-time anomaly detector; flags stragglers
                     (consistently slow steps) so the orchestrator can
                     checkpoint-and-reform. SPMD cannot drop a chip
                     mid-program: reform is the production mitigation.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Optional


class Heartbeat:
    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def beat(self, step: int):
        with open(self.path, "w") as f:
            f.write(f"{step} {time.time()}")

    def last(self) -> Optional[tuple]:
        try:
            with open(self.path) as f:
                s, t = f.read().split()
            return int(s), float(t)
        except (FileNotFoundError, ValueError):
            return None

    def stale(self, timeout_s: float) -> bool:
        last = self.last()
        return last is None or (time.time() - last[1]) > timeout_s


@dataclasses.dataclass
class StepTimer:
    """EWMA-based straggler/anomaly detector."""
    alpha: float = 0.1
    slow_factor: float = 2.0
    ewma: float = 0.0
    count: int = 0
    slow_steps: int = 0
    _t0: float = 0.0

    def start(self):
        self._t0 = time.perf_counter()

    def stop(self) -> dict:
        dt = time.perf_counter() - self._t0
        self.count += 1
        if self.count == 1:
            self.ewma = dt
        slow = dt > self.slow_factor * self.ewma and self.count > 5
        if slow:
            self.slow_steps += 1
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return {"step_time_s": dt, "ewma_s": self.ewma, "straggler": slow}

    def should_reform(self, patience: int = 10) -> bool:
        return self.slow_steps >= patience
