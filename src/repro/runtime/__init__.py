from .watchdog import Heartbeat, StepTimer
