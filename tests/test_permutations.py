import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import permutations as perm


def divisor_pairs():
    """Strategy producing (k, n) with k | n, small."""
    return st.integers(1, 8).flatmap(
        lambda k: st.integers(1, 8).map(lambda m: (k, k * m)))


@settings(max_examples=40, deadline=None)
@given(divisor_pairs())
def test_gs_sigma_is_permutation(kn):
    k, n = kn
    assert perm.is_permutation(perm.gs_sigma(k, n))


@settings(max_examples=40, deadline=None)
@given(divisor_pairs())
def test_inverse_sigma(kn):
    k, n = kn
    s = perm.gs_sigma(k, n)
    inv = perm.inverse_sigma(s)
    assert np.all(s[inv] == np.arange(n))
    assert np.all(inv[s] == np.arange(n))
    # paper fact: inverse of P_(k,n) is P_(n/k, n)
    assert np.all(inv == perm.gs_sigma(n // k, n))


def test_definition_example_figure3():
    # P_(3,12) from Figure 3: reshape 3x4, transpose, flatten.
    s = perm.gs_sigma(3, 12)
    x = np.arange(12)
    y = x[s]
    expected = np.arange(12).reshape(3, 4).T.reshape(-1)
    assert np.all(y == expected)


@settings(max_examples=40, deadline=None)
@given(divisor_pairs())
def test_reshape_fastpath_matches_gather(kn):
    k, n = kn
    x = np.random.default_rng(0).normal(size=(2, n)).astype(np.float32)
    spec = perm.PermSpec.gs(k)
    fast = np.asarray(perm.apply_perm(jnp.asarray(x), spec))
    sig = spec.sigma(n)
    assert np.allclose(fast, x[:, sig])
    # and the inverse fast path
    back = np.asarray(perm.apply_perm(jnp.asarray(fast), spec.inverse()))
    assert np.allclose(back, x)


def test_perm_matrix_semantics():
    s = perm.gs_sigma(4, 12)
    P = perm.perm_matrix(s)
    x = np.random.default_rng(1).normal(size=12)
    assert np.allclose(P @ x, x[s])
    # P^T is the inverse
    assert np.allclose(P.T @ (P @ x), x)


def test_apply_perm_T():
    s = perm.gs_sigma(4, 12)
    spec = perm.PermSpec.gs(4)
    P = perm.perm_matrix(s)
    x = np.random.default_rng(1).normal(size=12).astype(np.float32)
    y = np.asarray(perm.apply_perm_T(jnp.asarray(x), spec))
    assert np.allclose(y, P.T @ x, atol=1e-6)


def test_paired_sigma_keeps_pairs_together():
    k, n = 4, 32
    s = perm.paired_sigma(k, n)
    assert perm.is_permutation(s)
    # channels (2i, 2i+1) must land adjacently in the same pair slot
    for i in range(0, n, 2):
        assert s[i + 1] == s[i] + 1
        assert s[i] % 2 == 0


def test_paired_sigma_mixes_groups():
    # after pairing, pair j goes to (j mod k)-th group — perfect pair shuffle
    k, n = 4, 32
    s = perm.paired_sigma(k, n)
    group = n // k
    dest_groups = set()
    # pairs that land in output group 0 must come from k distinct input groups
    src = [s[i] // group for i in range(0, group, 2)]
    assert len(set(src)) == min(k, group // 2)


def test_compose_sigma():
    s1 = perm.gs_sigma(3, 12)
    s2 = perm.gs_sigma(4, 12)
    P1, P2 = perm.perm_matrix(s1), perm.perm_matrix(s2)
    sc = perm.compose_sigma(s1, s2)
    assert np.allclose(perm.perm_matrix(sc), P1 @ P2)


def test_apply_perm_axis_argument():
    x = np.random.default_rng(2).normal(size=(6, 12, 3)).astype(np.float32)
    spec = perm.PermSpec.gs(3)
    y = np.asarray(perm.apply_perm(jnp.asarray(x), spec, axis=1))
    sig = spec.sigma(12)
    assert np.allclose(y, x[:, sig, :])


def test_invalid_sizes_raise():
    with pytest.raises(ValueError):
        perm.gs_sigma(5, 12)
    with pytest.raises(ValueError):
        perm.paired_sigma(5, 12)  # needs 2k | n
