"""Optimizer / schedules / data pipeline / checkpoint / watchdog tests."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, LMDataSource
from repro.runtime import Heartbeat, StepTimer


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def _quad_problem():
    params = {"w": jnp.asarray([2.0, -3.0]), "norm": jnp.asarray([1.0])}
    loss = lambda p: jnp.sum(p["w"] ** 2) + jnp.sum(p["norm"] ** 2)
    return params, loss


@pytest.mark.parametrize("kind", ["adamw", "sgd"])
def test_optimizer_descends(kind):
    cfg = optim.OptimizerConfig(kind=kind, learning_rate=0.1, weight_decay=0.0)
    params, loss = _quad_problem()
    state = optim.init(cfg, params)
    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, state, _ = optim.update(cfg, g, state, params)
    assert float(loss(params)) < 0.1 * l0


def test_weight_decay_masks_1d():
    cfg = optim.OptimizerConfig(learning_rate=0.0, weight_decay=1.0)
    # lr = 0 -> only decay path could move params; with lr=0 nothing moves.
    # use lr>0, zero grads: 2D decays, 1D does not.
    cfg = optim.OptimizerConfig(learning_rate=0.1, weight_decay=0.5)
    params = {"w2": jnp.ones((2, 2)), "b1": jnp.ones((2,))}
    state = optim.init(cfg, params)
    g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = optim.update(cfg, g, state, params)
    assert float(jnp.abs(new["w2"] - 1.0).max()) > 1e-4
    assert float(jnp.abs(new["b1"] - 1.0).max()) < 1e-6


def test_grad_clip():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, gn = optim.clip_by_global_norm(g, 1.0)
    assert float(optim.global_norm(clipped)) <= 1.0 + 1e-5
    assert float(gn) > 100.0


def test_schedules():
    from repro.optim import warmup_cosine, warmup_linear
    f = warmup_cosine(10, 100)
    assert float(f(0)) == 0.0
    assert abs(float(f(10)) - 1.0) < 1e-5
    assert float(f(100)) <= 0.11
    g = warmup_linear(10, 100)
    assert abs(float(g(100))) < 1e-6


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_int8_quantization_roundtrip():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(64,)) * 3)
    q, s = optim.quantize_int8(x)
    err = np.abs(np.asarray(optim.dequantize_int8(q, s) - x))
    assert err.max() <= float(s) * 0.51 + 1e-6


def test_error_feedback_reduces_bias():
    """Accumulated EF error stays bounded; sum of dequantized updates tracks
    the true sum."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(32)
    deq_sum = np.zeros(32)
    err = {"g": jnp.zeros(32)}
    for _ in range(50):
        g = {"g": jnp.asarray(rng.normal(size=32) * 0.1)}
        q, s, err = optim.ef_compress(g, err)
        deq_sum += np.asarray(optim.dequantize_int8(q["g"], s["g"]))
        true_sum += np.asarray(g["g"])
    assert np.abs(deq_sum - true_sum).max() < 0.05


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_data_determinism_and_host_slicing():
    cfg = DataConfig(seq_len=16, global_batch=8, seed=3)
    src = LMDataSource(cfg)
    b1 = src.batch_at(5)
    b2 = src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host slices tile the global batch exactly
    lo = src.batch_at(5, 0, 4)
    hi = src.batch_at(5, 4, 8)
    np.testing.assert_array_equal(
        np.concatenate([lo["tokens"], hi["tokens"]]), b1["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_byte_corpus(tmp_path):
    p = tmp_path / "corpus.txt"
    p.write_bytes(b"hello world, this is a tiny corpus for byte-level lm!" * 10)
    cfg = DataConfig(seq_len=16, global_batch=2, corpus_path=str(p))
    src = LMDataSource(cfg)
    b = src.batch_at(0)
    assert b["tokens"].shape == (2, 16)
    assert b["tokens"].max() < 256
    b2 = src.batch_at(0)
    np.testing.assert_array_equal(b["tokens"], b2["tokens"])


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree():
    return {"model": {"w": jnp.arange(6.0).reshape(2, 3),
                      "b": jnp.ones((3,), jnp.float32)},
            "opt": {"step": jnp.asarray(7, jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree()
    mgr.save(12, tree, extra={"data_step": 12})
    assert mgr.latest_step() == 12
    out = mgr.restore(jax.tree.map(np.asarray, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))
    assert mgr.extra()["data_step"] == 12


def test_checkpoint_gc_and_latest(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, _tree())
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2
    assert mgr.latest_step() == 4


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(), blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_checkpoint_crash_safety(tmp_path):
    """A stale .tmp_ dir from a crashed writer must not break anything."""
    mgr = CheckpointManager(str(tmp_path))
    os.makedirs(tmp_path / ".tmp_step_0000000099")
    mgr.save(2, _tree())
    assert mgr.latest_step() == 2


# ---------------------------------------------------------------------------
# watchdog
# ---------------------------------------------------------------------------

def test_heartbeat(tmp_path):
    hb = Heartbeat(str(tmp_path / "hb"))
    assert hb.stale(0.1)
    hb.beat(5)
    assert not hb.stale(10.0)
    assert hb.last()[0] == 5


def test_step_timer_flags_stragglers(monkeypatch):
    t = StepTimer(slow_factor=1.5)
    times = iter([0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0, 4.0, 4.0, 5.0, 5.0, 6.0,
                  6.0, 16.0])  # last step takes 10x
    monkeypatch.setattr("time.perf_counter", lambda: next(times))
    for _ in range(6):
        t.start()
        assert not t.stop()["straggler"]
    t.start()
    assert t.stop()["straggler"]
