import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import gs, orthogonal as orth
from repro.core.permutations import PermSpec


def test_skew():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.normal(size=(3, 5, 5)), jnp.float32)
    k = orth.skew(a)
    assert np.allclose(np.asarray(k), -np.asarray(k).transpose(0, 2, 1))


def test_cayley_orthogonal():
    rng = np.random.default_rng(1)
    k = orth.skew(jnp.asarray(rng.normal(size=(8, 16, 16)) * 0.5, jnp.float32))
    q = orth.cayley(k)
    assert float(orth.orthogonality_error(q)) < 1e-5


def test_cayley_identity_init():
    q = orth.cayley(jnp.zeros((4, 8, 8)))
    assert np.allclose(np.asarray(q), np.eye(8)[None], atol=1e-7)


def test_cayley_inverse_roundtrip():
    rng = np.random.default_rng(2)
    k0 = orth.skew(jnp.asarray(rng.normal(size=(2, 6, 6)) * 0.3, jnp.float32))
    q = orth.cayley(k0)
    k1 = orth.cayley_inverse(q)
    assert np.allclose(np.asarray(k0), np.asarray(k1), atol=1e-4)
    q2 = orth.cayley(k1)
    assert np.allclose(np.asarray(q), np.asarray(q2), atol=1e-5)


def test_neumann_converges_with_order():
    rng = np.random.default_rng(3)
    # ||K|| < 1 so the series converges
    k = orth.skew(jnp.asarray(rng.normal(size=(4, 8, 8)) * 0.02, jnp.float32))
    exact = np.asarray(orth.cayley(k))
    errs = []
    for order in (1, 3, 5, 8):
        approx = np.asarray(orth.cayley(k, neumann_order=order))
        errs.append(np.abs(approx - exact).max())
    assert errs[-1] < errs[0]
    assert errs[-1] < 1e-5


def test_orthogonal_gs_matrix_is_orthogonal():
    """Cayley blocks in L, R  =>  full GS matrix orthogonal (paper §4)."""
    rng = np.random.default_rng(4)
    layout = gs.gsoft_layout(32, 8)
    L = orth.orthogonal_blocks(jnp.asarray(rng.normal(size=layout.lspec.param_shape), jnp.float32))
    R = orth.orthogonal_blocks(jnp.asarray(rng.normal(size=layout.rspec.param_shape), jnp.float32))
    A = gs.gs_materialize(layout, L, R)
    assert np.allclose(A.T @ A, np.eye(32), atol=1e-5)
    assert np.allclose(A @ A.T, np.eye(32), atol=1e-5)


def test_theorem1_block_orthogonal_representation():
    """Theorem 1: any orthogonal GS matrix admits a representation with
    orthogonal blocks — verified constructively via QR re-factorization of
    the block-skeleton decomposition."""
    rng = np.random.default_rng(5)
    layout = gs.gsoft_layout(24, 6)
    L = orth.random_orthogonal_blocks(rng, *layout.lspec.param_shape[:2])
    R = orth.random_orthogonal_blocks(rng, *layout.rspec.param_shape[:2])
    A = gs.gs_materialize(layout, L, R)
    # A orthogonal by construction; project back onto the class (Alg. 1)
    from repro.core.projection import project_to_gs
    L2, R2 = project_to_gs(A, layout)
    A2 = gs.gs_materialize(layout, L2, R2)
    assert np.allclose(A, A2, atol=1e-8)         # class membership: exact
    # Theorem 1: the recovered blocks can be made orthogonal; verify that the
    # projected factors have orthogonal row/col spaces up to diagonal scaling:
    # normalize each recovered L block column-wise and check Q^T Q = I.
    for blk in np.asarray(L2):
        g = blk.T @ blk
        d = np.sqrt(np.diag(g))
        gn = g / np.outer(d, d)
        assert np.allclose(gn, np.eye(blk.shape[1]), atol=1e-6)


def test_project_orthogonal_polar():
    rng = np.random.default_rng(6)
    a = jnp.asarray(rng.normal(size=(3, 7, 7)), jnp.float32)
    q = orth.project_orthogonal(a)
    assert float(orth.orthogonality_error(q)) < 1e-4


def test_random_orthogonal_blocks():
    rng = np.random.default_rng(7)
    q = orth.random_orthogonal_blocks(rng, 4, 5)
    assert float(orth.orthogonality_error(q)) < 1e-5
