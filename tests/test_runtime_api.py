"""Registry / AdapterContext / ModelRuntime API-surface tests: unknown
families fail loud, the context pytrees survive jit, the bank error paths
stay exercised through the attach API, the attach-era deprecation shims
warn exactly once, the PR-3 api shims stay DELETED, and the retired kwarg
triple cannot creep back into model/serve signatures."""
import dataclasses
import pathlib
import re
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ModelConfig, get_smoke_config
from repro.core import peft as peft_lib
from repro.core import runtime as runtime_lib
from repro.core.runtime import ModelRuntime
from repro.models import api, registry

CFG = get_smoke_config("qwen2-72b")
PARAMS = api.init_params(CFG, jax.random.PRNGKey(0))
PCFG = peft_lib.PEFTConfig(method="gsoft", block_size=8)

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def test_registry_has_explicit_entries_per_family():
    assert registry.families() == ["decoder", "encdec", "hybrid", "image",
                                   "ssm", "vlm"]


def test_unknown_family_raises_keyerror_listing_registered():
    bad = dataclasses.replace(CFG, family="retnet")
    with pytest.raises(KeyError, match="retnet") as ei:
        api.init_params(bad, jax.random.PRNGKey(0))
    # the error must tell the user what IS available
    for fam in ("decoder", "encdec", "ssm"):
        assert fam in str(ei.value)
    with pytest.raises(KeyError, match="retnet"):
        ModelRuntime(bad)


def test_registry_dispatch_matches_family_modules():
    from repro.models import encdec, transformer
    assert registry.get("decoder").prefill is transformer.prefill
    assert registry.get("ssm").decode_step is transformer.decode_step
    assert registry.get("encdec").prefill is encdec.prefill


# ---------------------------------------------------------------------------
# AdapterContext / PrefillRequest pytrees
# ---------------------------------------------------------------------------

def _small_ctx():
    bank = peft_lib.build_adapter_bank(PCFG, PARAMS, {})
    return bank.context([0, 0])


def test_adapter_context_tree_roundtrip():
    ctx = _small_ctx()
    leaves, treedef = jax.tree_util.tree_flatten(ctx)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, peft_lib.AdapterContext)
    assert back.peft == ctx.peft                    # static aux preserved
    for a, b in zip(leaves, jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adapter_context_jitted_identity():
    ctx = _small_ctx()
    out = jax.jit(lambda c: c)(ctx)
    assert isinstance(out, peft_lib.AdapterContext)
    assert out.peft == ctx.peft
    np.testing.assert_array_equal(np.asarray(out.slots),
                                  np.asarray(ctx.slots))
    for a, b in zip(jax.tree_util.tree_leaves(ctx.bank),
                    jax.tree_util.tree_leaves(out.bank)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_prefill_request_tree_roundtrip_and_jit():
    req = peft_lib.PrefillRequest(
        batch={"tokens": jnp.ones((1, 8), jnp.int32)},
        last_idx=jnp.asarray(3, jnp.int32), ctx=_small_ctx())
    leaves, treedef = jax.tree_util.tree_flatten(req)
    back = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(back, peft_lib.PrefillRequest)
    assert isinstance(back.ctx, peft_lib.AdapterContext)
    out = jax.jit(lambda r: r)(req)
    np.testing.assert_array_equal(np.asarray(out.batch["tokens"]),
                                  np.asarray(req.batch["tokens"]))
    assert int(out.last_idx) == 3


def test_context_group_and_rotator():
    ctx = _small_ctx()
    assert ctx.group("layers") is not None
    assert ctx.group("nope") is None
    assert ctx.rotator(None) is None
    layers = ctx.group("layers")
    rot = ctx.rotator(jax.tree.map(lambda v: v[0], layers)["attn"])
    x = jnp.ones((2, 1, CFG.d_model))
    np.testing.assert_allclose(np.asarray(rot("wq", x)), np.asarray(x),
                               atol=1e-6)          # identity slot
    np.testing.assert_array_equal(np.asarray(rot("not_adapted", x)),
                                  np.asarray(x))


# ---------------------------------------------------------------------------
# bank error paths through the new API
# ---------------------------------------------------------------------------

def test_bank_build_rejects_double_gsoft_and_use_scale():
    with pytest.raises(ValueError, match="double_gsoft|gsoft"):
        ModelRuntime(CFG, PARAMS).attach(
            {}, peft_lib.PEFTConfig(method="double_gsoft"))
    with pytest.raises(ValueError, match="use_scale"):
        ModelRuntime(CFG, PARAMS).attach(
            {}, peft_lib.PEFTConfig(method="gsoft", use_scale=True))


def test_bank_build_rejects_moe_batch_dims():
    moe_cfg = get_smoke_config("qwen3-moe-30b-a3b")
    rt = ModelRuntime(moe_cfg, key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="batch dims|routing-aware"):
        rt.attach({}, PCFG)


def test_runtime_slot_validation():
    rt = ModelRuntime(CFG, PARAMS).attach({}, PCFG)
    assert rt.slot(None) == 0
    with pytest.raises(KeyError, match="nope"):
        rt.slot("nope")
    # bare runtime: no bank — None maps to identity, a NAME must not
    # silently fall back to serving the base model
    assert ModelRuntime(CFG, PARAMS).slot(None) == 0
    assert ModelRuntime(CFG, PARAMS).context([0]) is None
    with pytest.raises(KeyError, match="no adapter bank"):
        ModelRuntime(CFG, PARAMS).slot("alice")


def test_load_adapter_checkpoints_handles_dir_with_equals(tmp_path):
    """A bare checkpoint dir whose PATH contains '=' must not be misparsed
    as a name=dir entry (the --save-adapters round-trip path)."""
    from repro.store import AdapterStore, load_adapter_checkpoints
    adapters = {"a0": peft_lib.init_peft(PCFG, PARAMS, jax.random.PRNGKey(2))}
    ckpt = tmp_path / "run=3"
    AdapterStore.from_adapters(adapters, PCFG).save(str(ckpt))
    loaded, cfg = load_adapter_checkpoints([str(ckpt)])
    assert sorted(loaded) == ["a0"] and cfg == PCFG
    # explicit name=dir still works against the same checkpoint
    picked, _ = load_adapter_checkpoints([f"a0={ckpt}"])
    assert sorted(picked) == ["a0"]
    # attach() takes the entry list directly — one surface end to end
    rt = ModelRuntime(CFG, PARAMS).attach([f"a0={ckpt}"])
    assert rt.bank.names == (peft_lib.BASE_ADAPTER, "a0")


def test_runtime_rejects_merge_plus_bank():
    adapters = peft_lib.init_peft(PCFG, PARAMS, jax.random.PRNGKey(1))
    bank = peft_lib.build_adapter_bank(PCFG, PARAMS, {})
    with pytest.raises(ValueError, match="EITHER"):
        ModelRuntime(CFG, PARAMS, bank=bank, adapters=adapters,
                     peft_cfg=PCFG)
    # banking on top of already-merged params would double-apply adapters
    merged = ModelRuntime(CFG, PARAMS, adapters=adapters, peft_cfg=PCFG)
    with pytest.raises(ValueError, match="already-rotated|merged"):
        merged.attach({}, PCFG)
    # half-passed merge args would silently serve the base model
    with pytest.raises(ValueError, match="BOTH"):
        ModelRuntime(CFG, PARAMS, adapters=adapters)
    with pytest.raises(ValueError, match="BOTH"):
        ModelRuntime(CFG, PARAMS, peft_cfg=PCFG)
    # so would "merging" an empty adapter tree (no targets matched)
    with pytest.raises(ValueError, match="empty adapter"):
        ModelRuntime(CFG, PARAMS, adapters={}, peft_cfg=PCFG)


def test_train_returns_runtime_over_trained_weights():
    """train()['runtime'] must serve the TRAINED model (adapters merged),
    not the init-time params."""
    from repro.data import DataConfig
    from repro.train.loop import LoopConfig, train
    from repro.train.steps import TrainStepConfig
    out = train(CFG, TrainStepConfig(peft=PCFG),
                DataConfig(seq_len=16, global_batch=2,
                           vocab_size=min(CFG.vocab_size, 256)),
                LoopConfig(steps=2, log_every=10))
    rt = out["runtime"]
    assert isinstance(rt, ModelRuntime)
    expected = peft_lib.materialize_tree(PCFG, out["frozen"],
                                         out["trainable"], merged=True)
    for a, b in zip(jax.tree.leaves(rt.params), jax.tree.leaves(expected)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# runtime facade basics
# ---------------------------------------------------------------------------

def test_runtime_loss_matches_api():
    from repro.data.synthetic import lm_batch
    batch = lm_batch(CFG, batch=2, seq=16)
    rt = ModelRuntime(CFG, PARAMS)
    loss_rt, _ = rt.loss(batch)
    loss_api, _ = api.loss_fn(CFG, PARAMS, batch)
    np.testing.assert_allclose(float(loss_rt), float(loss_api), rtol=1e-5)


def test_runtime_abstract_params_for_dryrun():
    rt = ModelRuntime.abstract(CFG)
    assert all(isinstance(l, jax.ShapeDtypeStruct)
               for l in jax.tree.leaves(rt.params))
    assert rt.active_param_count() > 0


# ---------------------------------------------------------------------------
# deprecation shims
# ---------------------------------------------------------------------------

def test_retired_api_shims_are_gone():
    """The PR-3 module-level prefill/decode_step shims on the api module
    had one release of backward compatibility and are now deleted —
    serving goes through ModelRuntime and the family registry only."""
    assert not hasattr(api, "prefill")
    assert not hasattr(api, "decode_step")
    assert not hasattr(api, "_legacy_warned")
    # the per-family ops are still the real surface
    assert callable(api.family_ops(CFG).prefill)
    assert callable(api.family_ops(CFG).decode_step)


def test_attach_shims_warn_once_and_forward(tmp_path):
    """with_bank/save_bank/load_named_adapters each DeprecationWarn exactly
    once per process and forward to the attach/store surface."""
    from repro.store import AdapterStore
    runtime_lib._deprecation_warned.clear()     # isolate from other tests
    adapters = {"a0": peft_lib.init_peft(PCFG, PARAMS, jax.random.PRNGKey(3))}
    rt = ModelRuntime(CFG, PARAMS)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        banked = rt.with_bank(adapters, PCFG)
        rt.with_bank(adapters, PCFG)            # second call: silent
        ModelRuntime.save_bank(str(tmp_path / "ck"), adapters, PCFG)
        loaded, cfg = ModelRuntime.load_named_adapters(
            [f"a0={tmp_path / 'ck'}"])
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 3, [str(w.message) for w in caught]
    for w in dep:
        assert "attach" in str(w.message) or "AdapterStore" in str(w.message)
    # ...and they forward: with_bank produced a working bank, save_bank a
    # loadable store, load_named_adapters the adapters themselves
    assert banked.bank.names == (peft_lib.BASE_ADAPTER, "a0")
    assert AdapterStore.open(str(tmp_path / "ck")).names == ("a0",)
    assert sorted(loaded) == ["a0"] and cfg == PCFG


def test_attach_rejects_bad_sources():
    rt = ModelRuntime(CFG, PARAMS)
    with pytest.raises(TypeError, match="attach"):
        rt.attach(42)
    # peft_cfg only makes sense for raw adapter mappings
    bank = peft_lib.build_adapter_bank(PCFG, PARAMS, {})
    with pytest.raises(ValueError, match="peft_cfg"):
        rt.attach(bank, PCFG)
    assert rt.attach(bank).detach().bank is None


# ---------------------------------------------------------------------------
# the retired kwarg triple must not creep back into signatures
# ---------------------------------------------------------------------------

def test_no_retired_adapter_kwargs_in_model_or_serve_signatures():
    """Mirror of the CI lint grep: per-request adapter state flows only
    through AdapterContext — no function under models/, serve/ or train/
    may take the loose bank/adapter_ids/bank_cfg kwargs again."""
    # kwarg syntax only (no space before '='): signature defaults and
    # call-site keyword threading are banned; PEP8 assignments are not
    pat = re.compile(r"\b(bank|adapter_ids|bank_cfg)=")
    offenders = []
    scanned = 0
    for sub in ("models", "serve", "train"):
        paths = sorted((SRC / sub).rglob("*.py"))
        assert paths, f"guard scanned nothing under src/repro/{sub}"
        scanned += len(paths)
        for path in paths:
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if pat.search(line):
                    offenders.append(f"{path.relative_to(SRC)}:{i}: {line.strip()}")
    assert scanned > 5, "guard expected to scan the model/serve/train stack"
    assert not offenders, "\n".join(offenders)


def test_no_retired_api_or_bank_calls_outside_runtime():
    """Mirror of the CI 'retired api-shim' and 'one-attach-surface' greps:
    the api-module prefill/decode_step names are gone everywhere, and the
    deprecated with_bank/save_bank/load_named_adapters shims are called
    only from their definitions in core/runtime.py (and the shim tests)."""
    root = SRC.parents[1]
    api_pat = re.compile(
        r"\bapi\.(prefill|decode_step)\b"
        r"|from repro\.models\.api import[^#]*\b(prefill|decode_step)\b")
    shim_pat = re.compile(r"\.(with_bank|load_named_adapters|save_bank)\(")
    api_offenders, shim_offenders = [], []
    scanned = 0
    for sub in ("src/repro", "benchmarks", "examples", "tests"):
        for path in sorted((root / sub).rglob("*.py")):
            scanned += 1
            rel = str(path.relative_to(root))
            for i, line in enumerate(path.read_text().splitlines(), 1):
                if api_pat.search(line):
                    api_offenders.append(f"{rel}:{i}: {line.strip()}")
                if (shim_pat.search(line) and sub != "tests"
                        and rel != "src/repro/core/runtime.py"):
                    shim_offenders.append(f"{rel}:{i}: {line.strip()}")
    assert scanned > 20, "guard expected to scan the whole python surface"
    assert not api_offenders, "\n".join(api_offenders)
    assert not shim_offenders, "\n".join(shim_offenders)
