"""Per-architecture smoke tests: a REDUCED config of the same family runs one
forward/train step and one decode step on CPU — shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config, list_archs
from repro.core.peft import PrefillRequest
from repro.data.synthetic import image_batch, lm_batch
from repro.models import api, registry

KEY = jax.random.PRNGKey(0)
ARCHS = list_archs()
TOKEN_ARCHS = [a for a in ARCHS
               if not registry.get(get_smoke_config(a).family).stateless]


def test_all_archs_registered():
    assert set(ARCHS) == {
        "qwen2-72b", "mistral-large-123b", "granite-34b", "gemma-7b",
        "phi3.5-moe-42b-a6.6b", "qwen3-moe-30b-a3b", "zamba2-2.7b",
        "pixtral-12b", "mamba2-130m", "seamless-m4t-medium",
        "lipconvnet-15"}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = get_smoke_config(arch)
    stateless = registry.get(cfg.family).stateless
    params = api.init_params(cfg, KEY)
    batch = (image_batch(cfg, 2) if stateless else
             lm_batch(cfg, batch=2, seq=32))

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: api.loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    leaves = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves), \
        f"{arch}: non-finite grads"
    gn = sum(float(jnp.abs(l).sum()) for l in leaves)
    assert gn > 0, f"{arch}: zero gradient"

    logits, _ = api.forward(cfg, params, batch)
    want = ((2, cfg.num_classes) if stateless else
            (2, batch["labels"].shape[1], cfg.padded_vocab()))
    assert logits.shape == want
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", TOKEN_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    b, max_len = 2, 16
    state = api.init_decode_state(cfg, b, max_len, enc_len=8)
    if cfg.family == "encdec":
        frames = jnp.zeros((b, 8, cfg.d_model), cfg.act_dtype)
        state["enc_out"] = frames
    tokens = jnp.ones((b, 1), jnp.int32)
    logits, new_state = api.family_ops(cfg).decode_step(
        cfg, params, tokens, state, jnp.asarray(3, jnp.int32))
    assert logits.shape == (b, 1, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), arch
    # state structure preserved
    assert jax.tree.structure(new_state) == jax.tree.structure(state)
    # something was written
    diff = sum(float(jnp.abs(a - b2).sum())
               for a, b2 in zip(jax.tree.leaves(state),
                                jax.tree.leaves(new_state))
               if a.dtype != jnp.bool_)
    assert diff > 0, f"{arch}: decode did not update state"


@pytest.mark.parametrize("arch", ["qwen2-72b", "zamba2-2.7b", "mamba2-130m",
                                  "seamless-m4t-medium"])
def test_smoke_prefill_matches_decode(arch):
    """Prefill then one decode step == scoring the sequence directly."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    b, s = 1, 8
    batch = lm_batch(cfg, batch=b, seq=s)
    if cfg.family in ("decoder", "encdec"):
        state = api.init_decode_state(cfg, b, s + 4, enc_len=max(s // 4, 8))
        logits_pre, state = api.family_ops(cfg).prefill(
            cfg, params, PrefillRequest(batch=batch), state)
        full, _ = api.forward(cfg, params, batch)
        np.testing.assert_allclose(np.asarray(logits_pre[:, 0], np.float32),
                                   np.asarray(full[:, -1], np.float32),
                                   atol=2e-2, rtol=2e-2)


@pytest.mark.parametrize("arch", ["mamba2-130m", "zamba2-2.7b"])
def test_ssm_decode_matches_forward(arch):
    """Token-by-token decode must reproduce the parallel (SSD) forward —
    the core state-space duality property."""
    cfg = get_smoke_config(arch)
    params = api.init_params(cfg, KEY)
    b, s = 1, 8
    batch = lm_batch(cfg, batch=b, seq=s)
    full, _ = api.forward(cfg, params, batch)          # (b, s, V)

    state = api.init_decode_state(cfg, b, s + 1)
    outs = []
    for t in range(s):
        logits, state = api.family_ops(cfg).decode_step(
            cfg, params, batch["tokens"][:, t:t + 1], state,
            jnp.asarray(t, jnp.int32))
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               atol=5e-2, rtol=5e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_math(arch):
    """Full configs build abstract param trees with sane total counts."""
    from repro.config import get_config
    cfg = get_config(arch)
    n = api.param_count(cfg)
    expected = {
        "qwen2-72b": 72.7e9, "mistral-large-123b": 122.6e9,
        "granite-34b": 33.7e9, "gemma-7b": 8.5e9,
        "phi3.5-moe-42b-a6.6b": 41.9e9, "qwen3-moe-30b-a3b": 30.5e9,
        "zamba2-2.7b": 2.7e9, "pixtral-12b": 12.4e9,
        # seamless: backbone-only (speech frontend is a stub) + untied
        # 256k-vocab embed/lm_head dominate -> 0.88B
        "mamba2-130m": 0.13e9, "seamless-m4t-medium": 0.88e9,
        # GS-SOC LipConvnet-15 at width 32, 100 classes (paper table 3
        # at groups (4,1): conv stack + wc mixers + SN head)
        "lipconvnet-15": 22.6e6,
    }[arch]
    assert abs(n - expected) / expected < 0.25, (arch, n, expected)
