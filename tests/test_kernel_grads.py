"""Gradient correctness of the differentiable Pallas kernel path.

The custom-VJP rules in kernels/dispatch.py (backward = Pallas kernels in
interpret mode on CPU) are checked three ways:
  * oracle-VJP comparison: jax.grad through the kernel path vs jax.grad
    through the pure-jnp ref.py path, swept over shapes/dtypes including the
    padding path (non-divisible token counts) and group_tile edge cases;
  * jax.test_util.check_grads numerical differentiation (rev mode);
  * end-to-end: the gradient of a GSOFT adapter loss with use_pallas=True
    matches the reference-path gradient to <= 1e-4 (acceptance criterion).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.test_util import check_grads

from repro.core import adapters as ad
from repro.core import peft as peft_lib
from repro.kernels import dispatch, ops, ref
from repro.kernels.gs_fused import (gs_fused_T_pallas, gs_fused_bwd_pallas,
                                    gs_fused_grads_pallas)

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return 1e-4 if dtype == jnp.float32 else 6e-2


def _assert_trees_close(a, b, tol):
    jax.tree.map(lambda x, y: np.testing.assert_allclose(
        np.asarray(x, np.float32), np.asarray(y, np.float32),
        atol=tol, rtol=tol), a, b)


# ---------------------------------------------------------------------------
# bdmm
# ---------------------------------------------------------------------------

BDMM_GRAD_SHAPES = [
    # (r, bo, bi, T) — ragged T exercises the zero-padding path
    (4, 8, 8, 16),
    (2, 8, 4, 33),       # rectangular blocks + padding
    (3, 5, 9, 64),       # odd sizes
    (16, 4, 4, 250),
]


@pytest.mark.parametrize("r,bo,bi,t", BDMM_GRAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bdmm_grads_vs_oracle(r, bo, bi, t, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    blocks = jax.random.normal(k1, (r, bo, bi), dtype)
    x = jax.random.normal(k2, (t, r * bi), dtype)
    cot = jax.random.normal(k3, (t, r * bo), dtype)

    def loss(w, xx, up):
        return jnp.sum(ops.bdmm(w, xx, use_pallas=up).astype(jnp.float32) *
                       cot.astype(jnp.float32))

    gw0, gx0 = jax.grad(loss, argnums=(0, 1))(blocks, x, False)
    gw1, gx1 = jax.grad(loss, argnums=(0, 1))(blocks, x, True)
    _assert_trees_close((gw0, gx0), (gw1, gx1), _tol(dtype))


@pytest.mark.parametrize("group_tile", [1, 2, 4])
@pytest.mark.parametrize("token_tile", [8, 32, 128])
def test_bdmm_grads_tilings(token_tile, group_tile):
    """group_tile edge cases: 1 (no grouping), r (single group step), and a
    non-divisor (5 -> rounded down internally)."""
    r, bo, bi, t = 4, 8, 8, 40
    blocks = jax.random.normal(KEY, (r, bo, bi))
    x = jax.random.normal(jax.random.PRNGKey(1), (t, r * bi))
    tun = dispatch.Tuning(token_tile=token_tile, group_tile=group_tile)

    def loss(w, xx, up, tu=None):
        return jnp.sum(ops.bdmm(w, xx, use_pallas=up, tuning=tu) ** 2)

    want = jax.grad(loss, argnums=(0, 1))(blocks, x, False)
    got = jax.grad(loss, argnums=(0, 1))(blocks, x, True, tun)
    _assert_trees_close(want, got, 1e-4)


def test_bdmm_grads_group_tile_nondivisor():
    r, bo, bi, t = 6, 4, 4, 17
    blocks = jax.random.normal(KEY, (r, bo, bi))
    x = jax.random.normal(jax.random.PRNGKey(2), (t, r * bi))
    tun = dispatch.Tuning(token_tile=16, group_tile=5)   # 5 does not divide 6

    def loss(w, xx):
        return jnp.sum(ops.bdmm(w, xx, use_pallas=True, tuning=tun) ** 2)

    want = jax.grad(lambda w, xx: jnp.sum(ops.bdmm(w, xx) ** 2),
                    argnums=(0, 1))(blocks, x)
    got = jax.grad(loss, argnums=(0, 1))(blocks, x)
    _assert_trees_close(want, got, 1e-4)


def test_bdmm_check_grads_numerical():
    blocks = jax.random.normal(KEY, (3, 4, 4)) * 0.5
    x = jax.random.normal(jax.random.PRNGKey(3), (10, 12)) * 0.5
    check_grads(lambda w, xx: ops.bdmm(w, xx, use_pallas=True),
                (blocks, x), order=1, modes=("rev",), atol=1e-2, rtol=1e-2)


# ---------------------------------------------------------------------------
# gs_transform / gs_transform_T
# ---------------------------------------------------------------------------

GS_GRAD_SHAPES = [(4, 4, 16), (2, 16, 33), (8, 8, 100), (4, 32, 20)]


@pytest.mark.parametrize("r,b,t", GS_GRAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gs_transform_grads_vs_oracle(r, b, t, dtype):
    ks = jax.random.split(KEY, 4)
    L = jax.random.normal(ks[0], (r, b, b), dtype)
    R = jax.random.normal(ks[1], (r, b, b), dtype)
    x = jax.random.normal(ks[2], (t, r * b), dtype)
    cot = jax.random.normal(ks[3], (t, r * b), dtype)

    def loss(p, xx, up):
        y = ops.gs_transform(p["L"], p["R"], xx, use_pallas=up)
        return jnp.sum(y.astype(jnp.float32) * cot.astype(jnp.float32))

    g0 = jax.grad(loss, argnums=(0, 1))({"L": L, "R": R}, x, False)
    g1 = jax.grad(loss, argnums=(0, 1))({"L": L, "R": R}, x, True)
    _assert_trees_close(g0, g1, _tol(dtype) * (1 + b // 8))


@pytest.mark.parametrize("r,b,t", GS_GRAD_SHAPES)
def test_gs_transform_T_grads_vs_oracle(r, b, t):
    ks = jax.random.split(KEY, 4)
    L = jax.random.normal(ks[0], (r, b, b))
    R = jax.random.normal(ks[1], (r, b, b))
    x = jax.random.normal(ks[2], (t, r * b))
    cot = jax.random.normal(ks[3], (t, r * b))

    def loss(p, xx, up):
        return jnp.sum(ops.gs_transform_T(p["L"], p["R"], xx,
                                          use_pallas=up) * cot)

    g0 = jax.grad(loss, argnums=(0, 1))({"L": L, "R": R}, x, False)
    g1 = jax.grad(loss, argnums=(0, 1))({"L": L, "R": R}, x, True)
    _assert_trees_close(g0, g1, 1e-4)


def test_gs_transform_check_grads_numerical():
    r, b, t = 2, 4, 9
    ks = jax.random.split(KEY, 3)
    L = jax.random.normal(ks[0], (r, b, b)) * 0.5
    R = jax.random.normal(ks[1], (r, b, b)) * 0.5
    x = jax.random.normal(ks[2], (t, r * b)) * 0.5
    check_grads(lambda *a: ops.gs_transform(*a, use_pallas=True),
                (L, R, x), order=1, modes=("rev",), atol=1e-2, rtol=1e-2)
    check_grads(lambda *a: ops.gs_transform_T(*a, use_pallas=True),
                (L, R, x), order=1, modes=("rev",), atol=1e-2, rtol=1e-2)


def test_gs_fused_T_kernel_vs_oracle():
    """Transpose rotation kernel == R^T P^T L^T P x oracle == gs_apply_T."""
    from repro.core import gs
    r, b, t = 4, 8, 33
    ks = jax.random.split(KEY, 3)
    L = jax.random.normal(ks[0], (r, b, b))
    R = jax.random.normal(ks[1], (r, b, b))
    x = jax.random.normal(ks[2], (t, r * b))
    got = gs_fused_T_pallas(L, R, x, interpret=True)
    want = ref.gs_fused_T_ref(L, R, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    lay = gs.gsoft_layout(r * b, b)
    np.testing.assert_allclose(np.asarray(want),
                               np.asarray(gs.gs_apply_T(lay, L, R, x)),
                               atol=1e-5)


def test_gs_fused_bwd_kernel_vs_autodiff():
    """The fused (dx, dL, dR) kernel against XLA autodiff of the oracle,
    with multiple token tiles so the in-place fp32 accumulation is hit."""
    r, b, t = 4, 8, 50
    ks = jax.random.split(KEY, 4)
    L = jax.random.normal(ks[0], (r, b, b))
    R = jax.random.normal(ks[1], (r, b, b))
    x = jax.random.normal(ks[2], (t, r * b))
    dy = jax.random.normal(ks[3], (t, r * b))
    dx, dL, dR = gs_fused_bwd_pallas(L, R, x, dy, token_tile=8,
                                     interpret=True)
    gL, gR, gx = jax.grad(
        lambda L_, R_, x_: jnp.sum(ref.gs_fused_ref(L_, R_, x_) * dy),
        argnums=(0, 1, 2))(L, R, x)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(gx), atol=1e-4)
    np.testing.assert_allclose(np.asarray(dL), np.asarray(gL), atol=1e-4)
    np.testing.assert_allclose(np.asarray(dR), np.asarray(gR), atol=1e-4)
    # grads-only variant (no dx slab) agrees
    dL2, dR2 = gs_fused_grads_pallas(L, R, x, dy, token_tile=8,
                                     interpret=True)
    np.testing.assert_allclose(np.asarray(dL2), np.asarray(dL), atol=1e-6)
    np.testing.assert_allclose(np.asarray(dR2), np.asarray(dR), atol=1e-6)


# ---------------------------------------------------------------------------
# GSOFT adapter loss end-to-end (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["gsoft", "double_gsoft"])
def test_gsoft_adapter_loss_grad_matches_reference(method):
    """jax.grad of an adapter loss with use_pallas=True vs the reference
    path, fp32, <= 1e-4 (interpret mode on CPU)."""
    spec = ad.AdapterSpec(method=method, d_in=32, d_out=24, block_size=8,
                          block_size_out=4)
    spec_pallas = dataclasses.replace(spec, use_pallas=True)
    key = jax.random.PRNGKey(7)
    params = ad.init_adapter(spec, key)
    params = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(key, p.shape), params)
    W = jax.random.normal(jax.random.PRNGKey(8), (32, 24))
    x = jax.random.normal(jax.random.PRNGKey(9), (16, 32))
    tgt = jax.random.normal(jax.random.PRNGKey(10), (16, 24))

    def loss(p, s):
        w_eff = ad.materialize(s, p, W)
        return jnp.mean((x @ w_eff - tgt) ** 2)

    assert np.isclose(float(loss(params, spec)),
                      float(loss(params, spec_pallas)), atol=1e-5)
    g_ref = jax.grad(loss)(params, spec)
    g_ker = jax.grad(loss)(params, spec_pallas)
    _assert_trees_close(g_ref, g_ker, 1e-4)


def test_peft_tree_grad_matches_reference():
    """materialize_tree (the train-step path) with use_pallas=True: adapter
    grads through a whole params tree match the reference path."""
    params = {
        "layer0": {"wq": jax.random.normal(KEY, (32, 32)),
                   "wo": jax.random.normal(jax.random.PRNGKey(1), (32, 32))},
    }
    cfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    cfg_pallas = dataclasses.replace(cfg, use_pallas=True)
    adapters = peft_lib.init_peft(cfg, params, jax.random.PRNGKey(2))
    adapters = jax.tree.map(
        lambda p: p + 0.05 * jax.random.normal(KEY, p.shape), adapters)
    x = jax.random.normal(jax.random.PRNGKey(3), (8, 32))

    def loss(adp, c):
        eff = peft_lib.materialize_tree(c, params, adp)
        h = jnp.tanh(x @ eff["layer0"]["wq"])
        return jnp.mean((h @ eff["layer0"]["wo"]) ** 2)

    g0 = jax.grad(loss)(adapters, cfg)
    g1 = jax.grad(loss)(adapters, cfg_pallas)
    _assert_trees_close(g0, g1, 1e-4)


# ---------------------------------------------------------------------------
# dispatch registry semantics
# ---------------------------------------------------------------------------

def test_dispatch_tuning_precedence():
    key = dispatch.gs_key(4, 8, jnp.float32)
    try:
        assert dispatch.get_tuning(key).token_tile == 128      # heuristic
        dispatch._TUNED[key] = dispatch.Tuning(token_tile=64)
        assert dispatch.get_tuning(key).token_tile == 64       # autotuned
        dispatch.install_tunings((("gs", 4, 8, 32),))          # config wins
        assert dispatch.get_tuning(key).token_tile == 32
    finally:
        dispatch.clear_tunings()


def test_dispatch_install_replaces_previous_config():
    """install_tunings is per-config: a later install clears the previous
    config's entries instead of accumulating them."""
    key_a = dispatch.gs_key(4, 8, jnp.float32)
    key_b = dispatch.gs_key(2, 16, jnp.float32)
    try:
        dispatch.install_tunings((("gs", 4, 8, 32),))
        assert dispatch.get_tuning(key_a).token_tile == 32
        dispatch.install_tunings((("gs", 2, 16, 64),))
        assert dispatch.get_tuning(key_b).token_tile == 64
        assert dispatch.get_tuning(key_a).token_tile == 128   # back to default
    finally:
        dispatch.clear_tunings()


def test_dispatch_autotune_caches():
    try:
        tun = dispatch.autotune_gs(2, 4, 16, token_tiles=(8, 16), iters=1)
        assert dispatch.gs_key(2, 4, jnp.float32) in dispatch._TUNED
        assert dispatch.autotune_gs(2, 4, 16, token_tiles=(8, 16),
                                    iters=1) == tun
        tun_b = dispatch.autotune_bdmm(2, 4, 4, 16, token_tiles=(8, 16),
                                       iters=1)
        assert tun_b.token_tile in (8, 16)
    finally:
        dispatch.clear_tunings()


def test_dispatch_tuned_result_is_used_and_correct():
    """A registered tuning actually drives the launch and stays correct."""
    r, b, t = 2, 8, 12
    ks = jax.random.split(KEY, 3)
    L = jax.random.normal(ks[0], (r, b, b))
    R = jax.random.normal(ks[1], (r, b, b))
    x = jax.random.normal(ks[2], (t, r * b))
    try:
        dispatch.register_tuning(dispatch.gs_key(r, b, jnp.float32),
                                 dispatch.Tuning(token_tile=4))
        y = ops.gs_transform(L, R, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(ref.gs_fused_ref(L, R, x)),
                                   atol=1e-5)
    finally:
        dispatch.clear_tunings()
