"""Distributed serving (ISSUE 8): in-process ``EngineCluster`` behavior
(routing, affinity, rebalance, aggregated stats, token parity with a
single engine) plus the serve-TP subprocess runner
(tests/serve_distributed_runner.py — it needs its own XLA_FLAGS device
count before jax initializes, so it cannot run in this process)."""
import json
import os
import subprocess
import sys

import pytest

jax = pytest.importorskip("jax")
np = pytest.importorskip("numpy")

from repro.config import get_smoke_config               # noqa: E402
from repro.core import peft as peft_lib                 # noqa: E402
from repro.core.runtime import ModelRuntime             # noqa: E402
from repro.distrib import EngineCluster, format_cluster_report  # noqa: E402
from repro.launch.serve import make_demo_adapters       # noqa: E402
from repro.serve.engine import ServeEngine              # noqa: E402
from repro.serve.kv import merge_pool_stats             # noqa: E402
from repro.store import AdapterStore                    # noqa: E402

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def rt():
    return ModelRuntime(get_smoke_config("qwen2-72b"),
                        key=jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def tenant_store(rt):
    """4 tenants, methods split so an affinity partition of the tenants
    (which alternates replicas) mixes methods within each replica."""
    bank_peft = {f"t{i}": peft_lib.PEFTConfig(
        method="gsoft" if i < 2 else "boft", block_size=8)
        for i in range(4)}
    adapters = make_demo_adapters(list(bank_peft), rt.params, bank_peft)
    return AdapterStore.from_adapters(adapters, bank_peft), bank_peft


def _cluster(rt, store, n, budget=2, max_batch=2, **kw):
    return EngineCluster(
        [ServeEngine(rt.attach(store, hbm_budget=budget),
                     max_batch=max_batch, max_len=32, eos_id=-1)
         for _ in range(n)], **kw)


def _workload(names, n_req, seed=0):
    rng = np.random.default_rng(seed)
    return [{"prompt": rng.integers(1, 200, size=int(
                 rng.integers(4, 11))).tolist(),
             "max_new_tokens": int(rng.integers(2, 7)),
             "adapter": names[i % len(names)]}
            for i in range(n_req)]


def test_cluster_affinity_keeps_tenants_warm(rt, tenant_store):
    """Repeat traffic lands on the home replica whose bank already holds
    the tenant — zero page-ins after the first round."""
    store, bank_peft = tenant_store
    cl = _cluster(rt, store, 2)
    wl = _workload(list(bank_peft), 8)
    for r in wl:
        cl.add_request(**r)
    cl.run()
    homes = dict(cl._affinity)
    assert sorted(homes.values()) == [0, 0, 1, 1]   # tenants partitioned
    page_ins = [e.rt.bank.counters["misses"] for e in cl.engines]
    for r in wl:
        cl.add_request(**r)
    cl.run()
    assert dict(cl._affinity) == homes
    assert [e.rt.bank.counters["misses"] for e in cl.engines] == page_ins
    assert cl.affinity_hit_rate() == 1.0
    assert cl.routing["fresh"] == 4
    assert cl.routing["affinity_hits"] == 12


def test_cluster_tokens_match_single_engine(rt, tenant_store):
    """Routing is a scheduling decision, not a math one: per-request greedy
    tokens agree exactly with one engine serving the same arrivals."""
    store, bank_peft = tenant_store
    wl = _workload(list(bank_peft), 10, seed=1)
    solo = ServeEngine(rt.attach(store, hbm_budget=4), max_batch=2,
                       max_len=32, eos_id=-1)
    rids = [solo.add_request(**r) for r in wl]
    ref = solo.run()
    cl = _cluster(rt, store, 2)
    crids = [cl.add_request(**r) for r in wl]
    out = cl.run()
    assert [out[c] for c in crids] == [ref[r] for r in rids]


def test_cluster_spill_and_rebalance(rt, tenant_store):
    """A flooded home spills to the least-loaded sibling (home stays
    sticky), and explicit rebalance moves only queued backlog."""
    store, _ = tenant_store
    cl = _cluster(rt, store, 2, auto_rebalance=False)
    crids = [cl.add_request([3, 4, 5], max_new_tokens=3, adapter="t0")
             for _ in range(10)]
    assert cl.routing["affinity_spills"] > 0
    assert cl._affinity["t0"] == 0                   # sticky through spills
    assert cl.engines[1].load > 0                    # spills actually landed
    moved = cl.rebalance()
    assert moved >= 0
    out = cl.run()
    assert sorted(out) == sorted(crids)
    assert cl.stats["requests"] == 10


def test_cluster_stats_and_report(rt, tenant_store):
    store, bank_peft = tenant_store
    cl = _cluster(rt, store, 2)
    for r in _workload(list(bank_peft), 6, seed=2):
        cl.add_request(**r)
    cl.run()
    cs = cl.cluster_stats()
    assert cs["replicas"] == 2
    assert cs["aggregate"]["requests"] == 6
    assert cs["aggregate"]["tokens_generated"] == cl.stats["tokens_generated"]
    assert len(cs["per_replica"]) == 2
    assert sum(row["requests"] for row in cs["per_replica"]) == 6
    assert cs["routing"]["affinity_hit_rate"] == 1.0
    rep = format_cluster_report(cs)
    assert "2 replica(s)" in rep and "replica[0]" in rep and "bank:" in rep


def test_cluster_n1_is_the_degenerate_case(rt, tenant_store):
    """The launcher wraps a single engine in the same cluster surface —
    stats and report must work without siblings."""
    store, bank_peft = tenant_store
    cl = _cluster(rt, store, 1, budget=4)
    for r in _workload(list(bank_peft), 4, seed=3):
        cl.add_request(**r)
    cl.run()
    cs = cl.cluster_stats()
    assert cs["replicas"] == 1
    assert cs["aggregate"]["requests"] == 4
    assert cl.affinity_hit_rate() == 1.0             # nothing to spill to
    format_cluster_report(cs)


def test_merge_pool_stats_contract():
    a = {"page_size": 8, "alloc": 3, "in_use": 2}
    b = {"page_size": 8, "alloc": 5, "in_use": 1}
    m = merge_pool_stats([a, b])
    assert m == {"page_size": 8, "alloc": 8, "in_use": 3}
    with pytest.raises(ValueError):
        merge_pool_stats([])
    with pytest.raises(ValueError):
        merge_pool_stats([a, {"page_size": 16, "alloc": 1, "in_use": 0}])


def _drive_runner(name, min_checks):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", name)],
        env=env, capture_output=True, text=True, timeout=1500)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, f"{name} failed"
    checks = [json.loads(l[6:]) for l in proc.stdout.splitlines()
              if l.startswith("CHECK ")]
    assert len(checks) >= min_checks
    assert all(c["ok"] for c in checks), [c for c in checks if not c["ok"]]


@pytest.mark.distributed
def test_serve_tp_stack():
    """Sharded serving == single-device serving, token for token (bf16
    eager mixed-method bank, int8 quantized, paged KV), on 8 fake CPU
    devices."""
    _drive_runner("serve_distributed_runner.py", min_checks=6)
