"""Drives tests/distributed_runner.py in a subprocess (it needs its own
XLA_FLAGS device-count before jax initializes)."""
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
@pytest.mark.distributed
def test_distributed_stack():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "distributed_runner.py")],
        env=env, capture_output=True, text=True, timeout=1500)
    sys.stdout.write(proc.stdout[-4000:])
    sys.stderr.write(proc.stderr[-4000:])
    assert proc.returncode == 0, "distributed checks failed"
    checks = [json.loads(l[6:]) for l in proc.stdout.splitlines()
              if l.startswith("CHECK ")]
    assert len(checks) >= 10
    assert all(c["ok"] for c in checks), [c for c in checks if not c["ok"]]
