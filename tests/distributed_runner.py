"""Runs under XLA_FLAGS=--xla_force_host_platform_device_count=8 in a
subprocess (tests/test_sharding.py drives it). Exercises the REAL
distribution stack — sharded params, GSPMD train step, decode step, elastic
checkpoint reshard — on smoke configs with actual execution (not just
compile), then prints one JSON line per check."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import json
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.checkpoint import CheckpointManager
from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.data.synthetic import lm_batch
from repro.launch.mesh import make_mesh
from repro.models import api
from repro.sharding.specs import ShardingRules, named
from repro.train.steps import TrainStepConfig, build_decode_step, build_train_step

OUT = []


def check(name, ok, **kw):
    OUT.append({"name": name, "ok": bool(ok), **kw})


def train_cell(arch, mesh, mesh_name):
    cfg = get_smoke_config(arch)
    rules = ShardingRules(cfg, mesh)
    key = jax.random.PRNGKey(0)
    params = api.init_params(cfg, key)
    peft_cfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = peft_lib.init_peft(peft_cfg, params, key)
    ocfg = optim.OptimizerConfig(learning_rate=1e-3)
    opt_state = optim.init(ocfg, adapters)
    batch = lm_batch(cfg, batch=8, seq=16)

    p_sh = named(mesh, rules.params_tree(params))
    a_sh = named(mesh, rules.adapters_tree(adapters))
    o_sh = {"mu": a_sh, "nu": a_sh,
            "step": named(mesh, jax.sharding.PartitionSpec())}
    b_sh = named(mesh, rules.batch_spec(batch, 8))

    params = jax.device_put(params, p_sh)
    adapters = jax.device_put(adapters, a_sh)
    opt_state = jax.device_put(opt_state, o_sh)
    batch = jax.device_put(batch, b_sh)

    tcfg = TrainStepConfig(peft=peft_cfg, opt=ocfg, num_microbatches=2)
    step = jax.jit(build_train_step(cfg, tcfg, mesh),
                   in_shardings=(p_sh, a_sh, o_sh, b_sh),
                   out_shardings=(a_sh, o_sh, None))

    # reference: identical math on a single device, no mesh
    ref_step = build_train_step(cfg, tcfg, mesh=None)
    ra, ro = jax.device_get(adapters), jax.device_get(opt_state)
    rp = jax.device_get(params)
    rb = jax.device_get(batch)

    losses = []
    for i in range(3):
        adapters, opt_state, m = step(params, adapters, opt_state, batch)
        losses.append(float(m["loss"]))
    ra2, ro2 = ra, ro
    ref_losses = []
    for i in range(3):
        ra2, ro2, rm = ref_step(rp, ra2, ro2, rb)
        ref_losses.append(float(rm["loss"]))

    agree = np.allclose(losses, ref_losses, rtol=2e-3, atol=2e-3)
    check(f"train/{arch}/{mesh_name}", np.isfinite(losses).all() and agree,
          losses=losses, ref_losses=ref_losses)

    # adapter grads actually moved params
    moved = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(jax.device_get(adapters)),
                    jax.tree.leaves(ra)))
    check(f"train/{arch}/{mesh_name}/adapters_updated", moved > 0)


def decode_cell(arch, mesh, mesh_name):
    cfg = get_smoke_config(arch)
    rules = ShardingRules(cfg, mesh)
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    state = api.init_decode_state(cfg, 8, 32, enc_len=8)
    if cfg.family == "encdec":
        state["enc_out"] = jnp.zeros((8, 8, cfg.d_model), cfg.act_dtype)
    p_sh = named(mesh, rules.params_tree(params))
    s_sh = named(mesh, rules.decode_state_spec(state, 8))
    params = jax.device_put(params, p_sh)
    state = jax.device_put(state, s_sh)
    tokens = jax.device_put(
        jnp.ones((8, 1), jnp.int32),
        named(mesh, rules.batch_spec(jnp.ones((8, 1), jnp.int32), 8)))
    step = jax.jit(build_decode_step(cfg, mesh),
                   donate_argnums=(3,))
    ref = build_decode_step(cfg, mesh=None)
    _, rl, _ = ref(jax.device_get(params), None, jax.device_get(tokens),
                   jax.device_get(state), jnp.asarray(0, jnp.int32))
    nt, logits, state = step(params, None, tokens, state,
                             jnp.asarray(0, jnp.int32))
    agree = np.allclose(np.asarray(jax.device_get(logits), np.float32),
                        np.asarray(jax.device_get(rl), np.float32),
                        atol=5e-2, rtol=5e-2)
    check(f"decode/{arch}/{mesh_name}",
          np.isfinite(np.asarray(logits, np.float32)).all() and agree)


def elastic_checkpoint():
    """Save on a (4,2) mesh, restore re-sharded onto (2,2) sub-mesh."""
    cfg = get_smoke_config("qwen2-72b")
    mesh_a = make_mesh(4, 2)
    rules_a = ShardingRules(cfg, mesh_a)
    params = api.init_params(cfg, jax.random.PRNGKey(1))
    params = jax.device_put(params, named(mesh_a, rules_a.params_tree(params)))
    with tempfile.TemporaryDirectory() as d:
        mgr = CheckpointManager(d)
        mgr.save(3, params)
        mesh_b = make_mesh(2, 2)
        rules_b = ShardingRules(cfg, mesh_b)
        restored = mgr.restore(
            jax.device_get(params),
            sharding_tree=named(mesh_b, rules_b.params_tree(params)))
        same = all(np.allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32))
                   for a, b in zip(jax.tree.leaves(jax.device_get(params)),
                                   jax.tree.leaves(jax.device_get(restored))))
        ndev = {d0.id for l in jax.tree.leaves(restored)
                for d0 in l.sharding.device_set}
        check("elastic_checkpoint", same and len(ndev) == 4)


def grad_compression(mesh):
    from repro.optim import compressed_psum_mean, init_error_buffer
    g = {"w": jnp.ones((16, 16)) * 0.5}
    err = init_error_buffer(g)
    red, err2 = compressed_psum_mean(g, err, mesh, ("data",))
    ok = np.allclose(np.asarray(red["w"]), 0.5, atol=1e-2)
    check("grad_compression_psum", ok)


def main():
    archs = ["qwen2-72b", "qwen3-moe-30b-a3b", "zamba2-2.7b", "mamba2-130m",
             "seamless-m4t-medium", "pixtral-12b"]
    mesh = make_mesh(4, 2)
    for arch in archs:
        train_cell(arch, mesh, "4x2")
    for arch in ["qwen2-72b", "zamba2-2.7b", "mamba2-130m",
                 "seamless-m4t-medium"]:
        decode_cell(arch, mesh, "4x2")
    # multi-pod style 3-axis mesh
    mesh3 = make_mesh(2, 2, pods=2)
    train_cell("qwen2-72b", mesh3, "2x2x2")
    elastic_checkpoint()
    grad_compression(mesh)
    for rec in OUT:
        print("CHECK " + json.dumps(rec))
    bad = [r for r in OUT if not r["ok"]]
    print(f"RESULT {len(OUT) - len(bad)}/{len(OUT)} ok")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
