"""Quantized serving subsystem (repro.quant + kernels/q_matmul):
round-trip error bounds, kernel-vs-reference numerics, quantized-runtime
decode equality, checkpoint round-trips, and the bf16-rotation invariant.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import quant
from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.peft import PrefillRequest
from repro.core.runtime import ModelRuntime
from repro.kernels import ops, ref
from repro.kernels.q_matmul import gs_q_matmul_pallas, q_matmul_pallas
from repro.serve.engine import ServeEngine, StaticServeEngine
from repro.train.steps import build_decode_step

CFG = get_smoke_config("qwen2-72b")
RT = ModelRuntime(CFG, key=jax.random.PRNGKey(0))
PCFG = peft_lib.PEFTConfig(method="gsoft", block_size=8)


def _tuned_adapters(seed, scale=0.3):
    ad = peft_lib.init_peft(PCFG, RT.params, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.PRNGKey(seed + 50), a.shape), ad)


# ---------------------------------------------------------------------------
# core: quantize/dequantize round trips
# ---------------------------------------------------------------------------

def test_int8_roundtrip_error_bound(rng):
    """|dequant(quant(w)) - w| <= scale/2 elementwise, per granularity."""
    w = jnp.asarray(rng.normal(size=(64, 48)) * rng.uniform(0.1, 10),
                    jnp.float32)
    for axis in (None, -1, 0):
        q, s = quant.quantize_int8(w, axis=axis)
        err = np.abs(np.asarray(quant.dequantize_int8(q, s)) - np.asarray(w))
        bound = np.broadcast_to(np.asarray(s) / 2 + 1e-7, err.shape)
        assert (err <= bound).all(), axis


def test_per_channel_beats_per_tensor_on_ragged_scales(rng):
    """Columns with wildly different magnitudes are the case per-channel
    scales exist for: per-tensor burns the int8 range on the big column."""
    w = jnp.asarray(rng.normal(size=(32, 8))
                    * (10.0 ** np.arange(-4, 4))[None, :], jnp.float32)
    qt, st = quant.quantize_int8(w, axis=None)
    qc, sc = quant.quantize_int8(w, axis=-1)
    err_t = np.abs(np.asarray(quant.dequantize_int8(qt, st) - w)).max(axis=0)
    err_c = np.abs(np.asarray(quant.dequantize_int8(qc, sc) - w)).max(axis=0)
    # every small-magnitude column must round-trip (much) better
    assert (err_c[:6] < err_t[:6]).all()


def test_stacked_weights_get_per_layer_scales():
    """(L, K, N) stacked weights: scales keep the layer dim (scan-sliced
    alongside the codes) and each layer quantizes independently."""
    w = jnp.stack([jnp.ones((4, 6)) * 0.01, jnp.ones((4, 6)) * 100.0])
    q, s = quant.quantize_int8(w, axis=-1, batch_dims=1)
    assert s.shape == (2, 1, 6)
    np.testing.assert_allclose(
        np.asarray(quant.dequantize_int8(q, s)), np.asarray(w), rtol=1e-2)


def test_compression_reexport_is_the_same_function():
    """optim.compression re-exports quant.core — one implementation."""
    from repro.optim import compression
    assert compression.quantize_int8 is quant.quantize_int8
    assert compression.dequantize_int8 is quant.dequantize_int8


def test_error_feedback_still_converges_after_refactor(rng):
    """ef_compress semantics unchanged: accumulated error stays bounded."""
    from repro.optim.compression import ef_compress, init_error_buffer
    g = {"w": jnp.asarray(rng.normal(size=(16, 16)), jnp.float32)}
    err = init_error_buffer(g)
    for _ in range(4):
        q, s, err = ef_compress(g, err)
    assert np.abs(np.asarray(err["w"])).max() < np.abs(np.asarray(g["w"])).max()


def test_fp8_stub_gated_on_dtype_support(rng):
    w = jnp.asarray(rng.normal(size=(16, 8)), jnp.float32)
    if quant.fp8_supported():
        q, s = quant.quantize_fp8(w, axis=-1)
        assert q.dtype == jnp.float8_e4m3fn
        err = np.abs(np.asarray(quant.dequantize_fp8(q, s)) - np.asarray(w))
        assert err.max() < 0.1 * np.abs(np.asarray(w)).max()
    else:
        with pytest.raises(NotImplementedError, match="fp8"):
            quant.quantize_fp8(w, axis=-1)


# ---------------------------------------------------------------------------
# kernels: q_matmul / gs_q_matmul vs reference
# ---------------------------------------------------------------------------

QMM_SHAPES = [
    # (T, K, N)
    (16, 32, 64),
    (128, 64, 128),
    (33, 48, 96),        # ragged T (padding path)
    (1, 64, 64),         # decode-shaped: single token
    (250, 24, 40),       # N not a multiple of the default tile
]


@pytest.mark.parametrize("t,k,n", QMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_q_matmul_kernel_vs_ref(t, k, n, dtype):
    kx, kw = jax.random.split(jax.random.PRNGKey(t * 7 + n))
    x = jax.random.normal(kx, (t, k), dtype)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    q, scale = quant.quantize_int8(w, axis=-1)
    got = q_matmul_pallas(x, q, scale, interpret=True)
    want = ref.q_matmul_ref(x, q, scale)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


@pytest.mark.parametrize("r,b,t,n", [(4, 8, 16, 64), (8, 4, 33, 48),
                                     (2, 16, 1, 32)])
def test_gs_q_matmul_fused_kernel_vs_ref(r, b, t, n):
    d = r * b
    ks = jax.random.split(jax.random.PRNGKey(r * b + n), 4)
    L = jax.random.normal(ks[0], (r, b, b), jnp.float32)
    R = jax.random.normal(ks[1], (r, b, b), jnp.float32)
    x = jax.random.normal(ks[2], (t, d), jnp.float32)
    w = jax.random.normal(ks[3], (d, n), jnp.float32)
    q, scale = quant.quantize_int8(w, axis=-1)
    got = gs_q_matmul_pallas(L, R, x, q, scale, interpret=True)
    want = ref.gs_q_matmul_ref(L, R, x, q, scale)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


def test_q_matmul_hypothesis_shapes():
    pytest.importorskip("hypothesis", reason="property sweep needs "
                        "hypothesis (pip install -e '.[dev]')")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 70), st.integers(1, 40), st.integers(1, 50),
           st.integers(0, 10 ** 6))
    def check(t, k, n, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=(t, k)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(k, n)), jnp.float32)
        q, scale = quant.quantize_int8(w, axis=-1)
        got = q_matmul_pallas(x, q, scale, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.q_matmul_ref(x, q, scale)),
                                   atol=1e-4, rtol=1e-4)

    check()


def test_ops_dispatch_handles_leading_dims(rng):
    x = jnp.asarray(rng.normal(size=(2, 3, 32)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(32, 16)), jnp.float32)
    q, scale = quant.quantize_int8(w, axis=-1)
    got = ops.q_matmul(x, q, scale, use_pallas=True)
    assert got.shape == (2, 3, 16)
    np.testing.assert_allclose(
        np.asarray(got),
        np.asarray(ref.q_matmul_ref(x.reshape(6, 32), q,
                                    scale)).reshape(2, 3, 16),
        atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# quantized runtime: decode equality / divergence bounds
# ---------------------------------------------------------------------------

def test_quantize_params_targets_only_hooked_projections():
    qp = quant.quantize_params(RT.params, quant.QuantConfig())
    flat = peft_lib.flatten_paths(qp, is_leaf=quant.is_quant_tensor)
    quantized = {p for p, l in flat.items() if quant.is_quant_tensor(l)}
    assert any(p.endswith("attn/wq") for p in quantized)
    assert any(p.endswith("mlp/wi") for p in quantized)
    assert "lm_head/w" in quantized
    # embeddings / norms stay float
    assert "embed/table" not in quantized
    assert not any("norm" in p for p in quantized)
    assert quant.tree_bytes(qp) < 0.5 * quant.tree_bytes(RT.params)


def test_quantized_greedy_rollout_divergence_bounded():
    """64-token greedy rollout: the int8 runtime must track the bf16
    reference. The divergence point is REPORTED via the assertion message
    and bounded: at least the first 16 tokens must match, and overall
    agreement must be >= 75% (on the smoke config it is exact today)."""
    qrt = RT.quantized("int8")
    outs = []
    for rt in (RT, qrt):
        eng = ServeEngine(rt, max_batch=1, max_len=96, eos_id=-1)
        eng.add_request([3, 4, 5, 6], max_new_tokens=64)
        outs.append(eng.run()[0])
    ref_toks, q_toks = outs
    first_div = next((i for i, (a, b) in enumerate(zip(ref_toks, q_toks))
                      if a != b), 64)
    agree = sum(a == b for a, b in zip(ref_toks, q_toks))
    assert first_div >= 16, (first_div, agree)
    assert agree >= 48, (first_div, agree)


def test_quantized_static_engine_matches_continuous():
    """Both engines serve the same quantized runtime identically."""
    qrt = RT.quantized("int8")
    outs = []
    for cls in (ServeEngine, StaticServeEngine):
        eng = cls(qrt, max_batch=2, max_len=48, eos_id=-1)
        rid = eng.add_request([5, 6, 7, 8], max_new_tokens=6)
        outs.append(eng.run()[rid])
    assert outs[0] == outs[1]


def test_quantized_runtime_guards():
    qrt = RT.quantized("int8")
    with pytest.raises(ValueError, match="already quantized"):
        qrt.quantized("int8")
    with pytest.raises(ValueError, match="already-quantized"):
        ModelRuntime(CFG, qrt.params, adapters=_tuned_adapters(3),
                     peft_cfg=PCFG)
    with pytest.raises(ValueError, match="unknown quantization mode"):
        RT.quantized("int4")
    # mode vs explicit qcfg must agree (silent override would serve the
    # wrong precision)
    with pytest.raises(ValueError, match="conflicts"):
        RT.quantized("fp8", qcfg=quant.QuantConfig(mode="int8"))


def test_with_bank_preserves_quantized_state():
    """Regression: quantize-then-bank must keep quant_cfg — a banked
    quantized runtime re-quantizing or checkpointing without it breaks."""
    qrt = RT.quantized("int8").attach({"a": _tuned_adapters(3)}, PCFG)
    assert qrt.is_quantized and qrt.quant_cfg.mode == "int8"
    with pytest.raises(ValueError, match="already quantized"):
        qrt.quantized("int8")


# ---------------------------------------------------------------------------
# multi-adapter bank over a quantized runtime
# ---------------------------------------------------------------------------

def test_adapter_bank_rotations_are_not_quantized():
    """Regression: quantization must never touch the GS rotations — the
    bank carries bf16/fp32 orthogonal blocks however the runtime's base
    weights are stored (QOFT rationale, DESIGN.md)."""
    qrt = RT.attach({"a": _tuned_adapters(3)}, PCFG).quantized("int8")
    assert quant.is_quantized_tree(qrt.params)
    bank_leaves = jax.tree_util.tree_leaves(
        qrt.bank.tree, is_leaf=quant.is_quant_tensor)
    assert bank_leaves, "bank unexpectedly empty"
    for leaf in bank_leaves:
        assert not quant.is_quant_tensor(leaf)
        assert jnp.issubdtype(leaf.dtype, jnp.floating)


def test_bank_vs_merged_equality_in_quantized_mode():
    """Acceptance: per-request rotation over int8 base weights == the
    adapter merged offline then quantized, within fp32-logit tolerance
    (both sides carry independent int8 rounding of W vs QW — measured
    max diff ~0.05 on logits with std ~1.0)."""
    adapters = {"a": _tuned_adapters(3)}
    qrt_bank = RT.attach(adapters, PCFG).quantized("int8")
    merged = ModelRuntime(CFG, RT.params, adapters=adapters["a"],
                          peft_cfg=PCFG).quantized("int8")
    tokens = jnp.asarray([[5], [9]], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    step = build_decode_step(CFG)
    _, logits_bank, _ = step(qrt_bank.params, qrt_bank.bank.context([1, 1]),
                             tokens, qrt_bank.init_decode_state(2, 16), pos)
    _, logits_merged, _ = step(merged.params, None, tokens,
                               merged.init_decode_state(2, 16), pos)
    np.testing.assert_allclose(np.asarray(logits_bank, np.float32),
                               np.asarray(logits_merged, np.float32),
                               atol=0.15)


def test_quantized_multi_adapter_serving_end_to_end():
    """ServeEngine over a quantized banked runtime: per-request adapters
    produce distinct outputs; identity slot == bare quantized model; the
    bank built before or after quantization serves identically."""
    adapters = {"alice": _tuned_adapters(7), "bob": _tuned_adapters(11)}
    qrt = RT.attach(adapters, PCFG).quantized("int8")
    prompt = [3, 4, 5, 6]
    eng = ServeEngine(qrt, max_batch=3, max_len=48, eos_id=-1)
    rids = {name: eng.add_request(prompt, max_new_tokens=5, adapter=name)
            for name in ("alice", "bob", None)}
    results = eng.run()
    assert results[rids["alice"]] != results[rids["bob"]]
    plain = ServeEngine(RT.quantized("int8"), max_batch=1, max_len=48,
                        eos_id=-1)
    rid = plain.add_request(prompt, max_new_tokens=5)
    assert results[rids[None]] == plain.run()[rid]
    # quantize-then-bank == bank-then-quantize
    qrt2 = RT.quantized("int8").attach(adapters, PCFG)
    eng2 = ServeEngine(qrt2, max_batch=1, max_len=48, eos_id=-1)
    rid2 = eng2.add_request(prompt, max_new_tokens=5, adapter="alice")
    assert eng2.run()[rid2] == results[rids["alice"]]


def test_quantized_banked_pallas_fused_matches_ref_path():
    """The fused gs_q_matmul kernel path (use_pallas on both the bank and
    the quantization) serves the same tokens as the reference einsums."""
    adapters = {"a": _tuned_adapters(3)}
    pcfg_k = peft_lib.PEFTConfig(method="gsoft", block_size=8,
                                 use_pallas=True)
    qcfg_k = quant.QuantConfig(mode="int8", use_pallas=True)
    qrt_k = RT.attach(adapters, pcfg_k).quantized(qcfg=qcfg_k)
    qrt_ref = RT.attach(adapters, PCFG).quantized("int8")
    outs = []
    for rt in (qrt_k, qrt_ref):
        eng = ServeEngine(rt, max_batch=2, max_len=48, eos_id=-1)
        rid = eng.add_request([3, 4, 5, 6], max_new_tokens=4, adapter="a")
        outs.append(eng.run()[rid])
    assert outs[0] == outs[1]


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_quantized_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    qrt = RT.quantized("int8")
    CheckpointManager(str(tmp_path)).save_quantized(1, qrt.params,
                                                    qrt.quant_cfg)
    rt2 = ModelRuntime.load_quantized(str(tmp_path), CFG)
    assert rt2.quant_cfg == qrt.quant_cfg
    for a, b in zip(jax.tree_util.tree_leaves(qrt.params),
                    jax.tree_util.tree_leaves(rt2.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bf16_checkpoint_quantizes_on_load(tmp_path):
    """A plain float checkpoint loads through the same entry point and is
    quantized on the way in — identical to quantizing offline."""
    from repro.checkpoint.manager import CheckpointManager
    CheckpointManager(str(tmp_path)).save(1, RT.params)
    rt2 = ModelRuntime.load_quantized(str(tmp_path), CFG)
    offline = RT.quantized("int8")
    for a, b in zip(jax.tree_util.tree_leaves(rt2.params),
                    jax.tree_util.tree_leaves(offline.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_quantized_checkpoint_mode_conflict_raises(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    qrt = RT.quantized("int8")
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_quantized(1, qrt.params, qrt.quant_cfg)
    with pytest.raises(ValueError, match="conflicts"):
        mgr.restore_quantized(
            jax.eval_shape(lambda k: RT.params, 0),
            qcfg=quant.QuantConfig(mode="int8", per_channel=False))


def test_checkpoint_use_pallas_is_loader_choice(tmp_path):
    """use_pallas is execution strategy, not data layout: a checkpoint
    saved on one backend restores under the loader's kernel choice (same
    codes/scales) instead of erroring or silently downgrading."""
    from repro.checkpoint.manager import CheckpointManager
    qrt = RT.quantized("int8")           # saved with use_pallas=False
    mgr = CheckpointManager(str(tmp_path))
    mgr.save_quantized(1, qrt.params, qrt.quant_cfg)
    base = jax.eval_shape(lambda k: RT.params, 0)
    tree, used = mgr.restore_quantized(
        base, qcfg=quant.QuantConfig(mode="int8", use_pallas=True))
    assert used.use_pallas
    # and via the runtime facade, a config with use_pallas=True flows in
    rt2 = ModelRuntime.load_quantized(
        str(tmp_path), CFG.with_overrides(use_pallas=True))
    assert rt2.quant_cfg.use_pallas
    leaves = [l for l in jax.tree_util.tree_leaves(
        rt2.params, is_leaf=quant.is_quant_tensor)
        if quant.is_quant_tensor(l)]
    assert leaves and all(l.meta.use_pallas for l in leaves)
    # float checkpoints inherit the loading model config's kernel path too
    mgr2 = CheckpointManager(str(tmp_path / "f"))
    mgr2.save(1, RT.params)
    rt3 = ModelRuntime.load_quantized(
        str(tmp_path / "f"), CFG.with_overrides(use_pallas=True))
    assert rt3.quant_cfg.use_pallas


# ---------------------------------------------------------------------------
# hygiene: one quantization implementation
# ---------------------------------------------------------------------------

def test_no_direct_compression_quantize_imports():
    """Mirrors the CI grep: quantize_int8 lives in repro.quant.core; only
    optim/compression.py (the re-export) may import it from there."""
    res = subprocess.run(
        ["grep", "-rn", "--include=*.py",
         r"from repro\.optim\.compression import", "src/repro",
         "benchmarks", "examples"],
        capture_output=True, text=True, cwd=str(_repo_root()))
    offenders = [ln for ln in res.stdout.splitlines() if "quantize" in ln]
    assert not offenders, offenders


def _repo_root():
    import pathlib
    return pathlib.Path(__file__).resolve().parents[1]


def test_cli_exposes_quantize_flag():
    """launch/serve.py --quantize is wired (smoke: help text)."""
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--help"],
        capture_output=True, text=True, cwd=str(_repo_root()),
        env={**__import__("os").environ,
             "PYTHONPATH": str(_repo_root() / "src")})
    assert "--quantize" in res.stdout
