"""Observability plane (ISSUE 10): typed-instrument registry semantics,
per-request span lifecycle on real engine traffic, stall attribution,
export formats, SLO thresholds/backpressure wiring, the bounded
``page_in_ms`` histogram (regression for the unbounded-list leak), and
the bench-regression gate's comparison logic."""
import io
import json

import pytest

jax = pytest.importorskip("jax")
np = pytest.importorskip("numpy")

from repro.config import get_smoke_config               # noqa: E402
from repro.core import peft as peft_lib                 # noqa: E402
from repro.core.runtime import ModelRuntime             # noqa: E402
from repro.launch.serve import make_demo_adapters       # noqa: E402
from repro.obs import (                                 # noqa: E402
    Counter, Gauge, Histogram, MetricsRegistry, RequestTrace, SLOMonitor,
    TraceRecorder)
from repro.serve.engine import ServeEngine              # noqa: E402
from repro.store import AdapterStore                    # noqa: E402

from benchmarks import check_regress                    # noqa: E402


@pytest.fixture(scope="module")
def rt():
    return ModelRuntime(get_smoke_config("qwen2-72b"),
                        key=jax.random.PRNGKey(0))


def _tenant_store(rt, n_ad, method="gsoft"):
    bank_peft = {f"a{i}": peft_lib.PEFTConfig(method=method, block_size=8)
                 for i in range(n_ad)}
    adapters = make_demo_adapters(list(bank_peft), rt.params, bank_peft)
    return AdapterStore.from_adapters(adapters, bank_peft), bank_peft


# -- registry / instrument semantics ------------------------------------------

def test_instrument_semantics():
    c = Counter("c")
    c.inc()
    c.inc(3)
    assert c.value == 4
    g = Gauge("g")
    g.set(7)
    g.set_max(3)            # lower: ignored
    g.set_max(11)
    assert g.value == 11
    h = Histogram("h", cap=8)
    for v in range(100):
        h.observe(float(v))
    assert h.count == 100 and len(h) == 8      # bounded reservoir
    assert h.sum == sum(range(100))
    # percentiles come from the RECENT window (last 8 samples: 92..99)
    assert h.percentile(0) >= 92.0
    assert h.percentiles()["p50"] >= 92.0


def test_registry_idempotent_and_kind_collision():
    r = MetricsRegistry()
    assert r.counter("x") is r.counter("x")
    with pytest.raises(TypeError):
        r.gauge("x")
    with pytest.raises(TypeError):
        r.histogram("x")


def test_scope_uniquify_isolates_replicas():
    r = MetricsRegistry()
    s0, s1 = r.scope("kvpool"), r.scope("kvpool")
    assert s0.prefix == "kvpool" and s1.prefix == "kvpool:1"
    c0 = s0.counters("alloc", "freed")
    c1 = s1.counters("alloc", "freed")
    c0["alloc"].inc(5)
    assert c1["alloc"].value == 0              # replicas never share
    assert r.get("kvpool/alloc").value == 5
    assert r.get("kvpool:1/alloc").value == 0


def test_snapshot_expands_histograms():
    r = MetricsRegistry()
    s = r.scope("bank")
    s.counter("hits").inc(2)
    h = s.histogram("page_in_ms", cap=16)
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    snap = r.snapshot()
    assert snap["bank/hits"] == 2
    assert snap["bank/page_in_ms.count"] == 3
    assert snap["bank/page_in_ms.mean"] == pytest.approx(2.0)
    assert "bank/page_in_ms.p95" in snap
    assert r.snapshot(prefix="nope") == {}
    r.reset()
    assert r.names() == []


# -- bounded page_in_ms (the leak regression) ---------------------------------

def test_page_in_histogram_bounded_under_thrash(rt, monkeypatch):
    """Regression: ``page_in_ms`` used to be an append-forever list; under
    LRU thrash past the cap the reservoir must stop growing while the
    streaming count keeps the true total."""
    from repro.store import paging
    monkeypatch.setattr(paging, "PAGE_IN_HIST_CAP", 4)
    store, _ = _tenant_store(rt, n_ad=6)
    bank = rt.attach(store, hbm_budget=3).bank
    for i in range(12):                        # cyclic over 6 tenants, cap 3
        name = f"a{i % 6}"
        assert bank.acquire(name) is not None
        bank.release(name)
    hist = bank._page_in_ms
    assert hist.count > 4, "expected >cap page-ins from LRU thrash"
    assert len(hist) <= 4, "page_in_ms reservoir exceeded its cap"
    st = bank.stats()
    assert st["page_in_ms_p95"] >= st["page_in_ms_p50"] >= 0.0


# -- span lifecycle on real traffic -------------------------------------------

@pytest.fixture(scope="module")
def traced_run(rt):
    """One continuous-batching run over ragged traffic with a recorder +
    SLO monitor attached; shared by the lifecycle/export/SLO tests."""
    slo = SLOMonitor(window=64)
    reg = MetricsRegistry()
    tracer = TraceRecorder(slo=slo, registry=reg)
    eng = ServeEngine(rt, max_batch=2, max_len=32, eos_id=-1, tracer=tracer)
    rng = np.random.default_rng(0)
    n_req = 8
    for _ in range(n_req):
        prompt = [int(t) for t in rng.integers(0, 100,
                                               size=int(rng.integers(4, 12)))]
        eng.add_request(prompt, max_new_tokens=int(rng.integers(2, 8)))
    out = eng.run()
    return tracer, slo, reg, out, n_req


def test_span_lifecycle_complete_on_ragged_traffic(traced_run):
    tracer, slo, reg, out, n_req = traced_run
    assert len(tracer.finished) == n_req
    assert tracer.pending_count == 0
    for tr in tracer.finished:
        assert tr.complete, f"rid {tr.rid} missing lifecycle events"
        # prefill happens after submit, first token after prefill: TTFT
        # must cover at least the prefill span(s)
        assert tr.ttft_s >= tr.prefill_s > 0.0
        assert tr.t_submit <= tr.t_first <= tr.t_finish
        assert tr.n_tokens == len(out[tr.rid])
        assert all(g >= 0.0 for g in tr.tpot_s)
    snap = reg.snapshot(prefix="trace/")
    assert snap["trace/submitted"] == snap["trace/finished"] == n_req
    assert snap["trace/tokens"] == sum(len(v) for v in out.values())


def test_slo_report_from_real_run(traced_run):
    _, slo, _, out, n_req = traced_run
    rep = slo.report()
    assert rep["window_requests"] == rep["total_requests"] == n_req
    assert rep["ttft_ms"]["p95"] >= rep["ttft_ms"]["p50"] > 0.0
    assert rep["tpot_ms"]["p50"] > 0.0
    assert rep["tok_s"] > 0.0
    text = SLOMonitor.format_report(rep)
    assert "ttft_ms" in text and "tok/s" in text


def test_export_formats(traced_run):
    tracer, _, _, out, n_req = traced_run
    buf = io.StringIO()
    n_lines = tracer.export_jsonl(buf)
    events = [json.loads(line) for line in buf.getvalue().splitlines()]
    assert len(events) == n_lines > 0
    by_kind = {}
    for ev in events:
        by_kind.setdefault(ev["event"], set()).add(ev["rid"])
    rids = {tr.rid for tr in tracer.finished}
    for kind in ("submit", "prefill", "first_token", "finish"):
        assert by_kind[kind] == rids, f"{kind} events missing for some rids"

    buf = io.StringIO()
    n_ev = tracer.export_chrome(buf)
    doc = json.loads(buf.getvalue())
    assert len(doc["traceEvents"]) == n_ev
    phases = {ev["ph"] for ev in doc["traceEvents"]}
    assert phases <= {"M", "X", "i"}
    for ev in doc["traceEvents"]:
        if "ts" in ev:
            assert ev["ts"] >= 0.0                  # relative to first submit
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0


# -- stall attribution --------------------------------------------------------

def test_adapter_stall_attribution(rt):
    """More concurrent tenants than the paged bank admits: the engine must
    record ``adapter`` stalls on the queue head (and nothing spurious)."""
    store, bank_peft = _tenant_store(rt, n_ad=4)
    reg = MetricsRegistry()
    tracer = TraceRecorder(registry=reg)
    eng = ServeEngine(rt.attach(store, hbm_budget=3), max_batch=4,
                      max_len=32, eos_id=-1, tracer=tracer)
    for i in range(8):
        eng.add_request([1, 2, 3, 4], max_new_tokens=4,
                        adapter=f"a{i % 4}")
    eng.run()
    assert eng.stats["admission_stalls"] > 0, "workload failed to stall"
    snap = reg.snapshot(prefix="trace/")
    assert snap["trace/stalls_adapter"] == eng.stats["admission_stalls"]
    stalled = [tr for tr in tracer.finished if tr.stalls.get("adapter")]
    assert stalled, "no finished trace carries the adapter stall"
    assert all(set(tr.stalls) <= {"adapter", "queue", "kv"}
               for tr in tracer.finished)


# -- SLO thresholds + backpressure --------------------------------------------

def _fake_trace(rid, ttft_s, t0=0.0):
    return RequestTrace(engine="e0", rid=rid, t_submit=t0,
                        t_first=t0 + ttft_s, t_finish=t0 + ttft_s + 0.01,
                        prefill_spans=[(t0, t0 + ttft_s / 2)],
                        token_times=[t0 + ttft_s, t0 + ttft_s + 0.01])


def test_slo_threshold_transitions_fire_once():
    slo = SLOMonitor(window=4, thresholds={"ttft_ms.p95": 50.0})
    fired = {"breach": 0, "clear": 0}
    slo.on_breach(lambda m, v, lim: fired.__setitem__(
        "breach", fired["breach"] + 1))
    slo.on_clear(lambda m, v, lim: fired.__setitem__(
        "clear", fired["clear"] + 1))
    rid = 0
    for _ in range(3):                          # healthy: 10ms TTFT
        slo.observe(_fake_trace(rid, 0.010)); rid += 1
    assert fired == {"breach": 0, "clear": 0} and not slo.any_breached
    for _ in range(4):                          # saturate window with 100ms
        slo.observe(_fake_trace(rid, 0.100)); rid += 1
    assert slo.any_breached and slo.report()["breached"] == ["ttft_ms.p95"]
    assert fired["breach"] == 1, "breach callback must fire on transition only"
    for _ in range(4):                          # recover: flush the window
        slo.observe(_fake_trace(rid, 0.010)); rid += 1
    assert not slo.any_breached
    assert fired == {"breach": 1, "clear": 1}


def test_cluster_backpressure_wiring(rt):
    from repro.distrib import EngineCluster
    slo = SLOMonitor(window=4, thresholds={"ttft_ms.p95": 50.0})
    cl = EngineCluster([ServeEngine(rt, max_batch=2, max_len=32, eos_id=-1)],
                       slo=slo)
    assert cl.accepting
    for rid in range(4):
        slo.observe(_fake_trace(rid, 0.100))
    assert not cl.accepting, "SLO breach must stop admission"
    for rid in range(4, 8):
        slo.observe(_fake_trace(rid, 0.010))
    assert cl.accepting, "clearing the breach must re-admit"
    assert cl.cluster_stats()["slo"]["total_requests"] == 8


# -- bench-regression gate ----------------------------------------------------

def _write_suite(root, suite, latest, prior):
    """BENCH file shaped like common.write_summary: history = prior runs
    plus a ts-stamped mirror of latest."""
    history = [dict(e, ts=f"2026-01-0{i + 1}T00:00:00+00:00")
               for i, e in enumerate(prior)]
    history.append(dict(latest, ts="2026-02-01T00:00:00+00:00"))
    (root / f"BENCH_{suite}.json").write_text(
        json.dumps({"latest": latest, "history": history}))


def test_check_regress_passes_and_fails(tmp_path, capsys):
    base = {"a_tok_s": 100.0, "b_tok_s": 200.0, "x_speedup": 2.0,
            "tokens_equal": True}
    _write_suite(tmp_path, "ok", dict(base), [dict(base)] * 3)
    assert check_regress.main(["--root", str(tmp_path)]) == 0

    # one absolute key collapses while its sibling holds -> fail
    bad = dict(base, a_tok_s=40.0)
    _write_suite(tmp_path, "ok", bad, [dict(base)] * 3)
    assert check_regress.main(["--root", str(tmp_path)]) == 1
    assert "a_tok_s" in capsys.readouterr().out


def test_check_regress_normalizes_machine_speed(tmp_path):
    base = {"a_tok_s": 100.0, "b_tok_s": 200.0, "x_speedup": 2.0}
    # uniformly half as fast (slower CI box): normalized gate passes,
    # absolute comparison fails
    slow = {"a_tok_s": 50.0, "b_tok_s": 100.0, "x_speedup": 2.0}
    _write_suite(tmp_path, "m", slow, [dict(base)] * 3)
    assert check_regress.main(["--root", str(tmp_path)]) == 0
    assert check_regress.main(
        ["--root", str(tmp_path), "--no-normalize"]) == 1


def test_check_regress_ratio_keys_compared_raw(tmp_path):
    # machine got 2x faster but the speedup RATIO collapsed: the
    # dimensionless key must not be excused by the machine factor
    base = {"a_tok_s": 100.0, "b_tok_s": 200.0, "x_speedup": 2.0}
    fast_but_flat = {"a_tok_s": 200.0, "b_tok_s": 400.0, "x_speedup": 1.0}
    _write_suite(tmp_path, "r", fast_but_flat, [dict(base)] * 3)
    assert check_regress.main(["--root", str(tmp_path)]) == 1


def test_check_regress_equality_drift_fails(tmp_path):
    base = {"a_tok_s": 100.0, "tokens_equal": True}
    drifted = {"a_tok_s": 100.0, "tokens_equal": False}
    _write_suite(tmp_path, "eq", drifted, [dict(base)] * 3)
    assert check_regress.main(["--root", str(tmp_path)]) == 1


def test_check_regress_no_history_is_vacuous(tmp_path):
    latest = {"a_tok_s": 1.0}
    (tmp_path / "BENCH_new.json").write_text(json.dumps(
        {"latest": latest, "history": [dict(latest, ts="2026-01-01")]}))
    assert check_regress.main(["--root", str(tmp_path)]) == 0
