import os

# Tests run single-device on CPU. The 512-device override belongs ONLY to
# launch/dryrun.py (which sets XLA_FLAGS before importing jax itself).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
