"""Roofline machinery: trip-count-aware HLO walker + term math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo_cost import module_cost, normalize_cost_analysis
from repro.analysis.roofline import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline,
                                     advice, model_flops)


def test_walker_multiplies_scan_trip_counts():
    """XLA cost_analysis counts while bodies once; the walker must not."""
    M, TRIPS = 128, 7

    def f(x, w):
        def body(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(body, x, None, length=TRIPS)
        return y

    comp = jax.jit(f).lower(jnp.ones((M, M)), jnp.ones((M, M))).compile()
    xla_flops = normalize_cost_analysis(comp.cost_analysis()).get("flops", 0)
    walk = module_cost(comp.as_text())
    expect = 2 * M ** 3 * TRIPS
    assert abs(walk.flops - expect) / expect < 0.05
    assert xla_flops < walk.flops / 2      # documents the XLA undercount


def test_walker_counts_dot_contraction():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jnp.ones((64, 32)), jnp.ones((32, 16))).compile()
    walk = module_cost(comp.as_text())
    expect = 2 * 64 * 32 * 16
    assert abs(walk.flops - expect) / expect < 0.2


def test_walker_bytes_reasonable():
    comp = jax.jit(lambda a, b: a @ b).lower(
        jnp.ones((256, 256)), jnp.ones((256, 256))).compile()
    walk = module_cost(comp.as_text())
    io = 3 * 256 * 256 * 4
    assert io * 0.5 <= walk.bytes <= io * 4


def test_roofline_terms_and_dominant():
    r = Roofline(arch="a", shape="s", mesh="single", chips=256,
                 flops_per_device=197e12,          # exactly 1 s of compute
                 bytes_per_device=819e9 * 2,       # 2 s of HBM
                 coll_bytes_per_device=50e9 * 0.5, # 0.5 s of ICI
                 model_flops=197e12 * 256)
    assert np.isclose(r.compute_s, 1.0)
    assert np.isclose(r.memory_s, 2.0)
    assert np.isclose(r.collective_s, 0.5)
    assert r.dominant == "memory"
    assert np.isclose(r.step_time_s, 2.0)
    assert np.isclose(r.roofline_fraction, 0.5)
    assert "HBM" in advice(r)


def test_model_flops():
    assert model_flops(int(1e9), 1000, "train") == 6e12
    assert model_flops(int(1e9), 1000, "serve") == 2e12


@pytest.mark.parametrize("dom,frag", [
    ("compute", "compute-bound"),
    ("collective", "collective-bound"),
])
def test_advice_strings(dom, frag):
    kw = dict(arch="a", shape="s", mesh="m", chips=1, model_flops=1e12,
              flops_per_device=1.0, bytes_per_device=1.0,
              coll_bytes_per_device=1.0)
    if dom == "compute":
        kw["flops_per_device"] = 1e20
    else:
        kw["coll_bytes_per_device"] = 1e20
    assert frag in advice(Roofline(**kw))
