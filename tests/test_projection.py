import numpy as np
import pytest

from repro.core import gs
from repro.core.permutations import PermSpec
from repro.core.projection import project_to_gs, gs_reconstruction_error


def _gsoft_like(d=24, b=6):
    return gs.gsoft_layout(d, b)


def test_exact_recovery_for_class_members():
    rng = np.random.default_rng(0)
    layout = _gsoft_like()
    L0 = rng.normal(size=layout.lspec.param_shape)
    R0 = rng.normal(size=layout.rspec.param_shape)
    A = gs.gs_materialize(layout, L0, R0)
    L, R = project_to_gs(A, layout)
    assert gs_reconstruction_error(A, layout, L, R) < 1e-8


def test_idempotence():
    rng = np.random.default_rng(1)
    layout = _gsoft_like()
    A = rng.normal(size=(layout.out_dim, layout.in_dim))
    L1, R1 = project_to_gs(A, layout)
    A1 = gs.gs_materialize(layout, L1, R1)
    L2, R2 = project_to_gs(A1, layout)
    A2 = gs.gs_materialize(layout, L2, R2)
    assert np.allclose(A1, A2, atol=1e-8)


def test_projection_beats_random_candidates():
    """Eckart–Young optimality: the projection error is <= any random GS
    candidate with the same layout."""
    rng = np.random.default_rng(2)
    layout = _gsoft_like(16, 4)
    A = rng.normal(size=(16, 16))
    L, R = project_to_gs(A, layout)
    err_opt = gs_reconstruction_error(A, layout, L, R)
    for _ in range(10):
        Lr = rng.normal(size=layout.lspec.param_shape)
        Rr = rng.normal(size=layout.rspec.param_shape)
        err_rand = gs_reconstruction_error(A, layout, Lr, Rr)
        assert err_opt <= err_rand + 1e-9


def test_projection_with_outer_permutations():
    """Stripping P_L / P_R must be consistent with gs_materialize."""
    rng = np.random.default_rng(3)
    d, b = 24, 6
    r = d // b
    spec = gs.BlockDiagSpec(r, b, b)
    layout = gs.GSLayout(
        lspec=spec, rspec=spec,
        perm_left=PermSpec.from_sigma(rng.permutation(d)),
        perm_mid=PermSpec.gs(r),
        perm_right=PermSpec.from_sigma(rng.permutation(d)),
    )
    L0 = rng.normal(size=spec.param_shape)
    R0 = rng.normal(size=spec.param_shape)
    A = gs.gs_materialize(layout, L0, R0)
    L, R = project_to_gs(A, layout)
    assert gs_reconstruction_error(A, layout, L, R) < 1e-8


def test_projection_rectangular_blocks():
    rng = np.random.default_rng(4)
    layout = gs.GSLayout(
        lspec=gs.BlockDiagSpec(2, 3, 6),
        rspec=gs.BlockDiagSpec(3, 4, 2),
        perm_left=PermSpec.identity(),
        perm_mid=PermSpec.gs(3),
        perm_right=PermSpec.identity(),
    )
    A = rng.normal(size=(layout.out_dim, layout.in_dim))
    L, R = project_to_gs(A, layout)
    assert L.shape == layout.lspec.param_shape
    assert R.shape == layout.rspec.param_shape
    # projecting its own reconstruction is exact (class membership)
    A1 = gs.gs_materialize(layout, L, R)
    L2, R2 = project_to_gs(A1, layout)
    assert gs_reconstruction_error(A1, layout, L2, R2) < 1e-8


def test_shape_mismatch_raises():
    layout = _gsoft_like()
    with pytest.raises(ValueError):
        project_to_gs(np.zeros((3, 3)), layout)
