"""Drives tests/pipeline_runner.py (needs its own XLA device count)."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_gpipe_schedule():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tests", "pipeline_runner.py")],
        env=env, capture_output=True, text=True, timeout=600)
    sys.stdout.write(proc.stdout[-2000:])
    sys.stderr.write(proc.stderr[-2000:])
    assert proc.returncode == 0
    assert "gpipe matches sequential: True" in proc.stdout
