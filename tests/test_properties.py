"""Hypothesis property tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e '.[dev]')")
from hypothesis import given, settings, strategies as st

from repro.core import adapters as ad
from repro.core import gs
from repro.core.orthogonal import cayley, orthogonality_error, skew
from repro.models.layers import cross_entropy
from repro.optim import dequantize_int8, quantize_int8


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 6), st.integers(1, 6), st.integers(0, 10 ** 6))
def test_orthogonal_gs_always_orthogonal(b, r, seed):
    """Cayley blocks => orthogonal GS matrix, for every (b, r)."""
    d = b * r
    rng = np.random.default_rng(seed)
    lay = gs.gsoft_layout(d, b)
    L = cayley(skew(jnp.asarray(rng.normal(size=lay.lspec.param_shape),
                                jnp.float32)))
    R = cayley(skew(jnp.asarray(rng.normal(size=lay.rspec.param_shape),
                                jnp.float32)))
    A = gs.gs_materialize(lay, L, R)
    assert np.abs(A.T @ A - np.eye(d)).max() < 1e-4


@settings(max_examples=15, deadline=None)
@given(st.sampled_from(["gsoft", "oft", "boft", "lora", "double_gsoft"]),
       st.integers(1, 4), st.integers(1, 4), st.integers(0, 10 ** 6))
def test_adapter_identity_init_any_shape(method, din_blocks, dout_blocks, seed):
    d_in, d_out = 8 * din_blocks, 8 * dout_blocks
    spec = ad.AdapterSpec(method=method, d_in=d_in, d_out=d_out, block_size=8)
    params = ad.init_adapter(spec, jax.random.PRNGKey(seed % 100))
    W = jnp.asarray(np.random.default_rng(seed).normal(size=(d_in, d_out)),
                    jnp.float32)
    np.testing.assert_allclose(np.asarray(ad.materialize(spec, params, W)),
                               np.asarray(W), atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(3, 17), st.integers(0, 10 ** 6))
def test_cross_entropy_matches_naive(b, v, seed):
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, 4, v)) * 3, jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(b, 4)), jnp.int32)
    loss, acc = cross_entropy(logits, labels)
    p = jax.nn.log_softmax(np.asarray(logits, np.float32), axis=-1)
    naive = -np.take_along_axis(np.asarray(p), np.asarray(labels)[..., None],
                                axis=-1).mean()
    assert np.isclose(float(loss), naive, rtol=1e-4, atol=1e-5)
    assert 0.0 <= float(acc) <= 1.0


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 5), st.integers(8, 24), st.integers(0, 10 ** 6))
def test_vocab_padding_never_changes_loss(b, v, seed):
    """Padding logits to a sharding multiple must not move the loss."""
    rng = np.random.default_rng(seed)
    logits = jnp.asarray(rng.normal(size=(b, 3, v)), jnp.float32)
    labels = jnp.asarray(rng.integers(0, v, size=(b, 3)), jnp.int32)
    loss0, _ = cross_entropy(logits, labels, vocab_size=v)
    pad = jnp.pad(logits, ((0, 0), (0, 0), (0, 7)), constant_values=5.0)
    loss1, _ = cross_entropy(pad, labels, vocab_size=v)
    assert np.isclose(float(loss0), float(loss1), rtol=1e-5)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.01, 100.0))
def test_quantize_roundtrip_bound(seed, scale):
    x = jnp.asarray(np.random.default_rng(seed).normal(size=32) * scale,
                    jnp.float32)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s)) - np.asarray(x)).max()
    assert err <= float(s) * 0.51 + 1e-6


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(0, 10 ** 5))
def test_projection_never_increases_error_vs_zero(kl, kr, seed):
    """||A - pi(A)|| <= ||A|| (zero is always in the class)."""
    from repro.core.projection import project_to_gs, gs_reconstruction_error
    from repro.core.permutations import PermSpec
    rng = np.random.default_rng(seed)
    s = int(np.lcm(kl, kr)) * 2
    lay = gs.GSLayout(
        lspec=gs.BlockDiagSpec(kl, 3, s // kl),
        rspec=gs.BlockDiagSpec(kr, s // kr, 2),
        perm_left=PermSpec.identity(),
        perm_mid=PermSpec.from_sigma(rng.permutation(s)),
        perm_right=PermSpec.identity(),
    )
    A = rng.normal(size=(lay.out_dim, lay.in_dim))
    L, R = project_to_gs(A, lay)
    assert gs_reconstruction_error(A, lay, L, R) <= np.linalg.norm(A) + 1e-9


@settings(max_examples=10, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10 ** 5))
def test_data_batches_partition_exactly(hosts, seed):
    """Host slices always tile the global batch, any host count."""
    from repro.data import DataConfig, LMDataSource
    gb = hosts * 2
    src = LMDataSource(DataConfig(seq_len=8, global_batch=gb, seed=seed))
    full = src.batch_at(3)["tokens"]
    parts = [src.batch_at(3, i * 2, (i + 1) * 2)["tokens"]
             for i in range(hosts)]
    np.testing.assert_array_equal(np.concatenate(parts), full)
