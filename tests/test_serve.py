"""Serving engine: batching, EOS handling, merged-PEFT equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.models import api
from repro.serve.engine import ServeEngine

CFG = get_smoke_config("qwen2-72b")
PARAMS = api.init_params(CFG, jax.random.PRNGKey(0))


def test_engine_serves_all_requests():
    eng = ServeEngine(CFG, PARAMS, max_batch=3, max_len=48, eos_id=-1)
    rng = np.random.default_rng(0)
    rids = [eng.add_request(rng.integers(1, 200, size=n).tolist(),
                            max_new_tokens=4)
            for n in (5, 7, 7, 3, 9)]
    results = eng.run()
    assert set(results) == set(rids)
    for r in results.values():
        assert 1 <= len(r) <= 4
        assert all(0 <= t < CFG.padded_vocab() for t in r)
    assert eng.stats["requests"] == 5


def test_engine_deterministic():
    def go():
        eng = ServeEngine(CFG, PARAMS, max_batch=2, max_len=32, eos_id=-1)
        eng.add_request([5, 6, 7], max_new_tokens=4)
        eng.add_request([9, 10, 11, 12], max_new_tokens=4)
        return eng.run()
    assert go() == go()


def test_merged_gsoft_identity_matches_base():
    """Zero-init adapters merged == base model outputs (paper §6.1)."""
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = peft_lib.init_peft(pcfg, PARAMS, jax.random.PRNGKey(1))
    base = ServeEngine(CFG, PARAMS, max_batch=2, max_len=32, eos_id=-1)
    merged = ServeEngine(CFG, PARAMS, max_batch=2, max_len=32, eos_id=-1,
                         adapters=adapters, peft_cfg=pcfg)
    for eng in (base, merged):
        eng.add_request([3, 4, 5], max_new_tokens=4)
    assert base.run()[0] == merged.run()[0]


def test_nonidentity_adapters_change_output():
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = peft_lib.init_peft(pcfg, PARAMS, jax.random.PRNGKey(1))
    # NB a constant shift is a no-op through K = A - A^T; perturb asymmetrically
    adapters = jax.tree.map(
        lambda a: a + 0.5 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        adapters)
    base = ServeEngine(CFG, PARAMS, max_batch=1, max_len=32, eos_id=-1)
    tuned = ServeEngine(CFG, PARAMS, max_batch=1, max_len=32, eos_id=-1,
                        adapters=adapters, peft_cfg=pcfg)
    for eng in (base, tuned):
        eng.add_request([3, 4, 5, 6, 7, 8], max_new_tokens=6)
    assert base.run()[0] != tuned.run()[0]
