"""Serving engines: continuous batching (slots, EOS refill, adapter bank)
and the static reference (ragged-prompt fix, merged-PEFT equivalence)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.serve.engine import ServeEngine, StaticServeEngine
from repro.store import AdapterStore, load_adapter_checkpoints

CFG = get_smoke_config("qwen2-72b")
RT = ModelRuntime(CFG, key=jax.random.PRNGKey(0))
PARAMS = RT.params
PCFG = peft_lib.PEFTConfig(method="gsoft", block_size=8)


def _tuned_adapters(seed, scale=0.3):
    ad = peft_lib.init_peft(PCFG, PARAMS, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.PRNGKey(seed + 50), a.shape), ad)


def _solo(prompt, max_new, adapters=None, eos_id=-1):
    """Single-request reference: batch of one, offline-merged adapter."""
    rt = (ModelRuntime(CFG, PARAMS, adapters=adapters, peft_cfg=PCFG)
          if adapters is not None else RT)
    eng = StaticServeEngine(rt, max_batch=1, max_len=48, eos_id=eos_id)
    rid = eng.add_request(list(prompt), max_new_tokens=max_new)
    return eng.run()[rid]


def test_engine_serves_all_requests():
    eng = ServeEngine(RT, max_batch=3, max_len=48, eos_id=-1)
    rng = np.random.default_rng(0)
    rids = [eng.add_request(rng.integers(1, 200, size=n).tolist(),
                            max_new_tokens=4)
            for n in (5, 7, 7, 3, 9)]
    results = eng.run()
    assert set(results) == set(rids)
    for r in results.values():
        assert 1 <= len(r) <= 4
        assert all(0 <= t < CFG.padded_vocab() for t in r)
    assert eng.stats["requests"] == 5


def test_engine_deterministic():
    def go():
        eng = ServeEngine(RT, max_batch=2, max_len=32, eos_id=-1)
        eng.add_request([5, 6, 7], max_new_tokens=4)
        eng.add_request([9, 10, 11, 12], max_new_tokens=4)
        return eng.run()
    assert go() == go()


def test_merged_gsoft_identity_matches_base():
    """Zero-init adapters merged == base model outputs (paper §6.1)."""
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = peft_lib.init_peft(pcfg, PARAMS, jax.random.PRNGKey(1))
    base = ServeEngine(RT, max_batch=2, max_len=32, eos_id=-1)
    merged = ServeEngine(ModelRuntime(CFG, PARAMS, adapters=adapters,
                                      peft_cfg=pcfg),
                         max_batch=2, max_len=32, eos_id=-1)
    for eng in (base, merged):
        eng.add_request([3, 4, 5], max_new_tokens=4)
    assert base.run()[0] == merged.run()[0]


def test_ragged_prompts_match_solo_reference():
    """Regression: rows shorter than the batch max used to sample their
    first token from a PADDED position. Every row of a mixed-length batch
    must now match its own single-request run — on both engines."""
    prompts = [[7, 8, 9], [3, 4, 5, 6, 7, 8, 9, 10, 11], [5, 6, 7, 8, 9]]
    refs = [_solo(p, 4) for p in prompts]
    for cls in (ServeEngine, StaticServeEngine):
        eng = cls(RT, max_batch=3, max_len=48, eos_id=-1)
        rids = [eng.add_request(list(p), max_new_tokens=4) for p in prompts]
        results = eng.run()
        for rid, ref in zip(rids, refs):
            assert results[rid] == ref, cls.__name__


def test_multi_adapter_slots_match_merged_references():
    """Per-request adapters served from one bank == each adapter merged
    offline into its own dedicated engine; the identity slot == no-PEFT."""
    adapters = {"alice": _tuned_adapters(7), "bob": _tuned_adapters(11)}
    rt = RT.attach(adapters, PCFG)
    assert rt.bank.names == (peft_lib.BASE_ADAPTER, "alice", "bob")
    prompt = [3, 4, 5, 6]
    eng = ServeEngine(rt, max_batch=3, max_len=48, eos_id=-1)
    rids = {name: eng.add_request(prompt, max_new_tokens=5, adapter=name)
            for name in ("alice", "bob", None)}
    results = eng.run()
    assert results[rids["alice"]] == _solo(prompt, 5, adapters["alice"])
    assert results[rids["bob"]] == _solo(prompt, 5, adapters["bob"])
    assert results[rids[None]] == _solo(prompt, 5)          # identity slot
    assert results[rids["alice"]] != results[rids["bob"]]


def test_banked_decode_logits_match_merged_fp32():
    """Step-level fp32 tolerance: one decode step through the activation-
    side bank == the same step through offline-merged weights."""
    from repro.train.steps import build_decode_step
    adapters = {"a": _tuned_adapters(3)}
    bank = peft_lib.build_adapter_bank(PCFG, PARAMS, adapters)
    merged = peft_lib.materialize_tree(PCFG, PARAMS, adapters["a"],
                                       merged=True)
    tokens = jnp.asarray([[5], [9]], jnp.int32)
    pos = jnp.zeros((2,), jnp.int32)
    state = RT.init_decode_state(2, 16)
    _, logits_bank, _ = build_decode_step(CFG)(
        PARAMS, bank.context([1, 1]), tokens, state, pos)
    state = RT.init_decode_state(2, 16)
    _, logits_merged, _ = build_decode_step(CFG)(merged, None, tokens, state,
                                                 pos)
    np.testing.assert_allclose(np.asarray(logits_bank),
                               np.asarray(logits_merged), atol=2e-4)


def test_banked_serving_kernel_path_matches_merged():
    """The vmapped-Pallas bank rotation serves the same tokens as the
    offline-merged reference (kernel bodies in interpret mode on CPU)."""
    pcfg_k = peft_lib.PEFTConfig(method="gsoft", block_size=8,
                                 use_pallas=True)
    adapters = {"a": _tuned_adapters(3)}
    eng = ServeEngine(RT.attach(adapters, pcfg_k), max_batch=2,
                      max_len=48, eos_id=-1)
    rid = eng.add_request([3, 4, 5, 6], max_new_tokens=4, adapter="a")
    assert eng.run()[rid] == _solo([3, 4, 5, 6], 4, adapters["a"])


def test_eos_frees_slot_and_admits_queued_request():
    """EOS early-exit releases the slot; a queued request is admitted
    mid-run instead of waiting out the finished request's token budget."""
    probe = _solo([3, 4, 5], 8)
    eos = next(t for t in probe[1:] if t != probe[0])
    k = probe.index(eos) + 1                   # tokens until EOS emitted
    assert k < 8
    eng = ServeEngine(RT, max_batch=1, max_len=64, eos_id=eos)
    r1 = eng.add_request([3, 4, 5], max_new_tokens=8)
    r2 = eng.add_request([9, 10, 11, 12], max_new_tokens=4)
    results = eng.run()
    assert results[r1] == probe[:k]            # truncated at EOS
    assert len(results[r2]) <= 4
    log = dict(eng.stats["admission_log"])
    # r2 entered when r1 hit EOS (k-1 decode steps), not at its budget (7)
    assert log[r2] == k - 1
    assert eng.stats["decode_steps"] < 7 + 3


def test_identity_bank_matches_no_peft_engine():
    """A bank with only the identity slot serves exactly the base model."""
    banked = ServeEngine(RT.attach({}, PCFG), max_batch=2, max_len=32,
                         eos_id=-1)
    plain = ServeEngine(RT, max_batch=2, max_len=32, eos_id=-1)
    for eng in (banked, plain):
        eng.add_request([3, 4, 5], max_new_tokens=4)
    assert banked.run()[0] == plain.run()[0]


def test_oversized_request_rejected_by_both_engines():
    """A request that cannot fit prompt + budget in the slot cache must be
    rejected up front (clamped cache writes would silently corrupt it)."""
    for cls in (ServeEngine, StaticServeEngine):
        eng = cls(RT, max_batch=1, max_len=16, eos_id=-1)
        with pytest.raises(ValueError, match="max_len"):
            eng.add_request(list(range(1, 13)), max_new_tokens=8)


def test_adapter_bank_build_validation():
    # registry-driven capability check: lora has bank_build=None and the
    # error names the method + why (weight-side only)
    with pytest.raises(ValueError, match="lora.*weight-side"):
        peft_lib.build_adapter_bank(
            peft_lib.PEFTConfig(method="lora"), PARAMS, {})
    with pytest.raises(ValueError, match="use_scale"):
        peft_lib.build_adapter_bank(
            peft_lib.PEFTConfig(method="gsoft", use_scale=True), PARAMS, {})
    bank = peft_lib.build_adapter_bank(PCFG, PARAMS, {})
    with pytest.raises(KeyError):
        bank.slot("nope")


def test_adapter_bank_checkpoint_roundtrip(tmp_path):
    """AdapterStore.save -> load_adapter_checkpoints preserves trees +
    PEFTConfig, and the restored bank serves identically (the launcher's
    --adapters path)."""
    adapters = {"alice": _tuned_adapters(7), "bob": _tuned_adapters(11)}
    AdapterStore.from_adapters(adapters, PCFG).save(str(tmp_path))
    restored, cfg2 = load_adapter_checkpoints([str(tmp_path)])
    assert cfg2 == PCFG
    assert sorted(restored) == ["alice", "bob"]
    for name in adapters:
        assert sorted(restored[name]) == sorted(adapters[name])
        for path, entry in adapters[name].items():
            for pkey, arr in entry.items():
                np.testing.assert_array_equal(
                    np.asarray(restored[name][path][pkey]), np.asarray(arr))
    # restored bank produces the same tokens
    outs = []
    for ad, pc in ((adapters, PCFG), (restored, cfg2)):
        eng = ServeEngine(RT.attach(ad, pc), max_batch=1, max_len=32,
                          eos_id=-1)
        eng.add_request([4, 5, 6], max_new_tokens=3, adapter="bob")
        outs.append(eng.run()[0])
    assert outs[0] == outs[1]


def test_continuous_scheduler_does_less_decode_work():
    """Deterministic scheduling metric: on a ragged-budget workload the
    slot engine needs fewer decode steps than the lockstep engine."""
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(1, 200, size=int(rng.integers(3, 10))).tolist(),
             int(rng.integers(2, 13))) for _ in range(8)]
    steps = {}
    for cls in (ServeEngine, StaticServeEngine):
        eng = cls(RT, max_batch=2, max_len=48, eos_id=-1)
        for p, m in reqs:
            eng.add_request(p, max_new_tokens=m)
        eng.run()
        steps[cls.__name__] = eng.stats["decode_steps"]
    assert steps["ServeEngine"] < steps["StaticServeEngine"]


def test_nonidentity_adapters_change_output():
    pcfg = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    adapters = peft_lib.init_peft(pcfg, PARAMS, jax.random.PRNGKey(1))
    # NB a constant shift is a no-op through K = A - A^T; perturb asymmetrically
    adapters = jax.tree.map(
        lambda a: a + 0.5 * jax.random.normal(jax.random.PRNGKey(7), a.shape),
        adapters)
    base = ServeEngine(RT, max_batch=1, max_len=32, eos_id=-1)
    tuned = ServeEngine(ModelRuntime(CFG, PARAMS, adapters=adapters,
                                     peft_cfg=pcfg),
                        max_batch=1, max_len=32, eos_id=-1)
    for eng in (base, tuned):
        eng.add_request([3, 4, 5, 6, 7, 8], max_new_tokens=6)
    assert base.run()[0] != tuned.run()[0]
