"""MethodOps registry: every parametrization is a first-class record —
unknown methods fail loud listing what IS registered, every method
identity-inits, weight-side merge and activation-side application agree,
heterogeneous (mixed-method) banks serve each tenant exactly like its solo
merged run (also over int8 base weights), checkpoints round-trip per-name
method metadata, and raw ``method ==`` dispatch cannot creep back outside
``core/methods.py``."""
import dataclasses
import pathlib
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.core import adapters as ad
from repro.core import methods as methods_lib
from repro.core import peft as peft_lib
from repro.core.orthogonal import orthogonality_error
from repro.core.runtime import ModelRuntime
from repro.serve.engine import ServeEngine, StaticServeEngine
from repro.store import AdapterStore, load_adapter_checkpoints

CFG = get_smoke_config("qwen2-72b")
RT = ModelRuntime(CFG, key=jax.random.PRNGKey(0))
PARAMS = RT.params

SRC = pathlib.Path(__file__).resolve().parents[1] / "src" / "repro"

MIXED_CFGS = {
    "alice": peft_lib.PEFTConfig(method="gsoft", block_size=8),
    "bob": peft_lib.PEFTConfig(method="boft", block_size=8),
    "carol": peft_lib.PEFTConfig(method="householder", reflections=4),
}


def _spec(method, d_in=16, d_out=16, **kw):
    kw.setdefault("reflections", 4)
    return ad.AdapterSpec(method=method, d_in=d_in, d_out=d_out,
                          block_size=4, **kw)


def _noisy(params, seed=3, scale=0.3):
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(jax.random.PRNGKey(seed),
                                                a.shape), params)


def _tuned_adapters(seed, cfg, scale=0.3):
    adp = peft_lib.init_peft(cfg, PARAMS, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.PRNGKey(seed + 50), a.shape), adp)


def _mixed_adapters():
    return {n: _tuned_adapters(i * 7 + 3, c)
            for i, (n, c) in enumerate(MIXED_CFGS.items())}


def _solo(prompt, max_new, adapters=None, cfg=None, quantize=False):
    """Single-request reference: batch of one, offline-merged adapter."""
    rt = (ModelRuntime(CFG, PARAMS, adapters=adapters, peft_cfg=cfg)
          if adapters is not None else RT)
    if quantize:
        rt = rt.quantized("int8")
    eng = StaticServeEngine(rt, max_batch=1, max_len=48, eos_id=-1)
    rid = eng.add_request(list(prompt), max_new_tokens=max_new)
    return eng.run()[rid]


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_registry_has_explicit_entries():
    assert methods_lib.registered() == ["boft", "double_gsoft", "givens",
                                        "gsoft", "householder", "lora", "oft"]


def test_unknown_method_raises_keyerror_listing_registered():
    with pytest.raises(KeyError, match="monarch") as ei:
        methods_lib.get("monarch")
    for m in ("gsoft", "boft", "householder", "lora"):
        assert m in str(ei.value)
    # the public dispatchers fail the same way
    with pytest.raises(KeyError, match="monarch"):
        ad.init_adapter(_spec("monarch"), jax.random.PRNGKey(0))
    with pytest.raises(KeyError, match="monarch"):
        peft_lib.build_adapter_bank(
            dataclasses.replace(MIXED_CFGS["alice"], method="monarch"),
            PARAMS, {})


def test_full_none_are_training_regimes_not_methods():
    assert not peft_lib.PEFTConfig(method="full").is_peft
    assert not peft_lib.PEFTConfig(method="none").is_peft
    t, f = methods_lib.trainable_split("full", {"w": 1}, {})
    assert t == {"w": 1} and f == {}
    t, f = methods_lib.trainable_split("none", {"w": 1}, {})
    assert t == {} and f == {"w": 1}
    with pytest.raises(KeyError, match="retnofit"):
        methods_lib.trainable_split("retnofit", {}, {})


# ---------------------------------------------------------------------------
# per-method numerics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", methods_lib.registered())
def test_identity_init_every_method(method):
    """W_eff == W at step 0 for every registered method."""
    spec = _spec(method, d_in=16, d_out=24)
    p = ad.init_adapter(spec, jax.random.PRNGKey(0))
    W = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
    np.testing.assert_allclose(np.asarray(ad.materialize(spec, p, W)),
                               np.asarray(W), atol=1e-6)


@pytest.mark.parametrize("method", [m for m in methods_lib.registered()
                                    if methods_lib.get(m)
                                    .apply_activation_side is not None])
def test_merge_vs_activation_side_equality(method):
    """x @ (Q W) == (x Q) @ W — the weight-side/activation-side contract
    every banked serving path relies on."""
    spec = _spec(method, d_in=16, d_out=24)
    p = _noisy(ad.init_adapter(spec, jax.random.PRNGKey(0)))
    W = jax.random.normal(jax.random.PRNGKey(1), (16, 24))
    x = jax.random.normal(jax.random.PRNGKey(2), (5, 16))
    y_merge = x @ ad.materialize(spec, p, W)
    y_act = ad.apply_activation_side(spec, p, x) @ W
    np.testing.assert_allclose(np.asarray(y_act), np.asarray(y_merge),
                               atol=1e-4)


@pytest.mark.parametrize("method", methods_lib.registered())
def test_param_count_analytic_matches_init(method):
    for batch, use_scale in (((), False), ((3,), True)):
        spec = _spec(method, batch=batch, use_scale=use_scale)
        p = ad.init_adapter(spec, jax.random.PRNGKey(0))
        counted = sum(int(np.prod(v.shape)) for v in jax.tree.leaves(p))
        assert ad.num_adapter_params(spec) == counted, (method, batch)


def test_householder_rejects_odd_reflections():
    with pytest.raises(ValueError, match="EVEN"):
        ad.init_adapter(_spec("householder", reflections=3),
                        jax.random.PRNGKey(0))


def test_orthogonality_error_sweep():
    """hypothesis sweep: merged rotation of EVERY orthogonal method stays
    orthogonal (error <= 1e-4) across random params / dims / block sizes."""
    pytest.importorskip("hypothesis",
                        reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    orth = [m for m in methods_lib.registered()
            if methods_lib.get(m).orthogonal]

    @settings(max_examples=25, deadline=None)
    @given(method=st.sampled_from(orth),
           d=st.sampled_from([8, 16, 32]),
           b=st.sampled_from([2, 4, 8]),
           seed=st.integers(0, 2 ** 16))
    def check(method, d, b, seed):
        spec = ad.AdapterSpec(method=method, d_in=d, d_out=d, block_size=b,
                              reflections=4)
        p = ad.init_adapter(spec, jax.random.PRNGKey(seed))
        p = jax.tree.map(
            lambda a: a + 0.5 * jax.random.normal(
                jax.random.PRNGKey(seed + 1), a.shape), p)
        Q = ad.merge(spec, p, jnp.eye(d, dtype=jnp.float32))
        assert float(orthogonality_error(Q)) <= 1e-4

    check()


# ---------------------------------------------------------------------------
# heterogeneous banks (the acceptance path)
# ---------------------------------------------------------------------------

def test_mixed_method_bank_matches_solo_merged_runs():
    """gsoft + boft + householder tenants in ONE bank: every request's
    tokens equal its adapter's solo offline-merged run; the identity slot
    serves the base model."""
    adapters = _mixed_adapters()
    rt = RT.attach(adapters, MIXED_CFGS)
    assert rt.bank.bank_methods == ("boft", "gsoft", "householder")
    prompt = [3, 4, 5, 6]
    eng = ServeEngine(rt, max_batch=4, max_len=48, eos_id=-1)
    rids = {n: eng.add_request(prompt, max_new_tokens=5, adapter=n)
            for n in ("alice", "bob", "carol", None)}
    results = eng.run()
    for name in ("alice", "bob", "carol"):
        ref = _solo(prompt, 5, adapters[name], MIXED_CFGS[name])
        assert results[rids[name]] == ref, name
    assert results[rids[None]] == _solo(prompt, 5)
    assert len({tuple(results[r]) for r in rids.values()}) == 4


def test_mixed_method_bank_quantized_int8():
    """The same heterogeneous bank over int8 base weights: per-request
    tokens still equal each adapter's solo merged (then quantized) run —
    rotations stay bf16 for every method (QOFT recipe)."""
    adapters = _mixed_adapters()
    qrt = RT.attach(adapters, MIXED_CFGS).quantized("int8")
    prompt = [3, 4, 5, 6]
    eng = ServeEngine(qrt, max_batch=4, max_len=48, eos_id=-1)
    rids = {n: eng.add_request(prompt, max_new_tokens=5, adapter=n)
            for n in ("alice", "bob", "carol", None)}
    results = eng.run()
    for name in ("alice", "bob", "carol"):
        ref = _solo(prompt, 5, adapters[name], MIXED_CFGS[name],
                    quantize=True)
        assert results[rids[name]] == ref, name
    assert results[rids[None]] == _solo(prompt, 5, quantize=True)
    # the bank's factors are never quantized, whatever the method
    for leaf in jax.tree.leaves(qrt.bank.tree):
        assert jnp.issubdtype(leaf.dtype, jnp.floating)


def test_bank_rejects_weight_side_only_methods():
    """Satellite regression: the old blanket "gsoft only" error is gone —
    capability comes from the registry, and the refusal names the method
    and the reason (lora: weight-side only)."""
    with pytest.raises(ValueError, match=r"'lora'.*weight-side"):
        RT.attach({"t": _tuned_adapters(3, MIXED_CFGS["alice"])},
                     {"t": peft_lib.PEFTConfig(method="lora")})
    with pytest.raises(ValueError, match="double_gsoft.*output-side"):
        RT.attach({}, peft_lib.PEFTConfig(method="double_gsoft"))
    # bankable non-gsoft methods are now ACCEPTED (the old error path
    # rejected everything but gsoft)
    bank = peft_lib.build_adapter_bank(
        peft_lib.PEFTConfig(method="boft", block_size=8), PARAMS, {})
    assert bank.num_slots == 1


def test_bank_config_consistency_errors():
    gs_cfg = MIXED_CFGS["alice"]
    other_targets = dataclasses.replace(gs_cfg, target_patterns=(r".*/wq$",))
    with pytest.raises(ValueError, match="target_patterns"):
        peft_lib.build_adapter_bank(
            {"a": gs_cfg, "b": other_targets}, PARAMS,
            {"a": _tuned_adapters(1, gs_cfg),
             "b": _tuned_adapters(2, other_targets)})
    with pytest.raises(ValueError, match="one config per adapter"):
        peft_lib.build_adapter_bank({"a": gs_cfg}, PARAMS,
                                    {"a": {}, "b": {}})
    # same method, different config -> one stack per method is violated
    gs16 = dataclasses.replace(gs_cfg, block_size=16)
    with pytest.raises(ValueError, match="one stack"):
        peft_lib.build_adapter_bank(
            {"a": gs_cfg, "b": gs16}, PARAMS,
            {"a": _tuned_adapters(1, gs_cfg),
             "b": _tuned_adapters(2, gs16)})


def test_checkpoint_roundtrip_preserves_method_metadata(tmp_path):
    """AdapterStore.save -> load_adapter_checkpoints keeps each adapter's
    method + spec (mixed-method bank), and the restored bank serves
    identical tokens."""
    adapters = _mixed_adapters()
    AdapterStore.from_adapters(adapters, MIXED_CFGS).save(str(tmp_path))
    restored, cfgs = load_adapter_checkpoints([str(tmp_path)])
    assert isinstance(cfgs, dict)
    assert {n: c.method for n, c in cfgs.items()} == {
        "alice": "gsoft", "bob": "boft", "carol": "householder"}
    assert cfgs == MIXED_CFGS
    prompt = [4, 5, 6]
    outs = []
    for adp, cfg in ((adapters, MIXED_CFGS), (restored, cfgs)):
        eng = ServeEngine(RT.attach(adp, cfg), max_batch=1, max_len=32,
                          eos_id=-1)
        rids = [eng.add_request(prompt, max_new_tokens=3, adapter=n)
                for n in ("bob", "carol")]
        res = eng.run()
        outs.append([res[r] for r in rids])
    assert outs[0] == outs[1]
    # homogeneous saves still load as ONE config (back-compat surface)
    single = peft_lib.PEFTConfig(method="gsoft", block_size=8)
    AdapterStore.from_adapters(
        {"x": _tuned_adapters(9, single)}, single).save(str(tmp_path / "homo"))
    _, cfg2 = load_adapter_checkpoints([str(tmp_path / "homo")])
    assert cfg2 == single


# ---------------------------------------------------------------------------
# extensibility: a new parametrization is ONE registry entry
# ---------------------------------------------------------------------------

def test_new_method_is_one_registry_entry_and_quant_gate():
    """Registering a record is all it takes to train/serve a new method;
    the quant_compatible flag gates quantized serving."""
    probe = dataclasses.replace(
        methods_lib.get("householder"), method="probe_hoft",
        quant_compatible=False)
    methods_lib.register(probe)
    try:
        cfg = peft_lib.PEFTConfig(method="probe_hoft", reflections=4)
        spec = peft_lib.spec_for(cfg, (16, 16))
        p = ad.init_adapter(spec, jax.random.PRNGKey(0))
        W = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
        np.testing.assert_allclose(np.asarray(ad.materialize(spec, p, W)),
                                   np.asarray(W), atol=1e-6)
        adapters = {"t": _tuned_adapters(5, cfg)}
        bank_rt = RT.attach(adapters, cfg)       # banks fine
        assert bank_rt.bank.bank_methods == ("probe_hoft",)
        with pytest.raises(ValueError, match="probe_hoft"):
            bank_rt.quantized("int8")               # ...but not over int8
        with pytest.raises(ValueError, match="probe_hoft"):
            RT.quantized("int8").attach(adapters, cfg)
    finally:
        del methods_lib._METHODS["probe_hoft"]
        peft_lib.spec_for.cache_clear()


# ---------------------------------------------------------------------------
# the grep guard, mirrored in-tree (CI lint step "method-registry
# dispatch guard")
# ---------------------------------------------------------------------------

def test_no_method_string_dispatch_outside_registry():
    """Raw ``method ==`` / ``spec.method ==`` dispatch outside
    core/methods.py forks the registry — models/api and serve must hold
    zero method-string conditionals."""
    pat = re.compile(r"\bmethod\s*==")
    offenders = []
    for path in SRC.rglob("*.py"):
        if path.name == "methods.py" and path.parent.name == "core":
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if pat.search(line):
                offenders.append(f"{path.relative_to(SRC)}:{i}: {line.strip()}")
    assert not offenders, "\n".join(offenders)
