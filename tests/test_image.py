"""Image serving lane (ISSUE 9): the registered stateless ``image`` family
end-to-end — conv-adapter orthogonality (hypothesis sweep over every
orthogonal method incl. givens), the 1-Lipschitz bound surviving a banked
adapter, banked-vs-solo-merged equality in f32/bf16/int8, store-paged
equality, cluster serving, and the engines refusing each other's families."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.core import methods as methods_lib
from repro.core import peft as peft_lib
from repro.core.orthogonal import orthogonality_error
from repro.core.peft import path_str
from repro.core.runtime import ModelRuntime
from repro.distrib import EngineCluster
from repro.serve.engine import ServeEngine, StaticServeEngine
from repro.serve.image import ImageServeEngine
from repro.store import AdapterStore

CFG = get_smoke_config("lipconvnet-15")
BASE = ModelRuntime(CFG, key=jax.random.PRNGKey(0))
PARAMS = BASE.params

TENANT_CFGS = {
    "alice": peft_lib.PEFTConfig(method="gsoft", block_size=4),
    "bob": peft_lib.PEFTConfig(method="givens"),
    "carol": peft_lib.PEFTConfig(method="householder", reflections=4),
    "dave": peft_lib.PEFTConfig(method="gsoft", block_size=4),
}


def _tuned(cfg, seed, scale=0.3):
    ad = peft_lib.init_peft(cfg, PARAMS, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.PRNGKey(seed + 50), a.shape), ad)


ADAPTERS = {n: _tuned(c, i + 1) for i, (n, c) in enumerate(TENANT_CFGS.items())}
BANKED = BASE.attach(ADAPTERS, TENANT_CFGS)


def _images(n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.normal(size=(n, CFG.image_size, CFG.image_size,
                            CFG.in_channels)).astype(np.float32)


def _serve(rt, reqs, max_batch=4):
    """reqs: [(image, adapter)] -> logits rows in request order."""
    eng = ImageServeEngine(rt, max_batch=max_batch)
    rids = [eng.add_request(img, adapter=name) for img, name in reqs]
    eng.run()
    return np.stack([eng.result_logits[r] for r in rids])


def _solo(cfg, name, images, quantize=None):
    rt = (ModelRuntime(cfg, PARAMS) if name is None else
          ModelRuntime(cfg, PARAMS, adapters=ADAPTERS[name],
                       peft_cfg=TENANT_CFGS[name]))
    if quantize:
        rt = rt.quantized(quantize)
    return np.asarray(rt.infer(jnp.asarray(images)))


# ---------------------------------------------------------------------------
# orthogonality of the conv attachment
# ---------------------------------------------------------------------------

ORTH = [m for m in methods_lib.registered()
        if methods_lib.get(m).orthogonal]


def _check_conv_orthogonality(method, seed):
    """A (noised, far-from-identity) adapter merged into the conv
    channel-mix leaves keeps each wc exactly a rotation (the base wc is
    the identity, so the merged leaf IS the adapter's Q)."""
    cfg = peft_lib.PEFTConfig(method=method, block_size=4, reflections=4)
    ad = jax.tree.map(
        lambda a, s=seed: a + 0.5 * jax.random.normal(
            jax.random.PRNGKey(s), a.shape),
        peft_lib.init_peft(cfg, PARAMS, jax.random.PRNGKey(seed)))
    merged = peft_lib.materialize_tree(cfg, PARAMS, ad, merged=True)
    wcs = [(path_str(p), l) for p, l in
           jax.tree_util.tree_flatten_with_path(merged)[0]
           if path_str(p).endswith("/wc")]
    assert wcs, "image params must expose /wc attachment leaves"
    for path, q in wcs:
        err = float(orthogonality_error(q.astype(jnp.float32)))
        assert err <= 1e-4, (method, path, err)


@pytest.mark.parametrize("method", ORTH)
def test_conv_adapter_orthogonality(method):
    _check_conv_orthogonality(method, seed=0)


def test_conv_adapter_orthogonality_sweep():
    """hypothesis sweep of the same property across random seeds."""
    pytest.importorskip("hypothesis",
                        reason="property sweep needs hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(method=st.sampled_from(ORTH), seed=st.integers(0, 2 ** 16))
    def check(method, seed):
        _check_conv_orthogonality(method, seed)

    check()


def test_banked_lipconvnet_stays_1_lipschitz():
    """End-to-end bound: with a (noised, still orthogonal) adapter routed
    through the bank, ||f(x1) - f(x2)||_2 <= ||x1 - x2||_2."""
    for name in ("alice", "bob", None):
        aid = BANKED.acquire_adapter(name)
        ctx = BANKED.context(np.array([aid, aid], np.int32))
        x = _images(2, seed=7)
        x[1] = x[0] + 0.1 * _images(1, seed=8)[0]
        out = np.asarray(BANKED.infer(jnp.asarray(x), ctx=ctx))
        BANKED.release_adapter(name)
        d_out = float(np.linalg.norm(out[0] - out[1]))
        d_in = float(np.linalg.norm(x[0] - x[1]))
        assert d_out <= d_in * (1 + 1e-3), (name, d_out / d_in)


# ---------------------------------------------------------------------------
# banked == solo merged (f32 / bf16 / int8)
# ---------------------------------------------------------------------------

def _equality_case(rt, cfg, atol, quantize=None):
    names = [None] + list(TENANT_CFGS)
    imgs = _images(len(names) * 2, seed=3)
    reqs = [(imgs[i], names[i % len(names)]) for i in range(len(imgs))]
    got = _serve(rt, reqs)
    for name in names:
        idxs = [i for i, (_, n) in enumerate(reqs) if n == name]
        ref = _solo(cfg, name, imgs[idxs], quantize=quantize)
        np.testing.assert_allclose(
            got[idxs].astype(np.float32), ref.astype(np.float32),
            atol=atol, err_msg=str(name))


def test_banked_matches_solo_merged_f32():
    _equality_case(BANKED, CFG, 1e-4)


def test_banked_matches_solo_merged_bf16():
    bf16 = CFG.with_overrides(dtype="bf16")
    _equality_case(ModelRuntime(bf16, PARAMS).attach(ADAPTERS, TENANT_CFGS),
                   bf16, 0.05)


def test_banked_matches_solo_merged_int8():
    _equality_case(BANKED.quantized("int8"), CFG, 0.05, quantize="int8")


def test_identity_slot_equals_unbanked_exactly():
    """The certificate carrier: adapter=None through the bank must be THE
    base model bit for bit (certified accuracy trivially preserved)."""
    imgs = _images(4, seed=5)
    got = _serve(BANKED, [(im, None) for im in imgs])
    np.testing.assert_array_equal(got, _solo(CFG, None, imgs))


# ---------------------------------------------------------------------------
# store paging + cluster
# ---------------------------------------------------------------------------

def test_store_paged_bank_matches_eager():
    store = AdapterStore.from_adapters(ADAPTERS, TENANT_CFGS)
    srt = BASE.attach(store, hbm_budget=3)   # 4 tenants, 3 methods: pages
    names = list(TENANT_CFGS) + [None]
    imgs = _images(8, seed=9)
    reqs = [(imgs[i], names[i % len(names)]) for i in range(8)]
    np.testing.assert_array_equal(_serve(srt, reqs), _serve(BANKED, reqs))


def test_image_engines_under_cluster():
    names = [None] + list(TENANT_CFGS)
    imgs = _images(10, seed=11)
    reqs = [(imgs[i], names[i % len(names)]) for i in range(10)]
    cluster = EngineCluster([ImageServeEngine(BANKED, max_batch=4)
                             for _ in range(2)])
    rids = [cluster.add_request(img, adapter=name) for img, name in reqs]
    results = cluster.run()
    assert set(rids) == set(results)
    by_rid = {r.rid: r.logits for r in cluster.drain_finished()}
    got = np.stack([by_rid[r] for r in rids])
    np.testing.assert_array_equal(got, _serve(BANKED, reqs))
    assert cluster.stats["requests"] == 10


# ---------------------------------------------------------------------------
# family gating: token engines vs the stateless lane
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine_cls", [ServeEngine, StaticServeEngine])
def test_token_engines_refuse_stateless_family(engine_cls):
    with pytest.raises(ValueError, match="stateless"):
        engine_cls(BASE, max_batch=2, max_len=16, eos_id=-1)


def test_image_engine_refuses_decoder_family():
    rt = ModelRuntime(get_smoke_config("qwen2-72b"),
                      key=jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="prefill/decode"):
        ImageServeEngine(rt)


def test_image_engine_rejects_bad_shape():
    eng = ImageServeEngine(BASE, max_batch=2)
    with pytest.raises(ValueError, match="shape"):
        eng.add_request(np.zeros((4, 4, 3), np.float32))
