"""GPipe schedule check on 8 virtual devices (subprocess; own XLA_FLAGS)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_axes_mesh
from repro.sharding.pipeline import gpipe_forward, pipeline_bubble_fraction


def main():
    nstage, nmb, mb, d = 4, 6, 2, 16
    mesh = make_axes_mesh((nstage,), ("pipe",))
    key = jax.random.PRNGKey(0)
    W = jax.random.normal(key, (nstage, d, d)) * (1.0 / np.sqrt(d))
    b = jax.random.normal(jax.random.fold_in(key, 1), (nstage, d)) * 0.1
    params = {"w": W, "b": b}
    x = jax.random.normal(jax.random.fold_in(key, 2), (nmb, mb, d))

    def stage_fn(p, h):
        return jnp.tanh(h @ p["w"] + p["b"])

    got = gpipe_forward(stage_fn, params, x, mesh, axis="pipe")

    # sequential reference: all stages applied in order
    ref = x
    for s in range(nstage):
        ref = jnp.tanh(ref @ W[s] + b[s])

    ok = np.allclose(np.asarray(got), np.asarray(ref), atol=1e-5)
    print("gpipe matches sequential:", ok)
    print("bubble fraction:", pipeline_bubble_fraction(nstage, nmb))
    assert ok
    # jit + grad through the pipeline
    def loss(p):
        return jnp.sum(gpipe_forward(stage_fn, p, x, mesh) ** 2)
    g = jax.grad(loss)(params)
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    print("grad flows through ppermute schedule:", gn > 0)
    assert gn > 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
