"""AdapterStore / PagedAdapterBank: insert-time capability checks, LRU
paging + pinning under a fixed HBM budget, slot-compaction equality vs
the padded eager bank (bf16 AND int8), evict->re-page determinism against
solo-merged references, and the store<->checkpoint round trip."""
import jax
import numpy as np
import pytest

from repro.config import get_smoke_config
from repro.core import peft as peft_lib
from repro.core.runtime import ModelRuntime
from repro.serve.engine import ServeEngine, StaticServeEngine
from repro.store import AdapterStore, PagedAdapterBank, split_budget

CFG = get_smoke_config("qwen2-72b")
RT = ModelRuntime(CFG, key=jax.random.PRNGKey(0))
PARAMS = RT.params
METHODS = ("gsoft", "boft", "householder")
PROMPT = [3, 4, 5, 6]


def _cfg(method):
    return peft_lib.PEFTConfig(method=method, block_size=8)


def _tuned(cfg, seed, scale=0.3):
    ad = peft_lib.init_peft(cfg, PARAMS, jax.random.PRNGKey(seed))
    return jax.tree.map(
        lambda a: a + scale * jax.random.normal(
            jax.random.PRNGKey(seed + 100), a.shape), ad)


def _mixed(n):
    """(store, adapters_by_name, cfg_by_name) round-robining METHODS."""
    cfgs = {f"t{i}": _cfg(METHODS[i % len(METHODS)]) for i in range(n)}
    adapters = {name: _tuned(cfg, i + 1)
                for i, (name, cfg) in enumerate(cfgs.items())}
    store = AdapterStore()
    for name in cfgs:
        store.add(name, adapters[name], cfgs[name])
    return store, adapters, cfgs


def _solo(adapters, cfg, max_new=4):
    """Single-request reference: the one adapter merged offline."""
    rt = ModelRuntime(CFG, PARAMS, adapters=adapters, peft_cfg=cfg)
    eng = StaticServeEngine(rt, max_batch=1, max_len=32, eos_id=-1)
    rid = eng.add_request(list(PROMPT), max_new_tokens=max_new)
    return eng.run()[rid]


# ---------------------------------------------------------------------------
# budget split
# ---------------------------------------------------------------------------

def test_split_budget_proportional_floored_and_capped():
    # proportional to population, min 1 per method, deterministic
    assert split_budget(4, {"a": 10, "b": 1}) == {"a": 3, "b": 1}
    # never more compact slots than a method has members
    assert split_budget(10, {"a": 2, "b": 2}) == {"a": 2, "b": 2}
    # a budget that cannot give every method one slot is a config error
    with pytest.raises(ValueError, match="one adapter per method"):
        split_budget(1, {"a": 3, "b": 3})


# ---------------------------------------------------------------------------
# insert-time capability checks (satellite: bank_build=None fails at add())
# ---------------------------------------------------------------------------

def test_store_rejects_unbankable_methods_at_insert():
    store = AdapterStore()
    # registry-driven: the error names the method AND the reason
    with pytest.raises(ValueError, match="lora.*weight-side"):
        store.add("x", {}, peft_lib.PEFTConfig(method="lora"))
    with pytest.raises(ValueError, match="double_gsoft.*output-side"):
        store.add("x", {}, peft_lib.PEFTConfig(method="double_gsoft"))
    with pytest.raises(ValueError, match="use_scale"):
        store.add("x", {}, peft_lib.PEFTConfig(method="gsoft",
                                               use_scale=True))
    assert len(store) == 0      # nothing slipped in


def test_store_rejects_config_forks_and_duplicates():
    store, _, _ = _mixed(3)
    ad = _tuned(_cfg("gsoft"), 9)
    with pytest.raises(ValueError, match="one bank holds one stack"):
        store.add("fork", ad, peft_lib.PEFTConfig(method="gsoft",
                                                  block_size=4))
    with pytest.raises(ValueError, match="already holds"):
        store.add("t0", ad, _cfg("gsoft"))
    with pytest.raises(ValueError, match="reserved identity"):
        store.add(peft_lib.BASE_ADAPTER, ad, _cfg("gsoft"))
    # remove()ing a method's last member frees its canonical config
    store.remove("t0")
    assert "t0" not in store and "gsoft" not in store.method_counts()
    fork_cfg = peft_lib.PEFTConfig(method="gsoft", block_size=4)
    store.add("fork", _tuned(fork_cfg, 9), fork_cfg)


def test_unknown_name_errors_list_resident_and_host_tiers():
    store, _, _ = _mixed(3)
    bank = PagedAdapterBank(store, PARAMS, hbm_budget=3)
    bank.acquire("t0")
    with pytest.raises(KeyError) as ei:
        bank.validate("nope")
    msg = str(ei.value)
    assert "t0" in msg and "t1" in msg and "t2" in msg and "resident" in msg
    # a known-but-not-resident name is NOT servable via slot(): admission
    # must go through acquire()
    with pytest.raises(KeyError, match="acquire"):
        bank.slot("t1")
    assert bank.slot("t0") == bank.acquire("t0")


# ---------------------------------------------------------------------------
# LRU paging + pinning
# ---------------------------------------------------------------------------

def test_lru_eviction_order_under_synthetic_trace():
    cfgs = {f"g{i}": _cfg("gsoft") for i in range(3)}
    store = AdapterStore()
    for i, (name, cfg) in enumerate(cfgs.items()):
        store.add(name, _tuned(cfg, i + 1), cfg)
    bank = PagedAdapterBank(store, PARAMS, hbm_budget=2)
    assert bank.caps == {"gsoft": 2} and bank.capacity == 2

    for name in ("g0", "g1"):
        assert bank.acquire(name) is not None
        bank.release(name)
    bank.acquire("g0")              # g0 -> MRU (hit)
    bank.release("g0")
    bank.acquire("g2")              # full region: evicts g1 (LRU), NOT g0
    bank.release("g2")
    assert set(bank.resident) == {"g0", "g2"}
    st = bank.stats()
    assert st["evictions"] == 1 and st["hits"] == 1 and st["misses"] == 3
    # re-admitting the victim hits the host page cache, not bank_build
    bank.acquire("g1")
    bank.release("g1")
    assert bank.counters["builds"] == 3
    assert bank.counters["build_cache_hits"] == 1


def test_pinned_pages_stall_instead_of_evicting():
    cfgs = {f"g{i}": _cfg("gsoft") for i in range(3)}
    store = AdapterStore()
    for i, (name, cfg) in enumerate(cfgs.items()):
        store.add(name, _tuned(cfg, i + 1), cfg)
    bank = PagedAdapterBank(store, PARAMS, hbm_budget=2)
    bank.acquire("g0")              # pinned (no release)
    bank.acquire("g1")              # pinned
    # every compact slot pinned by in-flight work: stall, don't evict
    assert bank.acquire("g2") is None
    assert bank.stats()["admission_stalls"] == 1
    assert set(bank.resident) == {"g0", "g1"}
    bank.release("g1")              # g1 unpinned -> evictable
    assert bank.acquire("g2") is not None
    assert set(bank.resident) == {"g0", "g2"}


# ---------------------------------------------------------------------------
# served-token equality (the whole point of compaction + paging)
# ---------------------------------------------------------------------------

def test_paged_tokens_match_solo_across_evict_repage():
    """6 tenants x 3 methods under budget 3 (one compact slot per method):
    every admission beyond the first per method evicts; tokens must match
    each tenant's solo-merged reference, including on REVISITS after the
    page was evicted and paged back in."""
    store, adapters, cfgs = _mixed(6)
    rt = RT.attach(store, hbm_budget=3)
    assert rt.bank.capacity == 3

    refs = {name: _solo(adapters[name], cfgs[name]) for name in cfgs}
    # same-method tenants adjacent: the second lands while the first still
    # PINS the method's only compact slot -> guaranteed admission stall
    order = [f"t{i}" for i in (0, 3, 1, 4, 2, 5)]
    for round_no in range(2):       # round 2 revisits evicted tenants
        eng = ServeEngine(rt, max_batch=2, max_len=32, eos_id=-1)
        rids = {name: eng.add_request(list(PROMPT), max_new_tokens=4,
                                      adapter=name) for name in order}
        results = eng.run()
        for name in cfgs:
            assert results[rids[name]] == refs[name], (round_no, name)
    st = rt.bank.stats()
    assert st["evictions"] > 0
    assert st["max_resident"] <= st["capacity"] == 3
    # same-method tenants contend for one pinned slot -> engine stalled
    # admission at least once and still finished everything
    assert eng.stats["admission_stalls"] >= 1


def test_compacted_bank_matches_padded_bank_bf16_and_int8():
    """Slot compaction is a representation change only: the paged bank and
    the eager padded bank serve identical tokens over bf16 AND int8 base
    weights — and at 3 methods compaction saves >=2x HBM."""
    _, adapters, cfgs = _mixed(3)

    def tokens(rt):
        eng = ServeEngine(rt, max_batch=2, max_len=32, eos_id=-1)
        rids = {name: eng.add_request(list(PROMPT), max_new_tokens=4,
                                      adapter=name)
                for name in (*cfgs, None)}
        res = eng.run()
        return {name: res[rid] for name, rid in rids.items()}

    for base in (RT, RT.quantized("int8")):
        padded = tokens(base.attach(dict(adapters), dict(cfgs)))
        paged_rt = base.attach(dict(adapters), dict(cfgs), hbm_budget=3)
        assert isinstance(paged_rt.bank, PagedAdapterBank)
        assert tokens(paged_rt) == padded
        st = paged_rt.bank.stats()
        assert st["compaction_ratio"] >= 2.0, st
        assert st["resident_bank_bytes"] < st["padded_bank_bytes"]


# ---------------------------------------------------------------------------
# persistence: store <-> checkpoint
# ---------------------------------------------------------------------------

def test_store_checkpoint_roundtrip_is_lazy_and_exact(tmp_path):
    store, adapters, cfgs = _mixed(3)
    store.save(str(tmp_path))

    opened = AdapterStore.open(str(tmp_path))
    assert opened.names == store.names
    assert {n: opened.cfg_for(n) for n in opened.names} == cfgs
    # open() reads ONLY the index; leaves load on first use
    assert not opened._host
    tree = opened.adapters_for("t1")
    assert "t1" in opened._host and "t0" not in opened._host
    for path, entry in adapters["t1"].items():
        for k, arr in entry.items():
            np.testing.assert_array_equal(np.asarray(tree[path][k]),
                                          np.asarray(arr))
    # attach() takes the directory straight to a disk-backed paged bank
    rt = RT.attach(str(tmp_path), hbm_budget=3)
    eng = ServeEngine(rt, max_batch=1, max_len=32, eos_id=-1)
    rid = eng.add_request(list(PROMPT), max_new_tokens=4, adapter="t2")
    assert eng.run()[rid] == _solo(adapters["t2"], cfgs["t2"])


def test_store_insert_after_attach_requires_reattach():
    store, _, _ = _mixed(2)         # gsoft + boft regions
    bank = PagedAdapterBank(store, PARAMS, hbm_budget=2)
    store.add("late", _tuned(_cfg("householder"), 8), _cfg("householder"))
    with pytest.raises(ValueError, match="re-attach"):
        bank.acquire("late")
