"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept across shapes and dtypes, plus oracle-vs-core consistency checks."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import gs
from repro.kernels import ref
from repro.kernels.bdmm import bdmm_pallas
from repro.kernels.gs_fused import gs_fused_pallas
from repro.kernels.ssd import ssd_pallas
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(atol=1e-5, rtol=1e-5) if dtype == jnp.float32 else \
        dict(atol=2e-2, rtol=2e-2)


# ---------------------------------------------------------------------------
# bdmm
# ---------------------------------------------------------------------------

BDMM_SHAPES = [
    # (r, b_out, b_in, T)
    (4, 8, 8, 16),
    (8, 16, 16, 128),
    (2, 8, 4, 33),       # rectangular blocks, ragged T (padding path)
    (16, 4, 4, 250),
    (1, 32, 32, 7),
    (3, 5, 9, 64),       # odd sizes
]


@pytest.mark.parametrize("r,bo,bi,t", BDMM_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_bdmm_kernel_vs_ref(r, bo, bi, t, dtype):
    k1, k2 = jax.random.split(KEY)
    blocks = jax.random.normal(k1, (r, bo, bi), dtype)
    x = jax.random.normal(k2, (t, r * bi), dtype)
    got = bdmm_pallas(blocks, x, interpret=True)
    want = ref.bdmm_ref(blocks, x)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_bdmm_ref_vs_core():
    """Oracle agrees with core.gs.block_diag_matmul (same contract)."""
    blocks = jax.random.normal(KEY, (4, 8, 8))
    x = jax.random.normal(jax.random.PRNGKey(1), (10, 32))
    np.testing.assert_allclose(np.asarray(ref.bdmm_ref(blocks, x)),
                               np.asarray(gs.block_diag_matmul(blocks, x)),
                               atol=1e-5)


@pytest.mark.parametrize("token_tile", [8, 32, 128])
@pytest.mark.parametrize("group_tile", [1, 2, 4])
def test_bdmm_tilings(token_tile, group_tile):
    blocks = jax.random.normal(KEY, (4, 8, 8))
    x = jax.random.normal(jax.random.PRNGKey(2), (40, 32))
    got = bdmm_pallas(blocks, x, token_tile=token_tile,
                      group_tile=group_tile, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(ref.bdmm_ref(blocks, x)), atol=1e-5)


# ---------------------------------------------------------------------------
# gs_fused
# ---------------------------------------------------------------------------

GS_SHAPES = [(4, 4, 16), (8, 8, 128), (2, 16, 33), (16, 16, 64), (4, 32, 20)]


@pytest.mark.parametrize("r,b,t", GS_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gs_fused_kernel_vs_ref(r, b, t, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    L = jax.random.normal(k1, (r, b, b), dtype)
    R = jax.random.normal(k2, (r, b, b), dtype)
    x = jax.random.normal(k3, (t, r * b), dtype)
    got = np.asarray(gs_fused_pallas(L, R, x, interpret=True), np.float32)
    # fp32 ground truth: the fused kernel keeps fp32 through the middle (no
    # inter-stage bf16 rounding), so compare against the fp32 oracle with a
    # magnitude-scaled bf16 tolerance rather than the twice-rounded bf16 ref.
    want = np.asarray(ref.gs_fused_ref(L.astype(jnp.float32),
                                       R.astype(jnp.float32),
                                       x.astype(jnp.float32)), np.float32)
    if dtype == jnp.float32:
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5)
    else:
        atol = 0.02 * np.abs(want).max()
        np.testing.assert_allclose(got, want, atol=atol, rtol=0.03)


def test_gs_fused_ref_vs_core_gsoft():
    """Oracle must equal core.gs.gs_apply on the GSOFT layout — the kernel
    therefore computes exactly the paper's Q."""
    r, b = 8, 8
    d = r * b
    L = jax.random.normal(KEY, (r, b, b))
    R = jax.random.normal(jax.random.PRNGKey(3), (r, b, b))
    x = jax.random.normal(jax.random.PRNGKey(4), (5, d))
    lay = gs.gsoft_layout(d, b)
    np.testing.assert_allclose(np.asarray(ref.gs_fused_ref(L, R, x)),
                               np.asarray(gs.gs_apply(lay, L, R, x)),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# ssd
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (T, H, P, N, chunk)
    (32, 2, 8, 8, 8),
    (64, 1, 16, 16, 16),
    (128, 4, 8, 16, 32),
    (16, 3, 4, 4, 16),    # single chunk
    (48, 2, 8, 8, 16),
]


def _ssd_inputs(t, h, p, n, dtype=jnp.float32):
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (t, h, p), dtype)
    loga = -jnp.abs(jax.random.normal(ks[1], (t, h), dtype)) * 0.3
    B = jax.random.normal(ks[2], (t, h, n), dtype) * 0.5
    C = jax.random.normal(ks[3], (t, h, n), dtype) * 0.5
    return x, loga, B, C


@pytest.mark.parametrize("t,h,p,n,chunk", SSD_SHAPES)
def test_ssd_chunked_ref_vs_sequential(t, h, p, n, chunk):
    x, loga, B, C = _ssd_inputs(t, h, p, n)
    seq = ref.ssd_ref(x, loga, B, C)
    chk = ref.ssd_chunked_ref(x, loga, B, C, chunk=chunk)
    np.testing.assert_allclose(np.asarray(seq), np.asarray(chk),
                               atol=1e-4, rtol=1e-4)


@pytest.mark.parametrize("t,h,p,n,chunk", SSD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_kernel_vs_ref(t, h, p, n, chunk, dtype):
    x, loga, B, C = _ssd_inputs(t, h, p, n, dtype)
    got = ssd_pallas(x, loga, B, C, chunk=chunk, interpret=True)
    want = ref.ssd_ref(x, loga, B, C)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-4)


def test_ssd_state_continuity_across_chunks():
    """The scratch-carried state must make chunked == unchunked exactly."""
    x, loga, B, C = _ssd_inputs(64, 2, 8, 8)
    y1 = ssd_pallas(x, loga, B, C, chunk=8, interpret=True)
    y2 = ssd_pallas(x, loga, B, C, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


# ---------------------------------------------------------------------------
# ops dispatch
# ---------------------------------------------------------------------------

def test_ops_bdmm_batched_dims():
    blocks = jax.random.normal(KEY, (4, 8, 8))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 3, 32))
    for up in (False, True):
        y = ops.bdmm(blocks, x, use_pallas=up)
        assert y.shape == (2, 3, 32)


def test_ops_gs_transform_paths_agree():
    r, b = 4, 8
    L = jax.random.normal(KEY, (r, b, b))
    R = jax.random.normal(jax.random.PRNGKey(6), (r, b, b))
    x = jax.random.normal(jax.random.PRNGKey(7), (3, 5, r * b))
    y0 = ops.gs_transform(L, R, x, use_pallas=False)
    y1 = ops.gs_transform(L, R, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


@pytest.mark.parametrize("bsz,r,b,t", [(3, 4, 8, 5), (2, 8, 16, 33), (1, 2, 8, 7)])
def test_ops_bdmm_banked_paths_agree(bsz, r, b, t):
    """Per-row blocks (multi-adapter serving): vmapped Pallas path == ref."""
    blocks = jax.random.normal(KEY, (bsz, r, b, b))
    x = jax.random.normal(jax.random.PRNGKey(8), (bsz, t, r * b))
    y0 = ops.bdmm_banked(blocks, x, use_pallas=False)
    y1 = ops.bdmm_banked(blocks, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)


@pytest.mark.parametrize("bsz,r,b,t", [(3, 4, 8, 5), (2, 8, 16, 33)])
def test_ops_gs_banked_transform_T_paths_agree(bsz, r, b, t):
    """Per-row transpose rotation: both paths agree with each other AND
    with the single-row core application per batch row."""
    L = jax.random.normal(KEY, (bsz, r, b, b))
    R = jax.random.normal(jax.random.PRNGKey(9), (bsz, r, b, b))
    x = jax.random.normal(jax.random.PRNGKey(10), (bsz, t, r * b))
    y0 = ops.gs_banked_transform_T(L, R, x, use_pallas=False)
    y1 = ops.gs_banked_transform_T(L, R, x, use_pallas=True)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), atol=1e-5)
    lay = gs.gsoft_layout(r * b, b)
    for i in range(bsz):
        want = gs.gs_apply_T(lay, L[i], R[i], x[i])
        np.testing.assert_allclose(np.asarray(y0[i]), np.asarray(want),
                                   atol=1e-5)


def test_ops_ssd_batched():
    x, loga, B, C = _ssd_inputs(32, 2, 8, 8)
    xb = jnp.stack([x, x * 0.5])
    lb = jnp.stack([loga, loga])
    Bb = jnp.stack([B, B])
    Cb = jnp.stack([C, C])
    for up in (False, True):
        y = ops.ssd(xb, lb, Bb, Cb, chunk=8, use_pallas=up)
        assert y.shape == xb.shape
        np.testing.assert_allclose(np.asarray(y[0]),
                                   np.asarray(ref.ssd_ref(x, loga, B, C)),
                                   atol=1e-4, rtol=1e-3)
