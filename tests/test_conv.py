import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import conv as c
from repro.models.lipconvnet import (LipConvnetConfig, apply_lipconvnet,
                                     count_conv_params, init_lipconvnet,
                                     lipconvnet_loss)

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("groups", [1, 2, 4])
def test_skew_kernel_inner_product(groups):
    """<L*X, Y> = -<X, L*Y>: the induced conv matrix is skew-symmetric."""
    ch = 8
    m = jax.random.normal(KEY, (3, 3, ch // groups, ch)) * 0.3
    k = c.skew_kernel(m, groups)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 6, ch))
    y = jax.random.normal(jax.random.PRNGKey(2), (2, 6, 6, ch))
    lx = c.conv2d(x, k, groups)
    ly = c.conv2d(y, k, groups)
    assert np.allclose(float(jnp.vdot(lx, y)), -float(jnp.vdot(x, ly)), atol=1e-3)


def test_conv_exponential_is_isometry():
    """exp of skew operator is orthogonal: linear map preserving norms."""
    ch = 4
    m = jax.random.normal(KEY, (3, 3, ch, ch)) * 0.05
    k = c.skew_kernel(m, 1)
    f = lambda x: c.conv_exponential(x, k, 1, terms=14)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 5, 5, ch))
    y = jax.random.normal(jax.random.PRNGKey(4), (1, 5, 5, ch))
    # conv_exponential is linear in x, so isometry <=> norm preservation
    nx = float(jnp.linalg.norm(f(x) - f(y)))
    assert np.isclose(nx, float(jnp.linalg.norm(x - y)), rtol=1e-4)


def test_conv_exponential_jacobian_orthogonal():
    ch, s = 2, 4
    m = jax.random.normal(KEY, (3, 3, ch, ch)) * 0.05
    k = c.skew_kernel(m, 1)
    f = lambda v: c.conv_exponential(v.reshape(1, s, s, ch), k, 1, 14).reshape(-1)
    J = jax.jacfwd(f)(jnp.zeros(s * s * ch))
    assert np.allclose(np.asarray(J.T @ J), np.eye(s * s * ch), atol=1e-4)


def test_grouped_conv_exp_independent_groups():
    """With g groups, channels of group 0 never influence group 1."""
    ch, g = 8, 2
    m = jax.random.normal(KEY, (3, 3, ch // g, ch)) * 0.3
    k = c.skew_kernel(m, g)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 5, 5, ch))
    x2 = x.at[..., : ch // g].add(1.0)        # perturb only group 0
    y, y2 = (c.conv_exponential(v, k, g, 6) for v in (x, x2))
    assert np.allclose(np.asarray(y[..., ch // g:]),
                       np.asarray(y2[..., ch // g:]), atol=1e-5)
    assert not np.allclose(np.asarray(y[..., : ch // g]),
                           np.asarray(y2[..., : ch // g]), atol=1e-3)


def test_maxmin_permuted_definition():
    x = jnp.asarray([[3.0, 1.0, -2.0, 5.0]])
    got = np.asarray(c.maxmin_permuted(x))
    assert np.allclose(got, [[3.0, 1.0, 5.0, -2.0]])


def test_maxmin_variants_are_1_lipschitz():
    x = jax.random.normal(KEY, (128, 16))
    y = x + jax.random.normal(jax.random.PRNGKey(6), (128, 16)) * 0.1
    for fn in (c.maxmin, c.maxmin_permuted):
        dx = np.linalg.norm(np.asarray(fn(x) - fn(y)), axis=-1)
        dy = np.linalg.norm(np.asarray(x - y), axis=-1)
        assert np.all(dx <= dy + 1e-5)
        # gradient-norm preserving (a.e.): jvp preserves norms
        v = jax.random.normal(jax.random.PRNGKey(7), x.shape)
        _, jv = jax.jvp(fn, (x,), (v,))
        assert np.allclose(float(jnp.linalg.norm(jv)),
                           float(jnp.linalg.norm(v)), rtol=1e-5)


def test_gs_soc_layer_isometry():
    for groups in [(4, 0), (4, 1), (4, 2), (4, 4)]:
        spec = c.GSSOCSpec(channels=8, groups1=groups[0], groups2=groups[1],
                           terms=12)
        params = init_gs_soc(spec, KEY)
        f = lambda x: c.gs_soc_layer(spec, params, x)
        x = jax.random.normal(jax.random.PRNGKey(8), (1, 6, 6, 8))
        y = jax.random.normal(jax.random.PRNGKey(9), (1, 6, 6, 8))
        assert np.isclose(float(jnp.linalg.norm(f(x) - f(y))),
                          float(jnp.linalg.norm(x - y)), rtol=1e-3)


def init_gs_soc(spec, key):
    from repro.core.conv import init_gs_soc as _init
    return _init(spec, key)


def test_gs_soc_param_savings():
    """Table 3: GS-SOC (4,-) uses ~4x fewer conv params than SOC."""
    soc = c.soc_layer_spec(64).num_params
    gs4 = c.GSSOCSpec(channels=64, groups1=4, groups2=0).num_params
    assert soc == 9 * 64 * 64
    assert gs4 == 9 * 64 * 16
    assert soc / gs4 == 4.0
    # (4,1): adds a 1x1 ungrouped conv exp
    gs41 = c.GSSOCSpec(channels=64, groups1=4, groups2=1).num_params
    assert gs41 == 9 * 64 * 16 + 64 * 64


def test_space_to_depth_orthogonal():
    x = jax.random.normal(KEY, (2, 8, 8, 3))
    y = c.space_to_depth(x, 2)
    assert y.shape == (2, 4, 4, 12)
    assert np.isclose(float(jnp.linalg.norm(y)), float(jnp.linalg.norm(x)))


def test_certified_radius():
    logits = jnp.asarray([[2.0, 0.5, 0.1]])
    r = float(c.certified_radius(logits)[0])
    assert np.isclose(r, 1.5 / np.sqrt(2))


# ---------------------------------------------------------------------------
# LipConvnet end-to-end
# ---------------------------------------------------------------------------

def _tiny_cfg(**kw):
    kw.setdefault("depth", 5)
    kw.setdefault("base_width", 4)
    kw.setdefault("num_classes", 10)
    kw.setdefault("image_size", 32)
    kw.setdefault("groups", (2, 0))
    kw.setdefault("terms", 4)
    return LipConvnetConfig(**kw)


def test_lipconvnet_forward():
    cfg = _tiny_cfg()
    params = init_lipconvnet(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(10), (2, 32, 32, 3))
    logits = apply_lipconvnet(cfg, params, x)
    assert logits.shape == (2, 10)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_lipconvnet_is_lipschitz():
    cfg = _tiny_cfg(terms=10)
    params = init_lipconvnet(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(11), (1, 32, 32, 3))
    d = jax.random.normal(jax.random.PRNGKey(12), x.shape)
    d = d / jnp.linalg.norm(d) * 0.1
    l0 = apply_lipconvnet(cfg, params, x)
    l1 = apply_lipconvnet(cfg, params, x + d)
    assert float(jnp.linalg.norm(l1 - l0)) <= 0.1 * 1.05


def test_lipconvnet_loss_and_grads():
    cfg = _tiny_cfg()
    params = init_lipconvnet(cfg, KEY)
    x = jax.random.normal(jax.random.PRNGKey(13), (4, 32, 32, 3))
    y = jnp.asarray([0, 1, 2, 3])
    (loss, metrics), g = jax.value_and_grad(
        lambda p: lipconvnet_loss(cfg, p, x, y), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.abs(v).sum()) for v in jax.tree.leaves(g))
    assert gn > 0
    assert 0.0 <= float(metrics["accuracy"]) <= 1.0


def test_soc_vs_gs_conv_param_counts_full_net():
    soc_cfg = _tiny_cfg(conv_layer="soc", depth=15)
    gs_cfg = _tiny_cfg(conv_layer="gs", depth=15, groups=(4, 0))
    n_soc = count_conv_params(soc_cfg)
    n_gs = count_conv_params(gs_cfg)
    assert n_soc / n_gs > 3.0   # paper: 24.1M vs 6.81M (~3.5x)
