"""Flash-attention kernel vs oracle across shapes/dtypes (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention

KEY = jax.random.PRNGKey(0)

SHAPES = [
    # (H, Sq, Sk, D, blk)
    (2, 64, 64, 16, 32),
    (1, 128, 128, 32, 64),
    (3, 100, 100, 16, 32),    # ragged (causal padding path)
    (2, 32, 32, 64, 32),      # single block
    (1, 256, 256, 16, 128),
]


def _qkv(h, sq, sk, d, dtype=jnp.float32):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (h, sq, d), dtype),
            jax.random.normal(ks[1], (h, sk, d), dtype),
            jax.random.normal(ks[2], (h, sk, d), dtype))


@pytest.mark.parametrize("h,sq,sk,d,blk", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal_vs_ref(h, sq, sk, d, blk, dtype):
    q, k, v = _qkv(h, sq, sk, d, dtype)
    got = flash_attention(q, k, v, causal=True, blk_q=blk, blk_k=blk,
                          interpret=True)
    want = ref.flash_ref(q, k, v, causal=True)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_flash_noncausal():
    q, k, v = _qkv(2, 64, 128, 16)
    got = flash_attention(q, k, v, causal=False, blk_q=32, blk_k=64,
                          interpret=True)
    want = ref.flash_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_mha_gqa_paths_agree():
    b, s, h, kh, d = 2, 64, 8, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, kh, d))
    v = jax.random.normal(ks[2], (b, s, kh, d))
    y0 = ops.flash_mha(q, k, v, causal=True, use_pallas=False)
    y1 = ops.flash_mha(q, k, v, causal=True, use_pallas=True, blk=32)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_model_attention_core():
    """The kernel's math == models.attention.online_attention (the XLA path
    used by the dry-run) — proving the kernel can substitute on TPU."""
    from repro.models.attention import online_attention, _positions
    b, s, h, d = 1, 64, 2, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, s, h, d))
    k = jax.random.normal(ks[1], (b, s, h, d))
    v = jax.random.normal(ks[2], (b, s, h, d))
    scale = 1.0 / np.sqrt(d)
    y_model = online_attention(q, k, v, _positions(b, s), 0, s, causal=True,
                               chunk=32, scale=scale)
    y_kernel = jax.vmap(lambda qq, kk, vv: flash_attention(
        qq, kk, vv, causal=True, blk_q=32, blk_k=32, interpret=True))(
        jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2), jnp.swapaxes(v, 1, 2))
    y_kernel = jnp.swapaxes(y_kernel, 1, 2)
    np.testing.assert_allclose(np.asarray(y_model), np.asarray(y_kernel),
                               atol=2e-4, rtol=2e-4)
